//! Reproduce the paper's Figures 1-3 data: weight vs activation magnitude
//! distributions per linear layer (Fig 1), per-channel activation
//! magnitudes of one decoder layer (Fig 2), and per-decoder-layer
//! quantization loss with/without smoothing (Fig 3). Prints TSV-ish rows
//! suitable for plotting.
//!
//! ```sh
//! cargo run --release --example outlier_analysis -- --model small
//! ```

use sqplus::config::{ModelConfig, QuantConfig};
use sqplus::data::{corpus, tasks};
use sqplus::model::init::{init_weights, injected_channels, InitSpec};
use sqplus::model::LAYER_LINEARS;
use sqplus::quant::loss::site_of;
use sqplus::quant::{calib, pipeline};
use sqplus::config::QuantMethod;
use sqplus::tokenizer::Tokenizer;
use sqplus::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let size = args.opt("model", "small", "model size");
    let fig2_layer = args.opt_usize("layer", 0, "decoder layer for fig 2");
    let cfg = ModelConfig::by_name(&size).expect("model size");
    let spec = InitSpec::with_outliers(0, 8, 12.0);
    let w = init_weights(&cfg, &spec);
    let tok = Tokenizer::train(&corpus::tokenizer_training_text(0, 4000),
                               cfg.vocab);
    let all = tasks::task_set(corpus::Domain::CodePython, 0);
    let prompts = tasks::tokenized_prompts(&all[..32], &tok, cfg.vocab, 24);
    let cal = calib::collect(&cfg, &w, &prompts, 128, 0);

    // ---- Fig 1: per-linear weight + activation magnitude summary
    println!("# fig1: linear_idx\tname\tw_mean\tw_max\tact_mean\tact_max");
    let mut idx = 0;
    for layer in 0..cfg.layers {
        for lin in LAYER_LINEARS {
            let name = format!("layers.{layer}.{lin}");
            let wt = w.f32(&name);
            let wabs: Vec<f32> =
                wt.data.iter().map(|x| x.abs()).collect();
            let w_mean =
                wabs.iter().sum::<f32>() / wabs.len() as f32;
            let w_max = wabs.iter().cloned().fold(0.0f32, f32::max);
            let st = cal.stats(layer, site_of(lin));
            let a_mean = st.absmean.iter().sum::<f32>()
                / st.absmean.len() as f32;
            let a_max =
                st.absmax.iter().cloned().fold(0.0f32, f32::max);
            println!("{idx}\t{name}\t{w_mean:.4}\t{w_max:.4}\t\
                      {a_mean:.4}\t{a_max:.2}");
            idx += 1;
        }
    }

    // ---- Fig 2: per-channel activation absmax of one decoder layer
    println!("\n# fig2: layer {fig2_layer} per-channel activation absmax \
              (injected outlier channels: {:?})",
             injected_channels(&cfg, &spec));
    for lin in LAYER_LINEARS {
        let st = cal.stats(fig2_layer, site_of(lin));
        let mut top: Vec<(usize, f32)> =
            st.absmax.iter().cloned().enumerate().collect();
        top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut sorted = st.absmax.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = sorted[sorted.len() / 2];
        println!(
            "{lin:>7}: median={med:.3} top8={:?}",
            top.iter().take(8)
                .map(|(c, v)| format!("ch{c}:{v:.1}"))
                .collect::<Vec<_>>()
        );
    }

    // ---- Fig 3: per-decoder-layer quant loss, RTN vs smoothed (SQ+)
    let qcfg = QuantConfig::default();
    let rtn = pipeline::quantize_model(&cfg, &w, &cal, QuantMethod::Rtn,
                                       &qcfg);
    let sqp = pipeline::quantize_model(&cfg, &w, &cal,
                                       QuantMethod::SmoothQuantPlus,
                                       &qcfg);
    println!("\n# fig3: layer\trtn_loss\tsmoothquant+_loss (alpha={:?})",
             sqp.alpha);
    for layer in 0..cfg.layers {
        println!("{layer}\t{:.5}\t{:.5}",
                 rtn.loss.per_layer[layer], sqp.loss.per_layer[layer]);
    }
    println!("\ntotal\t{:.5}\t{:.5}", rtn.loss.total, sqp.loss.total);
    Ok(())
}
