//! Compare quantization methods (FP16 / RTN / AWQ / SmoothQuant+) on one
//! model: quantization loss, accuracy proxies, search cost — the
//! interactive companion to `cargo bench --bench table1_accuracy`.
//!
//! ```sh
//! cargo run --release --example quantize_compare -- --model small
//! ```

use sqplus::config::{ModelConfig, QuantConfig, QuantMethod};
use sqplus::data::{corpus, tasks};
use sqplus::eval::evaluate;
use sqplus::model::init::{init_weights, InitSpec};
use sqplus::quant::{calib, pipeline};
use sqplus::tokenizer::Tokenizer;
use sqplus::util::bench::Table;
use sqplus::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let size = args.opt("model", "small", "model size");
    let n_eval = args.opt_usize("tasks", 24, "eval prompts");
    let outliers = args.opt_usize("outliers", 8, "outlier channels");
    let oscale =
        args.opt_f64("outlier-scale", 12.0, "outlier gain scale") as f32;
    let cfg = ModelConfig::by_name(&size).expect("model size");
    let w = init_weights(&cfg,
                         &InitSpec::with_outliers(0, outliers, oscale));
    let tok = Tokenizer::train(&corpus::tokenizer_training_text(0, 4000),
                               cfg.vocab);
    let all = tasks::task_set(corpus::Domain::CodePython, 0);
    let cal_prompts =
        tasks::tokenized_prompts(&all[..32], &tok, cfg.vocab, 24);
    let cal = calib::collect(&cfg, &w, &cal_prompts, 256, 0);
    let ev = tasks::tokenized_prompts(&all[32..32 + n_eval], &tok,
                                      cfg.vocab, 24);

    let mut t = Table::new(
        &format!("quantization methods on {size} (outliers={outliers} \
                  x{oscale})"),
        &["method", "exact-match", "agreement", "nll", "quant loss",
          "quantize s"],
    );
    for method in QuantMethod::all() {
        let out = pipeline::quantize_model(&cfg, &w, &cal, method,
                                           &QuantConfig::default());
        let r = evaluate(&cfg, &w, &out.effective, &ev, 8);
        t.row(&[
            method.as_str().to_string(),
            format!("{:.1}%", r.exact_match * 100.0),
            format!("{:.1}%", r.token_agreement * 100.0),
            format!("{:.3}", r.nll),
            format!("{:.5}", out.loss.total),
            format!("{:.2}", out.quantize_s),
        ]);
        if let Some(s) = &out.search {
            eprintln!(
                "  [{:>13}] alpha={:.2} grid={} evals in {:.2}s",
                method.as_str(), out.alpha.unwrap(), s.evals, s.elapsed_s
            );
        }
    }
    t.print();
    Ok(())
}
