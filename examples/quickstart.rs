//! Quickstart: build a model, smooth + quantize it with SmoothQuant+,
//! load it into the PJRT runtime and generate text through the serving
//! engine — the 60-second tour of the whole stack.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use sqplus::config::{
    EngineConfig, GpuProfile, ModelConfig, Precision, QuantConfig,
    QuantMethod,
};
use sqplus::coordinator::engine::Engine;
use sqplus::coordinator::sequence::SamplingParams;
use sqplus::data::{corpus, tasks};
use sqplus::model::init::{init_weights, InitSpec};
use sqplus::quant::{calib, pipeline};
use sqplus::runtime::executor::ModelRuntime;
use sqplus::runtime::manifest;
use sqplus::runtime::simtp::Deployment;
use sqplus::tokenizer::Tokenizer;

fn main() -> anyhow::Result<()> {
    // 1. a Llama-family model with the paper's activation-outlier pattern
    let cfg = ModelConfig::tiny();
    let weights =
        init_weights(&cfg, &InitSpec::with_outliers(0, 8, 12.0));
    println!("model: {} ({} params)", cfg.name, cfg.param_count());

    // 2. calibrate on the HumanEval-like task set (paper §3.4.1)
    let tok = Tokenizer::train(&corpus::tokenizer_training_text(0, 4000),
                               cfg.vocab);
    let task_set = tasks::task_set(corpus::Domain::CodePython, 0);
    let prompts =
        tasks::tokenized_prompts(&task_set[..32], &tok, cfg.vocab, 24);
    let cal = calib::collect(&cfg, &weights, &prompts, 128, 0);

    // 3. SmoothQuant+: global alpha search + smoothing + 4-bit group-wise
    let out = pipeline::quantize_model(&cfg, &weights, &cal,
                                       QuantMethod::SmoothQuantPlus,
                                       &QuantConfig::default());
    println!(
        "smoothquant+: alpha={:.2}, quant loss={:.5} ({} grid points in \
         {:.2}s)",
        out.alpha.unwrap(),
        out.loss.total,
        out.search.as_ref().unwrap().evals,
        out.search.as_ref().unwrap().elapsed_s
    );

    // 4. load the packed INT4 model into the PJRT runtime (W4A16 HLO
    //    lowered from the Pallas kernel) and serve through the engine
    let man = manifest::require_artifacts()?;
    let rt = ModelRuntime::load(&man, &cfg.name, Precision::W4a16,
                                out.deploy.as_ref().unwrap())?;
    let mut engine = Engine::new(
        Deployment::single(rt, GpuProfile::sim_small(256)),
        EngineConfig::default(),
    );

    let prompt = "// Write a python function to sum a list\n";
    let ids = tok.encode_for_model(prompt, cfg.vocab);
    let id = engine.submit(
        ids,
        SamplingParams { max_new_tokens: 24, ..Default::default() },
    );
    engine.run_to_completion(10_000)?;
    let fin = engine.take_finished();
    let seq = fin.iter().find(|s| s.id == id).unwrap();
    println!("prompt:     {prompt:?}");
    println!("generated:  {:?}", tok.decode(&seq.output));
    engine.metrics.report().print("quickstart");
    Ok(())
}
