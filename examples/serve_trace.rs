//! END-TO-END DRIVER (DESIGN.md §4): serve the ~100M-parameter `base`
//! model quantized with SmoothQuant+ under a Poisson request trace, with
//! the full stack engaged — tokenizer → router/scheduler → paged-KV block
//! manager → PJRT (Pallas-lowered W4A16 HLO) → sampler → detokenizer —
//! and report throughput, TTFT and per-token latency. Results recorded in
//! EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts
//! cargo run --release --example serve_trace -- [--model base] \
//!     [--requests 48] [--rate 4.0] [--method smoothquant+]
//! ```

use std::time::Instant;

use sqplus::config::{
    EngineConfig, GpuProfile, ModelConfig, Precision, QuantConfig,
    QuantMethod,
};
use sqplus::coordinator::engine::Engine;
use sqplus::coordinator::sequence::SamplingParams;
use sqplus::data::{corpus, tasks, trace};
use sqplus::model::init::{init_weights, InitSpec};
use sqplus::quant::{calib, pipeline};
use sqplus::runtime::executor::ModelRuntime;
use sqplus::runtime::manifest;
use sqplus::runtime::simtp::Deployment;
use sqplus::tokenizer::Tokenizer;
use sqplus::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let size = args.opt("model", "base", "model size");
    let n_req = args.opt_usize("requests", 48, "number of requests");
    let rate = args.opt_f64("rate", 4.0, "Poisson arrival rate (req/s)");
    let method = match args.opt("method", "smoothquant+", "method").as_str()
    {
        "fp16" => QuantMethod::Fp16,
        "rtn" => QuantMethod::Rtn,
        m => {
            assert!(m.contains("smooth"), "method {m}?");
            QuantMethod::SmoothQuantPlus
        }
    };
    let cfg = ModelConfig::by_name(&size).expect("model size");
    println!(
        "== serve_trace: {} ({:.0}M params), method {}, {} requests at \
         {} req/s ==",
        cfg.name,
        cfg.param_count() as f64 / 1e6,
        method.as_str(),
        n_req,
        rate
    );

    // model + quantization
    let t0 = Instant::now();
    let w = init_weights(&cfg, &InitSpec::with_outliers(0, 8, 12.0));
    let tok = Tokenizer::train(&corpus::tokenizer_training_text(0, 6000),
                               cfg.vocab);
    let task_set = tasks::task_set(corpus::Domain::CodePython, 0);
    let cal_prompts =
        tasks::tokenized_prompts(&task_set[..24], &tok, cfg.vocab, 24);
    let cal = calib::collect(&cfg, &w, &cal_prompts, 192, 0);
    let out = pipeline::quantize_model(&cfg, &w, &cal, method,
                                       &QuantConfig::default());
    println!(
        "[quantize] method={} alpha={:?} loss={:.5} in {:.1}s",
        method.as_str(), out.alpha, out.loss.total,
        t0.elapsed().as_secs_f64()
    );

    // runtime + engine
    let man = manifest::require_artifacts()?;
    let (precision, deploy) = match &out.deploy {
        Some(d) => (Precision::W4a16, d.clone()),
        None => (Precision::Fp16, pipeline::fp16_deploy(&cfg, &w)),
    };
    let t1 = Instant::now();
    let rt = ModelRuntime::load(&man, &size, precision, &deploy)?;
    rt.warmup()?;
    println!(
        "[runtime] weights uploaded + {} executables compiled in {:.1}s",
        rt.stats.borrow().compiles,
        t1.elapsed().as_secs_f64()
    );
    let mut engine = Engine::with_memory_budget(
        Deployment::single(rt, GpuProfile::sim_small(2048)),
        EngineConfig::default(),
    );

    // Poisson trace replay: submit when each arrival time passes,
    // stepping the engine in between (open-loop load generation).
    let reqs = trace::poisson(7, n_req, rate, 24, 16);
    let mut rng = sqplus::util::rng::Rng::new(99);
    let prompts: Vec<Vec<u32>> = reqs
        .iter()
        .map(|r| trace::prompt_tokens(&mut rng, r.prompt_tokens,
                                      cfg.vocab))
        .collect();
    let start = Instant::now();
    let mut next = 0usize;
    while next < reqs.len() || engine.has_work() {
        let now = start.elapsed().as_secs_f64();
        while next < reqs.len() && reqs[next].at_s <= now {
            engine.submit(
                prompts[next].clone(),
                SamplingParams {
                    max_new_tokens: reqs[next].output_tokens,
                    ..Default::default()
                },
            );
            next += 1;
        }
        if engine.has_work() {
            engine.step()?;
        } else if next < reqs.len() {
            let wait = reqs[next].at_s - start.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    wait.min(0.05),
                ));
            }
        }
    }
    let fin = engine.take_finished();
    println!("[done] {} finished, wall {:.1}s", fin.len(),
             start.elapsed().as_secs_f64());
    let report = engine.metrics.report();
    report.print("serve_trace");
    let st = engine.dep.runtime.stats.borrow();
    println!(
        "[runtime] prefills={} decodes={} exec={:.1}s h2d={:.1}MB \
         d2h={:.1}MB",
        st.prefills, st.decodes, st.exec_s,
        st.h2d_bytes as f64 / 1e6, st.d2h_bytes as f64 / 1e6
    );
    println!(
        "[sample] first output: {:?}",
        fin.first().map(|s| tok.decode(&s.output))
    );
    Ok(())
}
