//! Visualize the paper's global alpha grid search (§3.4.2): the loss
//! curve over alpha for two grid steps (0.05 vs 0.01), and the cost
//! comparison against AWQ's per-layer search.
//!
//! ```sh
//! cargo run --release --example alpha_search -- --model tiny
//! ```

use sqplus::config::{ModelConfig, QuantConfig};
use sqplus::data::{corpus, tasks};
use sqplus::model::init::{init_weights, InitSpec};
use sqplus::quant::{awq, calib, search};
use sqplus::tokenizer::Tokenizer;
use sqplus::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let size = args.opt("model", "tiny", "model size");
    let cfg = ModelConfig::by_name(&size).expect("model size");
    let w = init_weights(&cfg, &InitSpec::with_outliers(0, 8, 12.0));
    let tok = Tokenizer::train(&corpus::tokenizer_training_text(0, 4000),
                               cfg.vocab);
    let all = tasks::task_set(corpus::Domain::CodePython, 0);
    let prompts = tasks::tokenized_prompts(&all[..32], &tok, cfg.vocab, 24);
    let cal = calib::collect(&cfg, &w, &prompts, 192, 0);

    for step in [0.05, 0.01] {
        let qcfg = QuantConfig { alpha_step: step, ..Default::default() };
        let r = search::search_alpha(&cfg, &w, &cal, &qcfg);
        println!("\n# step {step}: best alpha={:.2} loss={:.6} \
                  ({} evals, {:.2}s)",
                 r.alpha, r.loss, r.evals, r.elapsed_s);
        if step == 0.05 {
            println!("alpha\tloss");
            for (a, l) in &r.grid {
                let bar = "#".repeat(
                    (60.0 * l / r.grid.iter().map(|g| g.1)
                        .fold(0.0, f64::max)) as usize);
                println!("{a:.2}\t{l:.6}\t{bar}");
            }
        }
    }

    // AWQ comparison: per-layer local search with clip grid
    let mut sm = w.clone();
    let res = awq::awq_search_and_smooth(&mut sm, &cfg, &cal,
                                         &QuantConfig::default());
    println!(
        "\n# AWQ per-layer search: {} evals in {:.2}s \
         (vs SmoothQuant+ global grid of 21)",
        res.evals, res.elapsed_s
    );
    Ok(())
}
