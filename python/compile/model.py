"""Layer-2: Llama-family forward pass in JAX, FP16 and W4A16 variants.

Three entry points per model config, all AOT-lowered by aot.py:

  * ``prefill(tokens[B,S], lens[B], *weights) -> (logits[B,S,V],
    kv_new[L,2,B,S,D])``
  * ``decode(tokens[B], lens[B], kv[L,2,B,MAX,D], *weights) ->
    (logits[B,V], kv_new[L,2,B,1,D])``
  * ``chunk(tokens[B,C], starts[B], kv[L,2,B,P,D], *weights) ->
    (logits[B,C,V], kv_new[L,2,B,C,D])`` — chunked prefill: C new
    tokens per sequence appended at absolute positions ``starts[b] ..
    starts[b]+C``, attending to the ``starts[b]`` cached prefix rows in
    ``kv`` plus causally within the chunk. One device call computes a
    whole continuation chunk (cache-hit suffixes, later chunks of a
    long prompt, post-preemption recompute) that the serving engine
    previously drove through ``decode`` token by token.

The *full* KV cache ``f32[L, 2, B, MAX, D]`` is an input of decode; the
outputs carry only the *newly produced* K/V rows. Rationale: the PJRT shim
returns results as one tuple buffer (no untuple/donation), so outputs
round-trip through the host every step — returning just the new rows keeps
that transfer O(B*D) while the Rust coordinator owns the authoritative
host-side cache (which also makes continuous batching a plain memcpy).
``lens[b]`` is the number of tokens already in the cache for sequence b;
decode writes its K/V row at position ``lens[b]`` (done host-side by the
coordinator) and attends over cache positions ``0..lens[b]-1`` plus the
current token.

The W4A16 variant routes every decoder linear through the Pallas kernel
(kernels/w4a16.py); norms, embedding and lm_head stay in floating point,
matching the paper's Figure 6 precision map. "FP16" computes in f32 on the
CPU PJRT backend (DESIGN.md §5).
"""

import functools

import jax
import jax.numpy as jnp

from . import configs
from .kernels import w4a16 as w4a16_kernel


# ---------------------------------------------------------------- helpers

def rmsnorm(x, gain, eps):
    """RMSNorm over the last axis: ``x * gain / rms(x)``."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * gain


def rope_tables(positions, head_dim, theta):
    """cos/sin tables ``f32[..., head_dim // 2]`` for given positions."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) * 2.0 / head_dim)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """Half-split rotary embedding; ``x: [..., head_dim]``."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def linear(x2d, weights, name, cfg, precision):
    """Dispatch one linear: plain matmul (fp16) or the Pallas W4A16 kernel."""
    if precision == "fp16":
        return x2d @ weights[name]
    return w4a16_kernel.w4a16_matmul(
        x2d,
        weights[name + ".packed"],
        weights[name + ".scales"],
        weights[name + ".zeros"],
        group_size=cfg.group_size,
    )


def _weights_dict(cfg, precision, flat):
    names = configs.weight_names(cfg, precision)
    assert len(flat) == len(names), (len(flat), len(names))
    return dict(zip(names, flat))


# ---------------------------------------------------------------- blocks

def attention_prefill(h, kv_lanes, lens, wd, lp, cfg, precision):
    """Causal self-attention over a padded [B, S, D] prefill block."""
    b, s, d = h.shape
    hd, nh = cfg.head_dim, cfg.heads
    x2 = h.reshape(b * s, d)
    q = linear(x2, wd, lp + "wq", cfg, precision).reshape(b, s, nh, hd)
    k = linear(x2, wd, lp + "wk", cfg, precision).reshape(b, s, nh, hd)
    v = linear(x2, wd, lp + "wv", cfg, precision).reshape(b, s, nh, hd)

    pos = jnp.arange(s, dtype=jnp.int32)
    cos, sin = rope_tables(pos, hd, cfg.rope_theta)  # [S, hd/2]
    cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
    causal = pos[None, :] <= pos[:, None]  # [q, k]
    valid = pos[None, :] < lens[:, None]  # [B, k] padding mask
    mask = causal[None, None, :, :] & valid[:, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b * s, d)
    out = linear(out, wd, lp + "wo", cfg, precision).reshape(b, s, d)

    # Emit this layer's K/V rows for the coordinator's host-side cache.
    kv_lanes.append(jnp.stack([k.reshape(b, s, d), v.reshape(b, s, d)],
                              axis=0))  # [2, B, S, D]
    return out


def attention_decode(h, kv_l, lens, wd, lp, cfg, precision):
    """Single-token attention against the cache.

    ``kv_l: [2, B, MAX, D]`` holds rows ``0..lens[b]-1``; the current
    token's K/V is used directly and returned as ``[2, B, 1, D]`` for the
    coordinator to append host-side.
    """
    b, d = h.shape
    hd, nh = cfg.head_dim, cfg.heads
    q = linear(h, wd, lp + "wq", cfg, precision).reshape(b, nh, hd)
    k = linear(h, wd, lp + "wk", cfg, precision).reshape(b, nh, hd)
    v = linear(h, wd, lp + "wv", cfg, precision).reshape(b, nh, hd)

    cos, sin = rope_tables(lens, hd, cfg.rope_theta)  # [B, hd/2]
    cos, sin = cos[:, None, :], sin[:, None, :]
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    kc = kv_l[0].reshape(b, cfg.max_len, nh, hd)
    vc = kv_l[1].reshape(b, cfg.max_len, nh, hd)
    scores = jnp.einsum("bhd,bthd->bht", q, kc) / jnp.sqrt(float(hd))
    t = jnp.arange(cfg.max_len, dtype=jnp.int32)
    mask = t[None, :] < lens[:, None]  # cache rows 0..lens-1
    scores = jnp.where(mask[:, None, :], scores, -1e30)
    self_score = (jnp.einsum("bhd,bhd->bh", q, k)
                  / jnp.sqrt(float(hd)))[:, :, None]
    all_scores = jnp.concatenate([scores, self_score], axis=-1)
    probs = jax.nn.softmax(all_scores, axis=-1)
    out = jnp.einsum("bht,bthd->bhd", probs[:, :, :-1], vc)
    out = out + probs[:, :, -1:] * v
    out = linear(out.reshape(b, d), wd, lp + "wo", cfg, precision)
    kv_new = jnp.stack([k.reshape(b, 1, d), v.reshape(b, 1, d)], axis=0)
    return out, kv_new


def attention_chunk(h, kv_l, starts, wd, lp, cfg, precision):
    """Causal attention for a mid-sequence chunk against a KV prefix.

    ``h: [B, C, D]`` are the chunk's hidden states; ``kv_l: [2, B, P, D]``
    holds cached rows ``0..starts[b]-1`` (``P >= starts[b]``). Query row
    ``i`` of sequence ``b`` sits at absolute position ``starts[b] + i``
    and attends to every prefix row plus chunk rows ``<= i`` — the same
    math ``decode`` applies one position at a time. Returns the block
    output and this layer's new K/V rows ``[2, B, C, D]`` for the
    coordinator to append host-side.
    """
    b, c, d = h.shape
    hd, nh = cfg.head_dim, cfg.heads
    x2 = h.reshape(b * c, d)
    q = linear(x2, wd, lp + "wq", cfg, precision).reshape(b, c, nh, hd)
    k = linear(x2, wd, lp + "wk", cfg, precision).reshape(b, c, nh, hd)
    v = linear(x2, wd, lp + "wv", cfg, precision).reshape(b, c, nh, hd)

    pos = starts[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    cos, sin = rope_tables(pos, hd, cfg.rope_theta)  # [B, C, hd/2]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    p = kv_l.shape[2]
    kc = kv_l[0].reshape(b, p, nh, hd)
    vc = kv_l[1].reshape(b, p, nh, hd)
    cache = jnp.einsum("bqhd,bthd->bhqt", q, kc) / jnp.sqrt(float(hd))
    t = jnp.arange(p, dtype=jnp.int32)
    valid = t[None, None, None, :] < starts[:, None, None, None]
    cache = jnp.where(valid, cache, -1e30)
    intra = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
    ci = jnp.arange(c, dtype=jnp.int32)
    causal = ci[None, :] <= ci[:, None]  # [q, k]
    intra = jnp.where(causal[None, None, :, :], intra, -1e30)
    probs = jax.nn.softmax(jnp.concatenate([cache, intra], axis=-1), -1)
    out = jnp.einsum("bhqt,bthd->bqhd", probs[..., :p], vc) \
        + jnp.einsum("bhqk,bkhd->bqhd", probs[..., p:], v)
    out = linear(out.reshape(b * c, d), wd, lp + "wo", cfg, precision)
    kv_new = jnp.stack([k.reshape(b, c, d), v.reshape(b, c, d)], axis=0)
    return out.reshape(b, c, d), kv_new


def mlp(x, wd, lp, cfg, precision):
    """SwiGLU MLP on ``x: [T, D]``."""
    gate = linear(x, wd, lp + "w_gate", cfg, precision)
    up = linear(x, wd, lp + "w_up", cfg, precision)
    return linear(jax.nn.silu(gate) * up, wd, lp + "w_down", cfg, precision)


# ------------------------------------------------------------ entry points

def prefill(cfg, precision, tokens, lens, *flat_weights):
    """Padded batch prefill. Returns (logits[B,S,V], kv_new[L,2,B,S,D])."""
    wd = _weights_dict(cfg, precision, flat_weights)
    b, s = tokens.shape
    h = wd["embed"][tokens]  # [B, S, D]
    kv_lanes = []
    for i in range(cfg.layers):
        lp = f"layers.{i}."
        a = attention_prefill(
            rmsnorm(h, wd[lp + "attn_norm"], cfg.norm_eps),
            kv_lanes, lens, wd, lp, cfg, precision)
        h = h + a
        m = mlp(
            rmsnorm(h, wd[lp + "mlp_norm"], cfg.norm_eps).reshape(b * s, -1),
            wd, lp, cfg, precision).reshape(b, s, -1)
        h = h + m
    h = rmsnorm(h, wd["final_norm"], cfg.norm_eps)
    logits = h.reshape(b * s, -1) @ wd["lm_head"]
    return logits.reshape(b, s, cfg.vocab), jnp.stack(kv_lanes, axis=0)


def decode(cfg, precision, tokens, lens, kv, *flat_weights):
    """One decode step. Returns (logits[B,V], kv_new[L,2,B,1,D])."""
    wd = _weights_dict(cfg, precision, flat_weights)
    h = wd["embed"][tokens]  # [B, D]
    new_lanes = []
    for i in range(cfg.layers):
        lp = f"layers.{i}."
        a, kv_l = attention_decode(
            rmsnorm(h, wd[lp + "attn_norm"], cfg.norm_eps),
            kv[i], lens, wd, lp, cfg, precision)
        new_lanes.append(kv_l)
        h = h + a
        h = h + mlp(rmsnorm(h, wd[lp + "mlp_norm"], cfg.norm_eps),
                    wd, lp, cfg, precision)
    h = rmsnorm(h, wd["final_norm"], cfg.norm_eps)
    return h @ wd["lm_head"], jnp.stack(new_lanes, axis=0)


def chunk(cfg, precision, tokens, starts, kv, *flat_weights):
    """One chunked-prefill call: ``tokens[B, C]`` appended at positions
    ``starts[b]..starts[b]+C`` against the prefix cache ``kv[L,2,B,P,D]``.
    Returns (logits[B,C,V], kv_new[L,2,B,C,D])."""
    wd = _weights_dict(cfg, precision, flat_weights)
    b, c = tokens.shape
    h = wd["embed"][tokens]  # [B, C, D]
    new_lanes = []
    for i in range(cfg.layers):
        lp = f"layers.{i}."
        a, kv_l = attention_chunk(
            rmsnorm(h, wd[lp + "attn_norm"], cfg.norm_eps),
            kv[i], starts, wd, lp, cfg, precision)
        new_lanes.append(kv_l)
        h = h + a
        m = mlp(
            rmsnorm(h, wd[lp + "mlp_norm"], cfg.norm_eps).reshape(b * c, -1),
            wd, lp, cfg, precision).reshape(b, c, -1)
        h = h + m
    h = rmsnorm(h, wd["final_norm"], cfg.norm_eps)
    logits = h.reshape(b * c, -1) @ wd["lm_head"]
    return logits.reshape(b, c, cfg.vocab), jnp.stack(new_lanes, axis=0)


def make_prefill(cfg, precision):
    return functools.partial(prefill, cfg, precision)


def make_decode(cfg, precision):
    return functools.partial(decode, cfg, precision)


def make_chunk(cfg, precision):
    return functools.partial(chunk, cfg, precision)


# ------------------------------------------------------------ test helpers

def random_weights(cfg, precision, seed=0, outlier_channels=0,
                   outlier_scale=30.0):
    """Seeded random weights in canonical flat order (numpy RNG).

    ``outlier_channels > 0`` scales that many RMSNorm gain channels by
    ``outlier_scale`` to induce the paper's fixed-channel activation
    outliers (DESIGN.md §5). For w4a16, fp16 weights are quantized with
    kernels/ref.py so tests share numerics with the AOT path.
    """
    import numpy as np
    from .kernels import ref as kref

    rng = np.random.default_rng(seed)
    fp16 = {}
    for name, (shape, _) in configs.weight_specs(cfg, "fp16").items():
        base = name.split(".")[-1]
        if base in ("attn_norm", "mlp_norm", "final_norm"):
            w = np.ones(shape, np.float32)
            if outlier_channels and base != "final_norm":
                idx = rng.choice(shape[0], outlier_channels, replace=False)
                w[idx] *= outlier_scale
        else:
            w = (rng.standard_normal(shape) / np.sqrt(shape[0])).astype(
                np.float32)
        fp16[name] = jnp.asarray(w)
    if precision == "fp16":
        return [fp16[n] for n in configs.weight_names(cfg, "fp16")]
    flat = []
    for name in configs.weight_names(cfg, "w4a16"):
        if name.endswith(".packed"):
            w = fp16[name[: -len(".packed")]]
            p, s, z = kref.quantize_pack(w, cfg.group_size)
            flat += [p, s, z]
        elif name.endswith((".scales", ".zeros")):
            continue  # appended with .packed
        else:
            flat.append(fp16[name])
    return flat
