"""AOT compile path: lower every (size, precision, phase, bucket) to HLO text.

Run once by ``make artifacts``; Python never runs at serving time. Emits:

  artifacts/<name>.hlo.txt   XLA HLO *text* (NOT a serialized proto: jax
                             >= 0.5 emits 64-bit instruction ids that
                             xla_extension 0.5.1 rejects; the text parser
                             reassigns ids and round-trips cleanly)
  artifacts/manifest.json    the Rust loader contract: per-artifact input/
                             output names, shapes, dtypes, in positional
                             order, plus the model config table.

Usage: ``python -m compile.aot --out-dir ../artifacts [--sizes tiny,small]
[--precisions fp16,w4a16]``.
"""

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model

DTYPES = {"f32": jnp.float32, "i32": jnp.int32, "u8": jnp.uint8}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, DTYPES[dtype])


def weight_specs_flat(cfg, precision):
    out = []
    for name, (shape, dtype) in configs.weight_specs(cfg, precision).items():
        out.append((name, shape, dtype))
    return out


def input_descs(cfg, precision, phase, batch, seq, prefix=0):
    """Positional input descriptors for one artifact."""
    descs = []
    if phase == "prefill":
        descs.append(("tokens", (batch, seq), "i32"))
        descs.append(("lens", (batch,), "i32"))
    elif phase == "chunk":
        descs.append(("tokens", (batch, seq), "i32"))
        descs.append(("starts", (batch,), "i32"))
        descs.append(("kv", configs.kv_prefix_shape(cfg, batch, prefix),
                      "f32"))
    else:
        descs.append(("tokens", (batch,), "i32"))
        descs.append(("lens", (batch,), "i32"))
        descs.append(("kv", configs.kv_cache_shape(cfg, batch), "f32"))
    descs += weight_specs_flat(cfg, precision)
    return descs


def output_descs(cfg, phase, batch, seq):
    if phase in ("prefill", "chunk"):
        return [
            ("logits", (batch, seq, cfg.vocab), "f32"),
            ("kv_new", (cfg.layers, 2, batch, seq, cfg.dim), "f32"),
        ]
    return [
        ("logits", (batch, cfg.vocab), "f32"),
        ("kv_new", (cfg.layers, 2, batch, 1, cfg.dim), "f32"),
    ]


def lower_one(cfg, precision, phase, batch, seq, prefix=0):
    descs = input_descs(cfg, precision, phase, batch, seq, prefix)
    args = [spec(s, d) for (_, s, d) in descs]
    if phase == "prefill":
        fn = model.make_prefill(cfg, precision)
    elif phase == "chunk":
        fn = model.make_chunk(cfg, precision)
    else:
        fn = model.make_decode(cfg, precision)
    return jax.jit(fn).lower(*args)


def artifact_name(size, precision, phase, batch, seq, prefix=0):
    if phase == "prefill":
        return f"{size}_{precision}_prefill_b{batch}_s{seq}"
    if phase == "chunk":
        return f"{size}_{precision}_chunk_b{batch}_s{seq}_p{prefix}"
    return f"{size}_{precision}_decode_b{batch}"


def build(out_dir, sizes, precisions, force=False):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"version": 1, "models": {}}
    for size in sizes:
        cfg = configs.SIZES[size]
        arts = []
        jobs = [("prefill", b, s, 0) for (b, s) in configs.PREFILL_BUCKETS]
        jobs += [("decode", b, 0, 0) for b in configs.DECODE_BATCHES]
        jobs += [("chunk", b, s, p) for (b, s) in configs.CHUNK_BUCKETS
                 for p in configs.chunk_prefix_buckets(cfg)]
        for precision in precisions:
            for phase, batch, seq, prefix in jobs:
                name = artifact_name(size, precision, phase, batch, seq,
                                     prefix)
                path = os.path.join(out_dir, name + ".hlo.txt")
                t0 = time.time()
                if force or not os.path.exists(path):
                    lowered = lower_one(cfg, precision, phase, batch, seq,
                                        prefix)
                    text = to_hlo_text(lowered)
                    with open(path, "w") as f:
                        f.write(text)
                    print(f"  {name}: {len(text) / 1e6:.1f} MB "
                          f"({time.time() - t0:.1f}s)")
                else:
                    print(f"  {name}: cached")
                arts.append({
                    "name": name,
                    "file": name + ".hlo.txt",
                    "precision": precision,
                    "phase": phase,
                    "batch": batch,
                    "seq": seq,
                    "prefix": prefix,
                    "inputs": [
                        {"name": n, "shape": list(s), "dtype": d}
                        for (n, s, d) in
                        input_descs(cfg, precision, phase, batch, seq,
                                    prefix)
                    ],
                    "outputs": [
                        {"name": n, "shape": list(s), "dtype": d}
                        for (n, s, d) in output_descs(cfg, phase, batch, seq)
                    ],
                })
        manifest["models"][size] = {
            "config": {
                "name": cfg.name, "vocab": cfg.vocab, "dim": cfg.dim,
                "layers": cfg.layers, "heads": cfg.heads, "ffn": cfg.ffn,
                "max_len": cfg.max_len, "group_size": cfg.group_size,
                "rope_theta": cfg.rope_theta, "norm_eps": cfg.norm_eps,
            },
            "artifacts": arts,
        }
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    digest = hashlib.sha256(open(mpath, "rb").read()).hexdigest()[:12]
    print(f"manifest: {mpath} ({digest})")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default="tiny,small,base")
    ap.add_argument("--precisions", default="fp16,w4a16")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    build(args.out_dir, args.sizes.split(","), args.precisions.split(","),
          force=args.force)


if __name__ == "__main__":
    main()
