"""Model-size table and the canonical flat weight ordering.

This file is the *contract* between the Python compile path and the Rust
runtime: `rust/src/model/config.rs` mirrors SIZES, and
`artifacts/manifest.json` (written by aot.py) records the exact parameter
order produced by :func:`weight_names` so the Rust loader can feed buffers
positionally.

The sizes stand in for the paper's Code Llama 7B/13B/34B (see DESIGN.md §5):
the quantization mechanics are distributional, so laptop-scale models with
injected outlier channels reproduce the same causal chain.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    dim: int
    layers: int
    heads: int
    ffn: int  # SwiGLU hidden size
    max_len: int  # static KV-cache length per executable
    group_size: int  # quant group (along input channels)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self):
        return self.dim // self.heads

    def param_count(self):
        d, f, v, l = self.dim, self.ffn, self.vocab, self.layers
        per_layer = 4 * d * d + 3 * d * f + 2 * d
        return v * d + l * per_layer + d + d * v

    def linear_shapes(self):
        """The 7 quantizable linears of one decoder layer: name -> (K, N)."""
        d, f = self.dim, self.ffn
        return {
            "wq": (d, d),
            "wk": (d, d),
            "wv": (d, d),
            "wo": (d, d),
            "w_gate": (d, f),
            "w_up": (d, f),
            "w_down": (f, d),
        }


# Stand-ins for Code Llama 7B / 13B / 34B. All K dims divisible by 128.
SIZES = {
    "tiny": ModelConfig("tiny", vocab=512, dim=128, layers=2, heads=4,
                        ffn=384, max_len=128, group_size=128),
    "small": ModelConfig("small", vocab=1024, dim=256, layers=4, heads=8,
                         ffn=768, max_len=256, group_size=128),
    "base": ModelConfig("base", vocab=8192, dim=768, layers=12, heads=12,
                        ffn=2048, max_len=256, group_size=128),
}

# Executable buckets compiled by aot.py: (phase, batch, seq).
PREFILL_BUCKETS = [(1, 32), (1, 128), (4, 32), (4, 128)]
DECODE_BATCHES = [1, 2, 4, 8]

# Chunked-prefill executable buckets: (batch, chunk_len). Each pair is
# compiled once per KV-prefix bucket (chunk_prefix_buckets), giving the
# serving engine a (chunk_len, prefix_len) grid to cover continuation
# chunks — cache-hit suffixes, later chunks of long prompts, recompute —
# in one device call instead of one decode call per token.
CHUNK_BUCKETS = [(1, 16), (1, 64), (4, 16), (4, 64)]


def chunk_prefix_buckets(cfg: "ModelConfig"):
    """KV-prefix length buckets for chunk executables.

    The chunk phase takes the prefix cache as a ``[L, 2, B, P, D]``
    input, so bucketing P (rather than always shipping ``max_len`` rows
    like decode does) halves the host->device transfer for chunks that
    start early in the sequence.
    """
    return [cfg.max_len // 2, cfg.max_len]

LAYER_LINEARS = ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"]


def weight_names(cfg: ModelConfig, precision: str):
    """Canonical flat weight order.

    fp16:   embed, [attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up,
            w_down] x layers, final_norm, lm_head
    w4a16:  each linear W is replaced *in place* by the triple
            (W.packed, W.scales, W.zeros); norms/embed/lm_head stay fp16.
    """
    names = ["embed"]
    for i in range(cfg.layers):
        p = f"layers.{i}."
        for w in ["attn_norm", "wq", "wk", "wv", "wo",
                  "mlp_norm", "w_gate", "w_up", "w_down"]:
            full = p + w
            if precision == "w4a16" and w in LAYER_LINEARS:
                names += [full + ".packed", full + ".scales", full + ".zeros"]
            else:
                names.append(full)
    names += ["final_norm", "lm_head"]
    return names


def weight_specs(cfg: ModelConfig, precision: str):
    """name -> (shape tuple, dtype str) in canonical order."""
    d, f, v, g = cfg.dim, cfg.ffn, cfg.vocab, cfg.group_size
    lin = cfg.linear_shapes()
    specs = {}
    for name in weight_names(cfg, precision):
        base = name.split(".")[-1]
        if name == "embed":
            specs[name] = ((v, d), "f32")
        elif name == "lm_head":
            specs[name] = ((d, v), "f32")
        elif base in ("attn_norm", "mlp_norm", "final_norm"):
            specs[name] = ((d,), "f32")
        elif base in ("packed", "scales", "zeros"):
            wname = name.split(".")[-2]
            k, n = lin[wname]
            if base == "packed":
                specs[name] = ((k // 2, n), "u8")
            else:
                specs[name] = ((k // g, n), "f32")
        else:
            specs[name] = (lin[base], "f32")
    return specs


def kv_cache_shape(cfg: ModelConfig, batch: int):
    """KV cache layout: [layers, 2 (k/v), batch, max_len, dim]."""
    return (cfg.layers, 2, batch, cfg.max_len, cfg.dim)


def kv_prefix_shape(cfg: ModelConfig, batch: int, prefix: int):
    """Chunk-phase KV-prefix input: [layers, 2, batch, prefix, dim]."""
    return (cfg.layers, 2, batch, prefix, cfg.dim)
