"""Pure-jnp oracle for the W4A16 kernel and group-wise INT4 quantization.

This module is the single source of truth for the quantization numerics and
the packing convention. The Pallas kernel (`w4a16.py`) and the Rust
`quant::` module both mirror these definitions and are tested against them.

Conventions (shared with rust/src/quant/):
  * Weights are stored as ``W[K, N]`` (input channels x output channels).
  * Quantization is asymmetric uniform 4-bit over groups of ``group_size``
    *consecutive input channels* (along K), per output channel:
        delta = (max - min) / 15
        z     = round(-min / delta)        # stored in f32, NOT clamped:
        q     = clamp(round(w / delta) + z, 0, 15)
        deq   = (q - z) * delta
    The paper's Eq. (1) clamps Z because it packs Z into INT4; we keep the
    zero point in the f32 ``zeros`` tensor (as the W4A16 LMDeploy-style
    kernels do), which makes the scheme correct for groups that do not
    straddle zero and bounds the error by 1.5 * delta everywhere.
  * Packing: two consecutive K rows per byte, low nibble first:
        packed[k2, n] = q[2*k2, n] | (q[2*k2 + 1, n] << 4)
    giving ``packed: uint8[K // 2, N]``.
  * ``scales: f32[K // group_size, N]`` holds delta, ``zeros`` holds z
    (integer-valued, stored in f32).
"""

import jax.numpy as jnp

NIBBLE_MAX = 15  # 2**4 - 1


def quantize_groupwise(w, group_size):
    """Group-wise asymmetric INT4 RTN quantization of ``w: f32[K, N]``.

    Returns ``(q, scales, zeros)`` with ``q: int32[K, N]`` in [0, 15],
    ``scales/zeros: f32[K // group_size, N]``. K must divide by group_size.
    """
    k, n = w.shape
    assert k % group_size == 0, f"K={k} not divisible by group={group_size}"
    g = k // group_size
    wg = w.reshape(g, group_size, n)
    wmax = wg.max(axis=1)
    wmin = wg.min(axis=1)
    delta = (wmax - wmin) / NIBBLE_MAX
    # Constant groups (delta == 0): pick delta = |c| / 15 so the constant
    # lands exactly on a grid point ((15 - z) * delta = c); zero stays 0.
    delta = jnp.where(delta == 0.0,
                      jnp.maximum(jnp.abs(wmax), 1e-12) / NIBBLE_MAX, delta)
    zeros = jnp.round(-wmin / delta)  # f32, unclamped (see module docstring)
    q = jnp.round(wg / delta[:, None, :]) + zeros[:, None, :]
    q = jnp.clip(q, 0, NIBBLE_MAX).astype(jnp.int32).reshape(k, n)
    return q, delta, zeros


def dequantize_groupwise(q, scales, zeros, group_size):
    """Inverse of :func:`quantize_groupwise` (up to rounding error)."""
    k, n = q.shape
    g = k // group_size
    qg = q.reshape(g, group_size, n).astype(jnp.float32)
    deq = (qg - zeros[:, None, :]) * scales[:, None, :]
    return deq.reshape(k, n)


def pack_nibbles(q):
    """Pack ``q: int{8,32}[K, N]`` (values in [0,15]) to ``uint8[K//2, N]``."""
    k, n = q.shape
    assert k % 2 == 0, f"K={k} must be even to pack nibbles"
    qq = q.astype(jnp.uint8).reshape(k // 2, 2, n)
    return qq[:, 0, :] | (qq[:, 1, :] << 4)


def unpack_nibbles(packed):
    """Inverse of :func:`pack_nibbles`: ``uint8[K//2, N] -> int32[K, N]``."""
    k2, n = packed.shape
    lo = (packed & 0xF).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    return jnp.stack([lo, hi], axis=1).reshape(k2 * 2, n)


def quantize_pack(w, group_size):
    """Quantize + pack: ``w: f32[K,N] -> (packed u8[K//2,N], scales, zeros)``."""
    q, scales, zeros = quantize_groupwise(w, group_size)
    return pack_nibbles(q), scales, zeros


def w4a16_matmul_ref(x, packed, scales, zeros, group_size):
    """Oracle for the Pallas kernel: ``x @ dequant(packed)``.

    ``x: f32[M, K]``, returns ``f32[M, N]``.
    """
    q = unpack_nibbles(packed)
    w = dequantize_groupwise(q, scales, zeros, group_size)
    return x.astype(jnp.float32) @ w


def fake_quant(w, group_size):
    """Quantize-dequantize round trip, the "what the model will see" weight."""
    q, scales, zeros = quantize_groupwise(w, group_size)
    return dequantize_groupwise(q, scales, zeros, group_size)
