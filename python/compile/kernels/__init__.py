"""Layer-1 kernels: Pallas W4A16 group-wise dequant-matmul + pure-jnp oracle."""

from . import ref, w4a16  # noqa: F401
