"""Pallas W4A16 kernel: group-wise INT4 dequantize + matmul.

The paper ships a CUDA W4A16 kernel (optimized from LMDeploy) where packed
INT4 weight tiles are staged in shared memory, dequantized to FP16 in
registers, and fed to tensor-core WMMA. This is the TPU-style Pallas
re-think (see DESIGN.md "Hardware adaptation"):

  * the (M, N, K) threadblock tiling becomes a Pallas ``grid = (M/bm,
    N/bn, K/bk)`` with ``BlockSpec`` index maps expressing the HBM->VMEM
    schedule;
  * the packed ``uint8`` block (bk/2 x bn) lands in VMEM, the VPU unpacks
    and dequantizes it, and the dequantized tile feeds ``jnp.dot`` (MXU);
  * ``bk`` equals one quant group (default 128, the MXU-native K tile), so
    each weight block needs exactly one (scale, zero) row — the same
    coalescing argument the paper uses for group-size 128;
  * the fp32 accumulator tile lives in the output VMEM block across the K
    grid dimension (Pallas "revisiting" pattern), mirroring the CUDA
    register accumulator.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO and runs (and AOT-exports)
on any backend. TPU perf is estimated analytically in EXPERIMENTS.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128


def _kernel(x_ref, packed_ref, scales_ref, zeros_ref, o_ref, *, nsteps_k):
    """One (bm x bn) output tile; K advances along the last grid axis."""
    k_step = pl.program_id(2)

    # --- VPU: unpack two nibbles per byte into the K order [lo0, hi0, ...].
    p = packed_ref[...]  # u8[bk//2, bn]
    lo = (p & 0xF).astype(jnp.float32)
    hi = (p >> 4).astype(jnp.float32)
    bk2, bn = p.shape
    w_q = jnp.stack([lo, hi], axis=1).reshape(bk2 * 2, bn)

    # --- VPU: dequantize with this K-block's (scale, zero) rows. bk is a
    # multiple of the group size, so each row of scales_ref covers a
    # contiguous `group` span of the unpacked block.
    scales = scales_ref[...]  # f32[groups_per_bk, bn]
    zeros = zeros_ref[...]
    gpb = scales.shape[0]
    group = (bk2 * 2) // gpb
    w_g = w_q.reshape(gpb, group, bn)
    w = ((w_g - zeros[:, None, :]) * scales[:, None, :]).reshape(bk2 * 2, bn)

    # --- MXU: fp32 accumulate into the revisited output block.
    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w, preferred_element_type=jnp.float32
    )
    del nsteps_k  # only the k_step == 0 predicate is needed


@functools.partial(
    jax.jit, static_argnames=("group_size", "block_m", "block_n", "block_k")
)
def w4a16_matmul(
    x,
    packed,
    scales,
    zeros,
    *,
    group_size=128,
    block_m=None,
    block_n=None,
    block_k=None,
):
    """``x: f32[M, K] @ dequant(packed: u8[K//2, N]) -> f32[M, N]``.

    ``scales``/``zeros``: ``f32[K // group_size, N]`` per-group parameters
    (see kernels/ref.py for the packing + quantization convention).

    Block sizes default to min(dim, 128) and are clamped so that
    ``block_k`` is a multiple of ``group_size`` (or the full K).
    """
    m, k = x.shape
    k2, n = packed.shape
    assert k == 2 * k2, f"x K={k} vs packed K/2={k2}"
    assert k % group_size == 0
    g = k // group_size
    assert scales.shape == (g, n), (scales.shape, (g, n))
    assert zeros.shape == (g, n)

    bm = block_m or min(m, DEFAULT_BLOCK_M)
    bn = block_n or min(n, DEFAULT_BLOCK_N)
    bk = block_k or min(k, group_size)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert bk % group_size == 0 or bk == k, (bk, group_size)
    gpb = max(1, bk // group_size)
    nsteps_k = k // bk

    grid = (m // bm, n // bn, nsteps_k)
    return pl.pallas_call(
        functools.partial(_kernel, nsteps_k=nsteps_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((gpb, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((gpb, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x.astype(jnp.float32), packed, scales, zeros)


def vmem_footprint_bytes(block_m, block_n, block_k, group_size=128):
    """Estimated VMEM bytes for one grid step (for the §Perf table).

    x tile (f32) + packed tile (u8) + dequantized tile (f32) + scale/zero
    rows (f32) + fp32 accumulator tile. Double-buffered inputs (x2).
    """
    gpb = max(1, block_k // group_size)
    x_t = 4 * block_m * block_k
    p_t = block_k // 2 * block_n
    w_t = 4 * block_k * block_n
    sz_t = 2 * 4 * gpb * block_n
    acc = 4 * block_m * block_n
    return 2 * (x_t + p_t + sz_t) + w_t + acc


def mxu_utilization_estimate(m, n, k, block_m, block_n, block_k, vpu_ratio=8.0):
    """Crude MXU busy-fraction estimate: dot FLOPs vs dequant VPU ops.

    ``vpu_ratio`` = MXU-to-VPU throughput ratio; dequant costs ~4 VPU ops
    per weight element (unpack, sub, mul, pack-into-tile), amortized over
    ``block_m`` rows of the x tile that reuse the dequantized weights.
    """
    dot_flops = 2.0 * m * n * k
    dequant_ops = 4.0 * n * k * vpu_ratio
    return dot_flops / (dot_flops + dequant_ops * 1.0)
