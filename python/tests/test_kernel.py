"""L1 correctness: Pallas W4A16 kernel vs the pure-jnp oracle.

This is the core kernel correctness signal: every numeric claim the Rust
runtime makes about W4A16 matmuls bottoms out here.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, w4a16

RNG = np.random.default_rng(1234)


def rand_w(k, n, scale=1.0):
    return (RNG.standard_normal((k, n)) * scale).astype(np.float32)


def rand_x(m, k, scale=1.0):
    return (RNG.standard_normal((m, k)) * scale).astype(np.float32)


# ----------------------------------------------------------- fixed shapes

@pytest.mark.parametrize(
    "m,k,n,g",
    [
        (1, 128, 128, 128),   # decode-shaped, one group
        (8, 256, 384, 128),   # decode batch 8
        (128, 768, 2048, 128),  # base model prefill gate/up shape
        (32, 384, 768, 128),  # non-square, K=ffn of tiny
        (4, 64, 32, 32),      # small groups
        (2, 256, 96, 64),
    ],
)
def test_kernel_matches_ref(m, k, n, g):
    w = jnp.asarray(rand_w(k, n))
    x = jnp.asarray(rand_x(m, k))
    packed, s, z = ref.quantize_pack(w, g)
    want = ref.w4a16_matmul_ref(x, packed, s, z, g)
    got = w4a16.w4a16_matmul(x, packed, s, z, group_size=g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_kernel_custom_blocks():
    m, k, n, g = 64, 256, 256, 128
    w, x = jnp.asarray(rand_w(k, n)), jnp.asarray(rand_x(m, k))
    packed, s, z = ref.quantize_pack(w, g)
    want = ref.w4a16_matmul_ref(x, packed, s, z, g)
    for bm, bn, bk in [(32, 64, 128), (64, 128, 256), (16, 256, 128)]:
        got = w4a16.w4a16_matmul(x, packed, s, z, group_size=g,
                                 block_m=bm, block_n=bn, block_k=bk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- invariants

def test_pack_unpack_roundtrip_all_nibbles():
    # every nibble pattern in both lanes
    q = jnp.asarray(np.arange(256, dtype=np.int32).reshape(16, 16) % 16)
    assert np.array_equal(np.asarray(ref.unpack_nibbles(ref.pack_nibbles(q))),
                          np.asarray(q))


def test_quant_error_bounded():
    # error <= delta/2 away from the clamp boundary; the zero-point
    # rounding can push boundary values one extra step -> 1.5 * delta.
    w = jnp.asarray(rand_w(256, 64, scale=3.0))
    q, s, z = ref.quantize_groupwise(w, 128)
    deq = ref.dequantize_groupwise(q, s, z, 128)
    err = np.asarray(jnp.abs(deq - w))
    bound = np.repeat(np.asarray(s), 128, axis=0) * 1.5 + 1e-6
    assert (err <= bound).all(), float((err - bound).max())


def test_quant_idempotent_on_grid():
    # weights already on a quantization grid survive the round trip exactly
    w0 = jnp.asarray(RNG.integers(0, 16, size=(128, 32)).astype(np.float32))
    scale = 0.25
    w = (w0 - 5.0) * scale
    q, s, z = ref.quantize_groupwise(w, 128)
    deq = ref.dequantize_groupwise(q, s, z, 128)
    np.testing.assert_allclose(np.asarray(deq), np.asarray(w), atol=1e-6)


def test_constant_group_is_exact():
    w = jnp.full((128, 8), 0.731, jnp.float32)
    q, s, z = ref.quantize_groupwise(w, 128)
    deq = ref.dequantize_groupwise(q, s, z, 128)
    np.testing.assert_allclose(np.asarray(deq), np.asarray(w), atol=1e-6)


def test_q_range_and_zero_grid():
    w = jnp.asarray(rand_w(512, 16, scale=10.0))
    q, s, z = ref.quantize_groupwise(w, 128)
    assert (np.asarray(q) >= 0).all() and (np.asarray(q) <= 15).all()
    # zero point is integer-valued even though stored in f32
    zz = np.asarray(z)
    assert np.array_equal(zz, np.round(zz))
    # zero-mean groups keep z within the nibble range (the common case)
    assert (zz >= -1).all() and (zz <= 16).all()


def test_positive_only_group_roundtrips():
    # groups that do not straddle zero (the case the paper's clamped-Z
    # formula mishandles) must still round-trip within 1.5 * delta
    w = jnp.asarray((RNG.standard_normal((64, 8)) * 0.001 + 5.0)
                    .astype(np.float32))
    q, s, z = ref.quantize_groupwise(w, 32)
    deq = ref.dequantize_groupwise(q, s, z, 32)
    err = np.abs(np.asarray(deq - w))
    bound = np.repeat(np.asarray(s), 32, axis=0) * 1.5 + 1e-6
    assert (err <= bound).all()


# ------------------------------------------------------ hypothesis sweeps

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 4).map(lambda e: 2 ** e),        # 2..16
    kg=st.integers(1, 3),                              # groups along K
    n=st.sampled_from([32, 64, 96, 128]),
    g=st.sampled_from([32, 64, 128]),
    scale=st.floats(0.01, 8.0),
)
def test_kernel_matches_ref_swept(m, kg, n, g, scale):
    k = kg * g
    w = jnp.asarray(rand_w(k, n, scale))
    x = jnp.asarray(rand_x(m, k))
    packed, s, z = ref.quantize_pack(w, g)
    want = ref.w4a16_matmul_ref(x, packed, s, z, g)
    got = w4a16.w4a16_matmul(x, packed, s, z, group_size=g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3 * scale)


@settings(max_examples=25, deadline=None)
@given(
    k=st.sampled_from([64, 128, 256]),
    n=st.sampled_from([8, 16, 64]),
    g=st.sampled_from([32, 64]),
    loc=st.floats(-5.0, 5.0),
    scale=st.floats(1e-3, 20.0),
)
def test_quant_bound_swept(k, n, g, loc, scale):
    w = jnp.asarray((RNG.standard_normal((k, n)) * scale + loc)
                    .astype(np.float32))
    q, s, z = ref.quantize_groupwise(w, g)
    deq = ref.dequantize_groupwise(q, s, z, g)
    err = np.asarray(jnp.abs(deq - w))
    # delta/2 interior + up to one extra step at the clamp boundary.
    bound = np.repeat(np.asarray(s), g, axis=0) * 1.5
    assert (err <= bound + 1e-5 + 1e-5 * np.abs(np.asarray(w))).all()


def test_vmem_footprint_under_budget():
    # default block choice must fit the ~16 MiB VMEM budget (DESIGN.md)
    assert w4a16.vmem_footprint_bytes(128, 128, 128) < 16 * 2 ** 20
    assert w4a16.vmem_footprint_bytes(256, 256, 256) < 16 * 2 ** 20
