"""AOT path: manifest contract + HLO text properties."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_weight_names_fp16_structure():
    cfg = configs.SIZES["tiny"]
    names = configs.weight_names(cfg, "fp16")
    assert names[0] == "embed" and names[-1] == "lm_head"
    assert names[-2] == "final_norm"
    assert len(names) == 2 + 1 + 9 * cfg.layers


def test_weight_names_w4a16_triples():
    cfg = configs.SIZES["tiny"]
    names = configs.weight_names(cfg, "w4a16")
    for lin in configs.LAYER_LINEARS:
        base = f"layers.0.{lin}"
        i = names.index(base + ".packed")
        assert names[i + 1] == base + ".scales"
        assert names[i + 2] == base + ".zeros"
    assert len(names) == 2 + 1 + (2 + 7 * 3) * cfg.layers


def test_weight_specs_shapes():
    cfg = configs.SIZES["small"]
    specs = configs.weight_specs(cfg, "w4a16")
    assert specs["embed"] == ((cfg.vocab, cfg.dim), "f32")
    assert specs["layers.0.wq.packed"] == ((cfg.dim // 2, cfg.dim), "u8")
    g = cfg.dim // cfg.group_size
    assert specs["layers.0.wq.scales"] == ((g, cfg.dim), "f32")
    gf = cfg.ffn // cfg.group_size
    assert specs["layers.1.w_down.packed"] == ((cfg.ffn // 2, cfg.dim), "u8")
    assert specs["layers.1.w_down.zeros"] == ((gf, cfg.dim), "f32")


def test_random_weights_match_specs():
    cfg = configs.SIZES["tiny"]
    for prec in ("fp16", "w4a16"):
        flat = model.random_weights(cfg, prec, seed=0)
        specs = configs.weight_specs(cfg, prec)
        for arr, (name, (shape, dtype)) in zip(flat, specs.items()):
            assert tuple(arr.shape) == tuple(shape), name
            want = {"f32": jnp.float32, "u8": jnp.uint8}[dtype]
            assert arr.dtype == want, name


def test_lower_one_hlo_text():
    cfg = configs.SIZES["tiny"]
    lowered = aot.lower_one(cfg, "fp16", "decode", 1, 0)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # Count parameters of the ENTRY computation only (fusion
    # subcomputations repeat `parameter(` in the text).
    entry = text[text.index("ENTRY"):]
    entry = entry[:entry.index("\n}")]
    n_params = entry.count("parameter(")
    assert n_params == len(aot.input_descs(cfg, "fp16", "decode", 1, 0))


def test_chunk_lowering_and_descs():
    cfg = configs.SIZES["tiny"]
    prefix = configs.chunk_prefix_buckets(cfg)[0]
    descs = aot.input_descs(cfg, "fp16", "chunk", 2, 16, prefix)
    assert descs[0] == ("tokens", (2, 16), "i32")
    assert descs[1] == ("starts", (2,), "i32")
    assert descs[2] == ("kv", (cfg.layers, 2, 2, prefix, cfg.dim), "f32")
    outs = aot.output_descs(cfg, "chunk", 2, 16)
    assert outs[0] == ("logits", (2, 16, cfg.vocab), "f32")
    assert outs[1] == ("kv_new", (cfg.layers, 2, 2, 16, cfg.dim), "f32")
    lowered = aot.lower_one(cfg, "fp16", "chunk", 1, 16, prefix)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    entry = text[text.index("ENTRY"):]
    entry = entry[:entry.index("\n}")]
    assert entry.count("parameter(") == len(descs)


def test_w4a16_hlo_contains_int4_path():
    cfg = configs.SIZES["tiny"]
    lowered = aot.lower_one(cfg, "w4a16", "decode", 1, 0)
    text = aot.to_hlo_text(lowered)
    assert "u8[" in text  # packed weights enter as uint8 parameters


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_consistent_with_configs():
    man = json.load(open(os.path.join(ART, "manifest.json")))
    for size, entry in man["models"].items():
        cfg = configs.SIZES[size]
        assert entry["config"]["dim"] == cfg.dim
        for art in entry["artifacts"]:
            descs = aot.input_descs(cfg, art["precision"], art["phase"],
                                    art["batch"], art["seq"],
                                    art.get("prefix", 0))
            assert [i["name"] for i in art["inputs"]] == [n for n, _, _ in
                                                          descs]
            assert os.path.exists(os.path.join(ART, art["file"]))


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_artifact_files_are_hlo_text():
    man = json.load(open(os.path.join(ART, "manifest.json")))
    arts = man["models"]["tiny"]["artifacts"]
    for art in arts[:2]:
        head = open(os.path.join(ART, art["file"])).read(64)
        assert head.startswith("HloModule"), art["file"]
