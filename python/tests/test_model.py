"""L2 correctness: the JAX llama forward (fp16 + w4a16 variants)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import configs, model

CFG = configs.SIZES["tiny"]


def toks(rng, b, s, cfg=CFG):
    return jnp.asarray(rng.integers(0, cfg.vocab, size=(b, s)), jnp.int32)


def to_cache(kv_new, start=0):
    """Scatter ``kv_new[L,2,B,S,D]`` into a zeroed full cache at ``start``."""
    l, _, b, s, d = kv_new.shape
    out = np.zeros((l, 2, b, CFG.max_len, d), np.float32)
    out[:, :, :, start:start + s, :] = np.asarray(kv_new)
    return jnp.asarray(out)


@pytest.fixture(scope="module")
def weights():
    return {p: model.random_weights(CFG, p, seed=7) for p in
            ("fp16", "w4a16")}


def test_prefill_shapes(weights):
    rng = np.random.default_rng(0)
    for prec in ("fp16", "w4a16"):
        logits, kv = model.prefill(CFG, prec, toks(rng, 2, 16),
                                   jnp.asarray([16, 9], jnp.int32),
                                   *weights[prec])
        assert logits.shape == (2, 16, CFG.vocab)
        assert kv.shape == (CFG.layers, 2, 2, 16, CFG.dim)
        assert bool(jnp.isfinite(logits).all())


def test_decode_shapes(weights):
    rng = np.random.default_rng(1)
    logits, kv_new = model.prefill(CFG, "fp16", toks(rng, 2, 8),
                                   jnp.asarray([8, 8], jnp.int32),
                                   *weights["fp16"])
    cache = to_cache(kv_new)
    lg, kv2 = model.decode(CFG, "fp16", jnp.asarray([1, 2], jnp.int32),
                           jnp.asarray([8, 8], jnp.int32), cache,
                           *weights["fp16"])
    assert lg.shape == (2, CFG.vocab)
    assert kv2.shape == (CFG.layers, 2, 2, 1, CFG.dim)
    assert bool(jnp.isfinite(kv2).all())


def test_prefill_decode_consistency(weights):
    """decode(t_n | prefill(t_0..n-1)) == prefill(t_0..n)[n]."""
    rng = np.random.default_rng(2)
    seq = toks(rng, 1, 12)
    full, _ = model.prefill(CFG, "fp16", seq,
                            jnp.asarray([12], jnp.int32), *weights["fp16"])
    part, kv = model.prefill(CFG, "fp16", seq[:, :11],
                             jnp.asarray([11], jnp.int32), *weights["fp16"])
    dec, _ = model.decode(CFG, "fp16", seq[:, 11],
                          jnp.asarray([11], jnp.int32), to_cache(kv),
                          *weights["fp16"])
    np.testing.assert_allclose(np.asarray(dec[0]), np.asarray(full[0, 11]),
                               rtol=1e-3, atol=1e-4)


def test_multi_step_decode_matches_prefill(weights):
    rng = np.random.default_rng(3)
    seq = toks(rng, 1, 10)
    full, _ = model.prefill(CFG, "fp16", seq, jnp.asarray([10], jnp.int32),
                            *weights["fp16"])
    _, kv = model.prefill(CFG, "fp16", seq[:, :6],
                          jnp.asarray([6], jnp.int32), *weights["fp16"])
    cache = np.asarray(to_cache(kv)).copy()
    for i in range(6, 10):
        lg, kv_new = model.decode(CFG, "fp16", seq[:, i],
                                  jnp.asarray([i], jnp.int32),
                                  jnp.asarray(cache), *weights["fp16"])
        cache[:, :, :, i, :] = np.asarray(kv_new)[:, :, :, 0, :]
        np.testing.assert_allclose(np.asarray(lg[0]), np.asarray(full[0, i]),
                                   rtol=1e-3, atol=1e-4)


def to_prefix_cache(kv_new, prefix):
    """Scatter ``kv_new[L,2,B,S,D]`` into a zeroed ``P``-row prefix cache."""
    l, _, b, s, d = kv_new.shape
    out = np.zeros((l, 2, b, prefix, d), np.float32)
    out[:, :, :, :s, :] = np.asarray(kv_new)
    return jnp.asarray(out)


def test_chunk_matches_prefill_rows(weights):
    """chunk(t[s:e] | prefill(t[:s])) == prefill(t)[s:e] logits rows."""
    rng = np.random.default_rng(10)
    seq = toks(rng, 1, 24)
    for prec in ("fp16", "w4a16"):
        full, fkv = model.prefill(CFG, prec, seq,
                                  jnp.asarray([24], jnp.int32),
                                  *weights[prec])
        _, kvp = model.prefill(CFG, prec, seq[:, :10],
                               jnp.asarray([10], jnp.int32),
                               *weights[prec])
        lg, kvn = model.chunk(CFG, prec, seq[:, 10:24],
                              jnp.asarray([10], jnp.int32),
                              to_prefix_cache(kvp, 16), *weights[prec])
        assert lg.shape == (1, 14, CFG.vocab)
        assert kvn.shape == (CFG.layers, 2, 1, 14, CFG.dim)
        np.testing.assert_allclose(np.asarray(lg[0]),
                                   np.asarray(full[0, 10:24]),
                                   rtol=1e-3, atol=1e-4)
        # the chunk's new K/V rows equal the full prefill's rows 10..24
        np.testing.assert_allclose(np.asarray(kvn),
                                   np.asarray(fkv[:, :, :, 10:24, :]),
                                   rtol=1e-3, atol=1e-4)


def test_chunk_positionwise_batch(weights):
    """Two sequences at *different* starts in one chunk call (the
    engine's positionwise batching) match their solo prefills; chunk
    padding rows past each sequence's width don't disturb real rows."""
    rng = np.random.default_rng(11)
    a = toks(rng, 1, 20)
    b = toks(rng, 1, 14)
    fa, _ = model.prefill(CFG, "fp16", a, jnp.asarray([20], jnp.int32),
                          *weights["fp16"])
    fb, _ = model.prefill(CFG, "fp16", b, jnp.asarray([14], jnp.int32),
                          *weights["fp16"])
    _, kva = model.prefill(CFG, "fp16", a[:, :12],
                           jnp.asarray([12], jnp.int32), *weights["fp16"])
    _, kvb = model.prefill(CFG, "fp16", b[:, :6],
                           jnp.asarray([6], jnp.int32), *weights["fp16"])
    # pack both prefixes into one padded [L,2,2,P,D] batch (P = 16)
    prefix = np.zeros((CFG.layers, 2, 2, 16, CFG.dim), np.float32)
    prefix[:, :, 0, :12, :] = np.asarray(kva)[:, :, 0]
    prefix[:, :, 1, :6, :] = np.asarray(kvb)[:, :, 0]
    # chunk widths 8 for both (a: 12..20, b: 6..14); bucket width 8
    tokens = np.stack([np.asarray(a[0, 12:20]), np.asarray(b[0, 6:14])])
    lg, _ = model.chunk(CFG, "fp16", jnp.asarray(tokens, jnp.int32),
                        jnp.asarray([12, 6], jnp.int32),
                        jnp.asarray(prefix), *weights["fp16"])
    np.testing.assert_allclose(np.asarray(lg[0]),
                               np.asarray(fa[0, 12:20]),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(lg[1]),
                               np.asarray(fb[0, 6:14]),
                               rtol=1e-3, atol=1e-4)


def test_chunk_equals_tokenwise_decode(weights):
    """A T-token chunk reproduces T decode steps (the serving path it
    replaces) to numerical tolerance."""
    rng = np.random.default_rng(12)
    seq = toks(rng, 1, 16)
    _, kvp = model.prefill(CFG, "fp16", seq[:, :8],
                           jnp.asarray([8], jnp.int32), *weights["fp16"])
    cache = np.asarray(to_cache(kvp)).copy()
    dec_logits = []
    for i in range(8, 16):
        lg, kv_new = model.decode(CFG, "fp16", seq[:, i],
                                  jnp.asarray([i], jnp.int32),
                                  jnp.asarray(cache), *weights["fp16"])
        cache[:, :, :, i, :] = np.asarray(kv_new)[:, :, :, 0, :]
        dec_logits.append(np.asarray(lg[0]))
    ck, _ = model.chunk(CFG, "fp16", seq[:, 8:16],
                        jnp.asarray([8], jnp.int32),
                        to_prefix_cache(kvp, 64), *weights["fp16"])
    np.testing.assert_allclose(np.asarray(ck[0]), np.stack(dec_logits),
                               rtol=1e-3, atol=1e-4)


def test_padding_invariance(weights):
    """logits for real positions must not depend on padded tail tokens."""
    rng = np.random.default_rng(4)
    seq = toks(rng, 1, 16)
    a = np.asarray(seq).copy()
    b = a.copy()
    b[0, 8:] = (b[0, 8:] + 1) % CFG.vocab  # perturb only the padding
    lens = jnp.asarray([8], jnp.int32)
    la, _ = model.prefill(CFG, "fp16", jnp.asarray(a), lens,
                          *weights["fp16"])
    lb, _ = model.prefill(CFG, "fp16", jnp.asarray(b), lens,
                          *weights["fp16"])
    np.testing.assert_allclose(np.asarray(la[0, :8]), np.asarray(lb[0, :8]),
                               rtol=1e-4, atol=1e-5)


def test_batch_invariance(weights):
    """a sequence's logits must not depend on its batch neighbours."""
    rng = np.random.default_rng(5)
    s1 = toks(rng, 1, 8)
    s2 = toks(rng, 1, 8)
    both = jnp.concatenate([s1, s2], axis=0)
    lens1 = jnp.asarray([8], jnp.int32)
    lens2 = jnp.asarray([8, 8], jnp.int32)
    solo, _ = model.prefill(CFG, "fp16", s1, lens1, *weights["fp16"])
    pair, _ = model.prefill(CFG, "fp16", both, lens2, *weights["fp16"])
    np.testing.assert_allclose(np.asarray(solo[0]), np.asarray(pair[0]),
                               rtol=1e-4, atol=1e-5)


def test_w4a16_close_to_fp16(weights):
    """quantized logits track fp16 logits (tiny model, benign init)."""
    rng = np.random.default_rng(6)
    seq = toks(rng, 1, 8)
    lens = jnp.asarray([8], jnp.int32)
    lf, _ = model.prefill(CFG, "fp16", seq, lens, *weights["fp16"])
    lq, _ = model.prefill(CFG, "w4a16", seq, lens, *weights["w4a16"])
    # Random (untrained) tiny-model logits are near-noise, so argmax
    # agreement is not meaningful here; directional closeness is. The
    # trained-scale "losslessness" evals live in the Rust eval harness.
    f, q = np.asarray(lf[0]), np.asarray(lq[0])
    cos = (f * q).sum(-1) / (np.linalg.norm(f, axis=-1)
                             * np.linalg.norm(q, axis=-1))
    assert (cos > 0.85).all(), cos


def test_rmsnorm_scale_invariance():
    x = jnp.asarray(np.random.default_rng(7)
                    .standard_normal((4, 32)).astype(np.float32))
    g = jnp.ones((32,), jnp.float32)
    a = model.rmsnorm(x, g, 1e-5)
    b = model.rmsnorm(x * 100.0, g, 1e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-3, atol=1e-4)


def test_rope_preserves_norm_and_zero_is_identity():
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.standard_normal((2, 5, 4, 16)).astype(np.float32))
    pos = jnp.arange(5, dtype=jnp.int32)
    cos, sin = model.rope_tables(pos, 16, 10000.0)
    y = model.apply_rope(x, cos[None, :, None, :], sin[None, :, None, :])
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-4)
    cos0, sin0 = model.rope_tables(jnp.asarray([0]), 16, 10000.0)
    y0 = model.apply_rope(x[:, :1], cos0[None, :, None, :],
                          sin0[None, :, None, :])
    np.testing.assert_allclose(np.asarray(y0), np.asarray(x[:, :1]),
                               atol=1e-6)


def test_outlier_injection_creates_outliers():
    w = model.random_weights(CFG, "fp16", seed=9, outlier_channels=4,
                             outlier_scale=50.0)
    names = configs.weight_names(CFG, "fp16")
    gains = np.asarray(w[names.index("layers.0.attn_norm")])
    top = np.sort(gains)[-4:]
    assert (top >= 49.0).all()
    assert np.median(gains) == pytest.approx(1.0)
