# Convenience entry points documented in README.md. The Rust crate
# lives in rust/; the AOT compile path (JAX + Pallas -> HLO text) lives
# in python/compile and only runs at build time, never while serving.

.PHONY: build test artifacts bench docs fmt lint

# Tier-1: build + tests with the PJRT stub (no artifacts needed).
build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

# AOT-lower every (size, precision, bucket) executable to
# artifacts/*.hlo.txt + manifest.json. Requires jax on the Python side;
# afterwards run tier-1 with --features xla to un-skip the PJRT tests.
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

# Paper-figure regeneration benches (write BENCH_*.json at repo root).
bench:
	cd rust && cargo bench --bench micro_quant --bench micro_kernel \
		--bench micro_scheduler --bench fig7a_throughput

docs:
	cd rust && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

fmt:
	cd rust && cargo fmt --check

# Project-invariant static analysis (panic paths, determinism, locks,
# wire parity) — the same gate CI runs first. See
# docs/STATIC_ANALYSIS.md for the pass catalog and allow-marker syntax.
lint:
	cd rust && cargo run --release --quiet --bin sqlint -- \
		--baseline lint-baseline.txt src tests
