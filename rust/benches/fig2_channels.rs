//! Regenerates **Figure 2**: per-channel activation magnitudes of the 7
//! linear layers of one decoder layer — outliers sit in a small set of
//! *fixed* channels across tokens, ~100x the median.

#[path = "common/mod.rs"]
mod common;

use sqplus::model::init::{injected_channels, InitSpec};
use sqplus::model::LAYER_LINEARS;
use sqplus::quant::loss::site_of;
use sqplus::util::bench::Table;

fn main() {
    let size = common::bench_sizes().last().cloned()
        .unwrap_or_else(|| "small".into());
    let layer: usize = std::env::var("SQPLUS_FIG2_LAYER")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let s = common::setup(&size);
    let spec = InitSpec::with_outliers(0, common::OUTLIER_CHANNELS,
                                       common::OUTLIER_SCALE);
    let injected = injected_channels(&s.cfg, &spec);
    println!("injected outlier channels: {injected:?}");

    let mut t = Table::new(
        &format!("Figure 2 (data): per-channel |X| of decoder layer \
                  {layer} ({size})"),
        &["linear", "median", "p99", "max", "top-4 channels",
          "overlap w/ injected"],
    );
    for lin in LAYER_LINEARS {
        let st = s.calib.stats(layer, site_of(lin));
        let mut sorted = st.absmax.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let median = sorted[n / 2];
        let p99 = sorted[(n * 99) / 100];
        let max = sorted[n - 1];
        let mut top: Vec<(usize, f32)> =
            st.absmax.iter().cloned().enumerate().collect();
        top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let top4: Vec<usize> = top.iter().take(4).map(|x| x.0).collect();
        let overlap = top4.iter().filter(|c| injected.contains(c)).count();
        t.row(&[
            lin.to_string(),
            format!("{median:.3}"),
            format!("{p99:.2}"),
            format!("{max:.1} ({:.0}x median)", max / median.max(1e-9)),
            format!("{top4:?}"),
            // DownIn/OIn sites have their own channel space (ffn/dim)
            format!("{overlap}/4"),
        ]);
    }
    t.print();
    println!(
        "\npaper Fig 2: outliers confined to a few fixed channels, \
         ~100x other amplitudes, consistent across the 7 linears fed by \
         the hidden stream. Here: the attn/mlp-norm sites (wq/wk/wv, \
         gate/up) share the injected channel set; wo/w_down sites live \
         in other channel spaces."
    );
}
