//! Shared setup for the table/figure regeneration benches.
//!
//! Each bench target uses a subset of these helpers.
#![allow(dead_code)]
//!
//! Environment knobs:
//! * `SQPLUS_BENCH_SIZES`  — comma list of model sizes (default
//!   `tiny,small`; add `base` for the full-scale run used in
//!   EXPERIMENTS.md).
//! * `SQPLUS_BENCH_TASKS`  — eval prompts per cell (default 24).

use sqplus::config::{ModelConfig, QuantConfig, QuantMethod};
use sqplus::data::corpus::Domain;
use sqplus::data::{corpus, tasks};
use sqplus::model::init::{init_weights, InitSpec};
use sqplus::model::store::WeightStore;
use sqplus::quant::calib::{self, CalibData};
use sqplus::quant::pipeline::{self, QuantOutcome};
use sqplus::tokenizer::Tokenizer;

pub const OUTLIER_CHANNELS: usize = 8;
pub const OUTLIER_SCALE: f32 = 12.0;

pub fn bench_sizes() -> Vec<String> {
    std::env::var("SQPLUS_BENCH_SIZES")
        .unwrap_or_else(|_| "tiny,small".into())
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

pub fn bench_tasks() -> usize {
    std::env::var("SQPLUS_BENCH_TASKS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(24)
}

pub struct Setup {
    pub cfg: ModelConfig,
    pub weights: WeightStore,
    pub tok: Tokenizer,
    pub calib: CalibData,
    pub eval_prompts: Vec<Vec<u32>>,
}

/// Standard setup: outlier-injected weights, tokenizer, calibration on
/// the HumanEval-like task set, eval prompts held out from it.
pub fn setup(size: &str) -> Setup {
    setup_with_calib(size, Domain::CodePython)
}

/// Setup with a specific calibration domain (Table 3).
pub fn setup_with_calib(size: &str, calib_domain: Domain) -> Setup {
    let cfg = ModelConfig::by_name(size).expect("model size");
    let weights = init_weights(
        &cfg,
        &InitSpec::with_outliers(0, OUTLIER_CHANNELS, OUTLIER_SCALE),
    );
    let tok = Tokenizer::train(&corpus::tokenizer_training_text(0, 4000),
                               cfg.vocab);
    let n = bench_tasks();
    let cal_prompts: Vec<Vec<u32>> = match calib_domain {
        // the paper's preferred calibration set: the task descriptions
        Domain::CodePython => {
            let all = tasks::task_set(Domain::CodePython, 0);
            tasks::tokenized_prompts(&all[..32], &tok, cfg.vocab, 24)
        }
        d => corpus::corpus(d, 0, 32, 160)
            .iter()
            .map(|doc| {
                let mut ids = tok.encode_for_model(doc, cfg.vocab);
                ids.truncate(24);
                if ids.is_empty() { ids.push(1) }
                ids
            })
            .collect(),
    };
    let calib = calib::collect(&cfg, &weights, &cal_prompts, 256, 0);
    let all = tasks::task_set(Domain::CodePython, 0);
    let eval_prompts =
        tasks::tokenized_prompts(&all[32..32 + n], &tok, cfg.vocab, 24);
    Setup { cfg, weights, tok, calib, eval_prompts }
}

pub fn quantize(s: &Setup, method: QuantMethod) -> QuantOutcome {
    pipeline::quantize_model(&s.cfg, &s.weights, &s.calib, method,
                             &QuantConfig::default())
}

/// Manifest, or None with a notice (benches print SKIP rather than fail).
pub fn manifest() -> Option<sqplus::runtime::manifest::Manifest> {
    let dir = sqplus::runtime::manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(sqplus::runtime::manifest::Manifest::load(&dir).unwrap())
}
