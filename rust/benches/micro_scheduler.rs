//! Micro-bench: scheduler + block-manager throughput without the model
//! (plans/second at varying pool pressure), and KV batch-assembly
//! bandwidth — the L3 hot-path pieces outside PJRT.

#[path = "common/mod.rs"]
mod common;

use std::collections::HashMap;

use sqplus::config::{EngineConfig, ModelConfig};
use sqplus::coordinator::block_manager::BlockManager;
use sqplus::coordinator::scheduler::{Scheduler, StepPlan};
use sqplus::coordinator::sequence::{SamplingParams, Sequence};
use sqplus::runtime::kv::{self, SeqKv};
use sqplus::util::bench::{Bench, Table};

fn churn(total_blocks: usize, n_seqs: usize, prefix_cache: bool,
         max_chunk: usize) -> usize {
    let mut seqs: HashMap<u64, Sequence> = HashMap::new();
    let mut sch = Scheduler::new(
        // identical 24-token prompts (one full block + a partial): with
        // the cache on, every prefill past the first shares the head
        // block (hash + refcount path); with it off, this is the
        // pre-cache pool-pressure workload
        EngineConfig {
            enable_prefix_caching: prefix_cache,
            max_prefill_chunk: max_chunk,
            ..Default::default()
        },
        BlockManager::new(16, total_blocks),
    );
    for id in 0..n_seqs as u64 {
        seqs.insert(id, Sequence::new(id, vec![1; 24],
                                      SamplingParams::default()));
        sch.add(id);
    }
    let mut plans = 0;
    let mut done = 0u64;
    while sch.has_work() {
        let plan: StepPlan = sch.plan(&seqs);
        for c in &plan.chunks {
            let toks = seqs[&c.id].full_tokens();
            sch.bm.register_prefix(c.id, &toks[..c.end]);
            let q = seqs.get_mut(&c.id).unwrap();
            q.prefill_progress = c.end;
            if c.end == toks.len() {
                q.state =
                    sqplus::coordinator::sequence::SeqState::Running;
                q.record_token(1);
            } else {
                q.state =
                    sqplus::coordinator::sequence::SeqState::Prefilling;
            }
        }
        for &id in &plan.decode {
            let q = seqs.get_mut(&id).unwrap();
            q.record_token(1);
            if q.output.len() >= 24 {
                sch.on_finished(id);
                done += 1;
            }
        }
        if plan.is_idle() && done == n_seqs as u64 {
            break;
        }
        plans += 1;
        if plans > 1_000_000 {
            break;
        }
    }
    plans
}

fn main() {
    let mut t = Table::new(
        "micro: scheduler plans/s under pool pressure (200 seqs, 24 \
         tokens each)",
        &["pool blocks", "prefix cache", "chunk cap", "plans",
          "plans/s"],
    );
    for blocks in [64usize, 128, 512, 4096] {
        for (cache, chunk) in [(false, 0usize), (true, 0), (true, 8)] {
            let mut plans = 0;
            let r = Bench::new(
                &format!("sched pool={blocks} cache={cache} \
                          chunk={chunk}"))
                .warmup(1)
                .iters(5)
                .run(|| {
                    plans = churn(blocks, 200, cache, chunk);
                });
            t.row(&[
                blocks.to_string(),
                if cache { "on" } else { "off" }.to_string(),
                if chunk == 0 { "∞".into() } else { chunk.to_string() },
                plans.to_string(),
                format!("{:.0}", plans as f64 / r.p50_s),
            ]);
        }
    }
    t.print();

    // KV assembly bandwidth (the per-step memcpy the engine pays)
    let cfg = ModelConfig::base();
    let seqs: Vec<SeqKv> = (0..8).map(|_| SeqKv::new(&cfg)).collect();
    let refs: Vec<&SeqKv> = seqs.iter().collect();
    let bytes = cfg.layers * 2 * 8 * cfg.max_len * cfg.dim * 4;
    let r = Bench::new("kv assemble_batch base b8")
        .warmup(2)
        .iters(10)
        .run(|| {
            let out = kv::assemble_batch(&refs, &cfg, 8);
            std::hint::black_box(out.len());
        });
    println!(
        "kv assembly: {:.1} MB in {:.2} ms = {:.1} GB/s",
        bytes as f64 / 1e6,
        r.p50_s * 1e3,
        bytes as f64 / r.p50_s / 1e9
    );
}
