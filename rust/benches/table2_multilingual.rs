//! Regenerates **Table 2**: multilingual HumanEval (Python/Java/Go/C++),
//! FP16 vs SmoothQuant+ — here the pass@1 proxy over the four synthetic
//! code domains.

#[path = "common/mod.rs"]
mod common;

use sqplus::config::QuantMethod;
use sqplus::data::corpus::Domain;
use sqplus::data::tasks;
use sqplus::eval::evaluate;
use sqplus::util::bench::Table;

fn main() {
    let size = common::bench_sizes().last().cloned()
        .unwrap_or_else(|| "small".into());
    eprintln!("== size {size} (largest requested) ==");
    let s = common::setup(&size);
    let sqp = common::quantize(&s, QuantMethod::SmoothQuantPlus);

    let mut headers = vec!["method".to_string()];
    let mut fp_row = vec!["FP16".to_string()];
    let mut sq_row = vec!["SmoothQuant+".to_string()];
    let mut fp_sum = 0.0;
    let mut sq_sum = 0.0;
    for domain in Domain::code_domains() {
        headers.push(domain.as_str().to_string());
        let all = tasks::task_set(domain, 0);
        let prompts = tasks::tokenized_prompts(
            &all[32..32 + common::bench_tasks()], &s.tok, s.cfg.vocab, 24);
        // FP16 vs itself = consistency ceiling (1.0 by construction);
        // report agreement of SQ+ vs FP16 per domain.
        let r = evaluate(&s.cfg, &s.weights, &sqp.effective, &prompts, 8);
        eprintln!("  {}: exact={:.1}% agree={:.1}%", domain.as_str(),
                  r.exact_match * 100.0, r.token_agreement * 100.0);
        fp_row.push("100.0%".into());
        sq_row.push(format!("{:.1}%", r.exact_match * 100.0));
        fp_sum += 100.0;
        sq_sum += r.exact_match * 100.0;
    }
    headers.push("average".into());
    fp_row.push(format!("{:.1}%", fp_sum / 4.0));
    sq_row.push(format!("{:.1}%", sq_sum / 4.0));
    let href: Vec<&str> = headers.iter().map(|x| x.as_str()).collect();
    let mut t = Table::new(
        "Table 2 (proxy): multilingual pass@1-proxy, FP16 vs SmoothQuant+",
        &href,
    );
    t.row(&fp_row);
    t.row(&sq_row);
    t.print();
    println!(
        "\npaper (Table 2, 34B): FP16 51.2/38.5/26.7/45.3 avg 40.5; SQ+ \
         54.3/44.1/24.2/41.6 avg 41.1 — SQ+ tracks FP16 per domain."
    );
}
