//! Regenerates **Figure 7(b)**: per-token latency under replayed
//! "online" traffic for the three deployments, plus the analytic A100
//! latency (paper: SQ+ per-token latency ≈ 68% of FP16-on-2-GPUs).

#[path = "common/mod.rs"]
mod common;

use sqplus::config::{EngineConfig, GpuProfile, Precision, QuantMethod};
use sqplus::coordinator::engine::Engine;
use sqplus::coordinator::sequence::SamplingParams;
use sqplus::data::trace;
use sqplus::quant::pipeline;
use sqplus::runtime::executor::ModelRuntime;
use sqplus::runtime::perfmodel::{self, Deploy, PaperModel};
use sqplus::runtime::simtp::{CommMode, Deployment};
use sqplus::util::bench::Table;

fn replay(
    man: &sqplus::runtime::manifest::Manifest, s: &common::Setup,
    precision: Precision, store: &sqplus::model::store::WeightStore,
    workers: usize,
) -> (f64, f64) {
    let rt = ModelRuntime::load(man, &s.cfg.name, precision, store)
        .unwrap();
    rt.warmup().unwrap(); // exclude XLA compile from the timed region
    let dep = if workers > 1 {
        Deployment::tensor_parallel(rt, GpuProfile::a100_40g(), workers,
                                    CommMode::Sleep)
    } else {
        Deployment::single(rt, GpuProfile::a100_40g())
    };
    let mut eng = Engine::new(dep, EngineConfig::default());
    let reqs = trace::online_replay(3, 16, 8.0, 32, 12);
    let mut rng = sqplus::util::rng::Rng::new(11);
    let start = std::time::Instant::now();
    let mut next = 0;
    while next < reqs.len() || eng.has_work() {
        let now = start.elapsed().as_secs_f64();
        while next < reqs.len() && reqs[next].at_s <= now {
            let p = trace::prompt_tokens(&mut rng,
                                         reqs[next].prompt_tokens,
                                         s.cfg.vocab);
            eng.submit(p, SamplingParams {
                max_new_tokens: reqs[next].output_tokens,
                ..Default::default()
            });
            next += 1;
        }
        if eng.has_work() {
            eng.step().unwrap();
        } else if next < reqs.len() {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }
    let rep = eng.metrics.report();
    (rep.inter_token.p50 * 1e3, rep.inter_token.p99 * 1e3)
}

fn main() {
    let Some(man) = common::manifest() else { return };
    let size = common::bench_sizes().first().cloned()
        .unwrap_or_else(|| "tiny".into());
    let s = common::setup(&size);
    let sqp = common::quantize(&s, QuantMethod::SmoothQuantPlus);
    let fp16 = pipeline::fp16_deploy(&s.cfg, &s.weights);

    let mut t = Table::new(
        &format!("Figure 7b measured ({size}, CPU PJRT, online replay \
                  trace): per-token latency"),
        &["deployment", "p50 (ms)", "p99 (ms)"],
    );
    let (fp1_50, fp1_99) = replay(&man, &s, Precision::Fp16, &fp16, 1);
    let (fp2_50, fp2_99) = replay(&man, &s, Precision::Fp16, &fp16, 2);
    let (q4_50, q4_99) = replay(&man, &s, Precision::W4a16,
                                sqp.deploy.as_ref().unwrap(), 1);
    t.row(&["FP16 x1 (measured)".into(), format!("{fp1_50:.1}"),
            format!("{fp1_99:.1}")]);
    t.row(&["FP16 x2 (meas + sim comm)".into(), format!("{fp2_50:.1}"),
            format!("{fp2_99:.1}")]);
    t.row(&["SQ+ W4A16 x1 (measured)".into(), format!("{q4_50:.1}"),
            format!("{q4_99:.1}")]);
    t.print();
    println!("SQ+/FP16x2 per-token p50 ratio: {:.2} (paper: 0.68)",
             q4_50 / fp2_50);

    // analytic A100 at paper scale
    let gpu = GpuProfile::a100_40g();
    let m34 = PaperModel::code_llama_34b();
    let mut t2 = Table::new(
        "Figure 7b analytic (A100, Code Llama-34B, batch 8, ctx 1024): \
         per-token latency",
        &["deployment", "ms/token", "vs FP16 x2"],
    );
    let fp = perfmodel::latency_per_token_s(&gpu, &m34,
                                            Deploy::Fp16TwoGpu, 1024, 8);
    let awq = perfmodel::latency_per_token_s(&gpu, &m34,
                                             Deploy::AwqOneGpu, 1024, 8);
    let q4 = perfmodel::latency_per_token_s(&gpu, &m34,
                                            Deploy::W4a16OneGpu, 1024, 8);
    t2.row(&["FP16 x2".into(), format!("{:.2}", fp * 1e3), "1.00".into()]);
    t2.row(&["AWQ x1".into(), format!("{:.2}", awq * 1e3),
             format!("{:.2}", awq / fp)]);
    t2.row(&["SQ+ W4A16 x1".into(), format!("{:.2}", q4 * 1e3),
             format!("{:.2}", q4 / fp)]);
    t2.print();
    println!(
        "\npaper Fig 7b: SQ+ per-token latency is 68% of FP16-2GPU; AWQ \
         is slower than FP16-2GPU."
    );
}
