//! Regenerates **Table 4**: alpha-search step sensitivity (0.05 vs 0.01)
//! with the whole-model quantization loss readout.

#[path = "common/mod.rs"]
mod common;

use sqplus::config::{QuantConfig, QuantMethod};
use sqplus::eval::evaluate;
use sqplus::quant::pipeline;
use sqplus::util::bench::Table;

fn main() {
    let sizes = common::bench_sizes();
    let mut rows: Vec<Vec<String>> = vec![
        vec!["FP16".into()],
        vec!["RTN".into()],
        vec!["SQ+(step=0.05)".into()],
        vec!["SQ+(step=0.01)".into()],
        vec!["SQ+(w4a16 host)".into()],
    ];
    for size in &sizes {
        eprintln!("== size {size} ==");
        let s = common::setup(size);
        // FP16 + RTN baselines
        for (i, method) in
            [QuantMethod::Fp16, QuantMethod::Rtn].into_iter().enumerate()
        {
            let out = common::quantize(&s, method);
            let r = evaluate(&s.cfg, &s.weights, &out.effective,
                             &s.eval_prompts, 8);
            rows[i].push(format!("{:.1}%", r.exact_match * 100.0));
        }
        for (i, step) in [0.05f64, 0.01].into_iter().enumerate() {
            let qcfg = QuantConfig { alpha_step: step,
                                     ..Default::default() };
            let out = pipeline::quantize_model(
                &s.cfg, &s.weights, &s.calib,
                QuantMethod::SmoothQuantPlus, &qcfg);
            let r = evaluate(&s.cfg, &s.weights, &out.effective,
                             &s.eval_prompts, 8);
            eprintln!("  step {step}: alpha={:?} loss={:.5} exact={:.1}%",
                      out.alpha, out.loss.total, r.exact_match * 100.0);
            rows[2 + i].push(format!(
                "{:.1}% ({:.5})",
                r.exact_match * 100.0,
                out.loss.total
            ));
            if i == 0 {
                // serve the packed deploy store through the fused host
                // W4A16 kernel — the eval the paper's serving claim is
                // actually about (not the fake-quant stand-in)
                let deploy = out.deploy.as_ref().unwrap();
                let rp = evaluate(&s.cfg, &s.weights, deploy,
                                  &s.eval_prompts, 8);
                eprintln!("  w4a16 host: exact={:.1}% agree={:.1}%",
                          rp.exact_match * 100.0,
                          rp.token_agreement * 100.0);
                rows[4].push(format!("{:.1}%", rp.exact_match * 100.0));
            }
        }
    }
    let mut headers = vec!["method".to_string()];
    headers.extend(sizes.iter().cloned());
    let href: Vec<&str> = headers.iter().map(|x| x.as_str()).collect();
    let mut t = Table::new(
        "Table 4 (proxy): search-step sensitivity — pass@1-proxy (loss)",
        &href,
    );
    for r in &rows {
        t.row(r);
    }
    t.print();
    println!(
        "\npaper (Table 4): step=0.05 matches or beats step=0.01 despite \
         the coarser grid (loss differs only in the 4th-5th decimal); \
         both beat RTN. Same expected shape here."
    );
}
