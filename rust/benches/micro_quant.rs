//! Micro-bench: quantization pipeline costs — RTN quantize+pack
//! bandwidth, the SmoothQuant+ global alpha search vs the AWQ per-layer
//! search (the paper's "1/5 of the time taken by AWQ" claim).

#[path = "common/mod.rs"]
mod common;

use sqplus::config::{QuantConfig, QuantMethod};
use sqplus::quant::{awq, rtn, search};
use sqplus::tensor::Tensor;
use sqplus::util::bench::{Bench, Table};
use sqplus::util::rng::Rng;

fn main() {
    // ---- RTN quantize + pack bandwidth
    let mut rng = Rng::new(0);
    let (k, n) = (2048usize, 2048usize);
    let w = Tensor::from_vec(&[k, n],
                             (0..k * n).map(|_| rng.normal()).collect());
    let r = Bench::new("rtn quantize+pack 2048x2048")
        .warmup(1)
        .iters(5)
        .run(|| {
            let q = rtn::quantize(&w, 128);
            std::hint::black_box(q.packed.data.len());
        });
    println!(
        "rtn quantize+pack: {:.1} MB weights in {:.1} ms = {:.2} GB/s",
        (k * n * 4) as f64 / 1e6,
        r.p50_s * 1e3,
        (k * n * 4) as f64 / r.p50_s / 1e9
    );

    // ---- search cost: SQ+ global grid vs AWQ per-layer
    let mut t = Table::new(
        "micro: smoothing-search cost, SQ+ global grid vs AWQ per-layer",
        &["size", "SQ+ evals", "SQ+ s", "AWQ evals", "AWQ s",
          "AWQ/SQ+ time"],
    );
    for size in common::bench_sizes() {
        let s = common::setup(&size);
        let qcfg = QuantConfig::default();
        let sr = search::search_alpha(&s.cfg, &s.weights, &s.calib, &qcfg);
        let mut sm = s.weights.clone();
        let ar = awq::awq_search_and_smooth(&mut sm, &s.cfg, &s.calib,
                                            &qcfg);
        t.row(&[
            size.clone(),
            sr.evals.to_string(),
            format!("{:.2}", sr.elapsed_s),
            ar.evals.to_string(),
            format!("{:.2}", ar.elapsed_s),
            format!("{:.1}x", ar.elapsed_s / sr.elapsed_s.max(1e-9)),
        ]);
        // full quantize timings
        for m in [QuantMethod::Rtn, QuantMethod::SmoothQuantPlus,
                  QuantMethod::Awq] {
            let out = common::quantize(&s, m);
            eprintln!("  {size} {:<13} quantize {:.2}s", m.as_str(),
                      out.quantize_s);
        }
    }
    t.print();
    println!(
        "\npaper: SQ+'s search takes ~1/5 the time of AWQ's (34B). Same \
         direction expected: the global grid (21 evals) is far cheaper \
         than AWQ's per-unit alpha x clip grid."
    );
}
