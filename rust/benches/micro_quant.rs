//! Micro-bench: quantization pipeline costs — RTN quantize+pack
//! bandwidth, the fused grid-point loss vs the pre-fusion
//! clone-and-fake-quant path, and the SmoothQuant+ global alpha search vs
//! the AWQ per-layer search (the paper's "1/5 of the time taken by AWQ"
//! claim). Writes machine-readable results to `BENCH_micro.json`
//! (section `micro_quant`) every run.

#[path = "common/mod.rs"]
mod common;

use sqplus::config::{ModelConfig, QuantConfig, QuantMethod};
use sqplus::model::store::WeightStore;
use sqplus::model::LAYER_LINEARS;
use sqplus::quant::calib::CalibData;
use sqplus::quant::loss::{linear_loss, site_of};
use sqplus::quant::smooth::{smoothing_factors, unit_weight_absmax};
use sqplus::quant::{awq, rtn, search};
use sqplus::tensor::Tensor;
use sqplus::util::bench::{Bench, JsonReport, Table};
use sqplus::util::rng::Rng;

/// The pre-fusion grid-point evaluation, reconstructed for an
/// apples-to-apples baseline: per linear it clones the weight, scales,
/// runs the quantize→dequantize round trip, unscales, materializes the
/// difference and multiplies it against the calibration rows.
fn loss_at_alpha_unfused(cfg: &ModelConfig, w: &WeightStore,
                         calib: &CalibData, group_size: usize, alpha: f32)
    -> f64 {
    let mut total = 0.0;
    for layer in 0..cfg.layers {
        for lin in LAYER_LINEARS {
            let site = site_of(lin);
            let stats = calib.stats(layer, site);
            let wmax = unit_weight_absmax(w, layer, site);
            let s = smoothing_factors(&stats.absmax, &wmax, alpha);
            let name = format!("layers.{layer}.{lin}");
            let orig = w.f32(&name);
            let mut scaled = orig.clone();
            scaled.scale_rows(&s);
            let mut eff = rtn::fake_quant(&scaled, group_size);
            let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
            eff.scale_rows(&inv);
            let rows = stats.rows.shape[0].max(1) as f64;
            total += linear_loss(&stats.rows, orig, &eff) / rows;
        }
    }
    total
}

fn main() {
    let mut report = JsonReport::micro("micro_quant");

    // ---- RTN quantize + pack bandwidth
    let mut rng = Rng::new(0);
    let (k, n) = (2048usize, 2048usize);
    let w = Tensor::from_vec(&[k, n],
                             (0..k * n).map(|_| rng.normal()).collect());
    let r = Bench::new("rtn quantize+pack 2048x2048")
        .warmup(1)
        .iters(5)
        .run(|| {
            let q = rtn::quantize(&w, 128);
            std::hint::black_box(q.packed.data.len());
        });
    println!(
        "rtn quantize+pack: {:.1} MB weights in {:.1} ms = {:.2} GB/s",
        (k * n * 4) as f64 / 1e6,
        r.p50_s * 1e3,
        (k * n * 4) as f64 / r.p50_s / 1e9
    );
    report.add("rtn_quantize_pack_2048x2048", &r);
    report.metric("rtn_quantize_pack_gbps",
                  (k * n * 4) as f64 / r.p50_s / 1e9);

    // ---- fused grid-point loss vs the pre-fusion clone+fake-quant path
    let mut t_loss = Table::new(
        "micro: alpha grid-point loss, fused vs pre-fusion path",
        &["size", "unfused (ms)", "fused (ms)", "speedup"],
    );
    for size in common::bench_sizes() {
        let s = common::setup(&size);
        let qcfg = QuantConfig::default();
        let r_old = Bench::new(&format!("{size} loss_at_alpha unfused"))
            .warmup(1)
            .iters(3)
            .run(|| {
                std::hint::black_box(loss_at_alpha_unfused(
                    &s.cfg, &s.weights, &s.calib, qcfg.group_size, 0.5,
                ));
            });
        let r_new = Bench::new(&format!("{size} loss_at_alpha fused"))
            .warmup(1)
            .iters(3)
            .run(|| {
                std::hint::black_box(search::loss_at_alpha(
                    &s.cfg, &s.weights, &s.calib, qcfg.group_size, 0.5,
                ));
            });
        t_loss.row(&[
            size.clone(),
            format!("{:.2}", r_old.p50_s * 1e3),
            format!("{:.2}", r_new.p50_s * 1e3),
            format!("{:.1}x", r_old.p50_s / r_new.p50_s.max(1e-12)),
        ]);
        report.add(&format!("{size}_loss_at_alpha_unfused"), &r_old);
        report.add(&format!("{size}_loss_at_alpha_fused"), &r_new);
        report.metric(&format!("{size}_loss_at_alpha_speedup"),
                      r_old.p50_s / r_new.p50_s.max(1e-12));

        // ---- end-to-end SQ+ quantize (search + smooth + quantize_store)
        // vs the pre-fusion search cost alone (a conservative lower bound
        // on the old end-to-end time: 21 unfused grid points)
        let steps = (1.0 / qcfg.alpha_step).round() as usize + 1;
        let t0 = std::time::Instant::now();
        for i in 0..steps {
            let alpha =
                (i as f64 * qcfg.alpha_step).min(1.0) as f32;
            std::hint::black_box(loss_at_alpha_unfused(
                &s.cfg, &s.weights, &s.calib, qcfg.group_size, alpha,
            ));
        }
        let old_search_s = t0.elapsed().as_secs_f64();
        let out = common::quantize(&s, QuantMethod::SmoothQuantPlus);
        eprintln!(
            "  {size} SQ+ end-to-end quantize {:.2}s (pre-fusion search \
             alone {:.2}s) => {:.1}x",
            out.quantize_s,
            old_search_s,
            old_search_s / out.quantize_s.max(1e-12)
        );
        report.metric(&format!("{size}_sqplus_quantize_s"),
                      out.quantize_s);
        report.metric(&format!("{size}_prefusion_search_s"), old_search_s);
        report.metric(
            &format!("{size}_sqplus_end_to_end_speedup"),
            old_search_s / out.quantize_s.max(1e-12),
        );
    }
    t_loss.print();

    // ---- search cost: SQ+ global grid vs AWQ per-layer
    let mut t = Table::new(
        "micro: smoothing-search cost, SQ+ global grid vs AWQ per-layer",
        &["size", "SQ+ evals", "SQ+ s", "AWQ evals", "AWQ s",
          "AWQ/SQ+ time"],
    );
    for size in common::bench_sizes() {
        let s = common::setup(&size);
        let qcfg = QuantConfig::default();
        let sr = search::search_alpha(&s.cfg, &s.weights, &s.calib, &qcfg);
        let mut sm = s.weights.clone();
        let ar = awq::awq_search_and_smooth(&mut sm, &s.cfg, &s.calib,
                                            &qcfg);
        t.row(&[
            size.clone(),
            sr.evals.to_string(),
            format!("{:.2}", sr.elapsed_s),
            ar.evals.to_string(),
            format!("{:.2}", ar.elapsed_s),
            format!("{:.1}x", ar.elapsed_s / sr.elapsed_s.max(1e-9)),
        ]);
        report.metric(&format!("{size}_sqplus_search_s"), sr.elapsed_s);
        report.metric(&format!("{size}_awq_search_s"), ar.elapsed_s);
        // full quantize timings
        for m in [QuantMethod::Rtn, QuantMethod::SmoothQuantPlus,
                  QuantMethod::Awq] {
            let out = common::quantize(&s, m);
            eprintln!("  {size} {:<13} quantize {:.2}s", m.as_str(),
                      out.quantize_s);
            report.metric(&format!("{size}_{}_quantize_s", m.as_str()),
                          out.quantize_s);
        }
    }
    t.print();
    println!(
        "\npaper: SQ+'s search takes ~1/5 the time of AWQ's (34B). Same \
         direction expected: the global grid (21 evals) is far cheaper \
         than AWQ's per-unit alpha x clip grid."
    );
    match report.write() {
        Ok(()) => eprintln!("wrote BENCH_micro.json (micro_quant)"),
        Err(e) => eprintln!("BENCH_micro.json write failed: {e}"),
    }
}
