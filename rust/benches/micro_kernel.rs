//! Micro-bench: the host-side fused W4A16 kernel (dequant folded into the
//! GEMM, straight from packed nibbles) against dequantize-then-matmul and
//! the dense f32 GEMM, then — when artifacts are built — the
//! PJRT-executed decode/prefill step, FP16 GEMM vs the Pallas-lowered
//! W4A16 dequant-GEMM, across batch buckets (the paper's kernel-level
//! claim: the W4A16 path must not lose to FP16 despite the dequant work,
//! because weight traffic shrinks 4x). Writes `BENCH_micro.json`
//! (section `micro_kernel`) every run.

#[path = "common/mod.rs"]
mod common;

use sqplus::config::{Precision, QuantMethod};
use sqplus::quant::{kernel, pipeline, rtn};
use sqplus::runtime::executor::ModelRuntime;
use sqplus::runtime::kv::{self, SeqKv};
use sqplus::tensor::Tensor;
use sqplus::util::bench::{Bench, JsonReport, Table};
use sqplus::util::rng::Rng;

/// Host fused-kernel section: no PJRT artifacts required.
fn host_kernel_bench(report: &mut JsonReport) {
    let mut rng = Rng::new(1);
    let (k, n) = (2048usize, 2048usize);
    let w = Tensor::from_vec(&[k, n],
                             (0..k * n).map(|_| rng.normal()).collect());
    let q = rtn::quantize(&w, 128);
    let dense = q.dequantize(); // resident-f32 baseline ("fp16" proxy)
    let mut t = Table::new(
        "micro: host W4A16 matmul (2048x2048, g=128)",
        &["rows", "fused (ms)", "deq+matmul (ms)", "dense f32 (ms)",
          "fused/dense"],
    );
    for m in [1usize, 16, 128] {
        let x = Tensor::from_vec(
            &[m, k],
            (0..m * k).map(|_| rng.normal()).collect(),
        );
        let r_fused = Bench::new(&format!("w4a16 fused m={m}"))
            .warmup(2)
            .iters(8)
            .run(|| {
                std::hint::black_box(
                    kernel::matmul_w4a16(&x, &q).data.len(),
                );
            });
        let r_deq = Bench::new(&format!("w4a16 deq+matmul m={m}"))
            .warmup(1)
            .iters(4)
            .run(|| {
                let d = q.dequantize();
                std::hint::black_box(x.matmul(&d).data.len());
            });
        let r_dense = Bench::new(&format!("dense f32 m={m}"))
            .warmup(2)
            .iters(8)
            .run(|| {
                std::hint::black_box(x.matmul(&dense).data.len());
            });
        t.row(&[
            m.to_string(),
            format!("{:.2}", r_fused.p50_s * 1e3),
            format!("{:.2}", r_deq.p50_s * 1e3),
            format!("{:.2}", r_dense.p50_s * 1e3),
            format!("{:.2}x", r_fused.p50_s / r_dense.p50_s.max(1e-12)),
        ]);
        report.add(&format!("host_w4a16_fused_m{m}"), &r_fused);
        report.add(&format!("host_w4a16_deq_matmul_m{m}"), &r_deq);
        report.add(&format!("host_dense_f32_m{m}"), &r_dense);
        report.metric(
            &format!("host_w4a16_fused_vs_deq_speedup_m{m}"),
            r_deq.p50_s / r_fused.p50_s.max(1e-12),
        );
    }
    t.print();
}

fn main() {
    let mut report = JsonReport::micro("micro_kernel");
    host_kernel_bench(&mut report);
    match report.write() {
        Ok(()) => eprintln!("wrote BENCH_micro.json (micro_kernel)"),
        Err(e) => eprintln!("BENCH_micro.json write failed: {e}"),
    }

    let Some(man) = common::manifest() else { return };
    let size = common::bench_sizes().first().cloned()
        .unwrap_or_else(|| "tiny".into());
    let s = common::setup(&size);
    let sqp = common::quantize(&s, QuantMethod::SmoothQuantPlus);
    let fp16 = pipeline::fp16_deploy(&s.cfg, &s.weights);

    let rt_fp = ModelRuntime::load(&man, &size, Precision::Fp16, &fp16)
        .unwrap();
    let rt_q4 = ModelRuntime::load(&man, &size, Precision::W4a16,
                                   sqp.deploy.as_ref().unwrap())
        .unwrap();

    let mut t = Table::new(
        &format!("micro: decode step latency ({size}, CPU PJRT)"),
        &["batch", "FP16 (ms)", "W4A16 (ms)", "W4A16/FP16"],
    );
    for batch in rt_fp.decode_batches() {
        // prefill `batch` short sequences to seed KV
        let prompts: Vec<Vec<u32>> = (0..batch)
            .map(|i| (0..8u32).map(|t| (i as u32 * 31 + t * 7)
                % s.cfg.vocab as u32).collect())
            .collect();
        let step = |rt: &'_ ModelRuntime| -> (Vec<u32>, Vec<usize>, Vec<f32>) {
            // prefill in chunks of the largest prefill batch bucket
            let max_pb = rt
                .prefill_buckets()
                .into_iter()
                .map(|(b, _)| b)
                .max()
                .unwrap();
            let mut kvs: Vec<SeqKv> =
                (0..batch).map(|_| SeqKv::new(&s.cfg)).collect();
            for chunk in (0..batch).collect::<Vec<_>>().chunks(max_pb) {
                let views: Vec<&[u32]> =
                    chunk.iter().map(|&i| &prompts[i][..]).collect();
                let pre = rt.prefill(&views).unwrap();
                // chunk indices are contiguous: borrow that sub-slice
                let lo = chunk[0];
                let hi = *chunk.last().unwrap();
                let mut refs: Vec<&mut SeqKv> =
                    kvs[lo..=hi].iter_mut().collect();
                kv::fill_prefill_rows(&mut refs, &s.cfg, pre.batch,
                                      pre.seq, &pre.kv_new,
                                      &vec![8; chunk.len()]);
            }
            let toks: Vec<u32> = vec![1; batch];
            let lens: Vec<usize> = vec![8; batch];
            let kvrefs: Vec<&SeqKv> = kvs.iter().collect();
            let kvb = kv::assemble_batch(&kvrefs, &s.cfg, batch);
            (toks, lens, kvb)
        };
        let (toks, lens, kvb) = step(&rt_fp);
        let r_fp = Bench::new(&format!("fp16 decode b{batch}"))
            .warmup(2)
            .iters(8)
            .run(|| {
                let _ = rt_fp.decode(&toks, &lens, &kvb).unwrap();
            });
        let (toks, lens, kvb) = step(&rt_q4);
        let r_q4 = Bench::new(&format!("w4a16 decode b{batch}"))
            .warmup(2)
            .iters(8)
            .run(|| {
                let _ = rt_q4.decode(&toks, &lens, &kvb).unwrap();
            });
        t.row(&[
            batch.to_string(),
            format!("{:.2}", r_fp.p50_s * 1e3),
            format!("{:.2}", r_q4.p50_s * 1e3),
            format!("{:.2}x", r_q4.p50_s / r_fp.p50_s),
        ]);
    }
    t.print();
}
