//! Micro-bench: the PJRT-executed decode/prefill step, FP16 GEMM vs the
//! Pallas-lowered W4A16 dequant-GEMM, across batch buckets (the paper's
//! kernel-level claim: the W4A16 path must not lose to FP16 despite the
//! dequant work, because weight traffic shrinks 4x).

#[path = "common/mod.rs"]
mod common;

use sqplus::config::{Precision, QuantMethod};
use sqplus::quant::pipeline;
use sqplus::runtime::executor::ModelRuntime;
use sqplus::runtime::kv::{self, SeqKv};
use sqplus::util::bench::{Bench, Table};

fn main() {
    let Some(man) = common::manifest() else { return };
    let size = common::bench_sizes().first().cloned()
        .unwrap_or_else(|| "tiny".into());
    let s = common::setup(&size);
    let sqp = common::quantize(&s, QuantMethod::SmoothQuantPlus);
    let fp16 = pipeline::fp16_deploy(&s.cfg, &s.weights);

    let rt_fp = ModelRuntime::load(&man, &size, Precision::Fp16, &fp16)
        .unwrap();
    let rt_q4 = ModelRuntime::load(&man, &size, Precision::W4a16,
                                   sqp.deploy.as_ref().unwrap())
        .unwrap();

    let mut t = Table::new(
        &format!("micro: decode step latency ({size}, CPU PJRT)"),
        &["batch", "FP16 (ms)", "W4A16 (ms)", "W4A16/FP16"],
    );
    for batch in rt_fp.decode_batches() {
        // prefill `batch` short sequences to seed KV
        let prompts: Vec<Vec<u32>> = (0..batch)
            .map(|i| (0..8u32).map(|t| (i as u32 * 31 + t * 7)
                % s.cfg.vocab as u32).collect())
            .collect();
        let step = |rt: &'_ ModelRuntime| -> (Vec<u32>, Vec<usize>, Vec<f32>) {
            // prefill in chunks of the largest prefill batch bucket
            let max_pb = rt
                .prefill_buckets()
                .into_iter()
                .map(|(b, _)| b)
                .max()
                .unwrap();
            let mut kvs: Vec<SeqKv> =
                (0..batch).map(|_| SeqKv::new(&s.cfg)).collect();
            for chunk in (0..batch).collect::<Vec<_>>().chunks(max_pb) {
                let views: Vec<&[u32]> =
                    chunk.iter().map(|&i| &prompts[i][..]).collect();
                let pre = rt.prefill(&views).unwrap();
                // chunk indices are contiguous: borrow that sub-slice
                let lo = chunk[0];
                let hi = *chunk.last().unwrap();
                let mut refs: Vec<&mut SeqKv> =
                    kvs[lo..=hi].iter_mut().collect();
                kv::fill_prefill_rows(&mut refs, &s.cfg, pre.batch,
                                      pre.seq, &pre.kv_new,
                                      &vec![8; chunk.len()]);
            }
            let toks: Vec<u32> = vec![1; batch];
            let lens: Vec<usize> = vec![8; batch];
            let kvrefs: Vec<&SeqKv> = kvs.iter().collect();
            let kvb = kv::assemble_batch(&kvrefs, &s.cfg, batch);
            (toks, lens, kvb)
        };
        let (toks, lens, kvb) = step(&rt_fp);
        let r_fp = Bench::new(&format!("fp16 decode b{batch}"))
            .warmup(2)
            .iters(8)
            .run(|| {
                let _ = rt_fp.decode(&toks, &lens, &kvb).unwrap();
            });
        let (toks, lens, kvb) = step(&rt_q4);
        let r_q4 = Bench::new(&format!("w4a16 decode b{batch}"))
            .warmup(2)
            .iters(8)
            .run(|| {
                let _ = rt_q4.decode(&toks, &lens, &kvb).unwrap();
            });
        t.row(&[
            batch.to_string(),
            format!("{:.2}", r_fp.p50_s * 1e3),
            format!("{:.2}", r_q4.p50_s * 1e3),
            format!("{:.2}x", r_q4.p50_s / r_fp.p50_s),
        ]);
    }
    t.print();
}
