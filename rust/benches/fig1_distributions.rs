//! Regenerates **Figure 1**: weight vs activation magnitude (mean + max)
//! for every linear layer. The paper's observation: weights are flat
//! (mean < 0.3, max < 2.5 in their units) while activations fluctuate
//! wildly (max up to 1600, ~100x the mean) — here induced by the
//! injected outlier channels.

#[path = "common/mod.rs"]
mod common;

use sqplus::model::LAYER_LINEARS;
use sqplus::quant::loss::site_of;
use sqplus::util::bench::Table;

fn main() {
    let size = common::bench_sizes().last().cloned()
        .unwrap_or_else(|| "small".into());
    let s = common::setup(&size);
    let mut t = Table::new(
        &format!("Figure 1 (data): per-linear |W| and |X| stats ({size})"),
        &["idx", "linear", "w_mean", "w_max", "act_mean", "act_max",
          "act max/mean"],
    );
    let mut idx = 0;
    let mut w_max_all = 0.0f32;
    let mut a_max_all = 0.0f32;
    for layer in 0..s.cfg.layers {
        for lin in LAYER_LINEARS {
            let name = format!("layers.{layer}.{lin}");
            let wt = s.weights.f32(&name);
            let w_mean = wt.data.iter().map(|x| x.abs()).sum::<f32>()
                / wt.numel() as f32;
            let w_max =
                wt.data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            let st = s.calib.stats(layer, site_of(lin));
            let a_mean = st.absmean.iter().sum::<f32>()
                / st.absmean.len() as f32;
            let a_max =
                st.absmax.iter().cloned().fold(0.0f32, f32::max);
            w_max_all = w_max_all.max(w_max);
            a_max_all = a_max_all.max(a_max);
            t.row(&[
                idx.to_string(),
                name,
                format!("{w_mean:.4}"),
                format!("{w_max:.3}"),
                format!("{a_mean:.3}"),
                format!("{a_max:.1}"),
                format!("{:.0}x", a_max / a_mean.max(1e-9)),
            ]);
            idx += 1;
        }
    }
    t.print();
    println!(
        "\nglobal: weight max {w_max_all:.2} vs activation max \
         {a_max_all:.1} — paper Fig 1 reports weight max < 2.5 and \
         activation max up to 1600 (fluctuation >> weights). Shape \
         reproduced: activations dominate by orders of magnitude."
    );
}
