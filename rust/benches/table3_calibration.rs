//! Regenerates **Table 3**: calibration-set sensitivity — SmoothQuant+
//! calibrated on pile-like / c4-like / task-set (HumanEval-like) corpora,
//! evaluated on the task set.

#[path = "common/mod.rs"]
mod common;

use sqplus::config::QuantMethod;
use sqplus::data::corpus::Domain;
use sqplus::eval::evaluate;
use sqplus::util::bench::Table;

fn main() {
    let sizes = common::bench_sizes();
    let cal_sets: [(&str, Domain); 3] = [
        ("Pile", Domain::PileProse),
        ("C4", Domain::C4Web),
        ("HumanEval", Domain::CodePython), // the task-set calibration
    ];
    let mut rows: Vec<Vec<String>> = cal_sets
        .iter()
        .map(|(n, _)| vec![n.to_string()])
        .collect();
    let mut loss_rows = rows.clone();

    for size in &sizes {
        eprintln!("== size {size} ==");
        // task-set activations are the common yardstick: every candidate
        // (whatever it calibrated on) is judged by its quantization loss
        // on the *eval* distribution, the paper's Table-3 question.
        let yardstick = common::setup(size);
        for (i, (name, domain)) in cal_sets.iter().enumerate() {
            let s = common::setup_with_calib(size, *domain);
            let out = common::quantize(&s, QuantMethod::SmoothQuantPlus);
            let r = evaluate(&s.cfg, &s.weights, &out.effective,
                             &s.eval_prompts, 8);
            // original-frame loss: s from this calib set, X rows from the
            // task-set yardstick
            let eval_loss = sqplus::quant::search::loss_at_alpha_cross(
                &s.cfg, &s.weights, &s.calib, &yardstick.calib,
                s.cfg.group_size, out.alpha.unwrap());
            eprintln!("  calib {name}: exact={:.1}% agree={:.1}% \
                       eval-loss={:.4} alpha={:?}",
                      r.exact_match * 100.0, r.token_agreement * 100.0,
                      eval_loss, out.alpha);
            rows[i].push(format!("{:.1}% / {:.1}%",
                                 r.exact_match * 100.0,
                                 r.token_agreement * 100.0));
            loss_rows[i].push(format!("{:.4}", eval_loss));
        }
    }
    let mut headers = vec!["calib set".to_string()];
    headers.extend(sizes.iter().cloned());
    let href: Vec<&str> = headers.iter().map(|x| x.as_str()).collect();
    let mut t = Table::new(
        "Table 3 (proxy): calibration-set sensitivity of SmoothQuant+ \
         (pass@1-proxy)",
        &href,
    );
    for r in &rows {
        t.row(r);
    }
    t.print();
    let mut t2 = Table::new("Table 3 companion: quant loss per calib set",
                            &href);
    for r in &loss_rows {
        t2.row(r);
    }
    t2.print();
    println!(
        "\npaper (Table 3): HumanEval calibration wins at every size \
         (35.98/37.80/53.05 vs Pile 28.05/32.32/50.0, C4 \
         31.71/32.32/45.12). Expected shape: task-set calibration >= \
         prose/web calibration on the task-set eval."
    );
}
