//! Regenerates **Table 1**: HumanEval-Python pass@1 across model sizes ×
//! {FP16, RTN, AWQ, SmoothQuant+} — here the pass@1 proxy (greedy exact
//! match vs FP16) and teacher-forced token agreement on the synthetic
//! task set (DESIGN.md §5).
//!
//! ```sh
//! cargo bench --bench table1_accuracy
//! SQPLUS_BENCH_SIZES=tiny,small,base cargo bench --bench table1_accuracy
//! ```

#[path = "common/mod.rs"]
mod common;

use sqplus::config::QuantMethod;
use sqplus::eval::evaluate;
use sqplus::util::bench::Table;

fn main() {
    let sizes = common::bench_sizes();
    let mut rows_exact: Vec<Vec<String>> = QuantMethod::all()
        .iter()
        .map(|m| vec![m.as_str().to_string()])
        .collect();
    let mut rows_agree = rows_exact.clone();
    let mut rows_loss = rows_exact.clone();

    for size in &sizes {
        eprintln!("== size {size} ==");
        let s = common::setup(size);
        for (i, method) in QuantMethod::all().into_iter().enumerate() {
            let out = common::quantize(&s, method);
            let r = evaluate(&s.cfg, &s.weights, &out.effective,
                             &s.eval_prompts, 8);
            eprintln!(
                "  {:<13} exact={:.1}% agree={:.1}% nll={:.3} loss={:.4}",
                method.as_str(), r.exact_match * 100.0,
                r.token_agreement * 100.0, r.nll, out.loss.total
            );
            rows_exact[i].push(format!("{:.1}%", r.exact_match * 100.0));
            rows_agree[i]
                .push(format!("{:.1}%", r.token_agreement * 100.0));
            rows_loss[i].push(format!("{:.4}", out.loss.total));
        }
    }

    let mut headers = vec!["method".to_string()];
    headers.extend(sizes.iter().cloned());
    let href: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    for (title, rows) in [
        ("Table 1 (proxy): pass@1-proxy (greedy exact match vs FP16)",
         &rows_exact),
        ("Table 1 (proxy): teacher-forced token agreement", &rows_agree),
        ("Table 1 companion: whole-model quantization loss", &rows_loss),
    ] {
        let mut t = Table::new(title, &href);
        for r in rows {
            t.row(r);
        }
        t.print();
    }
    println!(
        "\npaper (Table 1, HumanEval pass@1): FP16 36.0/36.0/51.2, RTN \
         36.6/33.5/46.3, AWQ 36.0/31.7/50.6, SQ+ 36.0/37.8/53.0 — the \
         reproduced shape is SQ+ > AWQ/RTN, SQ+ closest to FP16."
    );
}
