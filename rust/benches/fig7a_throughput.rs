//! Regenerates **Figure 7(a)**: decode throughput vs context length for
//! the three deployments — FP16 on 2 GPUs (tensor parallel), AWQ on 1
//! GPU, SmoothQuant+/W4A16 on 1 GPU.
//!
//! Two complementary readouts (DESIGN.md §5):
//! 1. **measured** — the real engine on this CPU testbed under a Poisson
//!    trace: FP16 single-worker vs W4A16 single-worker (both fully
//!    measured), plus FP16 with the simulated 2-worker interconnect cost
//!    slept into the wall clock;
//! 2. **analytic A100** — the roofline model at Code Llama-34B scale,
//!    which reproduces the paper's 1.9-4.0x band.

#[path = "common/mod.rs"]
mod common;

use sqplus::config::{
    CacheWatermarks, EngineConfig, GpuProfile, KvCacheMode, Precision,
    QuantMethod, RouterConfig, RoutingPolicy,
};
use sqplus::coordinator::engine::Engine;
use sqplus::coordinator::router::Router;
use sqplus::coordinator::sequence::SamplingParams;
use sqplus::data::trace;
use sqplus::quant::pipeline;
use sqplus::runtime::executor::ModelRuntime;
use sqplus::runtime::perfmodel::{self, Deploy, PaperModel};
use sqplus::runtime::simtp::{CommMode, Deployment};
use sqplus::util::bench::{JsonReport, Table};

fn run_measured(
    m: &sqplus::runtime::manifest::Manifest, s: &common::Setup,
    precision: Precision, deploy_store: &sqplus::model::store::WeightStore,
    workers: usize, prompt: usize, output: usize, n_req: usize,
) -> f64 {
    let rt = ModelRuntime::load(m, &s.cfg.name, precision, deploy_store)
        .unwrap();
    rt.warmup().unwrap(); // exclude XLA compile from the timed region
    let dep = if workers > 1 {
        Deployment::tensor_parallel(rt, GpuProfile::a100_40g(), workers,
                                    CommMode::Sleep)
    } else {
        Deployment::single(rt, GpuProfile::a100_40g())
    };
    let mut eng = Engine::new(dep, EngineConfig::default());
    let mut rng = sqplus::util::rng::Rng::new(5);
    let t0 = std::time::Instant::now();
    for _ in 0..n_req {
        let p = sqplus::data::trace::prompt_tokens(&mut rng, prompt,
                                                   s.cfg.vocab);
        eng.submit(p, SamplingParams { max_new_tokens: output,
                                       ..Default::default() });
    }
    eng.run_to_completion(100_000).unwrap();
    let out_tokens = eng.metrics.output_tokens;
    out_tokens as f64 / t0.elapsed().as_secs_f64()
}

/// Shared-prefix workload (system-prompt traffic): `n_req` requests of
/// `prefix + unique suffix`, submitted in waves so later waves can hit
/// the blocks earlier waves registered. Returns (tokens/s, prefill
/// tokens executed, cached prefix tokens).
fn run_shared_prefix(
    m: &sqplus::runtime::manifest::Manifest, s: &common::Setup,
    deploy_store: &sqplus::model::store::WeightStore, enable: bool,
    n_req: usize, prefix: usize, suffix: usize, output: usize,
) -> (f64, usize, usize) {
    let rt = ModelRuntime::load(m, &s.cfg.name, Precision::W4a16,
                                deploy_store)
        .unwrap();
    rt.warmup().unwrap();
    let dep = Deployment::single(rt, GpuProfile::a100_40g());
    let ecfg = EngineConfig {
        enable_prefix_caching: enable,
        ..Default::default()
    };
    let mut eng = Engine::new(dep, ecfg);
    let prompts = trace::shared_prefix_prompts(11, n_req, prefix, suffix,
                                               s.cfg.vocab);
    let t0 = std::time::Instant::now();
    for wave in prompts.chunks(4) {
        for p in wave {
            eng.submit(p.clone(), SamplingParams {
                max_new_tokens: output,
                ..Default::default()
            });
        }
        eng.run_to_completion(100_000).unwrap();
    }
    let tput = eng.metrics.output_tokens as f64
        / t0.elapsed().as_secs_f64();
    (tput, eng.metrics.prefill_tokens_executed,
     eng.metrics.cached_prefix_tokens)
}

/// Chunked-prefill workload: long cold prompts arriving while earlier
/// requests decode — the traffic shape where unchunked prefill stalls
/// decodes for whole steps and inflates inter-token latency. Returns
/// (tokens/s, TTFT p50 in engine steps, chunks, mixed steps, device
/// calls, sorted token streams for the bit-identity check).
fn run_chunked(
    m: &sqplus::runtime::manifest::Manifest, s: &common::Setup,
    deploy_store: &sqplus::model::store::WeightStore, chunked: bool,
    cap: usize, compiled: bool, n_req: usize, prompt: usize,
    output: usize,
) -> (f64, f64, usize, usize, usize, Vec<Vec<u32>>) {
    let rt = ModelRuntime::load(m, &s.cfg.name, Precision::W4a16,
                                deploy_store)
        .unwrap();
    rt.warmup().unwrap();
    let dep = Deployment::single(rt, GpuProfile::a100_40g());
    let ecfg = EngineConfig {
        enable_chunked_prefill: chunked,
        max_prefill_chunk: cap,
        enable_compiled_chunks: compiled,
        ..Default::default()
    };
    let mut eng = Engine::new(dep, ecfg);
    let mut rng = sqplus::util::rng::Rng::new(23);
    let prompts: Vec<Vec<u32>> = (0..n_req)
        .map(|_| trace::prompt_tokens(&mut rng, prompt, s.cfg.vocab))
        .collect();
    let t0 = std::time::Instant::now();
    // staggered submission: half up front, half mid-flight so prefill
    // chunks and decodes contend inside the same steps
    for p in &prompts[..n_req / 2] {
        eng.submit(p.clone(), SamplingParams {
            max_new_tokens: output,
            ..Default::default()
        });
    }
    for _ in 0..3 {
        let _ = eng.step();
    }
    for p in &prompts[n_req / 2..] {
        eng.submit(p.clone(), SamplingParams {
            max_new_tokens: output,
            ..Default::default()
        });
    }
    eng.run_to_completion(200_000).unwrap();
    let tput = eng.metrics.output_tokens as f64
        / t0.elapsed().as_secs_f64();
    let rep = eng.metrics.report();
    let mut fin = eng.take_finished();
    fin.sort_by_key(|q| q.id);
    let streams = fin.into_iter().map(|q| q.output).collect();
    (tput, rep.ttft_steps.p50, rep.prefill_chunks, rep.mixed_steps,
     rep.device_calls, streams)
}

/// Tiered KV pool workload: shared-prefix waves separated by cold
/// bursts big enough to evict the warm prefix between waves, under a
/// deliberately small block budget. With `pool == 0` every wave
/// re-prefills the evicted prefix; with a pool, the evicted blocks
/// demote to the host tier and restore on the next wave. Returns
/// (tok/s, prefill tokens executed, demotions, restores,
/// recompute-avoided tokens, sorted token streams).
#[allow(clippy::too_many_arguments)]
fn run_kv_tier(
    m: &sqplus::runtime::manifest::Manifest, s: &common::Setup,
    deploy_store: &sqplus::model::store::WeightStore, mode: KvCacheMode,
    pool: usize, n_req: usize, prefix: usize, suffix: usize,
    output: usize,
) -> (f64, usize, usize, usize, usize, Vec<Vec<u32>>) {
    let rt = ModelRuntime::load(m, &s.cfg.name, Precision::W4a16,
                                deploy_store)
        .unwrap();
    rt.warmup().unwrap();
    let dep = Deployment::single(rt, GpuProfile::a100_40g());
    let ecfg = EngineConfig {
        block_size: 4,
        total_blocks: 24, // 96 slots: a cold burst evicts the prefix
        kv_cache_mode: mode,
        kv_pool_blocks: pool,
        ..Default::default()
    };
    let mut eng = Engine::new(dep, ecfg);
    let warm = trace::shared_prefix_prompts(11, n_req, prefix, suffix,
                                            s.cfg.vocab);
    let mut rng = sqplus::util::rng::Rng::new(41);
    let t0 = std::time::Instant::now();
    let mut fins = vec![];
    for wave in warm.chunks(2) {
        for p in wave {
            eng.submit(p.clone(), SamplingParams {
                max_new_tokens: output,
                ..Default::default()
            });
        }
        eng.run_to_completion(100_000).unwrap();
        fins.extend(eng.take_finished());
        // cold burst needing most of the block budget: demand-evicts
        // the warm prefix (demoting it when the pool is on)
        let cold = trace::prompt_tokens(&mut rng, 72, s.cfg.vocab);
        eng.submit(cold, SamplingParams {
            max_new_tokens: output,
            ..Default::default()
        });
        eng.run_to_completion(100_000).unwrap();
        fins.extend(eng.take_finished());
    }
    let tput = eng.metrics.output_tokens as f64
        / t0.elapsed().as_secs_f64();
    fins.sort_by_key(|q| q.id);
    let streams = fins.into_iter().map(|q| q.output).collect();
    (tput, eng.metrics.prefill_tokens_executed,
     eng.metrics.kv_demotions, eng.metrics.kv_restores,
     eng.metrics.recompute_avoided_tokens, streams)
}

/// Cross-replica KV migration workload: a donor engine warms a shared
/// prefix, then the same warm rehit is served three ways — on a cold
/// receiver that imported the donor's stashed blocks in wire form, on
/// the warm donor itself, and on a cold engine that recomputes.
/// Returns (migrated tok/s, recompute tok/s, blocks shipped, wire
/// bytes, receiver prefill executed, cold prefill executed, streams
/// [migrated, warm, cold]).
#[allow(clippy::type_complexity)]
fn run_migration(
    m: &sqplus::runtime::manifest::Manifest, s: &common::Setup,
    deploy_store: &sqplus::model::store::WeightStore, mode: KvCacheMode,
    prefix: usize, output: usize,
) -> (f64, f64, usize, usize, usize, usize, [Vec<u32>; 3]) {
    let mk = || {
        let rt = ModelRuntime::load(m, &s.cfg.name, Precision::W4a16,
                                    deploy_store)
            .unwrap();
        rt.warmup().unwrap();
        Engine::new(
            Deployment::single(rt, GpuProfile::a100_40g()),
            EngineConfig {
                block_size: 4,
                kv_cache_mode: mode,
                kv_pool_blocks: 16,
                ..Default::default()
            },
        )
    };
    let (mut donor, mut recv, mut cold) = (mk(), mk(), mk());
    let mut rng = sqplus::util::rng::Rng::new(61);
    let shared = trace::prompt_tokens(&mut rng, prefix, s.cfg.vocab);
    let mut donor_p = shared.clone();
    donor_p.extend(trace::prompt_tokens(&mut rng, 2, s.cfg.vocab));
    let mut rehit = shared.clone();
    rehit.extend(trace::prompt_tokens(&mut rng, 3, s.cfg.vocab));
    let gen = |eng: &mut Engine, p: &[u32]| {
        eng.submit(p.to_vec(), SamplingParams {
            max_new_tokens: output,
            ..Default::default()
        });
        let t0 = std::time::Instant::now();
        eng.run_to_completion(100_000).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        let mut fin = eng.take_finished();
        (fin.pop().unwrap().output, dt)
    };
    let _ = gen(&mut donor, &donor_p);
    let blocks = donor.export_kv_blocks(&rehit);
    let shipped = blocks.len();
    let wire_bytes: usize = blocks.iter().map(|(_, w)| w.len()).sum();
    recv.import_kv_blocks(&blocks).unwrap();
    let (mig_out, mig_dt) = gen(&mut recv, &rehit);
    let (warm_out, _) = gen(&mut donor, &rehit);
    let (cold_out, cold_dt) = gen(&mut cold, &rehit);
    (mig_out.len() as f64 / mig_dt, cold_out.len() as f64 / cold_dt,
     shipped, wire_bytes, recv.metrics.prefill_tokens_executed,
     cold.metrics.prefill_tokens_executed, [mig_out, warm_out, cold_out])
}

/// Multi-replica router workload: shared-prefix waves (the cache-aware
/// policy's home turf) mixed with cold traffic, over `n_replicas`
/// engines. Returns (tok/s, TTFT-in-steps p50 across all replicas,
/// per-replica (routed, cold prefill tokens executed, cached prefix
/// tokens), sorted token streams for the bit-identity check).
#[allow(clippy::type_complexity)]
fn run_router(
    m: &sqplus::runtime::manifest::Manifest, s: &common::Setup,
    deploy_store: &sqplus::model::store::WeightStore,
    n_replicas: usize, routing: RoutingPolicy, n_req: usize,
    prefix: usize, suffix: usize, output: usize,
) -> (f64, f64, Vec<(usize, usize, usize)>, Vec<Vec<u32>>) {
    let cores: Vec<Engine> = (0..n_replicas)
        .map(|_| {
            let rt = ModelRuntime::load(m, &s.cfg.name, Precision::W4a16,
                                        deploy_store)
                .unwrap();
            rt.warmup().unwrap();
            Engine::new(
                Deployment::single(rt, GpuProfile::a100_40g()),
                EngineConfig::default(),
            )
        })
        .collect();
    let mut router = Router::new(cores, RouterConfig {
        routing,
        watermarks: CacheWatermarks::new(64, 32),
        // affinity dominates until a replica's backlog outweighs the
        // shared prefix (the default 16-token penalty would spill a
        // 1-block hit after a single queued request)
        load_penalty_tokens: 1,
        ..Default::default()
    });
    // a donor request registers the shared prefix on one replica (and
    // shifts round-robin parity so RR genuinely sprays warm traffic),
    // then waves of warm (shared prefix + suffix) followed by cold
    // (unique) prompts
    let warm = trace::shared_prefix_prompts(11, n_req, prefix, suffix,
                                            s.cfg.vocab);
    let mut rng = sqplus::util::rng::Rng::new(31);
    let t0 = std::time::Instant::now();
    let mut fins = vec![];
    router.submit(warm[0].clone(), SamplingParams {
        max_new_tokens: output,
        ..Default::default()
    });
    router.run_to_completion(100_000).unwrap();
    fins.extend(router.take_finished());
    for wave in warm[1..].chunks(4) {
        for p in wave {
            router.submit(p.clone(), SamplingParams {
                max_new_tokens: output,
                ..Default::default()
            });
        }
        for _ in wave {
            let cold = trace::prompt_tokens(&mut rng, prefix + suffix,
                                            s.cfg.vocab);
            router.submit(cold, SamplingParams {
                max_new_tokens: output,
                ..Default::default()
            });
        }
        router.run_to_completion(100_000).unwrap();
        fins.extend(router.take_finished());
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let out_tokens: usize =
        fins.iter().map(|f| f.seq.output.len()).sum();
    let mut ttft = sqplus::util::stats::Accum::new();
    for r in router.replicas() {
        ttft.extend(r.core().metrics.ttft_steps.samples());
    }
    let per_replica: Vec<(usize, usize, usize)> = router
        .replicas()
        .iter()
        .map(|r| {
            (r.requests_routed,
             r.core().metrics.prefill_tokens_executed,
             r.core().metrics.cached_prefix_tokens)
        })
        .collect();
    fins.sort_by_key(|f| f.id);
    let streams: Vec<Vec<u32>> =
        fins.into_iter().map(|f| f.seq.output).collect();
    (out_tokens as f64 / elapsed, ttft.summary().p50, per_replica,
     streams)
}

fn main() {
    let Some(man) = common::manifest() else { return };
    let size = common::bench_sizes().first().cloned()
        .unwrap_or_else(|| "tiny".into());
    let s = common::setup(&size);
    let n_req = 12;

    // quantized + fp16 deploy stores
    let sqp = common::quantize(&s, QuantMethod::SmoothQuantPlus);
    let fp16 = pipeline::fp16_deploy(&s.cfg, &s.weights);

    let mut t = Table::new(
        &format!("Figure 7a measured ({size}, CPU PJRT, {n_req} reqs): \
                  output tokens/s"),
        &["prompt+output", "FP16 x1 (measured)",
          "FP16 x2 (meas + simulated comm)", "SQ+ W4A16 x1 (measured)",
          "SQ+/FP16x2"],
    );
    for (prompt, output) in [(8usize, 8usize), (16, 16), (32, 24),
                             (64, 32)] {
        let fp1 = run_measured(&man, &s, Precision::Fp16, &fp16, 1,
                               prompt, output, n_req);
        let fp2 = run_measured(&man, &s, Precision::Fp16, &fp16, 2,
                               prompt, output, n_req);
        let q4 = run_measured(&man, &s, Precision::W4a16,
                              sqp.deploy.as_ref().unwrap(), 1, prompt,
                              output, n_req);
        t.row(&[
            format!("{prompt}+{output}"),
            format!("{fp1:.1}"),
            format!("{fp2:.1}"),
            format!("{q4:.1}"),
            format!("{:.2}x", q4 / fp2),
        ]);
    }
    t.print();

    // shared-prefix serving mode: the multi-user traffic shape (system
    // prompts / few-shot templates) where prefix caching pays off
    let (n_req2, prefix, suffix, output) = (16usize, 24usize, 8, 16);
    let (tput_cold, exec_cold, hit_cold) = run_shared_prefix(
        &man, &s, sqp.deploy.as_ref().unwrap(), false, n_req2, prefix,
        suffix, output,
    );
    let (tput_warm, exec_warm, hit_warm) = run_shared_prefix(
        &man, &s, sqp.deploy.as_ref().unwrap(), true, n_req2, prefix,
        suffix, output,
    );
    let mut t3 = Table::new(
        &format!(
            "Figure 7a shared-prefix serving ({size}, SQ+ W4A16, \
             {n_req2} reqs, prompt {prefix}+{suffix})"
        ),
        &["prefix cache", "prefill tokens executed", "cached tokens",
          "output tok/s"],
    );
    t3.row(&["off".into(), exec_cold.to_string(), hit_cold.to_string(),
             format!("{tput_cold:.1}")]);
    t3.row(&["on".into(), exec_warm.to_string(), hit_warm.to_string(),
             format!("{tput_warm:.1}")]);
    t3.print();
    assert!(hit_cold == 0 && exec_warm < exec_cold,
            "prefix cache saved no prefill work");
    let mut rep = JsonReport::at("BENCH_serve.json",
                                 "fig7a_shared_prefix");
    rep.metric("n_requests", n_req2 as f64);
    rep.metric("prompt_prefix_tokens", prefix as f64);
    rep.metric("prompt_suffix_tokens", suffix as f64);
    rep.metric("prefill_tokens_executed_cold", exec_cold as f64);
    rep.metric("prefill_tokens_executed_cached", exec_warm as f64);
    rep.metric("cached_prefix_tokens", hit_warm as f64);
    rep.metric("prefill_tokens_saved_frac",
               1.0 - exec_warm as f64 / exec_cold.max(1) as f64);
    rep.metric("output_tok_per_s_cold", tput_cold);
    rep.metric("output_tok_per_s_cached", tput_warm);
    rep.metric("tput_speedup", tput_warm / tput_cold.max(1e-9));
    if let Err(e) = rep.write() {
        eprintln!("warning: BENCH_serve.json not written: {e}");
    }

    // chunked-prefill serving mode: long prompts + staggered arrivals;
    // the same trace must stream identically for every chunking, while
    // chunked runs interleave decodes with prefill chunks. The
    // per-token rows re-run the same caps with the compiled chunk
    // executable disabled — the calls-per-chunk column is the PR 4
    // headline (a T-token chunk: 1 device call vs T).
    let (n_req3, prompt3, output3) = (10usize, 48usize, 16usize);
    let mut t4 = Table::new(
        &format!(
            "Figure 7a chunked prefill ({size}, SQ+ W4A16, {n_req3} \
             reqs, prompt {prompt3}, output {output3})"
        ),
        &["mode", "output tok/s", "ttft p50 (steps)", "chunks",
          "mixed steps", "device calls", "calls/chunk"],
    );
    let mut golden: Option<Vec<Vec<u32>>> = None;
    let mut chunk_rows = vec![];
    for (label, chunked, cap, compiled) in [
        ("unchunked (legacy)", false, 0usize, true),
        ("chunked ∞", true, 0, true),
        ("chunked 32", true, 32, true),
        ("chunked 17", true, 17, true),
        ("chunked 32 per-token", true, 32, false),
        ("chunked 17 per-token", true, 17, false),
    ] {
        let (tput, ttft_steps, chunks, mixed, calls, streams) =
            run_chunked(
                &man, &s, sqp.deploy.as_ref().unwrap(), chunked, cap,
                compiled, n_req3, prompt3, output3,
            );
        match &golden {
            None => golden = Some(streams),
            Some(g) => assert_eq!(
                g, &streams,
                "token streams changed under chunking mode {label}"
            ),
        }
        let per_chunk = calls as f64 / chunks.max(1) as f64;
        t4.row(&[label.into(), format!("{tput:.1}"),
                 format!("{ttft_steps:.1}"), chunks.to_string(),
                 mixed.to_string(), calls.to_string(),
                 format!("{per_chunk:.2}")]);
        chunk_rows.push((label, tput, ttft_steps, chunks, mixed, calls));
    }
    t4.print();
    // old vs new: at equal caps the compiled chunk path must issue
    // strictly fewer device calls than the per-token fallback — unless
    // the artifact set predates the chunk executables, in which case
    // the compiled rows silently ran the same fallback (the documented
    // graceful degradation) and the comparison is vacuous
    let has_chunk_arts = man
        .artifacts(&s.cfg.name, Precision::W4a16)
        .map(|arts| arts.iter().any(|a| a.phase == "chunk"))
        .unwrap_or(false);
    if has_chunk_arts {
        let calls_of = |want: &str| {
            chunk_rows.iter().find(|r| r.0 == want).map(|r| r.5).unwrap()
        };
        for cap in ["32", "17"] {
            let compiled = calls_of(&format!("chunked {cap}"));
            let per_token = calls_of(&format!("chunked {cap} per-token"));
            assert!(compiled < per_token,
                    "cap {cap}: compiled {compiled} !< per-token \
                     {per_token}");
        }
    } else {
        eprintln!("note: pre-chunk artifacts — compiled rows ran the \
                   per-token fallback (rebuild with `make artifacts`)");
    }
    let mut rep2 = JsonReport::at("BENCH_serve.json",
                                  "fig7a_chunked_prefill");
    rep2.metric("n_requests", n_req3 as f64);
    rep2.metric("prompt_tokens", prompt3 as f64);
    rep2.metric("output_tokens", output3 as f64);
    for (label, tput, ttft_steps, chunks, mixed, calls) in chunk_rows {
        let key: String = label
            .chars()
            .map(|c| if c.is_alphanumeric() { c } else { '_' })
            .collect();
        rep2.metric(&format!("{key}_tok_per_s"), tput);
        rep2.metric(&format!("{key}_ttft_p50_steps"), ttft_steps);
        rep2.metric(&format!("{key}_chunks"), chunks as f64);
        rep2.metric(&format!("{key}_mixed_steps"), mixed as f64);
        rep2.metric(&format!("{key}_device_calls"), calls as f64);
        rep2.metric(&format!("{key}_calls_per_chunk"),
                    calls as f64 / chunks.max(1) as f64);
    }
    if let Err(e) = rep2.write() {
        eprintln!("warning: BENCH_serve.json not written: {e}");
    }

    // multi-replica router serving mode: N data-parallel W4A16 engines
    // behind the front-end router, shared-prefix + cold traffic, one
    // row per routing policy. Streams must be bit-identical across
    // policies (routing never changes generations) and the cache-aware
    // policy must execute fewer cold prefill tokens than round-robin.
    let (n_rep, n_req4, prefix4, suffix4, output4) =
        (2usize, 16usize, 24usize, 8usize, 12usize);
    let mut t5 = Table::new(
        &format!(
            "Figure 7a router serving ({size}, SQ+ W4A16, {n_rep} \
             replicas, {n_req4} warm (incl. donor) + {} cold reqs, \
             prompt {prefix4}+{suffix4})",
            n_req4 - 1
        ),
        &["routing", "output tok/s", "ttft p50 (steps)",
          "routed/replica", "prefill executed/replica",
          "cached tokens/replica"],
    );
    let mut rep3 = JsonReport::at("BENCH_serve.json", "fig7a_router");
    rep3.metric("n_replicas", n_rep as f64);
    rep3.metric("n_requests_warm", n_req4 as f64);
    rep3.metric("n_requests_cold", (n_req4 - 1) as f64);
    rep3.metric("prompt_prefix_tokens", prefix4 as f64);
    rep3.metric("prompt_suffix_tokens", suffix4 as f64);
    let mut router_golden: Option<Vec<Vec<u32>>> = None;
    let mut exec_by_policy = vec![];
    for routing in [RoutingPolicy::CacheAware, RoutingPolicy::LeastLoaded,
                    RoutingPolicy::RoundRobin] {
        let (tput, ttft_steps, per_replica, streams) = run_router(
            &man, &s, sqp.deploy.as_ref().unwrap(), n_rep, routing,
            n_req4, prefix4, suffix4, output4,
        );
        match &router_golden {
            None => router_golden = Some(streams),
            Some(g) => assert_eq!(
                g, &streams,
                "token streams changed under {} routing",
                routing.as_str()
            ),
        }
        let fmt_col = |f: fn(&(usize, usize, usize)) -> usize| {
            per_replica.iter().map(|r| f(r).to_string())
                .collect::<Vec<_>>().join("/")
        };
        t5.row(&[routing.as_str().into(), format!("{tput:.1}"),
                 format!("{ttft_steps:.1}"),
                 fmt_col(|r| r.0), fmt_col(|r| r.1), fmt_col(|r| r.2)]);
        let key = routing.as_str().replace('-', "_");
        rep3.metric(&format!("{key}_tok_per_s"), tput);
        rep3.metric(&format!("{key}_ttft_p50_steps"), ttft_steps);
        let executed: usize = per_replica.iter().map(|r| r.1).sum();
        let cached: usize = per_replica.iter().map(|r| r.2).sum();
        rep3.metric(&format!("{key}_prefill_tokens_executed"),
                    executed as f64);
        rep3.metric(&format!("{key}_cached_prefix_tokens"),
                    cached as f64);
        for (i, (routed, exec, hit)) in per_replica.iter().enumerate() {
            rep3.metric(&format!("{key}_replica{i}_routed"),
                        *routed as f64);
            rep3.metric(&format!("{key}_replica{i}_prefill_executed"),
                        *exec as f64);
            rep3.metric(&format!("{key}_replica{i}_cached_tokens"),
                        *hit as f64);
        }
        exec_by_policy.push((routing, executed));
    }
    t5.print();
    let exec_of = |want: RoutingPolicy| {
        exec_by_policy.iter().find(|(p, _)| *p == want).unwrap().1
    };
    assert!(
        exec_of(RoutingPolicy::CacheAware)
            < exec_of(RoutingPolicy::RoundRobin),
        "cache-aware routing saved no cold prefill work"
    );
    if let Err(e) = rep3.write() {
        eprintln!("warning: BENCH_serve.json not written: {e}");
    }

    // tiered KV cache serving mode: shared-prefix waves with eviction
    // pressure between them. Tiering off vs on (f32: restores must be
    // bit-identical AND save prefill work), then the quantized stash
    // modes on the same trace (reported with token agreement vs f32).
    let (n_req5, prefix5, suffix5, output5) =
        (12usize, 24usize, 8usize, 12usize);
    let pool5 = 12usize;
    let mut t6 = Table::new(
        &format!(
            "Figure 7a tiered KV cache ({size}, SQ+ W4A16, {n_req5} warm \
             + cold-burst reqs, prompt {prefix5}+{suffix5}, pool \
             {pool5} blocks)"
        ),
        &["kv mode", "output tok/s", "prefill executed", "demotions",
          "restores", "recompute avoided", "agree vs f32"],
    );
    let mut rep4 = JsonReport::at("BENCH_serve.json", "fig7a_kv_tier");
    rep4.metric("n_requests_warm", n_req5 as f64);
    rep4.metric("prompt_prefix_tokens", prefix5 as f64);
    rep4.metric("prompt_suffix_tokens", suffix5 as f64);
    rep4.metric("pool_blocks_bound", pool5 as f64);
    let mut tier_golden: Option<Vec<Vec<u32>>> = None;
    let mut tier_exec = vec![];
    for (label, mode, pool) in [
        ("f32 untiered", KvCacheMode::F32, 0usize),
        ("f32 tiered", KvCacheMode::F32, pool5),
        ("q8 tiered", KvCacheMode::Q8, pool5),
        ("q4 tiered", KvCacheMode::Q4, pool5),
    ] {
        let (tput, exec, demotions, restores, avoided, streams) =
            run_kv_tier(&man, &s, sqp.deploy.as_ref().unwrap(), mode,
                        pool, n_req5, prefix5, suffix5, output5);
        let agree = match &tier_golden {
            None => {
                tier_golden = Some(streams.clone());
                1.0
            }
            Some(g) => {
                let total: usize = g.iter().map(|o| o.len()).sum();
                let same: usize = g.iter().zip(&streams)
                    .map(|(a, b)| {
                        a.iter().zip(b.iter())
                            .filter(|(x, y)| x == y).count()
                    })
                    .sum();
                same as f64 / total.max(1) as f64
            }
        };
        if mode == KvCacheMode::F32 {
            assert!((agree - 1.0).abs() < 1e-12,
                    "f32 tiered restore changed a stream");
        }
        if pool > 0 {
            assert!(restores > 0 && avoided == restores * 4,
                    "{label}: pool never restored or accounting broke");
        } else {
            assert_eq!((demotions, restores, avoided), (0, 0, 0));
        }
        t6.row(&[label.into(), format!("{tput:.1}"), exec.to_string(),
                 demotions.to_string(), restores.to_string(),
                 avoided.to_string(), format!("{agree:.3}")]);
        let key = label.replace(' ', "_");
        rep4.metric(&format!("{key}_tok_per_s"), tput);
        rep4.metric(&format!("{key}_prefill_tokens_executed"),
                    exec as f64);
        rep4.metric(&format!("{key}_pool_demotions"), demotions as f64);
        rep4.metric(&format!("{key}_pool_restores"), restores as f64);
        rep4.metric(&format!("{key}_recompute_avoided_tokens"),
                    avoided as f64);
        rep4.metric(&format!("{key}_token_agreement_vs_f32"), agree);
        tier_exec.push((label, exec));
    }
    t6.print();
    let exec_tier = |want: &str| {
        tier_exec.iter().find(|(l, _)| *l == want).unwrap().1
    };
    assert!(exec_tier("f32 tiered") < exec_tier("f32 untiered"),
            "tiered pool saved no prefill work");
    rep4.metric("prefill_tokens_saved_frac",
                1.0 - exec_tier("f32 tiered") as f64
                    / exec_tier("f32 untiered").max(1) as f64);
    if let Err(e) = rep4.write() {
        eprintln!("warning: BENCH_serve.json not written: {e}");
    }

    // cross-replica KV migration: ship the donor's stashed prefix
    // blocks to a cold replica in wire form instead of recomputing
    // them. Migrated serving must match the warm donor bit-for-bit in
    // every stash mode (both sides dequantize the same bytes); f32
    // additionally matches cold recompute exactly.
    let (prefix6, output6) = (32usize, 12usize);
    let mut t7 = Table::new(
        &format!(
            "Figure 7a KV migration ({size}, SQ+ W4A16, prefix \
             {prefix6}, output {output6})"
        ),
        &["kv mode", "migrated tok/s", "recompute tok/s",
          "blocks shipped", "wire bytes", "prefill migrated/cold",
          "matches warm"],
    );
    let mut rep5 = JsonReport::at("BENCH_serve.json", "fig7a_migration");
    rep5.metric("prompt_prefix_tokens", prefix6 as f64);
    rep5.metric("output_tokens", output6 as f64);
    let mut wire_bpt = vec![];
    for (label, mode) in [("f32", KvCacheMode::F32),
                          ("q8", KvCacheMode::Q8),
                          ("q4", KvCacheMode::Q4)] {
        let (mig_tps, cold_tps, shipped, wire_bytes, mig_exec,
             cold_exec, [mig, warm, cold_stream]) =
            run_migration(&man, &s, sqp.deploy.as_ref().unwrap(), mode,
                          prefix6, output6);
        assert_eq!(mig, warm,
                   "{label}: migrated stream diverged from the warm \
                    donor");
        if mode == KvCacheMode::F32 {
            assert_eq!(mig, cold_stream,
                       "f32 migration is not recompute-identical");
        }
        assert!(mig_exec < cold_exec,
                "{label}: migration saved no prefill work");
        assert!(shipped > 0 && wire_bytes > 0);
        t7.row(&[label.into(), format!("{mig_tps:.1}"),
                 format!("{cold_tps:.1}"), shipped.to_string(),
                 wire_bytes.to_string(),
                 format!("{mig_exec}/{cold_exec}"), "yes".into()]);
        rep5.metric(&format!("{label}_migrated_tok_per_s"), mig_tps);
        rep5.metric(&format!("{label}_recompute_tok_per_s"), cold_tps);
        rep5.metric(&format!("{label}_blocks_shipped"), shipped as f64);
        rep5.metric(&format!("{label}_wire_bytes"), wire_bytes as f64);
        rep5.metric(&format!("{label}_prefill_tokens_migrated"),
                    mig_exec as f64);
        rep5.metric(&format!("{label}_prefill_tokens_recompute"),
                    cold_exec as f64);
        wire_bpt.push((label, wire_bytes as f64
                           / (shipped * 4).max(1) as f64));
    }
    t7.print();
    // router-level: the same warm-rehit shape through an N=2
    // cache-aware router with migration on — a donor warms replica 0,
    // a cold blocker loads it, and the rehit spills to replica 1 with
    // the prefix shipped instead of recomputed. Happy path: the
    // counters flow end-to-end and no fallback fires.
    let mk_eng = || {
        let rt = ModelRuntime::load(&man, &s.cfg.name, Precision::W4a16,
                                    sqp.deploy.as_ref().unwrap())
            .unwrap();
        rt.warmup().unwrap();
        Engine::new(
            Deployment::single(rt, GpuProfile::a100_40g()),
            EngineConfig { block_size: 4, kv_pool_blocks: 16,
                           ..Default::default() },
        )
    };
    let mut router = Router::new(vec![mk_eng(), mk_eng()], RouterConfig {
        routing: RoutingPolicy::CacheAware,
        // the blocker's backlog must outweigh the 32-token prefix so
        // the rehit spills off the warm replica
        load_penalty_tokens: 33,
        kv_migrate: true,
        ..Default::default()
    });
    let mut rng6 = sqplus::util::rng::Rng::new(67);
    let shared6 = trace::prompt_tokens(&mut rng6, prefix6, s.cfg.vocab);
    let mut donor6 = shared6.clone();
    donor6.extend(trace::prompt_tokens(&mut rng6, 2, s.cfg.vocab));
    let mut rehit6 = shared6;
    rehit6.extend(trace::prompt_tokens(&mut rng6, 3, s.cfg.vocab));
    let sp6 = |max: usize| SamplingParams { max_new_tokens: max,
                                            ..Default::default() };
    router.submit(donor6, sp6(2));
    router.run_to_completion(100_000).unwrap();
    router.submit(trace::prompt_tokens(&mut rng6, 20, s.cfg.vocab),
                  sp6(8));
    router.submit(rehit6, sp6(output6));
    router.run_to_completion(100_000).unwrap();
    let rows = router.stats();
    let rs = router.router_stats();
    let migrated_in: usize =
        rows.iter().map(|r| r.core.kv_migrations_in).sum();
    assert!(migrated_in > 0, "router migration never fired");
    assert_eq!(rs.migration_fallbacks, 0,
               "happy-path migration fell back");
    rep5.metric("router_kv_migrations_in", migrated_in as f64);
    rep5.metric("router_migration_fallbacks",
                rs.migration_fallbacks as f64);
    // analytic: the measured wire footprints scaled to Code
    // Llama-34B on A100 — shipping the prefix must beat the
    // recompute bandwidth floor, with the quantized stash widening
    // the margin
    let gpu_a = GpuProfile::a100_40g();
    let m34_a = PaperModel::code_llama_34b();
    let tiny_kv_bpt = s.cfg.kv_bytes_per_token() as f64;
    let recompute_s =
        perfmodel::recompute_prefix_s(&gpu_a, &m34_a,
                                      Deploy::W4a16OneGpu);
    rep5.metric("analytic_recompute_prefix_s", recompute_s);
    for (label, bpt) in &wire_bpt {
        let scaled = m34_a.kv_bytes_per_token * bpt / tiny_kv_bpt;
        let mig_s = perfmodel::migrate_prefix_s(&gpu_a, 1024, scaled);
        rep5.metric(&format!("{label}_analytic_migrate_1k_prefix_s"),
                    mig_s);
        assert!(mig_s < recompute_s,
                "{label}: analytic migration slower than recompute");
    }
    if let Err(e) = rep5.write() {
        eprintln!("warning: BENCH_serve.json not written: {e}");
    }

    // analytic A100 curves at paper scale
    let gpu = GpuProfile::a100_40g();
    let m34 = PaperModel::code_llama_34b();
    let mut t2 = Table::new(
        "Figure 7a analytic (A100, Code Llama-34B): max-batch decode \
         tokens/s vs context",
        &["context", "FP16 x2 A100", "AWQ x1 A100", "SQ+ W4A16 x1 A100",
          "SQ+/FP16x2"],
    );
    for ctx in [512usize, 1024, 2048, 4096, 8192] {
        let fp = perfmodel::estimate(&gpu, &m34, Deploy::Fp16TwoGpu, ctx);
        let awq = perfmodel::estimate(&gpu, &m34, Deploy::AwqOneGpu, ctx);
        let q4 = perfmodel::estimate(&gpu, &m34, Deploy::W4a16OneGpu, ctx);
        t2.row(&[
            ctx.to_string(),
            format!("{:.0} (b={})", fp.tokens_per_s, fp.max_batch),
            format!("{:.0} (b={})", awq.tokens_per_s, awq.max_batch),
            format!("{:.0} (b={})", q4.tokens_per_s, q4.max_batch),
            format!("{:.2}x", q4.tokens_per_s / fp.tokens_per_s),
        ]);
    }
    t2.print();
    println!(
        "\npaper Fig 7a: SQ+ on one A100 reaches 1.9-4.0x the throughput \
         of FP16 on two A100s; AWQ on one GPU loses to FP16 on two."
    );
}
