//! Regenerates **Figure 7(a)**: decode throughput vs context length for
//! the three deployments — FP16 on 2 GPUs (tensor parallel), AWQ on 1
//! GPU, SmoothQuant+/W4A16 on 1 GPU.
//!
//! Two complementary readouts (DESIGN.md §5):
//! 1. **measured** — the real engine on this CPU testbed under a Poisson
//!    trace: FP16 single-worker vs W4A16 single-worker (both fully
//!    measured), plus FP16 with the simulated 2-worker interconnect cost
//!    slept into the wall clock;
//! 2. **analytic A100** — the roofline model at Code Llama-34B scale,
//!    which reproduces the paper's 1.9-4.0x band.

#[path = "common/mod.rs"]
mod common;

use sqplus::config::{
    EngineConfig, GpuProfile, Precision, QuantMethod,
};
use sqplus::coordinator::engine::Engine;
use sqplus::coordinator::sequence::SamplingParams;
use sqplus::quant::pipeline;
use sqplus::runtime::executor::ModelRuntime;
use sqplus::runtime::perfmodel::{self, Deploy, PaperModel};
use sqplus::runtime::simtp::{CommMode, Deployment};
use sqplus::util::bench::Table;

fn run_measured(
    m: &sqplus::runtime::manifest::Manifest, s: &common::Setup,
    precision: Precision, deploy_store: &sqplus::model::store::WeightStore,
    workers: usize, prompt: usize, output: usize, n_req: usize,
) -> f64 {
    let rt = ModelRuntime::load(m, &s.cfg.name, precision, deploy_store)
        .unwrap();
    rt.warmup().unwrap(); // exclude XLA compile from the timed region
    let dep = if workers > 1 {
        Deployment::tensor_parallel(rt, GpuProfile::a100_40g(), workers,
                                    CommMode::Sleep)
    } else {
        Deployment::single(rt, GpuProfile::a100_40g())
    };
    let mut eng = Engine::new(dep, EngineConfig::default());
    let mut rng = sqplus::util::rng::Rng::new(5);
    let t0 = std::time::Instant::now();
    for _ in 0..n_req {
        let p = sqplus::data::trace::prompt_tokens(&mut rng, prompt,
                                                   s.cfg.vocab);
        eng.submit(p, SamplingParams { max_new_tokens: output,
                                       ..Default::default() });
    }
    eng.run_to_completion(100_000).unwrap();
    let out_tokens = eng.metrics.output_tokens;
    out_tokens as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let Some(man) = common::manifest() else { return };
    let size = common::bench_sizes().first().cloned()
        .unwrap_or_else(|| "tiny".into());
    let s = common::setup(&size);
    let n_req = 12;

    // quantized + fp16 deploy stores
    let sqp = common::quantize(&s, QuantMethod::SmoothQuantPlus);
    let fp16 = pipeline::fp16_deploy(&s.cfg, &s.weights);

    let mut t = Table::new(
        &format!("Figure 7a measured ({size}, CPU PJRT, {n_req} reqs): \
                  output tokens/s"),
        &["prompt+output", "FP16 x1 (measured)",
          "FP16 x2 (meas + simulated comm)", "SQ+ W4A16 x1 (measured)",
          "SQ+/FP16x2"],
    );
    for (prompt, output) in [(8usize, 8usize), (16, 16), (32, 24),
                             (64, 32)] {
        let fp1 = run_measured(&man, &s, Precision::Fp16, &fp16, 1,
                               prompt, output, n_req);
        let fp2 = run_measured(&man, &s, Precision::Fp16, &fp16, 2,
                               prompt, output, n_req);
        let q4 = run_measured(&man, &s, Precision::W4a16,
                              sqp.deploy.as_ref().unwrap(), 1, prompt,
                              output, n_req);
        t.row(&[
            format!("{prompt}+{output}"),
            format!("{fp1:.1}"),
            format!("{fp2:.1}"),
            format!("{q4:.1}"),
            format!("{:.2}x", q4 / fp2),
        ]);
    }
    t.print();

    // analytic A100 curves at paper scale
    let gpu = GpuProfile::a100_40g();
    let m34 = PaperModel::code_llama_34b();
    let mut t2 = Table::new(
        "Figure 7a analytic (A100, Code Llama-34B): max-batch decode \
         tokens/s vs context",
        &["context", "FP16 x2 A100", "AWQ x1 A100", "SQ+ W4A16 x1 A100",
          "SQ+/FP16x2"],
    );
    for ctx in [512usize, 1024, 2048, 4096, 8192] {
        let fp = perfmodel::estimate(&gpu, &m34, Deploy::Fp16TwoGpu, ctx);
        let awq = perfmodel::estimate(&gpu, &m34, Deploy::AwqOneGpu, ctx);
        let q4 = perfmodel::estimate(&gpu, &m34, Deploy::W4a16OneGpu, ctx);
        t2.row(&[
            ctx.to_string(),
            format!("{:.0} (b={})", fp.tokens_per_s, fp.max_batch),
            format!("{:.0} (b={})", awq.tokens_per_s, awq.max_batch),
            format!("{:.0} (b={})", q4.tokens_per_s, q4.max_batch),
            format!("{:.2}x", q4.tokens_per_s / fp.tokens_per_s),
        ]);
    }
    t2.print();
    println!(
        "\npaper Fig 7a: SQ+ on one A100 reaches 1.9-4.0x the throughput \
         of FP16 on two A100s; AWQ on one GPU loses to FP16 on two."
    );
}
