//! Regenerates **Figure 3**: per-decoder-layer quantization loss,
//! un-smoothed (RTN) vs smoothed (SmoothQuant+) — smoothing flattens the
//! loss peaks.

#[path = "common/mod.rs"]
mod common;

use sqplus::config::QuantMethod;
use sqplus::util::bench::Table;

fn main() {
    for size in common::bench_sizes() {
        let s = common::setup(&size);
        let rtn = common::quantize(&s, QuantMethod::Rtn);
        let sqp = common::quantize(&s, QuantMethod::SmoothQuantPlus);
        let mut t = Table::new(
            &format!("Figure 3 (data): per-layer quant loss ({size}, \
                      alpha={:.2})", sqp.alpha.unwrap()),
            &["decoder layer", "RTN (unsmoothed)", "SmoothQuant+",
              "reduction"],
        );
        for l in 0..s.cfg.layers {
            let a = rtn.loss.per_layer[l];
            let b = sqp.loss.per_layer[l];
            t.row(&[
                l.to_string(),
                format!("{a:.5}"),
                format!("{b:.5}"),
                format!("{:.1}x", a / b.max(1e-12)),
            ]);
        }
        t.row(&["TOTAL".into(),
                format!("{:.5}", rtn.loss.total),
                format!("{:.5}", sqp.loss.total),
                format!("{:.1}x",
                        rtn.loss.total / sqp.loss.total.max(1e-12))]);
        t.print();
    }
    println!(
        "\npaper Fig 3: smoothing flattens per-layer loss peaks and \
         reduces total loss substantially; same shape expected here \
         (reduction > 1x on every outlier-carrying layer)."
    );
}
