//! Regenerates **Table 5**: the SmoothQuant / AWQ / SmoothQuant+ summary
//! (weight bits, activation bits, accuracy ✓, efficiency ✓). Accuracy
//! derives from the Table-1 proxy on this testbed; efficiency from the
//! analytic A100 model (paper scale) — a 1-GPU quantized deployment must
//! beat the 2-GPU FP16 deployment on throughput AND latency.

#[path = "common/mod.rs"]
mod common;

use sqplus::config::{GpuProfile, QuantMethod};
use sqplus::eval::evaluate;
use sqplus::runtime::perfmodel::{self, Deploy, PaperModel};
use sqplus::util::bench::Table;

fn main() {
    let size = common::bench_sizes().last().cloned()
        .unwrap_or_else(|| "small".into());
    let s = common::setup(&size);
    eprintln!("== accuracy proxies on {size} ==");
    let acc = |m: QuantMethod| {
        let out = common::quantize(&s, m);
        let r = evaluate(&s.cfg, &s.weights, &out.effective,
                         &s.eval_prompts, 8);
        r.token_agreement
    };
    let a_awq = acc(QuantMethod::Awq);
    let a_sqp = acc(QuantMethod::SmoothQuantPlus);
    // "lossless" proxy: within 2 points of the best quantized agreement
    // (SmoothQuant itself is W8A8 ≈ lossless by construction here).
    let ok = |a: f64| a + 0.02 >= a_sqp;

    // efficiency from the analytic A100 model at paper scale
    let gpu = GpuProfile::a100_40g();
    let m34 = PaperModel::code_llama_34b();
    let fp = perfmodel::estimate(&gpu, &m34, Deploy::Fp16TwoGpu, 1024);
    let awq = perfmodel::estimate(&gpu, &m34, Deploy::AwqOneGpu, 1024);
    let sqp = perfmodel::estimate(&gpu, &m34, Deploy::W4a16OneGpu, 1024);
    let eff_awq = awq.tokens_per_s > fp.tokens_per_s;
    let eff_sqp = sqp.tokens_per_s > fp.tokens_per_s;

    let mut t = Table::new(
        "Table 5: method summary (accuracy = proxy on this testbed, \
         efficiency = analytic A100 model @ ctx 1024)",
        &["method", "W bits", "A bits", "accuracy", "efficiency"],
    );
    t.row(&["SmoothQuant".into(), "8".into(), "8".into(), "yes".into(),
            "= (needs 2 GPUs at 34B fp16-sized)".into()]);
    t.row(&["AWQ".into(), "4".into(), "16".into(),
            if ok(a_awq) { "yes" } else { "no" }.into(),
            if eff_awq { "yes" } else { "no" }.into()]);
    t.row(&["SmoothQuant+".into(), "4".into(), "16".into(),
            "yes".into(),
            if eff_sqp { "yes" } else { "no" }.into()]);
    t.print();
    println!(
        "\nagreement: AWQ {:.1}% vs SQ+ {:.1}%; A100 model tokens/s: \
         FP16x2 {:.0}, AWQx1 {:.0}, SQ+x1 {:.0}",
        a_awq * 100.0, a_sqp * 100.0, fp.tokens_per_s, awq.tokens_per_s,
        sqp.tokens_per_s
    );
    println!(
        "paper (Table 5): SmoothQuant 8/8 ✓/=; AWQ 4/16 ✗/✗; \
         SmoothQuant+ 4/16 ✓/✓."
    );
}
