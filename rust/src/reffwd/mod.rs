//! Pure-Rust reference forward pass of the Llama-family model.
//!
//! Mirrors `python/compile/model.py` operation-for-operation (RMSNorm,
//! half-split RoPE, causal attention, SwiGLU) so it can cross-validate the
//! PJRT-executed HLO (`rust/tests/runtime_vs_reffwd.rs`). It is also the
//! workhorse for everything that needs activations on the host:
//! calibration statistics, quantization-loss evaluation, Fig 1/2/3, and
//! CPU-only accuracy evals.
//!
//! Quantized variants are evaluated two ways:
//!
//! * **fake-quant mode** — a canonical fp16-layout store whose linear
//!   weights have been fake-quantized (quantize→dequantize); dense f32
//!   matmuls throughout.
//! * **packed mode** — a w4a16-layout *deploy* store (each decoder linear
//!   present as `{name}.packed` / `.scales` / `.zeros`). Detected
//!   per-linear by name, and routed through the fused host W4A16 kernel
//!   ([`crate::quant::kernel::matmul_w4a16_parts`]) so the serving claim
//!   is exercised end-to-end on the host path without ever materializing
//!   the dequantized weights.

use crate::config::ModelConfig;
use crate::model::store::WeightStore;
use crate::quant::kernel;
use crate::tensor::Tensor;

/// Activation observation sites (the smoothing units of one decoder layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Site {
    /// Output of `attn_norm` = input of wq/wk/wv.
    AttnIn,
    /// Attention output = input of wo.
    OIn,
    /// Output of `mlp_norm` = input of w_gate/w_up.
    MlpIn,
    /// `silu(gate) * up` = input of w_down.
    DownIn,
}

impl Site {
    /// Every site, in layer-execution order.
    pub fn all() -> [Site; 4] {
        [Site::AttnIn, Site::OIn, Site::MlpIn, Site::DownIn]
    }
    /// The linears consuming this site's activation.
    pub fn consumers(&self) -> &'static [&'static str] {
        match self {
            Site::AttnIn => &["wq", "wk", "wv"],
            Site::OIn => &["wo"],
            Site::MlpIn => &["w_gate", "w_up"],
            Site::DownIn => &["w_down"],
        }
    }
    /// Stable snake_case name (used in calibration stats and figures).
    pub fn as_str(&self) -> &'static str {
        match self {
            Site::AttnIn => "attn_in",
            Site::OIn => "o_in",
            Site::MlpIn => "mlp_in",
            Site::DownIn => "down_in",
        }
    }
}

/// Observer for layer activations during a forward pass.
pub trait ActHook {
    /// `rows`: `[T, C]` activation rows entering `site` of `layer`.
    fn record(&mut self, layer: usize, site: Site, rows: &Tensor);
}

/// No-op hook.
pub struct NoHook;
impl ActHook for NoHook {
    fn record(&mut self, _: usize, _: Site, _: &Tensor) {}
}

/// Growable per-layer KV cache: `k[layer]`, `v[layer]` are `[len, D]`.
#[derive(Debug, Clone)]
pub struct KvCache {
    /// Per-layer key rows, flattened `[len * D]`.
    pub k: Vec<Vec<f32>>,
    /// Per-layer value rows, flattened `[len * D]`.
    pub v: Vec<Vec<f32>>,
    /// Number of cached positions.
    pub len: usize,
    dim: usize,
}

impl KvCache {
    /// Empty cache shaped for `cfg` (one k/v lane per layer).
    pub fn new(cfg: &ModelConfig) -> Self {
        KvCache {
            k: vec![vec![]; cfg.layers],
            v: vec![vec![]; cfg.layers],
            len: 0,
            dim: cfg.dim,
        }
    }
    fn push(&mut self, layer: usize, krow: &[f32], vrow: &[f32]) {
        self.k[layer].extend_from_slice(krow);
        self.v[layer].extend_from_slice(vrow);
    }
    /// All cached key rows of `layer`, flattened `[len * D]`.
    pub fn k_rows(&self, layer: usize) -> &[f32] {
        &self.k[layer]
    }
    /// Row width `D` (the model dim).
    pub fn dim(&self) -> usize {
        self.dim
    }
}

/// Reference model: a config plus a canonical fp16-layout weight store,
/// or a w4a16 deploy-layout store (packed mode — see module docs).
pub struct RefModel<'a> {
    /// Model geometry (layers, dim, heads, RoPE/eps constants).
    pub cfg: &'a ModelConfig,
    /// The weight store being evaluated.
    pub w: &'a WeightStore,
    /// Whether `w` is a deploy-layout store (decoder linears present as
    /// packed/scales/zeros triples). Detected once here so the dense
    /// fp16 path pays no per-matmul name probe.
    packed: bool,
}

impl<'a> RefModel<'a> {
    /// Wrap a store, probing it once for deploy (packed) layout.
    pub fn new(cfg: &'a ModelConfig, w: &'a WeightStore) -> Self {
        let packed = w.contains("layers.0.wq.packed");
        RefModel { cfg, w, packed }
    }

    /// One decoder linear `x @ W_name`: the dense f32 matmul, or — in
    /// packed mode — the fused W4A16 kernel on the packed triple.
    fn linear(&self, x: &Tensor, name: &str) -> Tensor {
        if self.packed {
            let packed = self.w.u8(&format!("{name}.packed"));
            let scales = self.w.f32(&format!("{name}.scales"));
            let zeros = self.w.f32(&format!("{name}.zeros"));
            let group = packed.shape[0] * 2 / scales.shape[0];
            kernel::matmul_w4a16_parts(x, packed, scales, zeros, group)
        } else {
            x.matmul(self.w.f32(name))
        }
    }

    /// Full-prompt forward. Returns per-position logits `[S, V]` and the
    /// populated KV cache.
    pub fn prefill<H: ActHook>(&self, tokens: &[u32], hook: &mut H)
        -> (Tensor, KvCache) {
        let cfg = self.cfg;
        let s = tokens.len();
        let d = cfg.dim;
        let mut cache = KvCache::new(cfg);
        let embed = self.w.f32("embed");
        let mut h = Tensor::zeros(&[s, d]);
        for (i, &t) in tokens.iter().enumerate() {
            h.row_mut(i).copy_from_slice(embed.row(t as usize));
        }
        for layer in 0..cfg.layers {
            let lp = format!("layers.{layer}.");
            // ---- attention
            let xn = self.rmsnorm(&h, &format!("{lp}attn_norm"));
            hook.record(layer, Site::AttnIn, &xn);
            let q = self.linear(&xn, &format!("{lp}wq"));
            let k = self.linear(&xn, &format!("{lp}wk"));
            let v = self.linear(&xn, &format!("{lp}wv"));
            let (q, k) = (self.rope_rows(q, 0), self.rope_rows(k, 0));
            for i in 0..s {
                cache.push(layer, k.row(i), v.row(i));
            }
            let attn = self.attention_causal(&q, &k, &v);
            hook.record(layer, Site::OIn, &attn);
            let o = self.linear(&attn, &format!("{lp}wo"));
            add_inplace(&mut h, &o);
            // ---- mlp
            let xm = self.rmsnorm(&h, &format!("{lp}mlp_norm"));
            hook.record(layer, Site::MlpIn, &xm);
            let gate = self.linear(&xm, &format!("{lp}w_gate"));
            let up = self.linear(&xm, &format!("{lp}w_up"));
            let a = swiglu(&gate, &up);
            hook.record(layer, Site::DownIn, &a);
            let down = self.linear(&a, &format!("{lp}w_down"));
            add_inplace(&mut h, &down);
        }
        cache.len = s;
        let hn = self.rmsnorm(&h, "final_norm");
        let logits = hn.matmul(self.w.f32("lm_head"));
        (logits, cache)
    }

    /// One decode step: append `token`, return next-token logits `[V]`.
    pub fn decode<H: ActHook>(&self, token: u32, cache: &mut KvCache,
                              hook: &mut H) -> Vec<f32> {
        let cfg = self.cfg;
        let d = cfg.dim;
        let pos = cache.len;
        let embed = self.w.f32("embed");
        let mut h = Tensor::from_vec(&[1, d],
                                     embed.row(token as usize).to_vec());
        for layer in 0..cfg.layers {
            let lp = format!("layers.{layer}.");
            let xn = self.rmsnorm(&h, &format!("{lp}attn_norm"));
            hook.record(layer, Site::AttnIn, &xn);
            let q = self.rope_rows(
                self.linear(&xn, &format!("{lp}wq")), pos);
            let k = self.rope_rows(
                self.linear(&xn, &format!("{lp}wk")), pos);
            let v = self.linear(&xn, &format!("{lp}wv"));
            cache.push(layer, k.row(0), v.row(0));
            let attn = self.attention_one(&q, cache, layer, pos + 1);
            hook.record(layer, Site::OIn, &attn);
            let o = self.linear(&attn, &format!("{lp}wo"));
            add_inplace(&mut h, &o);
            let xm = self.rmsnorm(&h, &format!("{lp}mlp_norm"));
            hook.record(layer, Site::MlpIn, &xm);
            let gate = self.linear(&xm, &format!("{lp}w_gate"));
            let up = self.linear(&xm, &format!("{lp}w_up"));
            let a = swiglu(&gate, &up);
            hook.record(layer, Site::DownIn, &a);
            let down = self.linear(&a, &format!("{lp}w_down"));
            add_inplace(&mut h, &down);
        }
        cache.len = pos + 1;
        let hn = self.rmsnorm(&h, "final_norm");
        hn.matmul(self.w.f32("lm_head")).data
    }

    // ------------------------------------------------------------ pieces

    fn rmsnorm(&self, x: &Tensor, gain_name: &str) -> Tensor {
        let gain = &self.w.f32(gain_name).data;
        let (m, n) = x.dims2();
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            let row = x.row(i);
            let ms: f64 = row.iter().map(|&v| (v as f64) * (v as f64)).sum();
            let r = 1.0 / ((ms / n as f64) + self.cfg.norm_eps as f64).sqrt();
            let orow = out.row_mut(i);
            for j in 0..n {
                orow[j] = (row[j] as f64 * r) as f32 * gain[j];
            }
        }
        out
    }

    /// Apply half-split RoPE to `[T, D]` rows; row i is at position
    /// `base_pos + i` (prefill passes base 0, decode passes its position).
    fn rope_rows(&self, mut x: Tensor, base_pos: usize) -> Tensor {
        let cfg = self.cfg;
        let (t, _) = x.dims2();
        let hd = cfg.head_dim();
        let half = hd / 2;
        for i in 0..t {
            let pos = (base_pos + i) as f32;
            let row = x.row_mut(i);
            for h in 0..cfg.heads {
                let off = h * hd;
                for f in 0..half {
                    let freq = cfg
                        .rope_theta
                        .powf(-2.0 * f as f32 / hd as f32);
                    let (sinv, cosv) = (pos * freq).sin_cos();
                    let a = row[off + f];
                    let b = row[off + half + f];
                    row[off + f] = a * cosv - b * sinv;
                    row[off + half + f] = a * sinv + b * cosv;
                }
            }
        }
        x
    }

    /// Causal multi-head attention over an `[S, D]` block (prefill).
    fn attention_causal(&self, q: &Tensor, k: &Tensor, v: &Tensor)
        -> Tensor {
        let cfg = self.cfg;
        let (s, d) = q.dims2();
        let hd = cfg.head_dim();
        let scale = 1.0 / (hd as f32).sqrt();
        let mut out = Tensor::zeros(&[s, d]);
        for h in 0..cfg.heads {
            let off = h * hd;
            for i in 0..s {
                // scores over keys 0..=i
                let mut scores = Vec::with_capacity(i + 1);
                for j in 0..=i {
                    let mut dot = 0.0f32;
                    for f in 0..hd {
                        dot += q.data[i * d + off + f]
                            * k.data[j * d + off + f];
                    }
                    scores.push(dot * scale);
                }
                softmax_inplace(&mut scores);
                let orow = &mut out.data[i * d + off..i * d + off + hd];
                for (j, &p) in scores.iter().enumerate() {
                    for f in 0..hd {
                        orow[f] += p * v.data[j * d + off + f];
                    }
                }
            }
        }
        out
    }

    /// Single-query attention against the cache (decode).
    fn attention_one(&self, q: &Tensor, cache: &KvCache, layer: usize,
                     klen: usize) -> Tensor {
        let cfg = self.cfg;
        let d = cfg.dim;
        let hd = cfg.head_dim();
        let scale = 1.0 / (hd as f32).sqrt();
        let kd = &cache.k[layer];
        let vd = &cache.v[layer];
        let mut out = Tensor::zeros(&[1, d]);
        for h in 0..cfg.heads {
            let off = h * hd;
            let mut scores = Vec::with_capacity(klen);
            for j in 0..klen {
                let mut dot = 0.0f32;
                for f in 0..hd {
                    dot += q.data[off + f] * kd[j * d + off + f];
                }
                scores.push(dot * scale);
            }
            softmax_inplace(&mut scores);
            let orow = &mut out.data[off..off + hd];
            for (j, &p) in scores.iter().enumerate() {
                for f in 0..hd {
                    orow[f] += p * vd[j * d + off + f];
                }
            }
        }
        out
    }
}

fn add_inplace(a: &mut Tensor, b: &Tensor) {
    debug_assert_eq!(a.shape, b.shape);
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += y;
    }
}

fn swiglu(gate: &Tensor, up: &Tensor) -> Tensor {
    debug_assert_eq!(gate.shape, up.shape);
    Tensor::from_vec(
        &gate.shape,
        gate.data
            .iter()
            .zip(&up.data)
            .map(|(&g, &u)| g / (1.0 + (-g).exp()) * u)
            .collect(),
    )
}

fn softmax_inplace(xs: &mut [f32]) {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::{init_weights, InitSpec};
    use crate::util::prop;

    fn tiny() -> (ModelConfig, WeightStore) {
        let cfg = ModelConfig::tiny();
        let w = init_weights(&cfg, &InitSpec::benign(0));
        (cfg, w)
    }

    #[test]
    fn prefill_shapes_and_finite() {
        let (cfg, w) = tiny();
        let m = RefModel::new(&cfg, &w);
        let (logits, cache) = m.prefill(&[1, 2, 3, 4, 5], &mut NoHook);
        assert_eq!(logits.shape, vec![5, cfg.vocab]);
        assert_eq!(cache.len, 5);
        assert!(logits.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn decode_matches_prefill() {
        // decode(t_n | prefill(t_0..n-1)) == prefill(t_0..n)[n]
        let (cfg, w) = tiny();
        let m = RefModel::new(&cfg, &w);
        let seq = [5u32, 9, 2, 7, 1, 4, 6, 8];
        let (full, _) = m.prefill(&seq, &mut NoHook);
        let (_, mut cache) = m.prefill(&seq[..7], &mut NoHook);
        let dec = m.decode(seq[7], &mut cache, &mut NoHook);
        prop::assert_allclose(&dec, full.row(7), 1e-4, 1e-5,
                              "decode vs prefill");
        assert_eq!(cache.len, 8);
    }

    #[test]
    fn multi_step_decode_consistent() {
        let (cfg, w) = tiny();
        let m = RefModel::new(&cfg, &w);
        let seq = [3u32, 1, 4, 1, 5, 9, 2, 6];
        let (full, _) = m.prefill(&seq, &mut NoHook);
        let (_, mut cache) = m.prefill(&seq[..4], &mut NoHook);
        for i in 4..8 {
            let dec = m.decode(seq[i], &mut cache, &mut NoHook);
            prop::assert_allclose(&dec, full.row(i), 1e-4, 1e-5, "step");
        }
    }

    #[test]
    fn causality() {
        // changing a later token must not change earlier logits
        let (cfg, w) = tiny();
        let m = RefModel::new(&cfg, &w);
        let (a, _) = m.prefill(&[1, 2, 3, 4], &mut NoHook);
        let (b, _) = m.prefill(&[1, 2, 3, 400], &mut NoHook);
        prop::assert_allclose(a.row(0), b.row(0), 1e-6, 1e-7, "pos 0");
        prop::assert_allclose(a.row(2), b.row(2), 1e-6, 1e-7, "pos 2");
    }

    #[test]
    fn hooks_fire_per_layer_and_site() {
        struct Count(std::collections::HashMap<(usize, Site), usize>);
        impl ActHook for Count {
            fn record(&mut self, l: usize, s: Site, rows: &Tensor) {
                *self.0.entry((l, s)).or_default() += rows.shape[0];
            }
        }
        let (cfg, w) = tiny();
        let m = RefModel::new(&cfg, &w);
        let mut h = Count(Default::default());
        m.prefill(&[1, 2, 3], &mut h);
        for l in 0..cfg.layers {
            for s in Site::all() {
                assert_eq!(h.0[&(l, s)], 3, "layer {l} site {s:?}");
            }
        }
    }

    #[test]
    fn packed_deploy_store_matches_effective() {
        // packed mode (deploy store through the fused W4A16 kernel) must
        // agree with fake-quant mode (effective store, dense matmuls) —
        // the same function up to f32 reassociation in the kernel
        use crate::config::{QuantConfig, QuantMethod};
        use crate::quant::{calib, pipeline};
        let cfg = ModelConfig::tiny();
        let w = init_weights(&cfg, &InitSpec::with_outliers(0, 4, 60.0));
        let prompts: Vec<Vec<u32>> =
            vec![(0..10).map(|t| (t * 37 + 5) % 512).collect()];
        let cal = calib::collect(&cfg, &w, &prompts, 16, 0);
        let out = pipeline::quantize_model(&cfg, &w, &cal,
                                           QuantMethod::Rtn,
                                           &QuantConfig::default());
        let deploy = out.deploy.unwrap();
        let tokens = [7u32, 301, 42, 9, 255];
        let meff = RefModel::new(&cfg, &out.effective);
        let mpkd = RefModel::new(&cfg, &deploy);
        let (le, _) = meff.prefill(&tokens, &mut NoHook);
        let (lp, _) = mpkd.prefill(&tokens, &mut NoHook);
        prop::assert_allclose(&lp.data, &le.data, 2e-3, 2e-3,
                              "packed prefill vs effective");
        // decode path too
        let (_, mut ce) = meff.prefill(&tokens[..4], &mut NoHook);
        let (_, mut cp) = mpkd.prefill(&tokens[..4], &mut NoHook);
        let de = meff.decode(tokens[4], &mut ce, &mut NoHook);
        let dp = mpkd.decode(tokens[4], &mut cp, &mut NoHook);
        prop::assert_allclose(&dp, &de, 2e-3, 2e-3,
                              "packed decode vs effective");
    }

    #[test]
    fn outlier_init_produces_outlier_activations() {
        let cfg = ModelConfig::tiny();
        let spec = InitSpec::with_outliers(0, 4, 60.0);
        let w = init_weights(&cfg, &spec);
        let m = RefModel::new(&cfg, &w);
        struct MaxIn(Vec<f32>);
        impl ActHook for MaxIn {
            fn record(&mut self, _: usize, s: Site, rows: &Tensor) {
                if s == Site::AttnIn {
                    for (j, v) in rows.col_absmax().iter().enumerate() {
                        self.0[j] = self.0[j].max(*v);
                    }
                }
            }
        }
        let mut h = MaxIn(vec![0.0; cfg.dim]);
        m.prefill(&[7, 42, 99, 3, 250, 17], &mut h);
        let mut mags = h.0.clone();
        mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = mags[cfg.dim / 2];
        let top = mags[cfg.dim - 1];
        assert!(
            top > 10.0 * median,
            "outlier {top} vs median {median} — injection too weak"
        );
    }
}
