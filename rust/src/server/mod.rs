//! JSON-lines TCP serving front end (std::net + threads; tokio is not
//! available in the offline build) over the multi-replica
//! [`Router`](crate::coordinator::router::Router).
//!
//! Wire protocol — one JSON object per line:
//!
//! ```text
//! -> {"prompt": [1,2,3], "max_new_tokens": 8, "temperature": 0.0}
//! <- {"id": 0, "replica": 0, "tokens": [4,5,...], "finish": "max_tokens",
//!     "ttft_ms": 12.3, "e2e_ms": 80.1, "cached_tokens": 0}
//!
//! -> {"cmd": "stats"}
//! <- {"replicas": [{"id": 0, "requests_routed": 4, "waiting": 0,
//!     "running": 1, "kv_occupancy": 0.03, "cache_hits": 6,
//!     "cache_misses": 2, "cache_hit_rate": 0.75, "evictions": 0,
//!     "prefill_tokens_executed": 120, "cached_prefix_tokens": 48,
//!     "ttft_p50_steps": 2.0}]}
//! ```
//!
//! `prompt` entries must be non-negative integer token ids and
//! `max_new_tokens`, when present, must be at least 1 (a request that
//! can never produce a token is malformed); any violation rejects the
//! whole request with an `{"error": ...}` line — nothing is silently
//! coerced or clamped to a different meaning. `replica` is the id of
//! the router replica that served the request; `cached_tokens` reports
//! how many tokens were served from that replica's shared prefix cache
//! at the last admission (see [`crate::coordinator`] for the design:
//! chained content hashes over full KV blocks, refcounted sharing, CoW
//! tail block, LRU + sliding-window eviction, chunked prefill;
//! `docs/ARCHITECTURE.md` walks a request end to end). `finish` is one
//! of `max_tokens`, `eos`, `prompt_too_long`, or `pool_exhausted` (the
//! request alone outgrew the KV pool).
//!
//! The `{"cmd": "stats"}` admin request snapshots one row per replica:
//! queue depth (`waiting`/`running`), KV occupancy, block-level cache
//! hit/miss/eviction counters with the derived hit rate, prefill
//! tokens executed vs served from cache, and the TTFT-in-steps p50.
//!
//! Architecture: connection threads parse requests into an inbox; the
//! router thread (the only owner of the PJRT runtimes, which are not
//! Sync) drains the inbox, steps every replica with work, and routes
//! finished sequences back through per-request response channels.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::coordinator::engine::Engine;
use crate::coordinator::replica::ReplicaStats;
use crate::coordinator::router::Router;
use crate::coordinator::sequence::{SamplingParams, Sequence};
use crate::util::json::{self, Value};

/// A parsed generation request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Prompt token ids (validated non-negative integers).
    pub prompt: Vec<u32>,
    /// Sampling parameters (defaults filled for absent fields).
    pub params: SamplingParams,
}

/// Any parsed client line: a generation request or an admin command.
#[derive(Debug, Clone)]
pub enum ClientRequest {
    /// `{"prompt": [...], ...}` — generate tokens.
    Generate(Request),
    /// `{"cmd": "stats"}` — per-replica stats snapshot.
    Stats,
}

/// Parse one generation-request line (strict: malformed prompt entries
/// or a zero `max_new_tokens` reject the whole request).
pub fn parse_request(line: &str) -> Result<Request> {
    let v = json::parse(line).map_err(|e| anyhow::anyhow!("json: {e}"))?;
    let arr = v
        .get("prompt")
        .as_arr()
        .context("prompt must be an array of token ids")?;
    let mut prompt = Vec::with_capacity(arr.len());
    for (i, t) in arr.iter().enumerate() {
        let f = t.as_f64().with_context(|| {
            format!("prompt[{i}] must be a number, not {t}")
        })?;
        if !f.is_finite() || f < 0.0 || f.fract() != 0.0
            || f > u32::MAX as f64
        {
            anyhow::bail!(
                "prompt[{i}] must be a non-negative integer token id \
                 (got {f})"
            );
        }
        prompt.push(f as u32);
    }
    let mut params = SamplingParams::default();
    if let Some(m) = v.get("max_new_tokens").as_usize() {
        if m == 0 {
            // a 0-token budget would admit a sequence that can never
            // produce a token: malformed, like any other bad field
            anyhow::bail!("max_new_tokens must be at least 1 (got 0)");
        }
        params.max_new_tokens = m;
    }
    if let Some(t) = v.get("temperature").as_f64() {
        params.temperature = t as f32;
    }
    if let Some(k) = v.get("top_k").as_usize() {
        params.top_k = k;
    }
    if let Some(s) = v.get("seed").as_f64() {
        params.seed = s as u64;
    }
    Ok(Request { prompt, params })
}

/// Parse any client line: `{"cmd": ...}` admin commands first, else a
/// generation request.
pub fn parse_client_request(line: &str) -> Result<ClientRequest> {
    let v = json::parse(line).map_err(|e| anyhow::anyhow!("json: {e}"))?;
    if let Some(cmd) = v.get("cmd").as_str() {
        return match cmd {
            "stats" => Ok(ClientRequest::Stats),
            other => Err(anyhow::anyhow!("unknown cmd {other:?}")),
        };
    }
    parse_request(line).map(ClientRequest::Generate)
}

/// Serialize one finished sequence as its wire response line.
pub fn response_json(id: u64, replica: usize, seq: &Sequence) -> String {
    let finish = match seq.finish {
        Some(crate::coordinator::sequence::FinishReason::Eos) => "eos",
        Some(crate::coordinator::sequence::FinishReason::MaxTokens) => {
            "max_tokens"
        }
        Some(crate::coordinator::sequence::FinishReason::PromptTooLong) => {
            "prompt_too_long"
        }
        Some(crate::coordinator::sequence::FinishReason::PoolExhausted) => {
            "pool_exhausted"
        }
        None => "unknown",
    };
    let ttft_ms = seq
        .first_token_at
        .map(|t| t.duration_since(seq.arrived).as_secs_f64() * 1e3)
        .unwrap_or(0.0);
    let e2e_ms = seq
        .finished_at
        .map(|t| t.duration_since(seq.arrived).as_secs_f64() * 1e3)
        .unwrap_or(0.0);
    Value::obj(vec![
        ("id", Value::num(id as f64)),
        ("replica", Value::num(replica as f64)),
        ("tokens",
         Value::Arr(seq.output.iter().map(|&t| Value::num(t as f64))
             .collect())),
        ("finish", Value::str(finish)),
        ("ttft_ms", Value::num(ttft_ms)),
        ("e2e_ms", Value::num(e2e_ms)),
        ("cached_tokens", Value::num(seq.cached_prefix_len as f64)),
    ])
    .to_string()
}

/// Serialize per-replica stats rows as the `{"cmd":"stats"}` response.
pub fn stats_json(stats: &[ReplicaStats]) -> Value {
    Value::obj(vec![(
        "replicas",
        Value::Arr(
            stats
                .iter()
                .map(|s| {
                    Value::obj(vec![
                        ("id", Value::num(s.id as f64)),
                        ("requests_routed",
                         Value::num(s.requests_routed as f64)),
                        ("waiting", Value::num(s.core.waiting as f64)),
                        ("running", Value::num(s.core.running as f64)),
                        ("kv_occupancy",
                         Value::num(s.core.kv_occupancy)),
                        ("cache_hits",
                         Value::num(s.core.cache.hits as f64)),
                        ("cache_misses",
                         Value::num(s.core.cache.misses as f64)),
                        ("cache_hit_rate",
                         Value::num(s.core.cache_hit_rate())),
                        ("evictions",
                         Value::num(s.core.cache.evictions as f64)),
                        ("prefill_tokens_executed",
                         Value::num(s.core.prefill_tokens_executed
                             as f64)),
                        ("cached_prefix_tokens",
                         Value::num(s.core.cached_prefix_tokens as f64)),
                        ("ttft_p50_steps",
                         Value::num(s.core.ttft_steps_p50)),
                    ])
                })
                .collect(),
        ),
    )])
}

enum Inbox {
    Submit(Request, mpsc::Sender<String>),
    Stats(mpsc::Sender<String>),
    Shutdown,
}

/// Move-only wrapper that transfers the router to its serving thread.
///
/// SAFETY: `Engine` is not `Send` because the xla crate's PJRT handles
/// use `Rc` internally. Every `Rc` clone of a client lives inside the
/// same `Engine` (runtime buffers + executable cache), and every engine
/// lives inside this router, so moving the whole router to exactly one
/// thread — which is all this wrapper permits — never shares an `Rc`
/// across threads. The router thread is the sole owner for the rest of
/// its life.
struct SendRouter(Router<Engine>);
unsafe impl Send for SendRouter {}

/// A running server; `addr()` gives the bound address, `shutdown()`
/// stops the router loop after draining.
pub struct Server {
    addr: std::net::SocketAddr,
    inbox: mpsc::Sender<Inbox>,
    router_thread: Option<std::thread::JoinHandle<()>>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Spawn the server on `127.0.0.1:port` (0 = ephemeral). Takes
    /// ownership of the router and its replicas (the PJRT runtimes are
    /// not Sync; they live on the router thread). A single engine can
    /// be served by wrapping it:
    /// `Server::spawn(Router::single(engine), port)`.
    pub fn spawn(router: Router<Engine>, port: u16) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        let (tx, rx) = mpsc::channel::<Inbox>();

        // router loop thread (sole owner of the PJRT runtimes).
        // NB: bind the whole wrapper inside the closure — edition-2021
        // disjoint capture would otherwise capture the non-Send field.
        let boxed = SendRouter(router);
        let router_thread = std::thread::spawn(move || {
            let whole = boxed; // force whole-struct capture (RFC 2229)
            router_loop(whole.0, rx);
        });

        // accept loop thread
        let tx_accept = tx.clone();
        let accept_thread = std::thread::spawn(move || {
            listener.set_nonblocking(false).ok();
            for stream in listener.incoming() {
                let Ok(stream) = stream else { break };
                let tx = tx_accept.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, tx);
                });
            }
        });

        Ok(Server {
            addr,
            inbox: tx,
            router_thread: Some(router_thread),
            accept_thread: Some(accept_thread),
        })
    }

    /// The bound listen address.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting, drain in-flight requests, and join the router
    /// thread.
    pub fn shutdown(mut self) {
        let _ = self.inbox.send(Inbox::Shutdown);
        if let Some(t) = self.router_thread.take() {
            let _ = t.join();
        }
        // unblock the accept loop with a dummy connection
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            // the accept thread may be blocked on `incoming`; detach is
            // fine here since the process owns it
            drop(t);
        }
    }
}

fn handle_conn(stream: TcpStream, tx: mpsc::Sender<Inbox>) -> Result<()> {
    let peer_read = stream.try_clone()?;
    let mut reader = BufReader::new(peer_read);
    let writer = Arc::new(Mutex::new(stream));
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match parse_client_request(line) {
            Ok(req) => {
                let (rtx, rrx) = mpsc::channel::<String>();
                let msg = match req {
                    ClientRequest::Generate(r) => Inbox::Submit(r, rtx),
                    ClientRequest::Stats => Inbox::Stats(rtx),
                };
                if tx.send(msg).is_err() {
                    return Ok(());
                }
                // wait for the router's response, then write it back
                if let Ok(resp) = rrx.recv() {
                    let mut w = writer.lock().unwrap();
                    writeln!(w, "{resp}")?;
                }
            }
            Err(e) => {
                let mut w = writer.lock().unwrap();
                writeln!(w, "{}", Value::obj(vec![
                    ("error", Value::str(format!("{e}"))),
                ]))?;
            }
        }
    }
}

fn router_loop(mut router: Router<Engine>, rx: mpsc::Receiver<Inbox>) {
    let mut pending: HashMap<u64, mpsc::Sender<String>> = HashMap::new();
    let mut shutdown = false;
    loop {
        // deliver finished responses first: a submission can finish
        // without any engine work (e.g. prompt_too_long), and its
        // response must go out before the loop blocks for new input
        for fin in router.take_finished() {
            if let Some(resp) = pending.remove(&fin.id) {
                let _ =
                    resp.send(response_json(fin.id, fin.replica, &fin.seq));
            }
        }
        if shutdown && !router.has_work() && pending.is_empty() {
            break;
        }
        // drain the inbox (blocking only while fully idle)
        loop {
            let msg = if router.has_work() || shutdown {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(mpsc::TryRecvError::Empty) => None,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        shutdown = true;
                        None
                    }
                }
            } else {
                match rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => {
                        shutdown = true;
                        None
                    }
                }
            };
            match msg {
                Some(Inbox::Submit(req, resp)) => {
                    let id = router.submit(req.prompt, req.params);
                    pending.insert(id, resp);
                    if !router.has_work() {
                        break; // finished at submission: drain now
                    }
                }
                Some(Inbox::Stats(resp)) => {
                    let _ = resp.send(stats_json(&router.stats())
                        .to_string());
                }
                Some(Inbox::Shutdown) => shutdown = true,
                None => break,
            }
            if shutdown {
                break;
            }
        }
        if router.has_work() && router.step().is_err() {
            break;
        }
    }
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    stream: BufReader<TcpStream>,
}

impl Client {
    /// Connect to a running [`Server`].
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        Ok(Client { stream: BufReader::new(TcpStream::connect(addr)?) })
    }

    /// Send one generation request and wait for its response line.
    pub fn request(&mut self, prompt: &[u32], max_new: usize)
        -> Result<Value> {
        let req = Value::obj(vec![
            ("prompt",
             Value::Arr(prompt.iter().map(|&t| Value::num(t as f64))
                 .collect())),
            ("max_new_tokens", Value::num(max_new as f64)),
        ]);
        self.roundtrip(&req)
    }

    /// Request the per-replica stats snapshot.
    pub fn stats(&mut self) -> Result<Value> {
        self.roundtrip(&Value::obj(vec![("cmd", Value::str("stats"))]))
    }

    fn roundtrip(&mut self, req: &Value) -> Result<Value> {
        let s = self.stream.get_mut();
        writeln!(s, "{req}")?;
        let mut line = String::new();
        self.stream.read_line(&mut line)?;
        json::parse(line.trim()).map_err(|e| anyhow::anyhow!("resp: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::replica::CoreStats;

    #[test]
    fn parse_request_fields() {
        let r = parse_request(
            r#"{"prompt":[1,2,3],"max_new_tokens":4,"temperature":0.5,
                "top_k":5,"seed":9}"#,
        )
        .unwrap();
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.params.max_new_tokens, 4);
        assert_eq!(r.params.temperature, 0.5);
        assert_eq!(r.params.top_k, 5);
        assert_eq!(r.params.seed, 9);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"promptX":[1]}"#).is_err());
    }

    #[test]
    fn parse_rejects_malformed_prompt_entries() {
        // these used to be silently coerced to token 0
        assert!(parse_request(r#"{"prompt":[1,"x",3]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[1,null]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[1.5]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[-3]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[1e12]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[[1]]}"#).is_err());
        assert!(parse_request(r#"{"prompt":7}"#).is_err());
        // boundary values that must still parse
        let r = parse_request(r#"{"prompt":[0, 4294967295]}"#).unwrap();
        assert_eq!(r.prompt, vec![0, u32::MAX]);
    }

    #[test]
    fn parse_rejects_zero_max_new_tokens() {
        // a 0-token generation budget admits a sequence that can never
        // produce a token — rejected like any other malformed field
        assert!(parse_request(r#"{"prompt":[1],"max_new_tokens":0}"#)
            .is_err());
        // 1 is the smallest valid budget; absent means the default
        let r = parse_request(r#"{"prompt":[1],"max_new_tokens":1}"#)
            .unwrap();
        assert_eq!(r.params.max_new_tokens, 1);
        let r = parse_request(r#"{"prompt":[1]}"#).unwrap();
        assert_eq!(r.params.max_new_tokens,
                   SamplingParams::default().max_new_tokens);
    }

    #[test]
    fn parse_client_request_dispatches() {
        assert!(matches!(parse_client_request(r#"{"cmd":"stats"}"#),
                         Ok(ClientRequest::Stats)));
        assert!(parse_client_request(r#"{"cmd":"reboot"}"#).is_err());
        assert!(matches!(
            parse_client_request(r#"{"prompt":[1,2]}"#),
            Ok(ClientRequest::Generate(_))
        ));
        assert!(parse_client_request("not json").is_err());
    }

    #[test]
    fn parse_request_roundtrip() {
        // a request built the way `Client::request` builds it survives
        // serialize -> parse unchanged
        let prompt: Vec<u32> = vec![5, 0, 917, 64000];
        let req = Value::obj(vec![
            ("prompt",
             Value::Arr(prompt.iter().map(|&t| Value::num(t as f64))
                 .collect())),
            ("max_new_tokens", Value::num(9.0)),
            ("temperature", Value::num(0.25)),
        ]);
        let r = parse_request(&req.to_string()).unwrap();
        assert_eq!(r.prompt, prompt);
        assert_eq!(r.params.max_new_tokens, 9);
        assert_eq!(r.params.temperature, 0.25);
    }

    #[test]
    fn response_shape() {
        use crate::coordinator::sequence::{FinishReason, Sequence};
        let mut s =
            Sequence::new(3, vec![1], SamplingParams::default());
        s.record_token(7);
        s.cached_prefix_len = 4;
        s.finish(FinishReason::MaxTokens);
        // global id 11 on replica 1 (seq.id is the replica-local id)
        let j = response_json(11, 1, &s);
        let v = json::parse(&j).unwrap();
        assert_eq!(v.get("id").as_usize(), Some(11));
        assert_eq!(v.get("replica").as_usize(), Some(1));
        assert_eq!(v.get("finish").as_str(), Some("max_tokens"));
        assert_eq!(v.get("tokens").as_arr().unwrap().len(), 1);
        assert_eq!(v.get("cached_tokens").as_usize(), Some(4));
    }

    #[test]
    fn stats_json_roundtrip() {
        let mut core = CoreStats {
            waiting: 2,
            running: 3,
            kv_occupancy: 0.5,
            ..Default::default()
        };
        core.cache.hits = 6;
        core.cache.misses = 2;
        core.cache.evictions = 1;
        core.prefill_tokens_executed = 120;
        core.cached_prefix_tokens = 48;
        core.ttft_steps_p50 = 2.5;
        let rows = vec![
            ReplicaStats { id: 0, requests_routed: 4, core },
            ReplicaStats {
                id: 1,
                requests_routed: 0,
                core: CoreStats::default(),
            },
        ];
        let v = json::parse(&stats_json(&rows).to_string()).unwrap();
        let reps = v.get("replicas").as_arr().unwrap();
        assert_eq!(reps.len(), 2);
        let r0 = &reps[0];
        assert_eq!(r0.get("id").as_usize(), Some(0));
        assert_eq!(r0.get("requests_routed").as_usize(), Some(4));
        assert_eq!(r0.get("waiting").as_usize(), Some(2));
        assert_eq!(r0.get("running").as_usize(), Some(3));
        assert_eq!(r0.get("kv_occupancy").as_f64(), Some(0.5));
        assert_eq!(r0.get("cache_hits").as_usize(), Some(6));
        assert_eq!(r0.get("cache_misses").as_usize(), Some(2));
        assert_eq!(r0.get("cache_hit_rate").as_f64(), Some(0.75));
        assert_eq!(r0.get("evictions").as_usize(), Some(1));
        assert_eq!(r0.get("prefill_tokens_executed").as_usize(),
                   Some(120));
        assert_eq!(r0.get("cached_prefix_tokens").as_usize(), Some(48));
        assert_eq!(r0.get("ttft_p50_steps").as_f64(), Some(2.5));
        let r1 = &reps[1];
        assert_eq!(r1.get("id").as_usize(), Some(1));
        assert_eq!(r1.get("cache_hit_rate").as_f64(), Some(0.0));
    }
}
