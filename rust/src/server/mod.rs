//! JSON-lines TCP serving front end (std::net + threads; tokio is not
//! available in the offline build).
//!
//! Wire protocol — one JSON object per line:
//!
//! ```text
//! -> {"prompt": [1,2,3], "max_new_tokens": 8, "temperature": 0.0}
//! <- {"id": 0, "tokens": [4,5,...], "finish": "max_tokens",
//!     "ttft_ms": 12.3, "e2e_ms": 80.1, "cached_tokens": 0}
//! ```
//!
//! `prompt` entries must be non-negative integer token ids; malformed
//! entries reject the whole request with an `{"error": ...}` line (they
//! are never silently coerced). `cached_tokens` reports how many tokens
//! were served from the engine's shared prefix cache at the last
//! admission (see [`crate::coordinator`] for the design: chained
//! content hashes over full KV blocks, refcounted sharing, CoW tail
//! block, LRU eviction, chunked prefill; `docs/ARCHITECTURE.md` walks a
//! request end to end). `finish` is one of `max_tokens`, `eos`,
//! `prompt_too_long`, or `pool_exhausted` (the request alone outgrew
//! the KV pool).
//!
//! Architecture: connection threads parse requests into an inbox; the
//! engine thread (the only owner of the PJRT runtime, which is not Sync)
//! drains the inbox, steps the engine, and routes finished sequences back
//! through per-request response channels.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::coordinator::engine::Engine;
use crate::coordinator::sequence::{SamplingParams, Sequence};
use crate::util::json::{self, Value};

/// A parsed client request.
#[derive(Debug, Clone)]
pub struct Request {
    pub prompt: Vec<u32>,
    pub params: SamplingParams,
}

pub fn parse_request(line: &str) -> Result<Request> {
    let v = json::parse(line).map_err(|e| anyhow::anyhow!("json: {e}"))?;
    let arr = v
        .get("prompt")
        .as_arr()
        .context("prompt must be an array of token ids")?;
    let mut prompt = Vec::with_capacity(arr.len());
    for (i, t) in arr.iter().enumerate() {
        let f = t.as_f64().with_context(|| {
            format!("prompt[{i}] must be a number, not {t}")
        })?;
        if !f.is_finite() || f < 0.0 || f.fract() != 0.0
            || f > u32::MAX as f64
        {
            anyhow::bail!(
                "prompt[{i}] must be a non-negative integer token id \
                 (got {f})"
            );
        }
        prompt.push(f as u32);
    }
    let mut params = SamplingParams::default();
    if let Some(m) = v.get("max_new_tokens").as_usize() {
        params.max_new_tokens = m;
    }
    if let Some(t) = v.get("temperature").as_f64() {
        params.temperature = t as f32;
    }
    if let Some(k) = v.get("top_k").as_usize() {
        params.top_k = k;
    }
    if let Some(s) = v.get("seed").as_f64() {
        params.seed = s as u64;
    }
    Ok(Request { prompt, params })
}

pub fn response_json(id: u64, seq: &Sequence) -> String {
    let finish = match seq.finish {
        Some(crate::coordinator::sequence::FinishReason::Eos) => "eos",
        Some(crate::coordinator::sequence::FinishReason::MaxTokens) => {
            "max_tokens"
        }
        Some(crate::coordinator::sequence::FinishReason::PromptTooLong) => {
            "prompt_too_long"
        }
        Some(crate::coordinator::sequence::FinishReason::PoolExhausted) => {
            "pool_exhausted"
        }
        None => "unknown",
    };
    let ttft_ms = seq
        .first_token_at
        .map(|t| t.duration_since(seq.arrived).as_secs_f64() * 1e3)
        .unwrap_or(0.0);
    let e2e_ms = seq
        .finished_at
        .map(|t| t.duration_since(seq.arrived).as_secs_f64() * 1e3)
        .unwrap_or(0.0);
    Value::obj(vec![
        ("id", Value::num(id as f64)),
        ("tokens",
         Value::Arr(seq.output.iter().map(|&t| Value::num(t as f64))
             .collect())),
        ("finish", Value::str(finish)),
        ("ttft_ms", Value::num(ttft_ms)),
        ("e2e_ms", Value::num(e2e_ms)),
        ("cached_tokens", Value::num(seq.cached_prefix_len as f64)),
    ])
    .to_string()
}

enum Inbox {
    Submit(Request, mpsc::Sender<String>),
    Shutdown,
}

/// Move-only wrapper that transfers the engine to its serving thread.
///
/// SAFETY: `Engine` is not `Send` because the xla crate's PJRT handles use
/// `Rc` internally. Every `Rc` clone of the client lives inside this same
/// `Engine` (runtime buffers + executable cache), so moving the whole
/// engine to exactly one thread — which is all this wrapper permits —
/// never shares an `Rc` across threads. The engine thread is the sole
/// owner for the rest of its life.
struct SendEngine(Engine);
unsafe impl Send for SendEngine {}

/// A running server; `addr()` gives the bound address, `shutdown()` stops
/// the engine loop after draining.
pub struct Server {
    addr: std::net::SocketAddr,
    inbox: mpsc::Sender<Inbox>,
    engine_thread: Option<std::thread::JoinHandle<()>>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Spawn the server on `127.0.0.1:port` (0 = ephemeral). Takes
    /// ownership of the engine (PJRT runtime is not Sync; it lives on the
    /// engine thread).
    pub fn spawn(engine: Engine, port: u16) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        let (tx, rx) = mpsc::channel::<Inbox>();

        // engine loop thread (sole owner of the PJRT runtime).
        // NB: bind the whole wrapper inside the closure — edition-2021
        // disjoint capture would otherwise capture the non-Send field.
        let boxed = SendEngine(engine);
        let engine_thread = std::thread::spawn(move || {
            let whole = boxed; // force whole-struct capture (RFC 2229)
            engine_loop(whole.0, rx);
        });

        // accept loop thread
        let tx_accept = tx.clone();
        let accept_thread = std::thread::spawn(move || {
            listener.set_nonblocking(false).ok();
            for stream in listener.incoming() {
                let Ok(stream) = stream else { break };
                let tx = tx_accept.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, tx);
                });
            }
        });

        Ok(Server {
            addr,
            inbox: tx,
            engine_thread: Some(engine_thread),
            accept_thread: Some(accept_thread),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn shutdown(mut self) {
        let _ = self.inbox.send(Inbox::Shutdown);
        if let Some(t) = self.engine_thread.take() {
            let _ = t.join();
        }
        // unblock the accept loop with a dummy connection
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            // the accept thread may be blocked on `incoming`; detach is
            // fine here since the process owns it
            drop(t);
        }
    }
}

fn handle_conn(stream: TcpStream, tx: mpsc::Sender<Inbox>) -> Result<()> {
    let peer_read = stream.try_clone()?;
    let mut reader = BufReader::new(peer_read);
    let writer = Arc::new(Mutex::new(stream));
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match parse_request(line) {
            Ok(req) => {
                let (rtx, rrx) = mpsc::channel::<String>();
                if tx.send(Inbox::Submit(req, rtx)).is_err() {
                    return Ok(());
                }
                // wait for the engine's response, then write it back
                if let Ok(resp) = rrx.recv() {
                    let mut w = writer.lock().unwrap();
                    writeln!(w, "{resp}")?;
                }
            }
            Err(e) => {
                let mut w = writer.lock().unwrap();
                writeln!(w, "{}", Value::obj(vec![
                    ("error", Value::str(format!("{e}"))),
                ]))?;
            }
        }
    }
}

fn engine_loop(mut engine: Engine, rx: mpsc::Receiver<Inbox>) {
    let mut pending: HashMap<u64, mpsc::Sender<String>> = HashMap::new();
    let mut shutdown = false;
    loop {
        // drain inbox (non-blocking while there is engine work)
        loop {
            let msg = if engine.has_work() || shutdown {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(mpsc::TryRecvError::Empty) => None,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        shutdown = true;
                        None
                    }
                }
            } else {
                // idle: block until the next request
                match rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => {
                        shutdown = true;
                        None
                    }
                }
            };
            match msg {
                Some(Inbox::Submit(req, resp)) => {
                    let id = engine.submit(req.prompt, req.params);
                    pending.insert(id, resp);
                }
                Some(Inbox::Shutdown) => shutdown = true,
                None => break,
            }
            if shutdown && !engine.has_work() {
                break;
            }
        }
        if engine.has_work() {
            if engine.step().is_err() {
                break;
            }
        }
        for seq in engine.take_finished() {
            if let Some(resp) = pending.remove(&seq.id) {
                let _ = resp.send(response_json(seq.id, &seq));
            }
        }
        if shutdown && !engine.has_work() && pending.is_empty() {
            break;
        }
    }
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    stream: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        Ok(Client { stream: BufReader::new(TcpStream::connect(addr)?) })
    }

    /// Send one request and wait for its response line.
    pub fn request(&mut self, prompt: &[u32], max_new: usize)
        -> Result<Value> {
        let req = Value::obj(vec![
            ("prompt",
             Value::Arr(prompt.iter().map(|&t| Value::num(t as f64))
                 .collect())),
            ("max_new_tokens", Value::num(max_new as f64)),
        ]);
        let s = self.stream.get_mut();
        writeln!(s, "{req}")?;
        let mut line = String::new();
        self.stream.read_line(&mut line)?;
        json::parse(line.trim()).map_err(|e| anyhow::anyhow!("resp: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_fields() {
        let r = parse_request(
            r#"{"prompt":[1,2,3],"max_new_tokens":4,"temperature":0.5,
                "top_k":5,"seed":9}"#,
        )
        .unwrap();
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.params.max_new_tokens, 4);
        assert_eq!(r.params.temperature, 0.5);
        assert_eq!(r.params.top_k, 5);
        assert_eq!(r.params.seed, 9);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"promptX":[1]}"#).is_err());
    }

    #[test]
    fn parse_rejects_malformed_prompt_entries() {
        // these used to be silently coerced to token 0
        assert!(parse_request(r#"{"prompt":[1,"x",3]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[1,null]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[1.5]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[-3]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[1e12]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[[1]]}"#).is_err());
        assert!(parse_request(r#"{"prompt":7}"#).is_err());
        // boundary values that must still parse
        let r = parse_request(r#"{"prompt":[0, 4294967295]}"#).unwrap();
        assert_eq!(r.prompt, vec![0, u32::MAX]);
    }

    #[test]
    fn parse_request_roundtrip() {
        // a request built the way `Client::request` builds it survives
        // serialize -> parse unchanged
        let prompt: Vec<u32> = vec![5, 0, 917, 64000];
        let req = Value::obj(vec![
            ("prompt",
             Value::Arr(prompt.iter().map(|&t| Value::num(t as f64))
                 .collect())),
            ("max_new_tokens", Value::num(9.0)),
            ("temperature", Value::num(0.25)),
        ]);
        let r = parse_request(&req.to_string()).unwrap();
        assert_eq!(r.prompt, prompt);
        assert_eq!(r.params.max_new_tokens, 9);
        assert_eq!(r.params.temperature, 0.25);
    }

    #[test]
    fn response_shape() {
        use crate::coordinator::sequence::{FinishReason, Sequence};
        let mut s =
            Sequence::new(3, vec![1], SamplingParams::default());
        s.record_token(7);
        s.cached_prefix_len = 4;
        s.finish(FinishReason::MaxTokens);
        let j = response_json(3, &s);
        let v = json::parse(&j).unwrap();
        assert_eq!(v.get("id").as_usize(), Some(3));
        assert_eq!(v.get("finish").as_str(), Some("max_tokens"));
        assert_eq!(v.get("tokens").as_arr().unwrap().len(), 1);
        assert_eq!(v.get("cached_tokens").as_usize(), Some(4));
    }
}
