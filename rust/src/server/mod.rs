//! JSON-lines TCP serving front end (std::net + threads; tokio is not
//! available in the offline build) over the multi-replica
//! [`Router`](crate::coordinator::router::Router).
//!
//! Wire protocol — one JSON object per line:
//!
//! ```text
//! -> {"prompt": [1,2,3], "max_new_tokens": 8, "temperature": 0.0}
//! <- {"id": 0, "replica": 0, "tokens": [4,5,...], "finish": "max_tokens",
//!     "ttft_ms": 12.3, "e2e_ms": 80.1, "cached_tokens": 0}
//!
//! -> {"cmd": "stats"}
//! <- {"replicas": [{"id": 0, "requests_routed": 4, "health": "healthy",
//!     "replayed_out": 0, "waiting": 0, "running": 1,
//!     "kv_occupancy": 0.03, "cache_hits": 6, "cache_misses": 2,
//!     "cache_hit_rate": 0.75, "evictions": 0,
//!     "prefill_tokens_executed": 120, "cached_prefix_tokens": 48,
//!     "ttft_p50_steps": 2.0}],
//!     "router": {"shed": 0, "replayed": 0, "retries": 0,
//!     "replica_failed": 0, "alive": 1, "dead": 0, "degraded": false}}
//!
//! -> {"cmd": "metrics"}
//! <- # TYPE sqplus_replica_up gauge
//!    sqplus_replica_up{replica="0",health="healthy"} 1
//!    ...
//!    # TYPE sqplus_router_shed_total counter
//!    sqplus_router_shed_total 0
//!    ...
//!    # EOF
//! ```
//!
//! `prompt` entries must be non-negative integer token ids and
//! `max_new_tokens`, when present, must be at least 1 (a request that
//! can never produce a token is malformed); any violation rejects the
//! whole request with an `{"error": ...}` line — nothing is silently
//! coerced or clamped to a different meaning. `replica` is the id of
//! the router replica that served the request — `null` when no replica
//! ever did (the request was shed at admission, or every replica died);
//! `cached_tokens` reports how many tokens were served from that
//! replica's shared prefix cache at the last admission (see
//! [`crate::coordinator`] for the design: chained content hashes over
//! full KV blocks, refcounted sharing, CoW tail block, LRU +
//! sliding-window eviction, chunked prefill; `docs/ARCHITECTURE.md`
//! walks a request end to end). `finish` is one of `max_tokens`, `eos`,
//! `prompt_too_long`, `pool_exhausted` (the request alone outgrew the
//! KV pool), `shed` (rejected by the router's load-shedding admission
//! control), or `replica_failed` (the serving replica died with no
//! survivor to replay onto). A request whose replica dies mid-stream
//! with a survivor is replayed transparently: its response carries the
//! full stitched token stream and the survivor's replica id.
//!
//! The `{"cmd": "stats"}` admin request snapshots one row per replica —
//! queue depth (`waiting`/`running`), health state, KV occupancy,
//! block-level cache hit/miss/eviction counters with the derived hit
//! rate, prefill tokens executed vs served from cache, the
//! TTFT-in-steps p50, and how many in-flight requests were replayed off
//! the replica at death — plus a `"router"` object with the shedding /
//! replay / retry counters and the degraded flag. `{"cmd": "metrics"}`
//! reports the same snapshot as Prometheus-style text (`# TYPE` +
//! name-value lines, `{replica="i"}` labels), terminated by a `# EOF`
//! line so line-based clients can frame the multi-line body.
//!
//! Architecture: connection threads parse requests into an inbox; the
//! router thread (the only owner of the PJRT runtimes, which are not
//! Sync) drains the inbox, steps every replica with work, and routes
//! finished sequences back through per-request response channels.
//! Connection reads carry a short timeout so an idle client can never
//! pin its thread past shutdown: [`Server::shutdown`] raises a flag,
//! drains in-flight work, and joins *both* service threads (accept
//! loop included — a self-connect wakes it to observe the flag).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::engine::Engine;
use crate::coordinator::replica::{
    CoreStats, ReplicaCore, ReplicaHealth, ReplicaStats,
};
use crate::coordinator::router::{Router, RouterStats};
use crate::coordinator::sequence::{
    FinishReason, SamplingParams, Sequence,
};
use crate::util::json::{self, Value};

/// How long a connection thread blocks on a read before re-checking
/// the shutdown flag. Short enough that shutdown never waits on an
/// idle client; long enough to stay off the scheduler's hot path.
const CONN_READ_TIMEOUT: Duration = Duration::from_millis(100);

/// A parsed generation request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Prompt token ids (validated non-negative integers).
    pub prompt: Vec<u32>,
    /// Sampling parameters (defaults filled for absent fields).
    pub params: SamplingParams,
}

/// Any parsed client line: a generation request or an admin command.
#[derive(Debug, Clone)]
pub enum ClientRequest {
    /// `{"prompt": [...], ...}` — generate tokens.
    Generate(Request),
    /// `{"cmd": "stats"}` — per-replica + router stats snapshot (JSON).
    Stats,
    /// `{"cmd": "metrics"}` — the same snapshot as Prometheus-style
    /// text.
    Metrics,
}

/// Parse one generation-request line (strict: malformed prompt entries
/// or a zero `max_new_tokens` reject the whole request).
pub fn parse_request(line: &str) -> Result<Request> {
    let v = json::parse(line).map_err(|e| anyhow::anyhow!("json: {e}"))?;
    let arr = v
        .get("prompt")
        .as_arr()
        .context("prompt must be an array of token ids")?;
    let mut prompt = Vec::with_capacity(arr.len());
    for (i, t) in arr.iter().enumerate() {
        let f = t.as_f64().with_context(|| {
            format!("prompt[{i}] must be a number, not {t}")
        })?;
        if !f.is_finite() || f < 0.0 || f.fract() != 0.0
            || f > u32::MAX as f64
        {
            anyhow::bail!(
                "prompt[{i}] must be a non-negative integer token id \
                 (got {f})"
            );
        }
        prompt.push(f as u32);
    }
    let mut params = SamplingParams::default();
    if let Some(m) = v.get("max_new_tokens").as_usize() {
        if m == 0 {
            // a 0-token budget would admit a sequence that can never
            // produce a token: malformed, like any other bad field
            anyhow::bail!("max_new_tokens must be at least 1 (got 0)");
        }
        params.max_new_tokens = m;
    }
    if let Some(t) = v.get("temperature").as_f64() {
        params.temperature = t as f32;
    }
    if let Some(k) = v.get("top_k").as_usize() {
        params.top_k = k;
    }
    if let Some(s) = v.get("seed").as_f64() {
        params.seed = s as u64;
    }
    Ok(Request { prompt, params })
}

/// Parse any client line: `{"cmd": ...}` admin commands first, else a
/// generation request.
pub fn parse_client_request(line: &str) -> Result<ClientRequest> {
    let v = json::parse(line).map_err(|e| anyhow::anyhow!("json: {e}"))?;
    if let Some(cmd) = v.get("cmd").as_str() {
        return match cmd {
            "stats" => Ok(ClientRequest::Stats),
            "metrics" => Ok(ClientRequest::Metrics),
            other => Err(anyhow::anyhow!("unknown cmd {other:?}")),
        };
    }
    parse_request(line).map(ClientRequest::Generate)
}

/// Serialize one finished sequence as its wire response line.
/// `replica` is `None` for requests no replica ever served (shed /
/// no-survivor failures) — reported as `"replica": null`.
pub fn response_json(id: u64, replica: Option<usize>, seq: &Sequence)
    -> String {
    let finish = match seq.finish {
        Some(FinishReason::Eos) => "eos",
        Some(FinishReason::MaxTokens) => "max_tokens",
        Some(FinishReason::PromptTooLong) => "prompt_too_long",
        Some(FinishReason::PoolExhausted) => "pool_exhausted",
        Some(FinishReason::Shed) => "shed",
        Some(FinishReason::ReplicaFailed) => "replica_failed",
        None => "unknown",
    };
    let ttft_ms = seq
        .first_token_at
        .map(|t| t.duration_since(seq.arrived).as_secs_f64() * 1e3)
        .unwrap_or(0.0);
    let e2e_ms = seq
        .finished_at
        .map(|t| t.duration_since(seq.arrived).as_secs_f64() * 1e3)
        .unwrap_or(0.0);
    Value::obj(vec![
        ("id", Value::num(id as f64)),
        ("replica",
         replica.map_or(Value::Null, |r| Value::num(r as f64))),
        ("tokens",
         Value::Arr(seq.output.iter().map(|&t| Value::num(t as f64))
             .collect())),
        ("finish", Value::str(finish)),
        ("ttft_ms", Value::num(ttft_ms)),
        ("e2e_ms", Value::num(e2e_ms)),
        ("cached_tokens", Value::num(seq.cached_prefix_len as f64)),
    ])
    .to_string()
}

/// Serialize the stats snapshot (per-replica rows + router counters)
/// as the `{"cmd":"stats"}` response.
pub fn stats_json(stats: &[ReplicaStats], router: &RouterStats)
    -> Value {
    Value::obj(vec![
        (
            "replicas",
            Value::Arr(
                stats
                    .iter()
                    .map(|s| {
                        Value::obj(vec![
                            ("id", Value::num(s.id as f64)),
                            ("requests_routed",
                             Value::num(s.requests_routed as f64)),
                            ("health", Value::str(s.health.as_str())),
                            ("replayed_out",
                             Value::num(s.replayed_out as f64)),
                            ("waiting",
                             Value::num(s.core.waiting as f64)),
                            ("running",
                             Value::num(s.core.running as f64)),
                            ("kv_occupancy",
                             Value::num(s.core.kv_occupancy)),
                            ("cache_hits",
                             Value::num(s.core.cache.hits as f64)),
                            ("cache_misses",
                             Value::num(s.core.cache.misses as f64)),
                            ("cache_hit_rate",
                             Value::num(s.core.cache_hit_rate())),
                            ("evictions",
                             Value::num(s.core.cache.evictions as f64)),
                            ("prefill_tokens_executed",
                             Value::num(s.core.prefill_tokens_executed
                                 as f64)),
                            ("cached_prefix_tokens",
                             Value::num(s.core.cached_prefix_tokens
                                 as f64)),
                            ("ttft_p50_steps",
                             Value::num(s.core.ttft_steps_p50)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "router",
            Value::obj(vec![
                ("shed", Value::num(router.shed as f64)),
                ("replayed", Value::num(router.replayed as f64)),
                ("retries", Value::num(router.retries as f64)),
                ("replica_failed",
                 Value::num(router.replica_failed as f64)),
                ("alive", Value::num(router.alive as f64)),
                ("dead", Value::num(router.dead as f64)),
                ("degraded", Value::Bool(router.degraded)),
            ]),
        ),
    ])
}

/// A required numeric field, as f64; errors name the missing field.
fn req_f64(v: &Value, path: &str, key: &str) -> Result<f64> {
    v.get(key).as_f64().with_context(|| {
        format!("{path}.{key}: missing or not a number")
    })
}

/// A required non-negative integer field; errors name the field.
fn req_usize(v: &Value, path: &str, key: &str) -> Result<usize> {
    let f = req_f64(v, path, key)?;
    if !f.is_finite() || f < 0.0 || f.fract() != 0.0 {
        anyhow::bail!(
            "{path}.{key}: must be a non-negative integer (got {f})"
        );
    }
    Ok(f as usize)
}

/// Decode a `{"cmd":"stats"}` response strictly: every field the
/// encoder writes must be present with the right type, and an error
/// names the first offending field — nothing is silently defaulted or
/// dropped. (The derived `cache_hit_rate` is re-derivable and
/// ignored; a `"quarantined"` health decodes with zeroed backoff
/// bookkeeping, which the wire format does not carry.)
pub fn decode_stats(v: &Value)
    -> Result<(Vec<ReplicaStats>, RouterStats)> {
    let reps = v
        .get("replicas")
        .as_arr()
        .context("replicas: missing or not an array")?;
    let mut rows = Vec::with_capacity(reps.len());
    for (i, r) in reps.iter().enumerate() {
        let path = format!("replicas[{i}]");
        let health = match r.get("health").as_str().with_context(|| {
            format!("{path}.health: missing or not a string")
        })? {
            "healthy" => ReplicaHealth::Healthy,
            "quarantined" => ReplicaHealth::Quarantined {
                failures: 0,
                retry_at_step: 0,
            },
            "dead" => ReplicaHealth::Dead,
            other => anyhow::bail!(
                "{path}.health: unknown state {other:?}"
            ),
        };
        let mut core = CoreStats {
            waiting: req_usize(r, &path, "waiting")?,
            running: req_usize(r, &path, "running")?,
            kv_occupancy: req_f64(r, &path, "kv_occupancy")?,
            prefill_tokens_executed:
                req_usize(r, &path, "prefill_tokens_executed")?,
            cached_prefix_tokens:
                req_usize(r, &path, "cached_prefix_tokens")?,
            ttft_steps_p50: req_f64(r, &path, "ttft_p50_steps")?,
            ..Default::default()
        };
        core.cache.hits = req_usize(r, &path, "cache_hits")?;
        core.cache.misses = req_usize(r, &path, "cache_misses")?;
        core.cache.evictions = req_usize(r, &path, "evictions")?;
        rows.push(ReplicaStats {
            id: req_usize(r, &path, "id")?,
            requests_routed: req_usize(r, &path, "requests_routed")?,
            health,
            replayed_out: req_usize(r, &path, "replayed_out")?,
            core,
        });
    }
    let ro = v.get("router");
    if ro.as_obj().is_none() {
        anyhow::bail!("router: missing or not an object");
    }
    let router = RouterStats {
        shed: req_usize(ro, "router", "shed")?,
        replayed: req_usize(ro, "router", "replayed")?,
        retries: req_usize(ro, "router", "retries")?,
        replica_failed: req_usize(ro, "router", "replica_failed")?,
        alive: req_usize(ro, "router", "alive")?,
        dead: req_usize(ro, "router", "dead")?,
        degraded: ro.get("degraded").as_bool().context(
            "router.degraded: missing or not a boolean",
        )?,
    };
    Ok((rows, router))
}

/// Format a metric value like the JSON encoder does (integers without
/// a fraction).
fn fmt_metric(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Render the stats snapshot as Prometheus-style text: one `# TYPE`
/// line per family, `{replica="i"}`-labelled per-replica samples,
/// unlabelled router-level samples, and a final `# EOF` line so
/// line-based clients can frame the body.
pub fn metrics_text(stats: &[ReplicaStats], router: &RouterStats)
    -> String {
    let mut out = String::new();
    let mut family = |name: &str, kind: &str,
                      samples: Vec<(String, f64)>| {
        out.push_str(&format!("# TYPE {name} {kind}\n"));
        for (labels, v) in samples {
            out.push_str(&format!("{name}{labels} {}\n",
                                  fmt_metric(v)));
        }
    };
    let per = |f: &dyn Fn(&ReplicaStats) -> f64| -> Vec<(String, f64)> {
        stats
            .iter()
            .map(|s| (format!("{{replica=\"{}\"}}", s.id), f(s)))
            .collect()
    };
    family(
        "sqplus_replica_up",
        "gauge",
        stats
            .iter()
            .map(|s| {
                (
                    format!("{{replica=\"{}\",health=\"{}\"}}",
                            s.id, s.health.as_str()),
                    if s.health.is_alive() { 1.0 } else { 0.0 },
                )
            })
            .collect(),
    );
    family("sqplus_replica_requests_routed", "counter",
           per(&|s| s.requests_routed as f64));
    family("sqplus_replica_replayed_out", "counter",
           per(&|s| s.replayed_out as f64));
    family("sqplus_replica_waiting", "gauge",
           per(&|s| s.core.waiting as f64));
    family("sqplus_replica_running", "gauge",
           per(&|s| s.core.running as f64));
    family("sqplus_replica_kv_occupancy", "gauge",
           per(&|s| s.core.kv_occupancy));
    family("sqplus_replica_cache_hits", "counter",
           per(&|s| s.core.cache.hits as f64));
    family("sqplus_replica_cache_misses", "counter",
           per(&|s| s.core.cache.misses as f64));
    family("sqplus_replica_cache_evictions", "counter",
           per(&|s| s.core.cache.evictions as f64));
    family("sqplus_replica_prefill_tokens_executed", "counter",
           per(&|s| s.core.prefill_tokens_executed as f64));
    family("sqplus_replica_cached_prefix_tokens", "counter",
           per(&|s| s.core.cached_prefix_tokens as f64));
    family("sqplus_replica_ttft_p50_steps", "gauge",
           per(&|s| s.core.ttft_steps_p50));
    let single = |v: f64| vec![(String::new(), v)];
    family("sqplus_router_shed_total", "counter",
           single(router.shed as f64));
    family("sqplus_router_replayed_total", "counter",
           single(router.replayed as f64));
    family("sqplus_router_retries_total", "counter",
           single(router.retries as f64));
    family("sqplus_router_replica_failed_total", "counter",
           single(router.replica_failed as f64));
    family("sqplus_router_replicas_alive", "gauge",
           single(router.alive as f64));
    family("sqplus_router_replicas_dead", "gauge",
           single(router.dead as f64));
    family("sqplus_router_degraded", "gauge",
           single(if router.degraded { 1.0 } else { 0.0 }));
    out.push_str("# EOF");
    out
}

enum Inbox {
    Submit(Request, mpsc::Sender<String>),
    Stats(mpsc::Sender<String>),
    Metrics(mpsc::Sender<String>),
    Shutdown,
}

/// Move-only wrapper that transfers the router to its serving thread.
///
/// SAFETY: `Engine` is not `Send` because the xla crate's PJRT handles
/// use `Rc` internally. Every `Rc` clone of a client lives inside the
/// same `Engine` (runtime buffers + executable cache), and every engine
/// lives inside this router, so moving the whole router to exactly one
/// thread — which is all this wrapper permits — never shares an `Rc`
/// across threads. The router thread is the sole owner for the rest of
/// its life.
struct SendRouter(Router<Engine>);
unsafe impl Send for SendRouter {}

/// A running server; `addr()` gives the bound address, `shutdown()`
/// stops the router loop after draining and joins every service
/// thread.
pub struct Server {
    addr: std::net::SocketAddr,
    inbox: mpsc::Sender<Inbox>,
    shutdown: Arc<AtomicBool>,
    router_thread: Option<std::thread::JoinHandle<()>>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Spawn the server on `127.0.0.1:port` (0 = ephemeral). Takes
    /// ownership of the router and its replicas (the PJRT runtimes are
    /// not Sync; they live on the router thread). A single engine can
    /// be served by wrapping it:
    /// `Server::spawn(Router::single(engine), port)`.
    pub fn spawn(router: Router<Engine>, port: u16) -> Result<Server> {
        // NB: bind the whole wrapper inside the closure — edition-2021
        // disjoint capture would otherwise capture the non-Send field.
        let boxed = SendRouter(router);
        Server::spawn_inner(port, move |rx| {
            let whole = boxed; // force whole-struct capture (RFC 2229)
            router_loop(whole.0, rx);
        })
    }

    /// Spawn the server over any `Send` replica core — the seam the
    /// server lifecycle tests use (a stub core needs no PJRT runtime).
    pub fn spawn_core<C>(router: Router<C>, port: u16) -> Result<Server>
    where
        C: ReplicaCore + Send + 'static,
    {
        Server::spawn_inner(port, move |rx| router_loop(router, rx))
    }

    fn spawn_inner(
        port: u16,
        run_router: impl FnOnce(mpsc::Receiver<Inbox>) + Send + 'static,
    ) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        let (tx, rx) = mpsc::channel::<Inbox>();
        let shutdown = Arc::new(AtomicBool::new(false));

        // router loop thread (sole owner of the replica cores)
        let router_thread = std::thread::spawn(move || run_router(rx));

        // accept loop thread; checks the shutdown flag per connection
        // (shutdown() self-connects to force one more iteration)
        let tx_accept = tx.clone();
        let flag = shutdown.clone();
        let accept_thread = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if flag.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { break };
                let tx = tx_accept.clone();
                let conn_flag = flag.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, tx, conn_flag);
                });
            }
        });

        Ok(Server {
            addr,
            inbox: tx,
            shutdown,
            router_thread: Some(router_thread),
            accept_thread: Some(accept_thread),
        })
    }

    /// The bound listen address.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting, drain in-flight requests, and join both service
    /// threads. Connection threads observe the flag at their next read
    /// timeout and exit on their own — an idle client cannot pin the
    /// process.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = self.inbox.send(Inbox::Shutdown);
        if let Some(t) = self.router_thread.take() {
            let _ = t.join();
        }
        // unblock the accept loop so it sees the flag, then join it
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_conn(stream: TcpStream, tx: mpsc::Sender<Inbox>,
               shutdown: Arc<AtomicBool>) -> Result<()> {
    // bounded reads: an idle client parks here at most one timeout
    // interval past shutdown instead of pinning the thread forever
    stream.set_read_timeout(Some(CONN_READ_TIMEOUT))?;
    let peer_read = stream.try_clone()?;
    let mut reader = BufReader::new(peer_read);
    let writer = Arc::new(Mutex::new(stream));
    // read_line appends, so a line split across timeouts accumulates
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock
                                         | ErrorKind::TimedOut) => {
                if shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        let req_line = line.trim().to_string();
        line.clear();
        if req_line.is_empty() {
            continue;
        }
        match parse_client_request(&req_line) {
            Ok(req) => {
                let (rtx, rrx) = mpsc::channel::<String>();
                let msg = match req {
                    ClientRequest::Generate(r) => Inbox::Submit(r, rtx),
                    ClientRequest::Stats => Inbox::Stats(rtx),
                    ClientRequest::Metrics => Inbox::Metrics(rtx),
                };
                if tx.send(msg).is_err() {
                    return Ok(());
                }
                // wait for the router's response, then write it back
                if let Ok(resp) = rrx.recv() {
                    let mut w = writer.lock().unwrap();
                    writeln!(w, "{resp}")?;
                }
            }
            Err(e) => {
                let mut w = writer.lock().unwrap();
                writeln!(w, "{}", Value::obj(vec![
                    ("error", Value::str(format!("{e}"))),
                ]))?;
            }
        }
    }
}

fn router_loop<C: ReplicaCore>(mut router: Router<C>,
                               rx: mpsc::Receiver<Inbox>) {
    let mut pending: HashMap<u64, mpsc::Sender<String>> = HashMap::new();
    let mut shutdown = false;
    loop {
        // deliver finished responses first: a submission can finish
        // without any engine work (e.g. prompt_too_long or shed), and
        // its response must go out before the loop blocks for new input
        for fin in router.take_finished() {
            if let Some(resp) = pending.remove(&fin.id) {
                let _ =
                    resp.send(response_json(fin.id, fin.replica, &fin.seq));
            }
        }
        if shutdown && !router.has_work() && pending.is_empty() {
            break;
        }
        // drain the inbox (blocking only while fully idle)
        loop {
            let msg = if router.has_work() || shutdown {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(mpsc::TryRecvError::Empty) => None,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        shutdown = true;
                        None
                    }
                }
            } else {
                match rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => {
                        shutdown = true;
                        None
                    }
                }
            };
            match msg {
                Some(Inbox::Submit(req, resp)) => {
                    let id = router.submit(req.prompt, req.params);
                    pending.insert(id, resp);
                    if !router.has_work() {
                        break; // finished at submission: drain now
                    }
                }
                Some(Inbox::Stats(resp)) => {
                    let _ = resp.send(
                        stats_json(&router.stats(),
                                   &router.router_stats())
                            .to_string(),
                    );
                }
                Some(Inbox::Metrics(resp)) => {
                    let _ = resp.send(metrics_text(
                        &router.stats(),
                        &router.router_stats(),
                    ));
                }
                Some(Inbox::Shutdown) => shutdown = true,
                None => break,
            }
            if shutdown {
                break;
            }
        }
        // step() handles replica failures internally (quarantine /
        // kill-and-replay) and only errs on router-fatal conditions
        if router.has_work() && router.step().is_err() {
            break;
        }
    }
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    stream: BufReader<TcpStream>,
}

impl Client {
    /// Connect to a running [`Server`].
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        Ok(Client { stream: BufReader::new(TcpStream::connect(addr)?) })
    }

    /// Send one generation request and wait for its response line.
    pub fn request(&mut self, prompt: &[u32], max_new: usize)
        -> Result<Value> {
        let req = Value::obj(vec![
            ("prompt",
             Value::Arr(prompt.iter().map(|&t| Value::num(t as f64))
                 .collect())),
            ("max_new_tokens", Value::num(max_new as f64)),
        ]);
        self.roundtrip(&req)
    }

    /// Request the stats snapshot (JSON).
    pub fn stats(&mut self) -> Result<Value> {
        self.roundtrip(&Value::obj(vec![("cmd", Value::str("stats"))]))
    }

    /// Request the Prometheus-style metrics text (everything up to,
    /// excluding, the `# EOF` frame line).
    pub fn metrics(&mut self) -> Result<String> {
        let s = self.stream.get_mut();
        writeln!(s, "{}",
                 Value::obj(vec![("cmd", Value::str("metrics"))]))?;
        let mut out = String::new();
        loop {
            let mut line = String::new();
            if self.stream.read_line(&mut line)? == 0 {
                anyhow::bail!("connection closed before # EOF");
            }
            if line.trim_end() == "# EOF" {
                return Ok(out);
            }
            out.push_str(&line);
        }
    }

    fn roundtrip(&mut self, req: &Value) -> Result<Value> {
        let s = self.stream.get_mut();
        writeln!(s, "{req}")?;
        let mut line = String::new();
        self.stream.read_line(&mut line)?;
        json::parse(line.trim()).map_err(|e| anyhow::anyhow!("resp: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CacheWatermarks, RouterConfig};
    use crate::coordinator::block_manager::CacheEvent;
    use crate::coordinator::engine::StepOutcome;
    use crate::coordinator::replica::ReplicaError;

    #[test]
    fn parse_request_fields() {
        let r = parse_request(
            r#"{"prompt":[1,2,3],"max_new_tokens":4,"temperature":0.5,
                "top_k":5,"seed":9}"#,
        )
        .unwrap();
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.params.max_new_tokens, 4);
        assert_eq!(r.params.temperature, 0.5);
        assert_eq!(r.params.top_k, 5);
        assert_eq!(r.params.seed, 9);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"promptX":[1]}"#).is_err());
    }

    #[test]
    fn parse_rejects_malformed_prompt_entries() {
        // these used to be silently coerced to token 0
        assert!(parse_request(r#"{"prompt":[1,"x",3]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[1,null]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[1.5]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[-3]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[1e12]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[[1]]}"#).is_err());
        assert!(parse_request(r#"{"prompt":7}"#).is_err());
        // boundary values that must still parse
        let r = parse_request(r#"{"prompt":[0, 4294967295]}"#).unwrap();
        assert_eq!(r.prompt, vec![0, u32::MAX]);
    }

    #[test]
    fn parse_rejects_zero_max_new_tokens() {
        // a 0-token generation budget admits a sequence that can never
        // produce a token — rejected like any other malformed field
        assert!(parse_request(r#"{"prompt":[1],"max_new_tokens":0}"#)
            .is_err());
        // 1 is the smallest valid budget; absent means the default
        let r = parse_request(r#"{"prompt":[1],"max_new_tokens":1}"#)
            .unwrap();
        assert_eq!(r.params.max_new_tokens, 1);
        let r = parse_request(r#"{"prompt":[1]}"#).unwrap();
        assert_eq!(r.params.max_new_tokens,
                   SamplingParams::default().max_new_tokens);
    }

    #[test]
    fn parse_client_request_dispatches() {
        assert!(matches!(parse_client_request(r#"{"cmd":"stats"}"#),
                         Ok(ClientRequest::Stats)));
        assert!(matches!(parse_client_request(r#"{"cmd":"metrics"}"#),
                         Ok(ClientRequest::Metrics)));
        assert!(parse_client_request(r#"{"cmd":"reboot"}"#).is_err());
        assert!(matches!(
            parse_client_request(r#"{"prompt":[1,2]}"#),
            Ok(ClientRequest::Generate(_))
        ));
        assert!(parse_client_request("not json").is_err());
    }

    #[test]
    fn parse_request_roundtrip() {
        // a request built the way `Client::request` builds it survives
        // serialize -> parse unchanged
        let prompt: Vec<u32> = vec![5, 0, 917, 64000];
        let req = Value::obj(vec![
            ("prompt",
             Value::Arr(prompt.iter().map(|&t| Value::num(t as f64))
                 .collect())),
            ("max_new_tokens", Value::num(9.0)),
            ("temperature", Value::num(0.25)),
        ]);
        let r = parse_request(&req.to_string()).unwrap();
        assert_eq!(r.prompt, prompt);
        assert_eq!(r.params.max_new_tokens, 9);
        assert_eq!(r.params.temperature, 0.25);
    }

    #[test]
    fn response_shape() {
        let mut s =
            Sequence::new(3, vec![1], SamplingParams::default());
        s.record_token(7);
        s.cached_prefix_len = 4;
        s.finish(FinishReason::MaxTokens);
        // global id 11 on replica 1 (seq.id is the replica-local id)
        let j = response_json(11, Some(1), &s);
        let v = json::parse(&j).unwrap();
        assert_eq!(v.get("id").as_usize(), Some(11));
        assert_eq!(v.get("replica").as_usize(), Some(1));
        assert_eq!(v.get("finish").as_str(), Some("max_tokens"));
        assert_eq!(v.get("tokens").as_arr().unwrap().len(), 1);
        assert_eq!(v.get("cached_tokens").as_usize(), Some(4));
    }

    #[test]
    fn response_shape_for_unrouted_finishes() {
        // shed / no-survivor responses carry no replica: null on the
        // wire, not 0 (which is a real replica id)
        let mut s =
            Sequence::new(0, vec![1, 2], SamplingParams::default());
        s.finish(FinishReason::Shed);
        let v = json::parse(&response_json(5, None, &s)).unwrap();
        assert_eq!(*v.get("replica"), Value::Null);
        assert_eq!(v.get("finish").as_str(), Some("shed"));
        let mut s =
            Sequence::new(0, vec![1, 2], SamplingParams::default());
        s.finish(FinishReason::ReplicaFailed);
        let v = json::parse(&response_json(6, None, &s)).unwrap();
        assert_eq!(v.get("finish").as_str(), Some("replica_failed"));
    }

    fn sample_rows() -> (Vec<ReplicaStats>, RouterStats) {
        let mut core = CoreStats {
            waiting: 2,
            running: 3,
            kv_occupancy: 0.5,
            ..Default::default()
        };
        core.cache.hits = 6;
        core.cache.misses = 2;
        core.cache.evictions = 1;
        core.prefill_tokens_executed = 120;
        core.cached_prefix_tokens = 48;
        core.ttft_steps_p50 = 2.5;
        let rows = vec![
            ReplicaStats {
                id: 0,
                requests_routed: 4,
                health: ReplicaHealth::Healthy,
                replayed_out: 0,
                core,
            },
            ReplicaStats {
                id: 1,
                requests_routed: 2,
                health: ReplicaHealth::Dead,
                replayed_out: 3,
                core: CoreStats::default(),
            },
        ];
        let router = RouterStats {
            shed: 5,
            replayed: 3,
            retries: 7,
            replica_failed: 1,
            alive: 1,
            dead: 1,
            degraded: true,
        };
        (rows, router)
    }

    #[test]
    fn stats_json_roundtrip() {
        let (rows, router) = sample_rows();
        let v = json::parse(&stats_json(&rows, &router).to_string())
            .unwrap();
        let reps = v.get("replicas").as_arr().unwrap();
        assert_eq!(reps.len(), 2);
        let r0 = &reps[0];
        assert_eq!(r0.get("id").as_usize(), Some(0));
        assert_eq!(r0.get("requests_routed").as_usize(), Some(4));
        assert_eq!(r0.get("health").as_str(), Some("healthy"));
        assert_eq!(r0.get("replayed_out").as_usize(), Some(0));
        assert_eq!(r0.get("waiting").as_usize(), Some(2));
        assert_eq!(r0.get("running").as_usize(), Some(3));
        assert_eq!(r0.get("kv_occupancy").as_f64(), Some(0.5));
        assert_eq!(r0.get("cache_hits").as_usize(), Some(6));
        assert_eq!(r0.get("cache_misses").as_usize(), Some(2));
        assert_eq!(r0.get("cache_hit_rate").as_f64(), Some(0.75));
        assert_eq!(r0.get("evictions").as_usize(), Some(1));
        assert_eq!(r0.get("prefill_tokens_executed").as_usize(),
                   Some(120));
        assert_eq!(r0.get("cached_prefix_tokens").as_usize(), Some(48));
        assert_eq!(r0.get("ttft_p50_steps").as_f64(), Some(2.5));
        let r1 = &reps[1];
        assert_eq!(r1.get("id").as_usize(), Some(1));
        assert_eq!(r1.get("health").as_str(), Some("dead"));
        assert_eq!(r1.get("replayed_out").as_usize(), Some(3));
        assert_eq!(r1.get("cache_hit_rate").as_f64(), Some(0.0));
        let ro = v.get("router");
        assert_eq!(ro.get("shed").as_usize(), Some(5));
        assert_eq!(ro.get("replayed").as_usize(), Some(3));
        assert_eq!(ro.get("retries").as_usize(), Some(7));
        assert_eq!(ro.get("replica_failed").as_usize(), Some(1));
        assert_eq!(ro.get("alive").as_usize(), Some(1));
        assert_eq!(ro.get("dead").as_usize(), Some(1));
        assert_eq!(ro.get("degraded").as_bool(), Some(true));
    }

    #[test]
    fn decode_stats_inverts_the_encoder() {
        let (rows, router) = sample_rows();
        let v = json::parse(&stats_json(&rows, &router).to_string())
            .unwrap();
        let (drows, drouter) = decode_stats(&v).unwrap();
        assert_eq!(drouter, router);
        assert_eq!(drows.len(), rows.len());
        for (d, r) in drows.iter().zip(&rows) {
            assert_eq!(d.id, r.id);
            assert_eq!(d.requests_routed, r.requests_routed);
            assert_eq!(d.health.as_str(), r.health.as_str());
            assert_eq!(d.replayed_out, r.replayed_out);
            assert_eq!(d.core.waiting, r.core.waiting);
            assert_eq!(d.core.running, r.core.running);
            assert_eq!(d.core.kv_occupancy, r.core.kv_occupancy);
            assert_eq!(d.core.cache.hits, r.core.cache.hits);
            assert_eq!(d.core.cache.misses, r.core.cache.misses);
            assert_eq!(d.core.cache.evictions, r.core.cache.evictions);
            assert_eq!(d.core.prefill_tokens_executed,
                       r.core.prefill_tokens_executed);
            assert_eq!(d.core.cached_prefix_tokens,
                       r.core.cached_prefix_tokens);
            assert_eq!(d.core.ttft_steps_p50, r.core.ttft_steps_p50);
        }
    }

    #[test]
    fn decode_stats_rejects_malformed_input() {
        // strict: a missing or mistyped field errors (naming it),
        // instead of being silently defaulted
        let (rows, router) = sample_rows();
        let good = stats_json(&rows, &router).to_string();
        // no replicas array at all
        let e = decode_stats(&json::parse(r#"{}"#).unwrap())
            .unwrap_err();
        assert!(format!("{e:#}").contains("replicas"));
        // drop one per-replica field
        let broken = good.replacen(r#""waiting":2,"#, "", 1);
        let e = decode_stats(&json::parse(&broken).unwrap())
            .unwrap_err();
        assert!(format!("{e:#}").contains("replicas[0].waiting"));
        // mistype a router field
        let broken = good.replacen(r#""shed":5"#, r#""shed":"5""#, 1);
        let e = decode_stats(&json::parse(&broken).unwrap())
            .unwrap_err();
        assert!(format!("{e:#}").contains("router.shed"));
        // unknown health state
        let broken =
            good.replacen(r#""health":"dead""#, r#""health":"zombie""#, 1);
        let e = decode_stats(&json::parse(&broken).unwrap())
            .unwrap_err();
        assert!(format!("{e:#}").contains("health"));
        // drop the router object
        let broken = json::parse(&good).unwrap();
        let mut o = broken.as_obj().unwrap().clone();
        o.remove("router");
        let e = decode_stats(&Value::Obj(o)).unwrap_err();
        assert!(format!("{e:#}").contains("router"));
    }

    #[test]
    fn metrics_text_shape() {
        let (rows, router) = sample_rows();
        let text = metrics_text(&rows, &router);
        assert!(text
            .contains("# TYPE sqplus_replica_waiting gauge\n"));
        assert!(text
            .contains("sqplus_replica_waiting{replica=\"0\"} 2\n"));
        assert!(text.contains(
            "sqplus_replica_up{replica=\"0\",health=\"healthy\"} 1\n"
        ));
        assert!(text.contains(
            "sqplus_replica_up{replica=\"1\",health=\"dead\"} 0\n"
        ));
        assert!(text
            .contains("sqplus_replica_replayed_out{replica=\"1\"} 3\n"));
        assert!(text.contains("sqplus_router_shed_total 5\n"));
        assert!(text.contains("sqplus_router_degraded 1\n"));
        assert!(text
            .contains("sqplus_replica_ttft_p50_steps{replica=\"0\"} 2.5\n"));
        // framed for line-based clients
        assert!(text.ends_with("# EOF"));
        // every non-comment line is `name{labels} value`
        for l in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(l.rsplit_once(' ').is_some(), "bad sample: {l}");
        }
    }

    /// A stub core that finishes every request at submission (echoing
    /// one token) — enough to drive the full server lifecycle without
    /// a PJRT runtime.
    struct EchoCore {
        next: u64,
        finished: Vec<Sequence>,
    }
    impl EchoCore {
        fn new() -> EchoCore {
            EchoCore { next: 0, finished: vec![] }
        }
    }
    impl ReplicaCore for EchoCore {
        fn submit(&mut self, prompt: Vec<u32>, params: SamplingParams)
            -> Result<u64, ReplicaError> {
            let id = self.next;
            self.next += 1;
            let first = prompt.first().copied().unwrap_or(0);
            let mut seq = Sequence::new(id, prompt, params);
            seq.record_token(first);
            seq.finish(FinishReason::MaxTokens);
            self.finished.push(seq);
            Ok(id)
        }
        fn step(&mut self) -> Result<StepOutcome, ReplicaError> {
            Ok(StepOutcome::Idle)
        }
        fn has_work(&self) -> bool {
            false
        }
        fn take_finished(&mut self) -> Vec<Sequence> {
            std::mem::take(&mut self.finished)
        }
        fn drain_inflight(&mut self) -> Vec<Sequence> {
            vec![]
        }
        fn block_size(&self) -> usize {
            4
        }
        fn queue_depths(&self) -> (usize, usize) {
            (0, 0)
        }
        fn enable_cache_events(&mut self) {}
        fn take_cache_events(&mut self) -> Vec<CacheEvent> {
            vec![]
        }
        fn set_cache_watermarks(&mut self, _: CacheWatermarks) {}
        fn core_stats(&self) -> CoreStats {
            CoreStats::default()
        }
    }

    fn echo_router() -> Router<EchoCore> {
        Router::new(vec![EchoCore::new()], RouterConfig::default())
    }

    #[test]
    fn server_round_trips_and_shuts_down_with_idle_connection() {
        let server = Server::spawn_core(echo_router(), 0).unwrap();
        let addr = server.addr();
        let mut c = Client::connect(addr).unwrap();
        let v = c.request(&[7, 8, 9], 4).unwrap();
        assert_eq!(v.get("finish").as_str(), Some("max_tokens"));
        assert_eq!(v.get("replica").as_usize(), Some(0));
        assert_eq!(v.get("tokens").as_arr().unwrap().len(), 1);
        // a second, never-used connection stays idle through shutdown:
        // the regression this pins is shutdown() hanging on (or
        // leaking) the accept loop and timeout-less reader threads
        let _idle = Client::connect(addr).unwrap();
        let (tx, rx) = mpsc::channel();
        std::thread::spawn(move || {
            server.shutdown();
            let _ = tx.send(());
        });
        assert!(
            rx.recv_timeout(Duration::from_secs(30)).is_ok(),
            "shutdown hung with an idle connection open"
        );
        drop(c);
    }

    #[test]
    fn server_stats_and_metrics_over_the_wire() {
        let server = Server::spawn_core(echo_router(), 0).unwrap();
        let mut c = Client::connect(server.addr()).unwrap();
        c.request(&[1, 2], 2).unwrap();
        let v = c.stats().unwrap();
        let (rows, router) = decode_stats(&v).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].requests_routed, 1);
        assert_eq!(rows[0].health.as_str(), "healthy");
        assert_eq!(router.alive, 1);
        assert!(!router.degraded);
        let text = c.metrics().unwrap();
        assert!(text.contains(
            "sqplus_replica_requests_routed{replica=\"0\"} 1\n"
        ));
        assert!(text.contains("sqplus_router_replicas_alive 1\n"));
        assert!(!text.contains("# EOF"), "frame line must be stripped");
        // the same connection still serves generation afterwards
        let v = c.request(&[3], 1).unwrap();
        assert_eq!(v.get("finish").as_str(), Some("max_tokens"));
        server.shutdown();
    }
}
