//! JSON-lines TCP serving front end (std::net + threads; tokio is not
//! available in the offline build) over the threaded multi-replica
//! [`AsyncRouter`](crate::coordinator::worker::AsyncRouter) (or, with
//! [`ServeOptions::sync_loop`], the synchronous
//! [`Router`](crate::coordinator::router::Router) loop).
//!
//! Wire protocol — one JSON object per line:
//!
//! ```text
//! -> {"prompt": [1,2,3], "max_new_tokens": 8, "temperature": 0.0}
//! <- {"id": 0, "replica": 0, "tokens": [4,5,...], "finish": "max_tokens",
//!     "ttft_ms": 12.3, "e2e_ms": 80.1, "cached_tokens": 0}
//!
//! -> {"prompt": [1,2,3], "max_new_tokens": 3, "stream": true}
//! <- {"id": 1, "index": 0, "token": 4}
//! <- {"id": 1, "index": 1, "token": 5}
//! <- {"id": 1, "index": 2, "token": 6}
//! <- {"id": 1, "replica": 0, "tokens": [4,5,6], "finish": "max_tokens",
//!     "ttft_ms": 12.3, "e2e_ms": 80.1, "cached_tokens": 0}
//!
//! -> {"cmd": "stats"}
//! <- {"replicas": [{"id": 0, "requests_routed": 4, "health": "healthy",
//!     "replayed_out": 0, "waiting": 0, "running": 1,
//!     "kv_occupancy": 0.03, "cache_hits": 6, "cache_misses": 2,
//!     "cache_hit_rate": 0.75, "evictions": 0,
//!     "prefill_tokens_executed": 120, "cached_prefix_tokens": 48,
//!     "ttft_p50_steps": 2.0, "pool_blocks": 1, "pool_demotions": 4,
//!     "pool_restores": 2, "recompute_avoided_tokens": 32,
//!     "kv_migrations_in": 0, "kv_migrations_out": 0,
//!     "migrated_bytes": 0}],
//!     "router": {"shed": 0, "replayed": 0, "retries": 0,
//!     "replica_failed": 0, "alive": 1, "dead": 0, "degraded": false,
//!     "migration_fallbacks": 0}}
//!
//! -> {"cmd": "metrics"}
//! <- # TYPE sqplus_replica_up gauge
//!    sqplus_replica_up{replica="0",health="healthy"} 1
//!    ...
//!    # TYPE sqplus_router_shed_total counter
//!    sqplus_router_shed_total 0
//!    ...
//!    # EOF
//! ```
//!
//! `prompt` entries must be non-negative integer token ids and
//! `max_new_tokens`, when present, must be at least 1 (a request that
//! can never produce a token is malformed); any violation rejects the
//! whole request with an `{"error": ...}` line — nothing is silently
//! coerced or clamped to a different meaning. `replica` is the id of
//! the router replica that served the request — `null` when no replica
//! ever did (the request was shed at admission, or every replica died);
//! `cached_tokens` reports how many tokens were served from that
//! replica's shared prefix cache at the last admission (see
//! [`crate::coordinator`] for the design: chained content hashes over
//! full KV blocks, refcounted sharing, CoW tail block, LRU +
//! sliding-window eviction, chunked prefill; `docs/ARCHITECTURE.md`
//! walks a request end to end). `finish` is one of `max_tokens`, `eos`,
//! `prompt_too_long`, `pool_exhausted` (the request alone outgrew the
//! KV pool), `shed` (rejected by the router's load-shedding admission
//! control), or `replica_failed` (the serving replica died with no
//! survivor to replay onto). A request whose replica dies mid-stream
//! with a survivor is replayed transparently: its response carries the
//! full stitched token stream and the survivor's replica id.
//!
//! With `"stream": true` the response is preceded by one JSON line per
//! emitted token — `{"id", "index", "token"}`, `index` contiguous from
//! 0 — and always terminated by the normal response line (which
//! repeats the full token list, so a streaming client can verify it
//! dropped nothing). Replica death mid-stream does not restart the
//! stream: replayed tokens are never re-sent, and indices stay
//! contiguous across the replay.
//!
//! The `{"cmd": "stats"}` admin request snapshots one row per replica —
//! queue depth (`waiting`/`running`), health state, KV occupancy,
//! block-level cache hit/miss/eviction counters with the derived hit
//! rate, prefill tokens executed vs served from cache, the
//! TTFT-in-steps p50, and how many in-flight requests were replayed off
//! the replica at death — plus a `"router"` object with the shedding /
//! replay / retry counters and the degraded flag. `{"cmd": "metrics"}`
//! reports the same snapshot as Prometheus-style text (`# TYPE` +
//! name-value lines, `{replica="i"}` labels), terminated by a `# EOF`
//! line so line-based clients can frame the multi-line body.
//!
//! Architecture: connection threads parse requests into an inbox; the
//! serving thread drains the inbox into the router front end and
//! routes response lines back through bounded per-request channels;
//! each replica core steps continuously on its own worker thread
//! (see [`crate::coordinator::worker`]) — or, in `sync_loop` mode, the
//! serving thread itself steps every replica in turn. Each connection
//! thread owns its write half outright (requests on one connection are
//! served strictly in order, so no lock is needed — and no lock means
//! no poison to cascade). Response channels are bounded
//! ([`ServeOptions::stream_buffer`] lines for a stream): when a slow
//! reader's channel fills, its remaining lines park in the serving
//! thread's per-stream queue and are re-offered round-robin each pass
//! — a stalled client delays only its own stream, never a replica
//! step and never another client. Connection reads carry a short
//! timeout so an idle client can never pin its thread past shutdown:
//! [`Server::shutdown`] raises a flag, drains in-flight work (streams
//! in flight get their token and finish lines), and joins *both*
//! service threads (accept loop included — a self-connect wakes it to
//! observe the flag).

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::{CacheWatermarks, RouterConfig};
use crate::coordinator::block_manager::CacheEvent;
use crate::coordinator::engine::{Engine, StepOutcome};
use crate::coordinator::replica::{
    CoreStats, ReplicaCore, ReplicaError, ReplicaHealth, ReplicaStats,
};
use crate::coordinator::router::{Router, RouterStats};
use crate::coordinator::sequence::{
    FinishReason, SamplingParams, Sequence,
};
use crate::coordinator::worker::{AsyncRouter, RouterEvent};
use crate::util::json::{self, Value};

/// How long a connection thread blocks on a read before re-checking
/// the shutdown flag. Short enough that shutdown never waits on an
/// idle client; long enough to stay off the scheduler's hot path.
const CONN_READ_TIMEOUT: Duration = Duration::from_millis(100);

/// A parsed generation request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Prompt token ids (validated non-negative integers).
    pub prompt: Vec<u32>,
    /// Sampling parameters (defaults filled for absent fields).
    pub params: SamplingParams,
    /// Stream one `{"id","index","token"}` line per emitted token
    /// before the response line (`"stream": true` on the wire).
    pub stream: bool,
}

/// Any parsed client line: a generation request or an admin command.
#[derive(Debug, Clone)]
pub enum ClientRequest {
    /// `{"prompt": [...], ...}` — generate tokens.
    Generate(Request),
    /// `{"cmd": "stats"}` — per-replica + router stats snapshot (JSON).
    Stats,
    /// `{"cmd": "metrics"}` — the same snapshot as Prometheus-style
    /// text.
    Metrics,
}

/// Parse one generation-request line (strict: malformed prompt entries
/// or a zero `max_new_tokens` reject the whole request).
pub fn parse_request(line: &str) -> Result<Request> {
    let v = json::parse(line).map_err(|e| anyhow::anyhow!("json: {e}"))?;
    let arr = v
        .get("prompt")
        .as_arr()
        .context("prompt must be an array of token ids")?;
    let mut prompt = Vec::with_capacity(arr.len());
    for (i, t) in arr.iter().enumerate() {
        let f = t.as_f64().with_context(|| {
            format!("prompt[{i}] must be a number, not {t}")
        })?;
        if !f.is_finite() || f < 0.0 || f.fract() != 0.0
            || f > u32::MAX as f64
        {
            anyhow::bail!(
                "prompt[{i}] must be a non-negative integer token id \
                 (got {f})"
            );
        }
        prompt.push(f as u32);
    }
    let mut params = SamplingParams::default();
    if let Some(m) = v.get("max_new_tokens").as_usize() {
        if m == 0 {
            // a 0-token budget would admit a sequence that can never
            // produce a token: malformed, like any other bad field
            anyhow::bail!("max_new_tokens must be at least 1 (got 0)");
        }
        params.max_new_tokens = m;
    }
    if let Some(t) = v.get("temperature").as_f64() {
        params.temperature = t as f32;
    }
    if let Some(k) = v.get("top_k").as_usize() {
        params.top_k = k;
    }
    if let Some(s) = v.get("seed").as_f64() {
        params.seed = s as u64;
    }
    let stream = v.get("stream").as_bool().unwrap_or(false);
    Ok(Request { prompt, params, stream })
}

/// Serialize one incrementally emitted token as its wire line.
pub fn token_json(id: u64, index: usize, token: u32) -> String {
    Value::obj(vec![
        ("id", Value::num(id as f64)),
        ("index", Value::num(index as f64)),
        ("token", Value::num(token as f64)),
    ])
    .to_string()
}

/// Parse any client line: `{"cmd": ...}` admin commands first, else a
/// generation request.
pub fn parse_client_request(line: &str) -> Result<ClientRequest> {
    let v = json::parse(line).map_err(|e| anyhow::anyhow!("json: {e}"))?;
    if let Some(cmd) = v.get("cmd").as_str() {
        return match cmd {
            "stats" => Ok(ClientRequest::Stats),
            "metrics" => Ok(ClientRequest::Metrics),
            other => Err(anyhow::anyhow!("unknown cmd {other:?}")),
        };
    }
    parse_request(line).map(ClientRequest::Generate)
}

/// Serialize one finished sequence as its wire response line.
/// `replica` is `None` for requests no replica ever served (shed /
/// no-survivor failures) — reported as `"replica": null`.
pub fn response_json(id: u64, replica: Option<usize>, seq: &Sequence)
    -> String {
    let finish = match seq.finish {
        Some(FinishReason::Eos) => "eos",
        Some(FinishReason::MaxTokens) => "max_tokens",
        Some(FinishReason::PromptTooLong) => "prompt_too_long",
        Some(FinishReason::PoolExhausted) => "pool_exhausted",
        Some(FinishReason::Shed) => "shed",
        Some(FinishReason::ReplicaFailed) => "replica_failed",
        None => "unknown",
    };
    let ttft_ms = seq
        .first_token_at
        .map(|t| t.duration_since(seq.arrived).as_secs_f64() * 1e3)
        .unwrap_or(0.0);
    let e2e_ms = seq
        .finished_at
        .map(|t| t.duration_since(seq.arrived).as_secs_f64() * 1e3)
        .unwrap_or(0.0);
    Value::obj(vec![
        ("id", Value::num(id as f64)),
        ("replica",
         replica.map_or(Value::Null, |r| Value::num(r as f64))),
        ("tokens",
         Value::Arr(seq.output.iter().map(|&t| Value::num(t as f64))
             .collect())),
        ("finish", Value::str(finish)),
        ("ttft_ms", Value::num(ttft_ms)),
        ("e2e_ms", Value::num(e2e_ms)),
        ("cached_tokens", Value::num(seq.cached_prefix_len as f64)),
    ])
    .to_string()
}

/// Serialize the stats snapshot (per-replica rows + router counters)
/// as the `{"cmd":"stats"}` response.
pub fn stats_json(stats: &[ReplicaStats], router: &RouterStats)
    -> Value {
    Value::obj(vec![
        (
            "replicas",
            Value::Arr(
                stats
                    .iter()
                    .map(|s| {
                        Value::obj(vec![
                            ("id", Value::num(s.id as f64)),
                            ("requests_routed",
                             Value::num(s.requests_routed as f64)),
                            ("health", Value::str(s.health.as_str())),
                            ("replayed_out",
                             Value::num(s.replayed_out as f64)),
                            ("waiting",
                             Value::num(s.core.waiting as f64)),
                            ("running",
                             Value::num(s.core.running as f64)),
                            ("kv_occupancy",
                             Value::num(s.core.kv_occupancy)),
                            ("cache_hits",
                             Value::num(s.core.cache.hits as f64)),
                            ("cache_misses",
                             Value::num(s.core.cache.misses as f64)),
                            ("cache_hit_rate",
                             Value::num(s.core.cache_hit_rate())),
                            ("evictions",
                             Value::num(s.core.cache.evictions as f64)),
                            ("prefill_tokens_executed",
                             Value::num(s.core.prefill_tokens_executed
                                 as f64)),
                            ("cached_prefix_tokens",
                             Value::num(s.core.cached_prefix_tokens
                                 as f64)),
                            ("ttft_p50_steps",
                             Value::num(s.core.ttft_steps_p50)),
                            ("pool_blocks",
                             Value::num(s.core.pool_blocks as f64)),
                            ("pool_demotions",
                             Value::num(s.core.cache.demotions
                                 as f64)),
                            ("pool_restores",
                             Value::num(s.core.cache.restores as f64)),
                            ("recompute_avoided_tokens",
                             Value::num(s.core.recompute_avoided_tokens
                                 as f64)),
                            ("kv_migrations_in",
                             Value::num(s.core.kv_migrations_in
                                 as f64)),
                            ("kv_migrations_out",
                             Value::num(s.core.kv_migrations_out
                                 as f64)),
                            ("migrated_bytes",
                             Value::num(s.core.migrated_bytes as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "router",
            Value::obj(vec![
                ("shed", Value::num(router.shed as f64)),
                ("replayed", Value::num(router.replayed as f64)),
                ("retries", Value::num(router.retries as f64)),
                ("replica_failed",
                 Value::num(router.replica_failed as f64)),
                ("alive", Value::num(router.alive as f64)),
                ("dead", Value::num(router.dead as f64)),
                ("degraded", Value::Bool(router.degraded)),
                ("migration_fallbacks",
                 Value::num(router.migration_fallbacks as f64)),
            ]),
        ),
    ])
}

/// A required numeric field, as f64; errors name the missing field.
fn req_f64(v: &Value, path: &str, key: &str) -> Result<f64> {
    v.get(key).as_f64().with_context(|| {
        format!("{path}.{key}: missing or not a number")
    })
}

/// A required non-negative integer field; errors name the field.
fn req_usize(v: &Value, path: &str, key: &str) -> Result<usize> {
    let f = req_f64(v, path, key)?;
    if !f.is_finite() || f < 0.0 || f.fract() != 0.0 {
        anyhow::bail!(
            "{path}.{key}: must be a non-negative integer (got {f})"
        );
    }
    Ok(f as usize)
}

/// Decode a `{"cmd":"stats"}` response strictly: every field the
/// encoder writes must be present with the right type, and an error
/// names the first offending field — nothing is silently defaulted or
/// dropped. (The derived `cache_hit_rate` is re-derivable and
/// ignored; a `"quarantined"` health decodes with zeroed backoff
/// bookkeeping, which the wire format does not carry.)
pub fn decode_stats(v: &Value)
    -> Result<(Vec<ReplicaStats>, RouterStats)> {
    let reps = v
        .get("replicas")
        .as_arr()
        .context("replicas: missing or not an array")?;
    let mut rows = Vec::with_capacity(reps.len());
    for (i, r) in reps.iter().enumerate() {
        let path = format!("replicas[{i}]");
        let health = match r.get("health").as_str().with_context(|| {
            format!("{path}.health: missing or not a string")
        })? {
            "healthy" => ReplicaHealth::Healthy,
            "quarantined" => ReplicaHealth::Quarantined {
                failures: 0,
                retry_at_step: 0,
            },
            "dead" => ReplicaHealth::Dead,
            other => anyhow::bail!(
                "{path}.health: unknown state {other:?}"
            ),
        };
        let mut core = CoreStats {
            waiting: req_usize(r, &path, "waiting")?,
            running: req_usize(r, &path, "running")?,
            kv_occupancy: req_f64(r, &path, "kv_occupancy")?,
            prefill_tokens_executed:
                req_usize(r, &path, "prefill_tokens_executed")?,
            cached_prefix_tokens:
                req_usize(r, &path, "cached_prefix_tokens")?,
            ttft_steps_p50: req_f64(r, &path, "ttft_p50_steps")?,
            pool_blocks: req_usize(r, &path, "pool_blocks")?,
            recompute_avoided_tokens:
                req_usize(r, &path, "recompute_avoided_tokens")?,
            kv_migrations_in: req_usize(r, &path, "kv_migrations_in")?,
            kv_migrations_out:
                req_usize(r, &path, "kv_migrations_out")?,
            migrated_bytes: req_usize(r, &path, "migrated_bytes")?,
            ..Default::default()
        };
        core.cache.hits = req_usize(r, &path, "cache_hits")?;
        core.cache.misses = req_usize(r, &path, "cache_misses")?;
        core.cache.evictions = req_usize(r, &path, "evictions")?;
        core.cache.demotions = req_usize(r, &path, "pool_demotions")?;
        core.cache.restores = req_usize(r, &path, "pool_restores")?;
        rows.push(ReplicaStats {
            id: req_usize(r, &path, "id")?,
            requests_routed: req_usize(r, &path, "requests_routed")?,
            health,
            replayed_out: req_usize(r, &path, "replayed_out")?,
            core,
        });
    }
    let ro = v.get("router");
    if ro.as_obj().is_none() {
        anyhow::bail!("router: missing or not an object");
    }
    let router = RouterStats {
        shed: req_usize(ro, "router", "shed")?,
        replayed: req_usize(ro, "router", "replayed")?,
        retries: req_usize(ro, "router", "retries")?,
        replica_failed: req_usize(ro, "router", "replica_failed")?,
        alive: req_usize(ro, "router", "alive")?,
        dead: req_usize(ro, "router", "dead")?,
        degraded: ro.get("degraded").as_bool().context(
            "router.degraded: missing or not a boolean",
        )?,
        migration_fallbacks:
            req_usize(ro, "router", "migration_fallbacks")?,
    };
    Ok((rows, router))
}

/// Format a metric value like the JSON encoder does (integers without
/// a fraction).
fn fmt_metric(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Render the stats snapshot as Prometheus-style text: one `# TYPE`
/// line per family, `{replica="i"}`-labelled per-replica samples,
/// unlabelled router-level samples, and a final `# EOF` line so
/// line-based clients can frame the body.
pub fn metrics_text(stats: &[ReplicaStats], router: &RouterStats)
    -> String {
    let mut out = String::new();
    let mut family = |name: &str, kind: &str,
                      samples: Vec<(String, f64)>| {
        out.push_str(&format!("# TYPE {name} {kind}\n"));
        for (labels, v) in samples {
            out.push_str(&format!("{name}{labels} {}\n",
                                  fmt_metric(v)));
        }
    };
    let per = |f: &dyn Fn(&ReplicaStats) -> f64| -> Vec<(String, f64)> {
        stats
            .iter()
            .map(|s| (format!("{{replica=\"{}\"}}", s.id), f(s)))
            .collect()
    };
    family(
        "sqplus_replica_up",
        "gauge",
        stats
            .iter()
            .map(|s| {
                (
                    format!("{{replica=\"{}\",health=\"{}\"}}",
                            s.id, s.health.as_str()),
                    if s.health.is_alive() { 1.0 } else { 0.0 },
                )
            })
            .collect(),
    );
    family("sqplus_replica_requests_routed", "counter",
           per(&|s| s.requests_routed as f64));
    family("sqplus_replica_replayed_out", "counter",
           per(&|s| s.replayed_out as f64));
    family("sqplus_replica_waiting", "gauge",
           per(&|s| s.core.waiting as f64));
    family("sqplus_replica_running", "gauge",
           per(&|s| s.core.running as f64));
    family("sqplus_replica_kv_occupancy", "gauge",
           per(&|s| s.core.kv_occupancy));
    family("sqplus_replica_cache_hits", "counter",
           per(&|s| s.core.cache.hits as f64));
    family("sqplus_replica_cache_misses", "counter",
           per(&|s| s.core.cache.misses as f64));
    family("sqplus_replica_cache_evictions", "counter",
           per(&|s| s.core.cache.evictions as f64));
    family("sqplus_replica_prefill_tokens_executed", "counter",
           per(&|s| s.core.prefill_tokens_executed as f64));
    family("sqplus_replica_cached_prefix_tokens", "counter",
           per(&|s| s.core.cached_prefix_tokens as f64));
    family("sqplus_replica_ttft_p50_steps", "gauge",
           per(&|s| s.core.ttft_steps_p50));
    family("sqplus_replica_pool_blocks", "gauge",
           per(&|s| s.core.pool_blocks as f64));
    family("sqplus_replica_pool_demotions", "counter",
           per(&|s| s.core.cache.demotions as f64));
    family("sqplus_replica_pool_restores", "counter",
           per(&|s| s.core.cache.restores as f64));
    family("sqplus_replica_recompute_avoided_tokens", "counter",
           per(&|s| s.core.recompute_avoided_tokens as f64));
    family("sqplus_replica_kv_migrations_in", "counter",
           per(&|s| s.core.kv_migrations_in as f64));
    family("sqplus_replica_kv_migrations_out", "counter",
           per(&|s| s.core.kv_migrations_out as f64));
    family("sqplus_replica_migrated_bytes", "counter",
           per(&|s| s.core.migrated_bytes as f64));
    let single = |v: f64| vec![(String::new(), v)];
    family("sqplus_router_shed_total", "counter",
           single(router.shed as f64));
    family("sqplus_router_replayed_total", "counter",
           single(router.replayed as f64));
    family("sqplus_router_retries_total", "counter",
           single(router.retries as f64));
    family("sqplus_router_replica_failed_total", "counter",
           single(router.replica_failed as f64));
    family("sqplus_router_replicas_alive", "gauge",
           single(router.alive as f64));
    family("sqplus_router_replicas_dead", "gauge",
           single(router.dead as f64));
    family("sqplus_router_degraded", "gauge",
           single(if router.degraded { 1.0 } else { 0.0 }));
    family("sqplus_router_migration_fallbacks_total", "counter",
           single(router.migration_fallbacks as f64));
    out.push_str("# EOF");
    out
}

enum Inbox {
    Submit(Request, mpsc::SyncSender<String>),
    Stats(mpsc::SyncSender<String>),
    Metrics(mpsc::SyncSender<String>),
    Shutdown,
}

/// Serving-loop options.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Capacity, in lines, of each streaming response channel. A
    /// stream whose client stops reading parks after this many
    /// undelivered lines (further lines queue in the serving thread,
    /// bounded by the request's own token budget) — other streams and
    /// the replica step loops are unaffected.
    pub stream_buffer: usize,
    /// Serve from the single-thread synchronous [`Router`] loop
    /// instead of per-replica worker threads — the pre-threading
    /// behavior, kept for debugging and A/B tests (the stream-identity
    /// golden pins the two loops to identical output).
    pub sync_loop: bool,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions { stream_buffer: 32, sync_loop: false }
    }
}

/// Move-only wrapper that lets an [`Engine`] cross onto its serving
/// thread.
///
/// SAFETY: `Engine` is not `Send` because the xla crate's PJRT handles
/// use `Rc` internally. Every `Rc` clone of a client lives inside the
/// same `Engine` (runtime buffers + executable cache), so an engine
/// moved *whole* to one thread never shares an `Rc` across threads.
/// The serving loops uphold exactly that: each wrapped engine is owned
/// by a single thread for the rest of its life — the synchronous
/// router-loop thread (all replicas together), or in threaded mode its
/// own worker thread (one replica each).
pub struct SendEngine(pub Engine);
unsafe impl Send for SendEngine {}

impl ReplicaCore for SendEngine {
    fn submit(&mut self, prompt: Vec<u32>, params: SamplingParams)
        -> Result<u64, ReplicaError> {
        // the trait impl, not the inherent method: it carries the
        // catch_unwind fault classification
        ReplicaCore::submit(&mut self.0, prompt, params)
    }
    fn step(&mut self) -> Result<StepOutcome, ReplicaError> {
        ReplicaCore::step(&mut self.0)
    }
    fn has_work(&self) -> bool {
        ReplicaCore::has_work(&self.0)
    }
    fn take_finished(&mut self) -> Vec<Sequence> {
        ReplicaCore::take_finished(&mut self.0)
    }
    fn take_emitted(&mut self) -> Vec<(u64, u32)> {
        ReplicaCore::take_emitted(&mut self.0)
    }
    fn drain_inflight(&mut self) -> Vec<Sequence> {
        ReplicaCore::drain_inflight(&mut self.0)
    }
    fn block_size(&self) -> usize {
        ReplicaCore::block_size(&self.0)
    }
    fn queue_depths(&self) -> (usize, usize) {
        ReplicaCore::queue_depths(&self.0)
    }
    fn load(&self) -> usize {
        ReplicaCore::load(&self.0)
    }
    fn enable_cache_events(&mut self) {
        ReplicaCore::enable_cache_events(&mut self.0)
    }
    fn take_cache_events(&mut self) -> Vec<CacheEvent> {
        ReplicaCore::take_cache_events(&mut self.0)
    }
    fn set_cache_watermarks(&mut self, wm: CacheWatermarks) {
        ReplicaCore::set_cache_watermarks(&mut self.0, wm)
    }
    fn export_blocks(&mut self, tokens: &[u32])
        -> Result<Vec<(u64, Vec<u8>)>, ReplicaError> {
        ReplicaCore::export_blocks(&mut self.0, tokens)
    }
    fn import_blocks(&mut self, blocks: &[(u64, Vec<u8>)])
        -> Result<usize, ReplicaError> {
        ReplicaCore::import_blocks(&mut self.0, blocks)
    }
    fn core_stats(&self) -> CoreStats {
        ReplicaCore::core_stats(&self.0)
    }
}

/// A running server; `addr()` gives the bound address, `shutdown()`
/// stops the router loop after draining and joins every service
/// thread.
pub struct Server {
    addr: std::net::SocketAddr,
    inbox: mpsc::Sender<Inbox>,
    shutdown: Arc<AtomicBool>,
    router_thread: Option<std::thread::JoinHandle<()>>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Spawn the server on `127.0.0.1:port` (0 = ephemeral) over PJRT
    /// engines — one replica each. Default options serve from
    /// per-replica worker threads; `opts.sync_loop` restores the
    /// single-thread loop.
    pub fn spawn(engines: Vec<Engine>, rcfg: RouterConfig, port: u16,
                 opts: ServeOptions) -> Result<Server> {
        let cores: Vec<SendEngine> =
            engines.into_iter().map(SendEngine).collect();
        Server::spawn_core(cores, rcfg, port, opts)
    }

    /// Spawn the server over any `Send` replica cores — the seam the
    /// server lifecycle tests use (a stub core needs no PJRT runtime).
    pub fn spawn_core<C>(cores: Vec<C>, rcfg: RouterConfig, port: u16,
                         opts: ServeOptions) -> Result<Server>
    where
        C: ReplicaCore + Send + 'static,
    {
        let stream_buffer = opts.stream_buffer.max(1);
        if opts.sync_loop {
            Server::spawn_inner(port, stream_buffer, move |rx| {
                router_loop(Router::new(cores, rcfg), rx)
            })
        } else {
            Server::spawn_inner(port, stream_buffer, move |rx| {
                async_loop(AsyncRouter::new(cores, rcfg), rx)
            })
        }
    }

    fn spawn_inner(
        port: u16,
        stream_buffer: usize,
        run_router: impl FnOnce(mpsc::Receiver<Inbox>) + Send + 'static,
    ) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        let (tx, rx) = mpsc::channel::<Inbox>();
        let shutdown = Arc::new(AtomicBool::new(false));

        // serving-loop thread (owner of the router front end; in
        // sync mode also of every replica core)
        let router_thread = std::thread::spawn(move || run_router(rx));

        // accept loop thread; checks the shutdown flag per connection
        // (shutdown() self-connects to force one more iteration)
        let tx_accept = tx.clone();
        let flag = shutdown.clone();
        let accept_thread = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if flag.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { break };
                let tx = tx_accept.clone();
                let conn_flag = flag.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, tx, conn_flag,
                                        stream_buffer);
                });
            }
        });

        Ok(Server {
            addr,
            inbox: tx,
            shutdown,
            router_thread: Some(router_thread),
            accept_thread: Some(accept_thread),
        })
    }

    /// The bound listen address.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting, drain in-flight requests, and join both service
    /// threads. Connection threads observe the flag at their next read
    /// timeout and exit on their own — an idle client cannot pin the
    /// process.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = self.inbox.send(Inbox::Shutdown);
        if let Some(t) = self.router_thread.take() {
            let _ = t.join();
        }
        // unblock the accept loop so it sees the flag, then join it
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// The `{"error": ...}` line a request gets when the serving loop goes
/// away before answering it (shutdown race, or a serving-loop crash).
/// Silently writing *nothing* here — the old behavior — left the
/// client blocked on a response that would never come.
fn dropped_request_line() -> String {
    Value::obj(vec![(
        "error",
        Value::str("server dropped the request (shutting down)"),
    )])
    .to_string()
}

fn handle_conn(stream: TcpStream, tx: mpsc::Sender<Inbox>,
               shutdown: Arc<AtomicBool>, stream_buffer: usize)
    -> Result<()> {
    // bounded reads: an idle client parks here at most one timeout
    // interval past shutdown instead of pinning the thread forever
    stream.set_read_timeout(Some(CONN_READ_TIMEOUT))?;
    let peer_read = stream.try_clone()?;
    let mut reader = BufReader::new(peer_read);
    // this thread is the write half's sole owner — requests on one
    // connection are answered strictly in order, so no shared writer,
    // no lock, and no lock poison to cascade across requests
    let mut writer = stream;
    // read_line appends, so a line split across timeouts accumulates
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock
                                         | ErrorKind::TimedOut) => {
                if shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        let req_line = line.trim().to_string();
        line.clear();
        if req_line.is_empty() {
            continue;
        }
        match parse_client_request(&req_line) {
            Ok(req) => {
                // bounded response channel: the serving loop parks a
                // stream whose client lags more than `stream_buffer`
                // lines (admin responses are a single line)
                let cap = match &req {
                    ClientRequest::Generate(r) if r.stream => {
                        stream_buffer
                    }
                    _ => 1,
                };
                let (rtx, rrx) = mpsc::sync_channel::<String>(cap);
                let msg = match req {
                    ClientRequest::Generate(r) => Inbox::Submit(r, rtx),
                    ClientRequest::Stats => Inbox::Stats(rtx),
                    ClientRequest::Metrics => Inbox::Metrics(rtx),
                };
                if tx.send(msg).is_err() {
                    writeln!(writer, "{}", dropped_request_line())?;
                    return Ok(());
                }
                // write every line (token lines, then the response)
                // until the serving loop drops its sender
                let mut delivered = 0usize;
                while let Ok(resp) = rrx.recv() {
                    writeln!(writer, "{resp}")?;
                    delivered += 1;
                }
                if delivered == 0 {
                    // the loop dropped the request unanswered — tell
                    // the client instead of leaving it to hang
                    writeln!(writer, "{}", dropped_request_line())?;
                }
            }
            Err(e) => {
                writeln!(writer, "{}", Value::obj(vec![
                    ("error", Value::str(format!("{e}"))),
                ]))?;
            }
        }
    }
}

/// Per-request response plumbing shared by both serving loops:
/// bounded-channel delivery with per-stream parking and round-robin
/// fairness across parked streams.
struct Streams {
    pending: HashMap<u64, Pending>,
    /// Flush-pass rotation offset (fairness: no stream is always
    /// first in line for channel capacity).
    rotate: usize,
}

struct Pending {
    tx: mpsc::SyncSender<String>,
    stream: bool,
    /// Token lines produced so far — i.e. the next token's index.
    tokens: usize,
    /// Lines produced but not yet accepted by the bounded channel
    /// (a slow reader parks here; bounded by the request's budget).
    queued: VecDeque<String>,
    /// The response line is queued; the entry retires (dropping `tx`,
    /// which ends the client's read loop) once `queued` drains.
    done: bool,
}

impl Streams {
    fn new() -> Streams {
        Streams { pending: HashMap::new(), rotate: 0 }
    }

    fn insert(&mut self, id: u64, tx: mpsc::SyncSender<String>,
              stream: bool) {
        self.pending.insert(id, Pending {
            tx,
            stream,
            tokens: 0,
            queued: VecDeque::new(),
            done: false,
        });
    }

    fn on_token(&mut self, id: u64, token: u32) {
        let Some(p) = self.pending.get_mut(&id) else { return };
        if p.stream {
            p.queued.push_back(token_json(id, p.tokens, token));
        }
        p.tokens += 1;
    }

    fn on_finished(&mut self, fin: &RoutedFinish) {
        if let Some(p) = self.pending.get_mut(&fin.id) {
            p.queued
                .push_back(response_json(fin.id, fin.replica, &fin.seq));
            p.done = true;
        }
    }

    /// One delivery pass: offer each stream's queued lines to its
    /// bounded channel, one line per stream per round (round-robin, so
    /// a deep backlog cannot monopolize the pass), until every channel
    /// is full or every queue is empty. Fully delivered requests
    /// retire here. Never blocks.
    fn flush(&mut self) {
        let mut ids: Vec<u64> = self.pending.keys().copied().collect();
        if ids.is_empty() {
            return;
        }
        ids.sort_unstable();
        self.rotate = (self.rotate + 1) % ids.len();
        ids.rotate_left(self.rotate);
        loop {
            let mut progressed = false;
            for &id in &ids {
                let Some(p) = self.pending.get_mut(&id) else {
                    continue;
                };
                let Some(line) = p.queued.front() else { continue };
                match p.tx.try_send(line.clone()) {
                    Ok(()) => {
                        p.queued.pop_front();
                        progressed = true;
                    }
                    Err(mpsc::TrySendError::Full(_)) => {}
                    Err(mpsc::TrySendError::Disconnected(_)) => {
                        // client gone; drop its lines (the router
                        // still runs the request to completion)
                        p.queued.clear();
                    }
                }
            }
            if !progressed {
                break;
            }
        }
        self.pending
            .retain(|_, p| !(p.done && p.queued.is_empty()));
    }

    fn has_pending(&self) -> bool {
        !self.pending.is_empty()
    }

    /// Any produced-but-undelivered lines? (Idle-blocking is only safe
    /// when false — otherwise a parked stream would never drain.)
    fn any_queued(&self) -> bool {
        self.pending.values().any(|p| !p.queued.is_empty())
    }

    /// Retry delivery until everything drains or `total` elapses, then
    /// drop the leftovers (each dropped sender ends its client's read
    /// loop). Shutdown must not hang on a client that stopped reading.
    fn flush_deadline(&mut self, total: Duration) {
        let deadline = std::time::Instant::now() + total;
        loop {
            self.flush();
            if !self.has_pending()
                || std::time::Instant::now() >= deadline
            {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        self.pending.clear();
    }
}

/// The synchronous serving loop (`ServeOptions::sync_loop`): one
/// thread owns every replica core and steps them in turn. Kept as the
/// reference implementation the threaded loop is pinned against.
fn router_loop<C: ReplicaCore>(mut router: Router<C>,
                               rx: mpsc::Receiver<Inbox>) {
    let mut streams = Streams::new();
    let mut shutdown = false;
    loop {
        // deliver produced lines first: a submission can finish
        // without any engine work (e.g. prompt_too_long or shed), and
        // its response must go out before the loop blocks for input.
        // Tokens drain before finishes — a finish retires its stream.
        for (id, tok) in router.take_emitted() {
            streams.on_token(id, tok);
        }
        for fin in router.take_finished() {
            streams.on_finished(&fin);
        }
        streams.flush();
        if shutdown && !router.has_work() {
            break;
        }
        // drain the inbox (blocking only while fully idle)
        loop {
            let idle = !router.has_work()
                && !streams.any_queued()
                && !shutdown;
            let msg = if idle {
                match rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => {
                        shutdown = true;
                        None
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(mpsc::TryRecvError::Empty) => None,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        shutdown = true;
                        None
                    }
                }
            };
            match msg {
                Some(Inbox::Submit(req, resp)) => {
                    let id = router.submit(req.prompt, req.params);
                    streams.insert(id, resp, req.stream);
                    if !router.has_work() {
                        break; // finished at submission: drain now
                    }
                }
                Some(Inbox::Stats(resp)) => {
                    let _ = resp.try_send(
                        stats_json(&router.stats(),
                                   &router.router_stats())
                            .to_string(),
                    );
                }
                Some(Inbox::Metrics(resp)) => {
                    let _ = resp.try_send(metrics_text(
                        &router.stats(),
                        &router.router_stats(),
                    ));
                }
                Some(Inbox::Shutdown) => shutdown = true,
                None => break,
            }
            if shutdown {
                break;
            }
        }
        // step() handles replica failures internally (quarantine /
        // kill-and-replay) and only errs on router-fatal conditions
        if router.has_work() {
            if router.step().is_err() {
                break;
            }
        } else if streams.any_queued() && !shutdown {
            // only a parked stream is left: wait for its reader
            // without spinning
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    // bounded final delivery: a reader that stopped consuming cannot
    // pin shutdown
    streams.flush_deadline(Duration::from_secs(2));
}

/// The threaded serving loop (default): replica cores step on their
/// own worker threads; this thread only moves messages — inbox
/// requests into the [`AsyncRouter`], router events out to the
/// per-request channels.
fn async_loop(mut router: AsyncRouter, rx: mpsc::Receiver<Inbox>) {
    let mut streams = Streams::new();
    let mut shutdown = false;
    while !shutdown {
        // block for input only when fully idle; otherwise just drain
        // what's already queued
        let idle = !router.has_work() && !streams.any_queued();
        if idle {
            match rx.recv() {
                Ok(m) => {
                    shutdown |= handle_msg(&mut router, &mut streams, m)
                }
                Err(_) => shutdown = true,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(m) => {
                    shutdown |= handle_msg(&mut router, &mut streams, m)
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }
        if shutdown {
            break;
        }
        // collect worker events; the bounded wait paces this loop
        // while work is in flight (workers never wait on it)
        for ev in router.poll(Duration::from_millis(5)) {
            match ev {
                RouterEvent::Token { id, token, .. } => {
                    streams.on_token(id, token)
                }
                RouterEvent::Finished(fin) => streams.on_finished(&fin),
            }
        }
        streams.flush();
    }
    // drain the workers — every in-flight stream gets its remaining
    // token lines and its finish line
    for ev in router.shutdown() {
        match ev {
            RouterEvent::Token { id, token, .. } => {
                streams.on_token(id, token)
            }
            RouterEvent::Finished(fin) => streams.on_finished(&fin),
        }
    }
    streams.flush_deadline(Duration::from_secs(2));
}

/// Apply one inbox message to the threaded loop; `true` means
/// shutdown was requested.
fn handle_msg(router: &mut AsyncRouter, streams: &mut Streams,
              msg: Inbox) -> bool {
    match msg {
        Inbox::Submit(req, resp) => {
            let id = router.submit(req.prompt, req.params);
            streams.insert(id, resp, req.stream);
            false
        }
        Inbox::Stats(resp) => {
            let _ = resp.try_send(
                stats_json(&router.stats(), &router.router_stats())
                    .to_string(),
            );
            false
        }
        Inbox::Metrics(resp) => {
            let _ = resp.try_send(metrics_text(
                &router.stats(),
                &router.router_stats(),
            ));
            false
        }
        Inbox::Shutdown => true,
    }
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    stream: BufReader<TcpStream>,
}

impl Client {
    /// Connect to a running [`Server`].
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        Ok(Client { stream: BufReader::new(TcpStream::connect(addr)?) })
    }

    /// Send one generation request and wait for its response line.
    pub fn request(&mut self, prompt: &[u32], max_new: usize)
        -> Result<Value> {
        let req = Value::obj(vec![
            ("prompt",
             Value::Arr(prompt.iter().map(|&t| Value::num(t as f64))
                 .collect())),
            ("max_new_tokens", Value::num(max_new as f64)),
        ]);
        self.roundtrip(&req)
    }

    /// Send one streaming generation request; returns the token lines
    /// (in arrival order) and the final response line.
    pub fn request_stream(&mut self, prompt: &[u32], max_new: usize)
        -> Result<(Vec<Value>, Value)> {
        let req = Value::obj(vec![
            ("prompt",
             Value::Arr(prompt.iter().map(|&t| Value::num(t as f64))
                 .collect())),
            ("max_new_tokens", Value::num(max_new as f64)),
            ("stream", Value::Bool(true)),
        ]);
        let s = self.stream.get_mut();
        writeln!(s, "{req}")?;
        let mut tokens = vec![];
        loop {
            let mut line = String::new();
            if self.stream.read_line(&mut line)? == 0 {
                anyhow::bail!("connection closed mid-stream");
            }
            let v = json::parse(line.trim())
                .map_err(|e| anyhow::anyhow!("resp: {e}"))?;
            // token lines carry "token"; the final line carries
            // "finish" (or "error")
            if v.get("token").as_f64().is_some() {
                tokens.push(v);
            } else {
                return Ok((tokens, v));
            }
        }
    }

    /// Request the stats snapshot (JSON).
    pub fn stats(&mut self) -> Result<Value> {
        self.roundtrip(&Value::obj(vec![("cmd", Value::str("stats"))]))
    }

    /// Request the Prometheus-style metrics text (everything up to,
    /// excluding, the `# EOF` frame line).
    pub fn metrics(&mut self) -> Result<String> {
        let s = self.stream.get_mut();
        writeln!(s, "{}",
                 Value::obj(vec![("cmd", Value::str("metrics"))]))?;
        let mut out = String::new();
        loop {
            let mut line = String::new();
            if self.stream.read_line(&mut line)? == 0 {
                anyhow::bail!("connection closed before # EOF");
            }
            if line.trim_end() == "# EOF" {
                return Ok(out);
            }
            out.push_str(&line);
        }
    }

    fn roundtrip(&mut self, req: &Value) -> Result<Value> {
        let s = self.stream.get_mut();
        writeln!(s, "{req}")?;
        let mut line = String::new();
        self.stream.read_line(&mut line)?;
        json::parse(line.trim()).map_err(|e| anyhow::anyhow!("resp: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineConfig, RouterConfig};
    use crate::coordinator::fake::{EchoCore, FakeCore};

    #[test]
    fn parse_request_fields() {
        let r = parse_request(
            r#"{"prompt":[1,2,3],"max_new_tokens":4,"temperature":0.5,
                "top_k":5,"seed":9}"#,
        )
        .unwrap();
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.params.max_new_tokens, 4);
        assert_eq!(r.params.temperature, 0.5);
        assert_eq!(r.params.top_k, 5);
        assert_eq!(r.params.seed, 9);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"promptX":[1]}"#).is_err());
    }

    #[test]
    fn parse_rejects_malformed_prompt_entries() {
        // these used to be silently coerced to token 0
        assert!(parse_request(r#"{"prompt":[1,"x",3]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[1,null]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[1.5]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[-3]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[1e12]}"#).is_err());
        assert!(parse_request(r#"{"prompt":[[1]]}"#).is_err());
        assert!(parse_request(r#"{"prompt":7}"#).is_err());
        // boundary values that must still parse
        let r = parse_request(r#"{"prompt":[0, 4294967295]}"#).unwrap();
        assert_eq!(r.prompt, vec![0, u32::MAX]);
    }

    #[test]
    fn parse_rejects_zero_max_new_tokens() {
        // a 0-token generation budget admits a sequence that can never
        // produce a token — rejected like any other malformed field
        assert!(parse_request(r#"{"prompt":[1],"max_new_tokens":0}"#)
            .is_err());
        // 1 is the smallest valid budget; absent means the default
        let r = parse_request(r#"{"prompt":[1],"max_new_tokens":1}"#)
            .unwrap();
        assert_eq!(r.params.max_new_tokens, 1);
        let r = parse_request(r#"{"prompt":[1]}"#).unwrap();
        assert_eq!(r.params.max_new_tokens,
                   SamplingParams::default().max_new_tokens);
    }

    #[test]
    fn parse_client_request_dispatches() {
        assert!(matches!(parse_client_request(r#"{"cmd":"stats"}"#),
                         Ok(ClientRequest::Stats)));
        assert!(matches!(parse_client_request(r#"{"cmd":"metrics"}"#),
                         Ok(ClientRequest::Metrics)));
        assert!(parse_client_request(r#"{"cmd":"reboot"}"#).is_err());
        assert!(matches!(
            parse_client_request(r#"{"prompt":[1,2]}"#),
            Ok(ClientRequest::Generate(_))
        ));
        assert!(parse_client_request("not json").is_err());
    }

    #[test]
    fn parse_request_roundtrip() {
        // a request built the way `Client::request` builds it survives
        // serialize -> parse unchanged
        let prompt: Vec<u32> = vec![5, 0, 917, 64000];
        let req = Value::obj(vec![
            ("prompt",
             Value::Arr(prompt.iter().map(|&t| Value::num(t as f64))
                 .collect())),
            ("max_new_tokens", Value::num(9.0)),
            ("temperature", Value::num(0.25)),
        ]);
        let r = parse_request(&req.to_string()).unwrap();
        assert_eq!(r.prompt, prompt);
        assert_eq!(r.params.max_new_tokens, 9);
        assert_eq!(r.params.temperature, 0.25);
    }

    #[test]
    fn response_shape() {
        let mut s =
            Sequence::new(3, vec![1], SamplingParams::default());
        s.record_token(7);
        s.cached_prefix_len = 4;
        s.finish(FinishReason::MaxTokens);
        // global id 11 on replica 1 (seq.id is the replica-local id)
        let j = response_json(11, Some(1), &s);
        let v = json::parse(&j).unwrap();
        assert_eq!(v.get("id").as_usize(), Some(11));
        assert_eq!(v.get("replica").as_usize(), Some(1));
        assert_eq!(v.get("finish").as_str(), Some("max_tokens"));
        assert_eq!(v.get("tokens").as_arr().unwrap().len(), 1);
        assert_eq!(v.get("cached_tokens").as_usize(), Some(4));
    }

    #[test]
    fn response_shape_for_unrouted_finishes() {
        // shed / no-survivor responses carry no replica: null on the
        // wire, not 0 (which is a real replica id)
        let mut s =
            Sequence::new(0, vec![1, 2], SamplingParams::default());
        s.finish(FinishReason::Shed);
        let v = json::parse(&response_json(5, None, &s)).unwrap();
        assert_eq!(*v.get("replica"), Value::Null);
        assert_eq!(v.get("finish").as_str(), Some("shed"));
        let mut s =
            Sequence::new(0, vec![1, 2], SamplingParams::default());
        s.finish(FinishReason::ReplicaFailed);
        let v = json::parse(&response_json(6, None, &s)).unwrap();
        assert_eq!(v.get("finish").as_str(), Some("replica_failed"));
    }

    fn sample_rows() -> (Vec<ReplicaStats>, RouterStats) {
        let mut core = CoreStats {
            waiting: 2,
            running: 3,
            kv_occupancy: 0.5,
            ..Default::default()
        };
        core.cache.hits = 6;
        core.cache.misses = 2;
        core.cache.evictions = 1;
        core.prefill_tokens_executed = 120;
        core.cached_prefix_tokens = 48;
        core.ttft_steps_p50 = 2.5;
        core.cache.demotions = 4;
        core.cache.restores = 2;
        core.pool_blocks = 1;
        core.recompute_avoided_tokens = 32;
        core.kv_migrations_in = 2;
        core.kv_migrations_out = 3;
        core.migrated_bytes = 640;
        let rows = vec![
            ReplicaStats {
                id: 0,
                requests_routed: 4,
                health: ReplicaHealth::Healthy,
                replayed_out: 0,
                core,
            },
            ReplicaStats {
                id: 1,
                requests_routed: 2,
                health: ReplicaHealth::Dead,
                replayed_out: 3,
                core: CoreStats::default(),
            },
        ];
        let router = RouterStats {
            shed: 5,
            replayed: 3,
            retries: 7,
            replica_failed: 1,
            alive: 1,
            dead: 1,
            degraded: true,
            migration_fallbacks: 2,
        };
        (rows, router)
    }

    #[test]
    fn stats_json_roundtrip() {
        let (rows, router) = sample_rows();
        let v = json::parse(&stats_json(&rows, &router).to_string())
            .unwrap();
        let reps = v.get("replicas").as_arr().unwrap();
        assert_eq!(reps.len(), 2);
        let r0 = &reps[0];
        assert_eq!(r0.get("id").as_usize(), Some(0));
        assert_eq!(r0.get("requests_routed").as_usize(), Some(4));
        assert_eq!(r0.get("health").as_str(), Some("healthy"));
        assert_eq!(r0.get("replayed_out").as_usize(), Some(0));
        assert_eq!(r0.get("waiting").as_usize(), Some(2));
        assert_eq!(r0.get("running").as_usize(), Some(3));
        assert_eq!(r0.get("kv_occupancy").as_f64(), Some(0.5));
        assert_eq!(r0.get("cache_hits").as_usize(), Some(6));
        assert_eq!(r0.get("cache_misses").as_usize(), Some(2));
        assert_eq!(r0.get("cache_hit_rate").as_f64(), Some(0.75));
        assert_eq!(r0.get("evictions").as_usize(), Some(1));
        assert_eq!(r0.get("prefill_tokens_executed").as_usize(),
                   Some(120));
        assert_eq!(r0.get("cached_prefix_tokens").as_usize(), Some(48));
        assert_eq!(r0.get("ttft_p50_steps").as_f64(), Some(2.5));
        assert_eq!(r0.get("pool_blocks").as_usize(), Some(1));
        assert_eq!(r0.get("pool_demotions").as_usize(), Some(4));
        assert_eq!(r0.get("pool_restores").as_usize(), Some(2));
        assert_eq!(r0.get("recompute_avoided_tokens").as_usize(),
                   Some(32));
        assert_eq!(r0.get("kv_migrations_in").as_usize(), Some(2));
        assert_eq!(r0.get("kv_migrations_out").as_usize(), Some(3));
        assert_eq!(r0.get("migrated_bytes").as_usize(), Some(640));
        let r1 = &reps[1];
        assert_eq!(r1.get("id").as_usize(), Some(1));
        assert_eq!(r1.get("health").as_str(), Some("dead"));
        assert_eq!(r1.get("replayed_out").as_usize(), Some(3));
        assert_eq!(r1.get("cache_hit_rate").as_f64(), Some(0.0));
        let ro = v.get("router");
        assert_eq!(ro.get("shed").as_usize(), Some(5));
        assert_eq!(ro.get("replayed").as_usize(), Some(3));
        assert_eq!(ro.get("retries").as_usize(), Some(7));
        assert_eq!(ro.get("replica_failed").as_usize(), Some(1));
        assert_eq!(ro.get("alive").as_usize(), Some(1));
        assert_eq!(ro.get("dead").as_usize(), Some(1));
        assert_eq!(ro.get("degraded").as_bool(), Some(true));
        assert_eq!(ro.get("migration_fallbacks").as_usize(), Some(2));
    }

    #[test]
    fn decode_stats_inverts_the_encoder() {
        let (rows, router) = sample_rows();
        let v = json::parse(&stats_json(&rows, &router).to_string())
            .unwrap();
        let (drows, drouter) = decode_stats(&v).unwrap();
        assert_eq!(drouter, router);
        assert_eq!(drows.len(), rows.len());
        for (d, r) in drows.iter().zip(&rows) {
            assert_eq!(d.id, r.id);
            assert_eq!(d.requests_routed, r.requests_routed);
            assert_eq!(d.health.as_str(), r.health.as_str());
            assert_eq!(d.replayed_out, r.replayed_out);
            assert_eq!(d.core.waiting, r.core.waiting);
            assert_eq!(d.core.running, r.core.running);
            assert_eq!(d.core.kv_occupancy, r.core.kv_occupancy);
            assert_eq!(d.core.cache.hits, r.core.cache.hits);
            assert_eq!(d.core.cache.misses, r.core.cache.misses);
            assert_eq!(d.core.cache.evictions, r.core.cache.evictions);
            assert_eq!(d.core.prefill_tokens_executed,
                       r.core.prefill_tokens_executed);
            assert_eq!(d.core.cached_prefix_tokens,
                       r.core.cached_prefix_tokens);
            assert_eq!(d.core.ttft_steps_p50, r.core.ttft_steps_p50);
            assert_eq!(d.core.pool_blocks, r.core.pool_blocks);
            assert_eq!(d.core.cache.demotions, r.core.cache.demotions);
            assert_eq!(d.core.cache.restores, r.core.cache.restores);
            assert_eq!(d.core.recompute_avoided_tokens,
                       r.core.recompute_avoided_tokens);
            assert_eq!(d.core.kv_migrations_in,
                       r.core.kv_migrations_in);
            assert_eq!(d.core.kv_migrations_out,
                       r.core.kv_migrations_out);
            assert_eq!(d.core.migrated_bytes, r.core.migrated_bytes);
        }
    }

    #[test]
    fn decode_stats_rejects_malformed_input() {
        // strict: a missing or mistyped field errors (naming it),
        // instead of being silently defaulted
        let (rows, router) = sample_rows();
        let good = stats_json(&rows, &router).to_string();
        // no replicas array at all
        let e = decode_stats(&json::parse(r#"{}"#).unwrap())
            .unwrap_err();
        assert!(format!("{e:#}").contains("replicas"));
        // drop one per-replica field
        let broken = good.replacen(r#""waiting":2,"#, "", 1);
        let e = decode_stats(&json::parse(&broken).unwrap())
            .unwrap_err();
        assert!(format!("{e:#}").contains("replicas[0].waiting"));
        // drop a tiered-pool field
        let broken = good.replacen(r#""pool_blocks":1,"#, "", 1);
        let e = decode_stats(&json::parse(&broken).unwrap())
            .unwrap_err();
        assert!(format!("{e:#}").contains("replicas[0].pool_blocks"));
        // mistype a tiered-pool field (fractional counters are
        // malformed, not rounded)
        let broken = good
            .replacen(r#""pool_restores":2"#, r#""pool_restores":2.5"#, 1);
        let e = decode_stats(&json::parse(&broken).unwrap())
            .unwrap_err();
        assert!(format!("{e:#}").contains("replicas[0].pool_restores"));
        // drop a migration field
        let broken = good.replacen(r#""kv_migrations_out":3,"#, "", 1);
        let e = decode_stats(&json::parse(&broken).unwrap())
            .unwrap_err();
        assert!(format!("{e:#}")
            .contains("replicas[0].kv_migrations_out"));
        // mistype the router migration counter
        let broken = good.replacen(
            r#""migration_fallbacks":2"#,
            r#""migration_fallbacks":null"#,
            1,
        );
        let e = decode_stats(&json::parse(&broken).unwrap())
            .unwrap_err();
        assert!(format!("{e:#}")
            .contains("router.migration_fallbacks"));
        // mistype a router field
        let broken = good.replacen(r#""shed":5"#, r#""shed":"5""#, 1);
        let e = decode_stats(&json::parse(&broken).unwrap())
            .unwrap_err();
        assert!(format!("{e:#}").contains("router.shed"));
        // unknown health state
        let broken =
            good.replacen(r#""health":"dead""#, r#""health":"zombie""#, 1);
        let e = decode_stats(&json::parse(&broken).unwrap())
            .unwrap_err();
        assert!(format!("{e:#}").contains("health"));
        // drop the router object
        let broken = json::parse(&good).unwrap();
        let mut o = broken.as_obj().unwrap().clone();
        o.remove("router");
        let e = decode_stats(&Value::Obj(o)).unwrap_err();
        assert!(format!("{e:#}").contains("router"));
    }

    #[test]
    fn metrics_text_shape() {
        let (rows, router) = sample_rows();
        let text = metrics_text(&rows, &router);
        assert!(text
            .contains("# TYPE sqplus_replica_waiting gauge\n"));
        assert!(text
            .contains("sqplus_replica_waiting{replica=\"0\"} 2\n"));
        assert!(text.contains(
            "sqplus_replica_up{replica=\"0\",health=\"healthy\"} 1\n"
        ));
        assert!(text.contains(
            "sqplus_replica_up{replica=\"1\",health=\"dead\"} 0\n"
        ));
        assert!(text
            .contains("sqplus_replica_replayed_out{replica=\"1\"} 3\n"));
        assert!(text.contains("sqplus_router_shed_total 5\n"));
        assert!(text.contains("sqplus_router_degraded 1\n"));
        assert!(text
            .contains("sqplus_replica_ttft_p50_steps{replica=\"0\"} 2.5\n"));
        assert!(text
            .contains("# TYPE sqplus_replica_pool_blocks gauge\n"));
        assert!(text
            .contains("sqplus_replica_pool_blocks{replica=\"0\"} 1\n"));
        assert!(text
            .contains("sqplus_replica_pool_demotions{replica=\"0\"} 4\n"));
        assert!(text
            .contains("sqplus_replica_pool_restores{replica=\"0\"} 2\n"));
        assert!(text.contains(
            "sqplus_replica_recompute_avoided_tokens{replica=\"0\"} 32\n"
        ));
        assert!(text.contains(
            "# TYPE sqplus_replica_kv_migrations_in counter\n"
        ));
        assert!(text.contains(
            "sqplus_replica_kv_migrations_in{replica=\"0\"} 2\n"
        ));
        assert!(text.contains(
            "sqplus_replica_kv_migrations_out{replica=\"0\"} 3\n"
        ));
        assert!(text.contains(
            "sqplus_replica_migrated_bytes{replica=\"0\"} 640\n"
        ));
        assert!(text
            .contains("sqplus_router_migration_fallbacks_total 2\n"));
        // framed for line-based clients
        assert!(text.ends_with("# EOF"));
        // every non-comment line is `name{labels} value`
        for l in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(l.rsplit_once(' ').is_some(), "bad sample: {l}");
        }
    }

    fn echo_server(opts: ServeOptions) -> Server {
        Server::spawn_core(vec![EchoCore::new()],
                           RouterConfig::default(), 0, opts)
            .unwrap()
    }

    #[test]
    fn server_round_trips_and_shuts_down_with_idle_connection() {
        let server = echo_server(ServeOptions::default());
        let addr = server.addr();
        let mut c = Client::connect(addr).unwrap();
        let v = c.request(&[7, 8, 9], 4).unwrap();
        assert_eq!(v.get("finish").as_str(), Some("max_tokens"));
        assert_eq!(v.get("replica").as_usize(), Some(0));
        assert_eq!(v.get("tokens").as_arr().unwrap().len(), 1);
        // a second, never-used connection stays idle through shutdown:
        // the regression this pins is shutdown() hanging on (or
        // leaking) the accept loop and timeout-less reader threads
        let _idle = Client::connect(addr).unwrap();
        let (tx, rx) = mpsc::channel();
        std::thread::spawn(move || {
            server.shutdown();
            let _ = tx.send(());
        });
        assert!(
            rx.recv_timeout(Duration::from_secs(30)).is_ok(),
            "shutdown hung with an idle connection open"
        );
        drop(c);
    }

    #[test]
    fn server_stats_and_metrics_over_the_wire() {
        let server = echo_server(ServeOptions::default());
        let mut c = Client::connect(server.addr()).unwrap();
        c.request(&[1, 2], 2).unwrap();
        let v = c.stats().unwrap();
        let (rows, router) = decode_stats(&v).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].requests_routed, 1);
        assert_eq!(rows[0].health.as_str(), "healthy");
        assert_eq!(router.alive, 1);
        assert!(!router.degraded);
        let text = c.metrics().unwrap();
        assert!(text.contains(
            "sqplus_replica_requests_routed{replica=\"0\"} 1\n"
        ));
        assert!(text.contains("sqplus_router_replicas_alive 1\n"));
        assert!(!text.contains("# EOF"), "frame line must be stripped");
        // the same connection still serves generation afterwards
        let v = c.request(&[3], 1).unwrap();
        assert_eq!(v.get("finish").as_str(), Some("max_tokens"));
        server.shutdown();
    }

    #[test]
    fn dropped_reply_sender_yields_error_line() {
        // regression: the serving loop dying (or shutting down) with a
        // request outstanding used to silently write *nothing*,
        // leaving the client blocked forever on a response line
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let (tx, rx) = mpsc::channel::<Inbox>();
        let flag = Arc::new(AtomicBool::new(false));
        let conn = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let _ = handle_conn(stream, tx, flag, 8);
        });
        // the "router" receives the request, then dies without a reply
        let router = std::thread::spawn(move || match rx.recv() {
            Ok(Inbox::Submit(_, rtx)) => drop(rtx),
            other => panic!("expected a submit, got {:?}",
                            other.is_ok()),
        });
        let mut c = Client::connect(addr).unwrap();
        let v = c.request(&[1, 2], 3).unwrap();
        assert!(
            v.get("error")
                .as_str()
                .map(|e| e.contains("dropped"))
                .unwrap_or(false),
            "expected a dropped-request error line, got {v}"
        );
        router.join().unwrap();
        drop(c);
        conn.join().unwrap();
    }

    #[test]
    fn streaming_over_the_wire_tokens_before_finish() {
        let ecfg = EngineConfig {
            block_size: 4,
            ..Default::default()
        };
        let server = Server::spawn_core(
            vec![FakeCore::new(ecfg, 64)],
            RouterConfig::default(),
            0,
            ServeOptions::default(),
        )
        .unwrap();
        let mut c = Client::connect(server.addr()).unwrap();
        let (tokens, fin) = c.request_stream(&[1, 2, 3, 4, 5], 4)
            .unwrap();
        // every token line precedes the finish line, in index order
        assert_eq!(tokens.len(), 4);
        let idx: Vec<usize> = tokens
            .iter()
            .map(|t| t.get("index").as_usize().unwrap())
            .collect();
        assert_eq!(idx, vec![0, 1, 2, 3]);
        // the finish line repeats the streamed tokens exactly
        let streamed: Vec<usize> = tokens
            .iter()
            .map(|t| t.get("token").as_usize().unwrap())
            .collect();
        let fin_tokens: Vec<usize> = fin
            .get("tokens")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(streamed, fin_tokens);
        assert_eq!(fin.get("finish").as_str(), Some("max_tokens"));
        server.shutdown();
    }

    #[test]
    fn sync_loop_mode_serves_and_streams() {
        let server = echo_server(ServeOptions {
            sync_loop: true,
            ..Default::default()
        });
        let mut c = Client::connect(server.addr()).unwrap();
        let v = c.request(&[5], 1).unwrap();
        assert_eq!(v.get("finish").as_str(), Some("max_tokens"));
        let (tokens, fin) = c.request_stream(&[9, 8], 1).unwrap();
        assert_eq!(tokens.len(), 1);
        assert_eq!(tokens[0].get("token").as_usize(), Some(9));
        assert_eq!(fin.get("tokens").as_arr().unwrap().len(), 1);
        server.shutdown();
    }
}
