//! A lexed source file plus its `sqlint:` allow markers and
//! `#[cfg(test)]` regions — the unit every pass operates on.

use std::collections::HashSet;

use super::lexer::{lex, Comment, TokKind, Token};
use super::Diagnostic;

/// A parsed allow marker: `// sqlint: allow(<pass>) <justification>` or
/// `// sqlint: allow-file(<pass>) <justification>`.
struct Marker {
    is_file: bool,
    pass: String,
    justification: String,
}

/// Parse the first `sqlint:` marker in a comment's text, if any.
fn parse_marker(text: &str) -> Option<Marker> {
    let at = text.find("sqlint:")?;
    let rest = text[at + "sqlint:".len()..].trim_start();
    let rest = rest.strip_prefix("allow")?;
    let (is_file, rest) = match rest.strip_prefix("-file") {
        Some(r) => (true, r),
        None => (false, rest),
    };
    let rest = rest.strip_prefix('(')?;
    let close = rest.find(')')?;
    let pass = &rest[..close];
    if pass.is_empty() || !pass.bytes().all(|b| b.is_ascii_lowercase()) {
        return None;
    }
    Some(Marker {
        is_file,
        pass: pass.to_string(),
        justification: rest[close + 1..].trim().to_string(),
    })
}

/// One source file, lexed and annotated for the passes.
pub struct SourceFile {
    /// Path as given on the command line (used in diagnostics and for
    /// pass scoping by substring, e.g. `src/coordinator/`).
    pub rel: String,
    /// Raw source lines (1-based access via `lines[n - 1]`).
    pub lines: Vec<String>,
    /// The token stream.
    pub toks: Vec<Token>,
    /// Passes allowed for the whole file.
    pub allow_file: HashSet<String>,
    /// `(pass, line)` pairs individually allowed.
    pub allowed: HashSet<(String, usize)>,
    /// Markers with an empty justification: `(line, pass)`.
    pub bad_markers: Vec<(usize, String)>,
    /// Lines inside `#[cfg(test)]` / `#[test]` items.
    pub test_lines: HashSet<usize>,
}

impl SourceFile {
    /// Lex `src` and resolve its markers and test regions.
    pub fn new(rel: &str, src: &str) -> SourceFile {
        let (toks, comments) = lex(src);
        let mut allow_file = HashSet::new();
        let mut allowed = HashSet::new();
        let mut bad_markers = Vec::new();
        let comment_lines: HashSet<usize> = comments
            .iter()
            .filter(|c| c.standalone)
            .map(|c| c.line)
            .collect();
        for c in &comments {
            let Some(m) = parse_marker(&c.text) else {
                continue;
            };
            if m.justification.is_empty() {
                bad_markers.push((c.line, m.pass));
                continue;
            }
            if m.is_file {
                allow_file.insert(m.pass);
            } else if c.standalone {
                // a standalone marker covers the next non-comment line
                let mut tgt = c.line + 1;
                while comment_lines.contains(&tgt) {
                    tgt += 1;
                }
                allowed.insert((m.pass, tgt));
            } else {
                allowed.insert((m.pass, c.line));
            }
        }
        let test_lines = test_regions(&toks);
        SourceFile {
            rel: rel.to_string(),
            lines: src.split('\n').map(str::to_string).collect(),
            toks,
            allow_file,
            allowed,
            bad_markers,
            test_lines,
        }
    }

    /// Record a finding unless a marker (or test region) suppresses it.
    pub fn emit(
        &self,
        diags: &mut Vec<Diagnostic>,
        pass: &str,
        line: usize,
        msg: String,
        skip_test: bool,
    ) {
        if skip_test && self.test_lines.contains(&line) {
            return;
        }
        if self.allow_file.contains(pass)
            || self.allowed.contains(&(pass.to_string(), line))
        {
            return;
        }
        diags.push(Diagnostic {
            pass: pass.to_string(),
            path: self.rel.clone(),
            line,
            message: msg,
        });
    }
}

/// Any substring of `parts` present in `rel`?
pub fn in_scope(rel: &str, parts: &[&str]) -> bool {
    parts.iter().any(|p| rel.contains(p))
}

/// Lines covered by `#[cfg(test)]` / `#[test]` items (attribute through
/// the item's matching close brace).
fn test_regions(t: &[Token]) -> HashSet<usize> {
    let mut out = HashSet::new();
    let mut i = 0usize;
    while i < t.len() {
        if !(t[i].text == "#" && i + 1 < t.len() && t[i + 1].text == "[") {
            i += 1;
            continue;
        }
        // scan attribute contents
        let mut j = i + 2;
        let mut depth = 1usize;
        let mut names: Vec<&str> = Vec::new();
        while j < t.len() && depth > 0 {
            if t[j].text == "[" {
                depth += 1;
            } else if t[j].text == "]" {
                depth -= 1;
            } else if t[j].kind == TokKind::Ident {
                names.push(&t[j].text);
            }
            j += 1;
        }
        let is_test = (names.iter().any(|n| *n == "cfg")
            && names.iter().any(|n| *n == "test"))
            || names == ["test"];
        if !is_test {
            i = j;
            continue;
        }
        // skip any further attributes on the same item
        while j < t.len()
            && t[j].text == "#"
            && j + 1 < t.len()
            && t[j + 1].text == "["
        {
            let mut d = 1usize;
            j += 2;
            while j < t.len() && d > 0 {
                if t[j].text == "[" {
                    d += 1;
                } else if t[j].text == "]" {
                    d -= 1;
                }
                j += 1;
            }
        }
        // the item runs to its first `{` (brace-matched) or a `;`
        let mut k = j;
        while k < t.len() && t[k].text != "{" && t[k].text != ";" {
            k += 1;
        }
        let end_line = if k < t.len() && t[k].text == "{" {
            let mut d = 1usize;
            let mut e = k + 1;
            while e < t.len() && d > 0 {
                if t[e].text == "{" {
                    d += 1;
                } else if t[e].text == "}" {
                    d -= 1;
                }
                e += 1;
            }
            if e >= 1 && e - 1 < t.len() {
                t[e - 1].line
            } else {
                t.last().map_or(1, |x| x.line)
            }
        } else if k < t.len() {
            t[k].line
        } else {
            t.last().map_or(1, |x| x.line)
        };
        for ln in t[i].line..=end_line {
            out.insert(ln);
        }
        i = k.max(i + 1);
    }
    out
}
