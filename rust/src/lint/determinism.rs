//! Pass `determinism`: the scheduling/quantization core must be a pure
//! function of its inputs.
//!
//! Scope: `src/coordinator/`, `src/runtime/`, `src/quant/` — the
//! async-vs-sync stream-identity goldens and the quantization
//! round-trip tests both depend on bit-identical replay. Flags
//! wall-clock reads (`Instant::now`, `SystemTime`), unseeded RNG
//! construction, and iteration over `HashMap`/`HashSet` values whose
//! order can leak into output. Iteration is exempt when the adaptor
//! chain is order-insensitive (`any`/`sum`/`max`/… or a re-`collect`
//! into a map/set) or when a `.sort` appears within the next 20 lines.

use super::source::{in_scope, SourceFile};
use super::Diagnostic;
use crate::lint::lexer::{TokKind, Token};
use std::collections::HashSet;

const ITER_FNS: [&str; 9] = [
    "iter", "iter_mut", "keys", "values", "values_mut", "drain",
    "into_iter", "into_keys", "into_values",
];
const ORDER_OK: [&str; 12] = [
    "any", "all", "count", "sum", "product", "min", "max", "contains",
    "contains_key", "is_empty", "len", "retain",
];
const RNG_IDENTS: [&str; 3] = ["thread_rng", "from_entropy", "OsRng"];
const MAP_TYPES: [&str; 4] = ["HashMap", "HashSet", "BTreeMap", "BTreeSet"];

/// Names bound to a `HashMap`/`HashSet`: struct fields (`name:
/// HashMap<..>`) and local lets (`let name = HashMap::new()`).
fn collect_map_names(sf: &SourceFile) -> HashSet<String> {
    let mut names = HashSet::new();
    let t = &sf.toks;
    for (i, tok) in t.iter().enumerate() {
        if tok.kind != TokKind::Ident
            || (tok.text != "HashMap" && tok.text != "HashSet")
        {
            continue;
        }
        let mut j = i as isize - 1;
        while j >= 0 {
            let x = &t[j as usize];
            let skip = matches!(
                x.text.as_str(),
                ":" | "&" | "mut" | "std" | "collections" | "<" | ">"
            ) || x.kind == TokKind::Life;
            if !skip {
                break;
            }
            j -= 1;
        }
        if j >= 1 {
            let x = &t[j as usize];
            if x.kind == TokKind::Ident && t[j as usize + 1].text == ":" {
                names.insert(x.text.clone());
                continue;
            }
        }
        // `let name = HashMap::new()`
        if j >= 0 && t[j as usize].text == "=" {
            j -= 1;
            while j >= 0 && t[j as usize].text == "mut" {
                j -= 1;
            }
            if j >= 1
                && t[j as usize].kind == TokKind::Ident
                && t[j as usize - 1].text == "let"
            {
                names.insert(t[j as usize].text.clone());
            }
        }
    }
    names
}

/// Is there a `.sort` within the next 20 source lines?
fn sorted_lookahead(sf: &SourceFile, line: usize) -> bool {
    let hi = (line + 20).min(sf.lines.len());
    for ln in line..=hi {
        if ln >= 1 && ln <= sf.lines.len() && sf.lines[ln - 1].contains(".sort")
        {
            return true;
        }
    }
    false
}

/// Does the adaptor chain after token `i` (scanning at most 80 tokens,
/// stopping at `;`) reach an order-insensitive consumer?
fn chain_order_ok(t: &[Token], i: usize) -> bool {
    let mut j = i;
    while j < t.len() && j < i + 80 {
        if t[j].text == ";" {
            return false;
        }
        if t[j].kind == TokKind::Ident {
            if ORDER_OK.contains(&t[j].text.as_str()) {
                return true;
            }
            if t[j].text == "collect" {
                // collect::<HashMap/HashSet/BTreeMap/BTreeSet<..>>
                let mut k = j + 1;
                while k < t.len() && k < j + 12 {
                    if t[k].kind == TokKind::Ident
                        && MAP_TYPES.contains(&t[k].text.as_str())
                    {
                        return true;
                    }
                    if t[k].text == "(" {
                        break;
                    }
                    k += 1;
                }
            }
        }
        j += 1;
    }
    false
}

/// Run the pass over one file.
pub fn run(sf: &SourceFile, diags: &mut Vec<Diagnostic>) {
    if !in_scope(
        &sf.rel,
        &["src/coordinator/", "src/runtime/", "src/quant/"],
    ) {
        return;
    }
    let t = &sf.toks;
    let maps = collect_map_names(sf);
    for (i, tok) in t.iter().enumerate() {
        if tok.kind != TokKind::Ident {
            continue;
        }
        match tok.text.as_str() {
            "Instant" => {
                if i + 3 < t.len()
                    && t[i + 1].text == ":"
                    && t[i + 2].text == ":"
                    && t[i + 3].text == "now"
                {
                    sf.emit(
                        diags,
                        "determinism",
                        tok.line,
                        "wall-clock `Instant::now()` in deterministic core"
                            .to_string(),
                        true,
                    );
                }
            }
            "SystemTime" => {
                sf.emit(
                    diags,
                    "determinism",
                    tok.line,
                    "wall-clock `SystemTime` in deterministic core"
                        .to_string(),
                    true,
                );
            }
            s if RNG_IDENTS.contains(&s) => {
                sf.emit(
                    diags,
                    "determinism",
                    tok.line,
                    format!("unseeded RNG `{s}` in deterministic core"),
                    true,
                );
            }
            s if ITER_FNS.contains(&s) => {
                if !(i > 0
                    && t[i - 1].text == "."
                    && i + 1 < t.len()
                    && t[i + 1].text == "(")
                {
                    continue;
                }
                if i < 2 || t[i - 2].kind != TokKind::Ident {
                    continue;
                }
                let recv = &t[i - 2].text;
                if !maps.contains(recv) {
                    continue;
                }
                if chain_order_ok(t, i) || sorted_lookahead(sf, tok.line) {
                    continue;
                }
                sf.emit(
                    diags,
                    "determinism",
                    tok.line,
                    format!(
                        "`{recv}.{}()` iterates a HashMap/HashSet in \
                         arbitrary order",
                        tok.text
                    ),
                    true,
                );
            }
            "for" => {
                // for pat in [&][mut] [self .] name {
                let mut j = i + 1;
                while j < t.len() && t[j].text != "in" && t[j].text != "{" {
                    j += 1;
                }
                if j >= t.len() || t[j].text != "in" {
                    continue;
                }
                let mut k = j + 1;
                let mut expr: Vec<&Token> = Vec::new();
                while k < t.len() && t[k].text != "{" {
                    expr.push(&t[k]);
                    k += 1;
                    if expr.len() > 5 {
                        break;
                    }
                }
                if expr.len() > 5 || expr.is_empty() {
                    continue;
                }
                if expr.iter().any(|e| e.text == "(") {
                    continue;
                }
                let last = expr[expr.len() - 1];
                if last.kind != TokKind::Ident || !maps.contains(&last.text) {
                    continue;
                }
                if !sorted_lookahead(sf, tok.line) {
                    sf.emit(
                        diags,
                        "determinism",
                        tok.line,
                        format!(
                            "`for .. in {}` iterates a HashMap/HashSet in \
                             arbitrary order",
                            last.text
                        ),
                        true,
                    );
                }
            }
            _ => {}
        }
    }
}
