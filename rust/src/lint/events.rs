//! Pass `events`: exhaustive event handling.
//!
//! Scope: `src/coordinator/` and `src/server/`. Every `match` over an
//! event enum — any enum whose name ends in `Event` (`WorkerEvent`,
//! `RouterEvent`, `CacheEvent`) — must name every variant: no `_`
//! wildcard and no catch-all binding arm. A wildcard keeps compiling
//! when a new event variant is added, which is exactly the moment the
//! handler most needs revisiting — a silently dropped `Dead` or
//! `Demoted` corrupts the router's replica and directory mirrors. With
//! no catch-all, rustc's exhaustiveness check turns "new variant" into
//! a compile error at every handler.
//!
//! The pass is fileset-wide: event enums are collected from every file
//! (the enum and its `match` sites live in different modules), then
//! each in-scope file's `match` expressions are walked arm by arm. A
//! `match` is an event match when any arm's pattern contains a
//! collected enum name followed by `::`; within such a match an arm is
//! a catch-all when its pattern (before any `if` guard) is a lone `_`
//! or a lone lowercase binding.

use super::source::{in_scope, SourceFile};
use super::Diagnostic;
use crate::lint::lexer::{TokKind, Token};
use std::collections::HashSet;

/// Collect the names of event enums defined in `sf`.
fn collect_event_enums(sf: &SourceFile, out: &mut HashSet<String>) {
    let t = &sf.toks;
    for (i, tok) in t.iter().enumerate() {
        if tok.kind == TokKind::Ident
            && tok.text == "enum"
            && i + 1 < t.len()
            && t[i + 1].kind == TokKind::Ident
            && t[i + 1].text.ends_with("Event")
        {
            out.insert(t[i + 1].text.clone());
        }
    }
}

/// Token-index ranges of each arm's pattern (including any `if` guard)
/// in the `match` body opening at `t[open]`. Arm bodies are skipped:
/// block bodies to their matching brace, expression bodies to the comma
/// (or match close) at top level.
fn match_arm_patterns(t: &[Token], open: usize) -> Vec<(usize, usize)> {
    let mut arms = Vec::new();
    let mut depth = 1usize;
    let (mut par, mut brk) = (0usize, 0usize);
    let mut k = open + 1;
    let mut pat_start = k;
    while k < t.len() && depth > 0 {
        match t[k].text.as_str() {
            "{" => depth += 1,
            "}" => depth -= 1,
            "(" => par += 1,
            ")" => par = par.saturating_sub(1),
            "[" => brk += 1,
            "]" => brk = brk.saturating_sub(1),
            "=" if depth == 1
                && par == 0
                && brk == 0
                && k + 1 < t.len()
                && t[k + 1].text == ">" =>
            {
                arms.push((pat_start, k));
                // skip the arm body: block → matching brace, else →
                // comma (or match close) at top level
                k += 2;
                if k < t.len() && t[k].text == "{" {
                    let mut d = 1usize;
                    k += 1;
                    while k < t.len() && d > 0 {
                        match t[k].text.as_str() {
                            "{" => d += 1,
                            "}" => d -= 1,
                            _ => {}
                        }
                        k += 1;
                    }
                    if k < t.len() && t[k].text == "," {
                        k += 1;
                    }
                } else {
                    while k < t.len() {
                        match t[k].text.as_str() {
                            "{" => depth += 1,
                            "}" => {
                                if depth == 1 {
                                    break; // match close ends last arm
                                }
                                depth -= 1;
                            }
                            "(" => par += 1,
                            ")" => par = par.saturating_sub(1),
                            "[" => brk += 1,
                            "]" => brk = brk.saturating_sub(1),
                            "," if depth == 1 && par == 0 && brk == 0 => {
                                k += 1;
                                break;
                            }
                            _ => {}
                        }
                        k += 1;
                    }
                }
                pat_start = k;
                continue;
            }
            _ => {}
        }
        k += 1;
    }
    arms
}

/// Does the pattern slice reference one of the event enums (`Name::`)?
fn pattern_event_enum<'a>(
    t: &[Token],
    (s, e): (usize, usize),
    enums: &'a HashSet<String>,
) -> Option<&'a str> {
    for i in s..e {
        if t[i].kind == TokKind::Ident
            && i + 2 < t.len()
            && t[i + 1].text == ":"
            && t[i + 2].text == ":"
        {
            if let Some(name) = enums.get(&t[i].text) {
                return Some(name.as_str());
            }
        }
    }
    None
}

/// Is the pattern slice a catch-all — a lone `_` or a lone lowercase
/// binding, optionally followed by an `if` guard?
fn pattern_is_catchall(t: &[Token], (s, e): (usize, usize)) -> bool {
    let mut end = e;
    for i in s..e {
        if t[i].kind == TokKind::Ident && t[i].text == "if" {
            end = i;
            break;
        }
    }
    if end != s + 1 {
        return false;
    }
    let x = &t[s];
    x.kind == TokKind::Ident
        && x.text
            .chars()
            .next()
            .map_or(false, |c| c.is_ascii_lowercase() || c == '_')
}

/// Run the pass over the whole file set (the enum and its handlers
/// live in different files).
pub fn run(files: &[SourceFile], diags: &mut Vec<Diagnostic>) {
    let mut enums: HashSet<String> = HashSet::new();
    for sf in files {
        collect_event_enums(sf, &mut enums);
    }
    if enums.is_empty() {
        return;
    }
    for sf in files {
        if !in_scope(&sf.rel, &["src/coordinator/", "src/server/"]) {
            continue;
        }
        let t = &sf.toks;
        for i in 0..t.len() {
            if t[i].kind != TokKind::Ident || t[i].text != "match" {
                continue;
            }
            // scrutinee runs to the body `{` at top bracket level
            let mut j = i + 1;
            let (mut par, mut brk) = (0usize, 0usize);
            while j < t.len() {
                match t[j].text.as_str() {
                    "(" => par += 1,
                    ")" => par = par.saturating_sub(1),
                    "[" => brk += 1,
                    "]" => brk = brk.saturating_sub(1),
                    "{" if par == 0 && brk == 0 => break,
                    ";" if par == 0 && brk == 0 => break, // not a match expr
                    _ => {}
                }
                j += 1;
            }
            if j >= t.len() || t[j].text != "{" {
                continue;
            }
            let arms = match_arm_patterns(t, j);
            let Some(name) = arms
                .iter()
                .find_map(|a| pattern_event_enum(t, *a, &enums))
            else {
                continue;
            };
            for arm in &arms {
                if pattern_is_catchall(t, *arm) {
                    sf.emit(
                        diags,
                        "events",
                        t[arm.0].line,
                        format!(
                            "catch-all arm in `match` over `{name}`; name \
                             every variant so a new event fails the build \
                             here"
                        ),
                        true,
                    );
                }
            }
        }
    }
}
