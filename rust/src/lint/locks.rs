//! Pass `locks`: mutex and channel discipline.
//!
//! Rule A (all of `src/`): `.lock().unwrap()` / `.lock().expect(..)`
//! turns mutex poisoning — some *other* thread panicked — into a panic
//! here too, cascading one replica's death into its neighbors. Handle
//! the `Err` (the poisoned data is still accessible via
//! `into_inner`).
//!
//! Rule B (`coordinator/worker.rs` and `src/server/` only): a lock
//! guard bound by `let`/`match` and then held across a channel
//! `.send()`/`.recv()` serializes the serving loop on that mutex — or
//! deadlocks it outright if the peer needs the same lock to make
//! progress. Drop the guard before blocking on a channel.

use super::source::SourceFile;
use super::Diagnostic;
use crate::lint::lexer::TokKind;

const SEND_RECV: [&str; 5] =
    ["send", "recv", "try_recv", "recv_timeout", "send_timeout"];

/// Run the pass over one file.
pub fn run(sf: &SourceFile, diags: &mut Vec<Diagnostic>) {
    let t = &sf.toks;
    let in_src = sf.rel.contains("src/");
    let rule_b = (sf.rel.ends_with("worker.rs")
        && sf.rel.contains("coordinator"))
        || sf.rel.contains("src/server/");
    for (i, tok) in t.iter().enumerate() {
        if tok.kind != TokKind::Ident || tok.text != "lock" {
            continue;
        }
        if !(i > 0
            && t[i - 1].text == "."
            && i + 1 < t.len()
            && t[i + 1].text == "(")
        {
            continue;
        }
        // rule A: .lock().unwrap() / .lock().expect(
        if in_src
            && i + 4 < t.len()
            && t[i + 2].text == ")"
            && t[i + 3].text == "."
            && (t[i + 4].text == "unwrap" || t[i + 4].text == "expect")
        {
            sf.emit(
                diags,
                "locks",
                tok.line,
                "`.lock().unwrap()` propagates mutex poisoning; handle \
                 the Err"
                    .to_string(),
                true,
            );
        }
        if !rule_b {
            continue;
        }
        // rule B: guard bound by let/match and held across send/recv
        let mut j = i as isize - 1;
        let mut bound = false;
        while j >= 0 {
            let x = &t[j as usize].text;
            if x == ";" || x == "{" || x == "}" {
                break;
            }
            if x == "let" || x == "match" {
                bound = true;
                break;
            }
            j -= 1;
        }
        if !bound {
            continue;
        }
        let mut depth = 0isize;
        let mut k = i + 1;
        while k < t.len() {
            let x = &t[k];
            if x.text == "{" {
                depth += 1;
            } else if x.text == "}" {
                depth -= 1;
                if depth < 0 {
                    break;
                }
            } else if x.text == ";" && depth < 0 {
                break;
            } else if x.kind == TokKind::Ident
                && SEND_RECV.contains(&x.text.as_str())
                && k > 0
                && t[k - 1].text == "."
                && k + 1 < t.len()
                && t[k + 1].text == "("
            {
                sf.emit(
                    diags,
                    "locks",
                    x.line,
                    "channel send/recv while a lock guard may still be \
                     held"
                        .to_string(),
                    true,
                );
                break;
            }
            k += 1;
        }
    }
}
