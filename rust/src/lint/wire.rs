//! Pass `wire`: stats-surface parity.
//!
//! Every field of [`CoreStats`](crate::coordinator::replica::CoreStats)
//! and `RouterStats` must appear in all three wire functions —
//! `stats_json` (the `/stats` encoder), `decode_stats` (the client
//! decoder), and `metrics_text` (the Prometheus exposition) — either
//! as an identifier in the function body or as a substring of one of
//! its string literals. Adding a counter to a stats struct without
//! threading it through the wire silently ships a surface that lies by
//! omission; this pass turns that into a CI failure at the field's
//! declaration site.

use super::source::SourceFile;
use super::Diagnostic;
use crate::lint::lexer::TokKind;
use std::collections::{HashMap, HashSet};

const WIRE_STRUCTS: [&str; 2] = ["CoreStats", "RouterStats"];
const WIRE_FNS: [&str; 3] = ["stats_json", "decode_stats", "metrics_text"];

/// Fields of each wire struct defined in `sf`: name → `(field, line)`.
fn collect_struct_fields(
    sf: &SourceFile,
) -> Vec<(String, Vec<(String, usize)>)> {
    let mut out = Vec::new();
    let t = &sf.toks;
    for (i, tok) in t.iter().enumerate() {
        if tok.text != "struct"
            || i + 1 >= t.len()
            || !WIRE_STRUCTS.contains(&t[i + 1].text.as_str())
        {
            continue;
        }
        let name = t[i + 1].text.clone();
        let mut j = i + 2;
        while j < t.len() && t[j].text != "{" && t[j].text != ";" {
            j += 1;
        }
        if j >= t.len() || t[j].text == ";" {
            continue;
        }
        let mut depth = 1usize;
        j += 1;
        let mut fields: Vec<(String, usize)> = Vec::new();
        let mut expect_field = true;
        while j < t.len() && depth > 0 {
            let x = &t[j];
            if x.text == "{" {
                depth += 1;
            } else if x.text == "}" {
                depth -= 1;
            } else if depth == 1 {
                if x.text == "#" {
                    // skip an attribute
                    j += 1;
                    if j < t.len() && t[j].text == "[" {
                        let mut d = 1usize;
                        j += 1;
                        while j < t.len() && d > 0 {
                            if t[j].text == "[" {
                                d += 1;
                            } else if t[j].text == "]" {
                                d -= 1;
                            }
                            j += 1;
                        }
                    }
                    continue;
                }
                if expect_field
                    && x.kind == TokKind::Ident
                    && x.text != "pub"
                    && j + 1 < t.len()
                    && t[j + 1].text == ":"
                {
                    fields.push((x.text.clone(), x.line));
                    expect_field = false;
                } else if x.text == "," {
                    expect_field = true;
                }
            }
            j += 1;
        }
        out.push((name, fields));
    }
    out
}

/// Bodies of the wire functions defined in `sf`: name → (idents in the
/// body, string literals in the body).
fn collect_fn_bodies(
    sf: &SourceFile,
) -> Vec<(String, HashSet<String>, Vec<String>)> {
    let mut out = Vec::new();
    let t = &sf.toks;
    for (i, tok) in t.iter().enumerate() {
        if tok.text != "fn"
            || i + 1 >= t.len()
            || !WIRE_FNS.contains(&t[i + 1].text.as_str())
        {
            continue;
        }
        let name = t[i + 1].text.clone();
        let mut j = i + 2;
        while j < t.len() && t[j].text != "{" {
            j += 1;
        }
        let mut depth = 1usize;
        j += 1;
        let mut idents: HashSet<String> = HashSet::new();
        let mut strings: Vec<String> = Vec::new();
        while j < t.len() && depth > 0 {
            let x = &t[j];
            if x.text == "{" {
                depth += 1;
            } else if x.text == "}" {
                depth -= 1;
            } else if x.kind == TokKind::Ident {
                idents.insert(x.text.clone());
            } else if x.kind == TokKind::Str {
                strings.push(x.text.clone());
            }
            j += 1;
        }
        out.push((name, idents, strings));
    }
    out
}

/// Run the pass over the whole file set (the struct and the wire
/// functions live in different files).
pub fn run(files: &[SourceFile], diags: &mut Vec<Diagnostic>) {
    let mut structs: Vec<(String, Vec<(String, usize)>, usize)> = Vec::new();
    let mut seen_structs: HashSet<String> = HashSet::new();
    let mut fns: HashMap<String, (HashSet<String>, Vec<String>)> =
        HashMap::new();
    let mut fn_order: Vec<String> = Vec::new();
    for (fi, sf) in files.iter().enumerate() {
        for (name, fields) in collect_struct_fields(sf) {
            if seen_structs.insert(name.clone()) {
                structs.push((name, fields, fi));
            }
        }
        for (name, idents, strings) in collect_fn_bodies(sf) {
            if !fns.contains_key(&name) {
                fn_order.push(name.clone());
                fns.insert(name, (idents, strings));
            }
        }
    }
    if fns.is_empty() {
        return;
    }
    for (sname, fields, fi) in &structs {
        let sf = &files[*fi];
        for fname in &fn_order {
            let Some((idents, strings)) = fns.get(fname) else {
                continue;
            };
            for (field, line) in fields {
                if idents.contains(field) {
                    continue;
                }
                if strings.iter().any(|s| s.contains(field.as_str())) {
                    continue;
                }
                sf.emit(
                    diags,
                    "wire",
                    *line,
                    format!(
                        "field `{sname}.{field}` does not appear in \
                         `{fname}`"
                    ),
                    false,
                );
            }
        }
    }
}
