//! Pass `panic`: no panic-capable constructs on the request path.
//!
//! Scope: `src/coordinator/` and `src/server/` — a panic there takes a
//! replica (or the whole server) down with every in-flight request.
//! Flags `.unwrap()` / `.expect()`, the panicking macros, and map-key
//! indexing `m[&k]` (the narrowed indexing rule: `[` preceded by an
//! identifier / `]` / `)` and immediately followed by `&`, which in
//! this codebase is exactly the `HashMap` index sugar that panics on a
//! missing key).

use super::source::{in_scope, SourceFile};
use super::Diagnostic;
use crate::lint::lexer::TokKind;

const PANIC_MACROS: [&str; 4] =
    ["panic", "unreachable", "todo", "unimplemented"];

/// Run the pass over one file.
pub fn run(sf: &SourceFile, diags: &mut Vec<Diagnostic>) {
    if !in_scope(&sf.rel, &["src/coordinator/", "src/server/"]) {
        return;
    }
    let t = &sf.toks;
    for (i, tok) in t.iter().enumerate() {
        if tok.kind != TokKind::Ident {
            if tok.text == "["
                && i > 0
                && i + 1 < t.len()
                && (t[i - 1].kind == TokKind::Ident
                    || t[i - 1].text == ")"
                    || t[i - 1].text == "]")
                && t[i + 1].text == "&"
            {
                sf.emit(
                    diags,
                    "panic",
                    tok.line,
                    "map index `[&..]` can panic; use `.get()`".to_string(),
                    true,
                );
            }
            continue;
        }
        if tok.text == "unwrap" || tok.text == "expect" {
            if i > 0
                && t[i - 1].text == "."
                && i + 1 < t.len()
                && t[i + 1].text == "("
            {
                sf.emit(
                    diags,
                    "panic",
                    tok.line,
                    format!(
                        "request-path `.{}()` can panic (replica death)",
                        tok.text
                    ),
                    true,
                );
            }
        } else if PANIC_MACROS.contains(&tok.text.as_str())
            && i + 1 < t.len()
            && t[i + 1].text == "!"
            && (i == 0 || t[i - 1].text != ".")
        {
            sf.emit(
                diags,
                "panic",
                tok.line,
                format!("request-path `{}!` macro", tok.text),
                true,
            );
        }
    }
}
