//! Minimal hand-rolled Rust token lexer for the `sqlint` passes.
//!
//! No `syn`, no external deps — the repo must stay offline-buildable.
//! Produces a flat token stream with 1-based line numbers plus the
//! comment list (comments carry the `sqlint:` allow markers). It
//! understands just enough Rust to make pattern passes reliable:
//! line and nested block comments, plain/raw/byte strings, char
//! literals vs lifetimes, identifiers, and numbers; everything else
//! is a single-character punctuation token.

/// Kind of a lexed token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident,
    /// Single punctuation character.
    Punct,
    /// String literal (including raw and byte strings).
    Str,
    /// Char literal.
    Char,
    /// Lifetime (`'a`).
    Life,
    /// Numeric literal.
    Num,
}

/// One lexed token.
#[derive(Debug, Clone)]
pub struct Token {
    /// Token class.
    pub kind: TokKind,
    /// Exact source text.
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: usize,
}

/// One comment (not part of the token stream).
#[derive(Debug, Clone)]
pub struct Comment {
    /// 1-based line the comment starts on.
    pub line: usize,
    /// Full text including the `//` / `/*` delimiters.
    pub text: String,
    /// True when nothing but whitespace precedes it on its line.
    pub standalone: bool,
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}
fn is_ident_cont(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}
fn text_of(bytes: &[u8], i: usize, j: usize) -> String {
    String::from_utf8_lossy(&bytes[i..j.min(bytes.len())]).into_owned()
}

/// `b?r#*"` raw-string opener at `i`? Returns one past its end.
fn try_raw_string(b: &[u8], i: usize) -> Option<usize> {
    let mut k = i;
    if b[k] == b'b' {
        k += 1;
    }
    if k >= b.len() || b[k] != b'r' {
        return None;
    }
    k += 1;
    let mut hashes = 0usize;
    while k < b.len() && b[k] == b'#' {
        hashes += 1;
        k += 1;
    }
    if k >= b.len() || b[k] != b'"' {
        return None;
    }
    k += 1;
    while k < b.len() {
        if b[k] == b'"' {
            let mut h = 0usize;
            while h < hashes && k + 1 + h < b.len() && b[k + 1 + h] == b'#' {
                h += 1;
            }
            if h == hashes {
                return Some(k + 1 + hashes);
            }
        }
        k += 1;
    }
    Some(b.len())
}

/// Lex `src` into its token stream and comment list.
pub fn lex(src: &str) -> (Vec<Token>, Vec<Comment>) {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks: Vec<Token> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut line_has_code = false;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            line_has_code = false;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        // line comment
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let mut j = i;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            comments.push(Comment {
                line,
                text: text_of(b, i, j),
                standalone: !line_has_code,
            });
            i = j;
            continue;
        }
        // block comment (nested)
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let start_line = line;
            let standalone = !line_has_code;
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'\n' {
                    line += 1;
                    j += 1;
                } else if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            comments.push(Comment {
                line: start_line,
                text: text_of(b, i, j),
                standalone,
            });
            i = j;
            continue;
        }
        line_has_code = true;
        // raw / byte-raw strings
        if c == b'r' || c == b'b' {
            if let Some(j) = try_raw_string(b, i) {
                let start_line = line;
                let t = text_of(b, i, j);
                line += t.bytes().filter(|&x| x == b'\n').count();
                toks.push(Token { kind: TokKind::Str, text: t, line: start_line });
                i = j;
                continue;
            }
        }
        // plain / byte strings
        if c == b'"' || (c == b'b' && i + 1 < n && b[i + 1] == b'"') {
            let start = i;
            let start_line = line;
            let mut j = i + if c == b'b' { 2 } else { 1 };
            while j < n && b[j] != b'"' {
                if b[j] == b'\\' {
                    if j + 1 < n && b[j + 1] == b'\n' {
                        line += 1;
                    }
                    j += 2;
                } else {
                    if b[j] == b'\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            j = (j + 1).min(n);
            toks.push(Token {
                kind: TokKind::Str,
                text: text_of(b, start, j),
                line: start_line,
            });
            i = j;
            continue;
        }
        // char literal vs lifetime
        if c == b'\'' {
            if i + 3 < n && b[i + 1] == b'\\' && b[i + 3] == b'\'' {
                toks.push(Token {
                    kind: TokKind::Char,
                    text: text_of(b, i, i + 4),
                    line,
                });
                i += 4;
                continue;
            }
            if i + 2 < n && b[i + 1] != b'\\' && b[i + 1] != b'\'' && b[i + 2] == b'\'' {
                toks.push(Token {
                    kind: TokKind::Char,
                    text: text_of(b, i, i + 3),
                    line,
                });
                i += 3;
                continue;
            }
            let mut j = i + 1;
            while j < n && is_ident_cont(b[j]) {
                j += 1;
            }
            toks.push(Token { kind: TokKind::Life, text: text_of(b, i, j), line });
            i = j.max(i + 1);
            continue;
        }
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_cont(b[j]) {
                j += 1;
            }
            toks.push(Token {
                kind: TokKind::Ident,
                text: text_of(b, i, j),
                line,
            });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n && is_ident_cont(b[j]) {
                j += 1;
            }
            if j + 1 < n && b[j] == b'.' && b[j + 1].is_ascii_digit() {
                j += 1;
                while j < n && is_ident_cont(b[j]) {
                    j += 1;
                }
            }
            toks.push(Token { kind: TokKind::Num, text: text_of(b, i, j), line });
            i = j;
            continue;
        }
        toks.push(Token {
            kind: TokKind::Punct,
            text: text_of(b, i, i + 1),
            line,
        });
        i += 1;
    }
    (toks, comments)
}
