//! `sqlint` — the project-invariant static-analysis passes.
//!
//! A dependency-free lint over the repo's own source (no `syn`, no
//! network): a hand-rolled token [`lexer`] feeds five passes that pin
//! the invariants this codebase's tests rely on but rustc cannot see:
//!
//! * **panic** — no `.unwrap()` / `.expect()` / panicking macros /
//!   `m[&k]` map indexing in `coordinator/` and `server/`; a panic
//!   there takes a replica down with every in-flight request.
//! * **determinism** — no wall-clock reads, unseeded RNG, or
//!   order-leaking `HashMap`/`HashSet` iteration in `coordinator/`,
//!   `runtime/`, `quant/`; the stream-identity goldens depend on
//!   bit-identical replay.
//! * **locks** — no `.lock().unwrap()` anywhere in `src/`; no lock
//!   guard held across a channel `.send()`/`.recv()` in the serving
//!   loop.
//! * **wire** — every field of `CoreStats`/`RouterStats` must appear
//!   in `stats_json`, `decode_stats`, and `metrics_text`.
//! * **events** — no `_` wildcard or catch-all binding arm in a
//!   `match` over an event enum (`WorkerEvent`, `RouterEvent`,
//!   `CacheEvent`) in `coordinator/` and `server/`; a new variant must
//!   fail the build at every handler, not be silently dropped.
//!
//! Findings are suppressed per line with
//! `// sqlint: allow(<pass>) <justification>` (a standalone marker
//! covers the next non-comment line; a trailing marker covers its own
//! line) or per file with `// sqlint: allow-file(<pass>)
//! <justification>`. The justification is mandatory — an empty one is
//! itself a finding (pass id `marker`). `#[cfg(test)]` / `#[test]`
//! regions are skipped by every pass except `wire`.
//!
//! The CLI front-end is `src/bin/sqlint.rs`; run it via `make lint`.
//! See `docs/STATIC_ANALYSIS.md` for the pass catalog and the
//! baseline workflow.

pub mod lexer;
pub mod source;

mod determinism;
mod events;
mod locks;
mod panic;
mod wire;

use std::collections::HashSet;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use source::SourceFile;

/// One finding: `path:line: [pass] message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Pass id: `panic`, `determinism`, `locks`, `wire`, `events`, or
    /// `marker`.
    pub pass: String,
    /// Path as given on the command line.
    pub path: String,
    /// 1-based line.
    pub line: usize,
    /// Human-readable explanation.
    pub message: String,
}

impl Diagnostic {
    /// Render as `path:line: [pass] message`.
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.path, self.line, self.pass, self.message)
    }

    /// Stable key used by the baseline file: `pass path:line`.
    pub fn baseline_key(&self) -> String {
        format!("{} {}:{}", self.pass, self.path, self.line)
    }
}

/// Collect the `.rs` files under each root (a root may also be a single
/// file), skipping `lint_fixtures` and `target` directories. Directory
/// entries are visited in sorted order so output is stable.
pub fn collect_files(roots: &[PathBuf]) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    for root in roots {
        if root.is_file() {
            out.push(root.clone());
            continue;
        }
        walk_dir(root, &mut out)?;
    }
    Ok(out)
}

fn walk_dir(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)?
        .collect::<Result<Vec<_>, _>>()?
        .into_iter()
        .map(|e| e.path())
        .collect();
    entries.sort();
    let mut subdirs = Vec::new();
    for p in entries {
        if p.is_dir() {
            let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if name == "lint_fixtures" || name == "target" {
                continue;
            }
            subdirs.push(p);
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    for d in subdirs {
        walk_dir(&d, out)?;
    }
    Ok(())
}

/// Run every pass over the `.rs` files under `roots` and return the
/// findings sorted by `(path, line, pass)`.
pub fn run_paths(roots: &[PathBuf]) -> io::Result<Vec<Diagnostic>> {
    let mut diags: Vec<Diagnostic> = Vec::new();
    let mut files: Vec<SourceFile> = Vec::new();
    for path in collect_files(roots)? {
        let rel = path.to_string_lossy().into_owned();
        let src = fs::read_to_string(&path)?;
        let sf = SourceFile::new(&rel, &src);
        for (line, pid) in &sf.bad_markers {
            diags.push(Diagnostic {
                pass: "marker".to_string(),
                path: rel.clone(),
                line: *line,
                message: format!(
                    "allow({pid}) marker missing a justification"
                ),
            });
        }
        panic::run(&sf, &mut diags);
        determinism::run(&sf, &mut diags);
        locks::run(&sf, &mut diags);
        files.push(sf);
    }
    wire::run(&files, &mut diags);
    events::run(&files, &mut diags);
    diags.sort_by(|a, b| {
        (&a.path, a.line, &a.pass).cmp(&(&b.path, b.line, &b.pass))
    });
    Ok(diags)
}

/// Load a baseline file: one `pass path:line` key per line, `#`
/// comments and blank lines ignored.
pub fn load_baseline(path: &Path) -> io::Result<HashSet<String>> {
    let text = fs::read_to_string(path)?;
    Ok(text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect())
}

/// Drop findings whose [`Diagnostic::baseline_key`] is in `baseline`.
pub fn apply_baseline(
    diags: Vec<Diagnostic>,
    baseline: &HashSet<String>,
) -> Vec<Diagnostic> {
    diags
        .into_iter()
        .filter(|d| !baseline.contains(&d.baseline_key()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::lexer::{lex, TokKind};
    use super::source::SourceFile;
    use super::*;

    #[test]
    fn lexer_strings_comments_lifetimes() {
        let src = "fn f<'a>(s: &'a str) { let c = 'x'; // tail\n\
                   let r = r#\"raw \" here\"#; /* block\nstill */ }";
        let (toks, comments) = lex(src);
        assert_eq!(comments.len(), 2);
        assert!(!comments[0].standalone);
        let lifes: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::Life).collect();
        assert_eq!(lifes.len(), 2);
        let strs: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::Str).collect();
        assert_eq!(strs.len(), 1);
        assert!(strs[0].text.contains("raw \" here"));
        let chars: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::Char).collect();
        assert_eq!(chars.len(), 1);
    }

    #[test]
    fn standalone_marker_covers_next_code_line() {
        let src = "// sqlint: allow(panic) reason here\n\
                   // another comment\n\
                   x.unwrap();\n";
        let sf = SourceFile::new("src/coordinator/x.rs", src);
        assert!(sf.allowed.contains(&("panic".to_string(), 3)));
        let mut diags = Vec::new();
        super::panic::run(&sf, &mut diags);
        assert!(diags.is_empty());
    }

    #[test]
    fn marker_without_justification_is_a_finding() {
        let src = "x.unwrap(); // sqlint: allow(panic)\n";
        let sf = SourceFile::new("src/coordinator/x.rs", src);
        assert_eq!(sf.bad_markers.len(), 1);
        let mut diags = Vec::new();
        super::panic::run(&sf, &mut diags);
        assert_eq!(diags.len(), 1);
    }

    #[test]
    fn test_regions_are_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() { x.unwrap(); }\n}\n";
        let sf = SourceFile::new("src/coordinator/x.rs", src);
        let mut diags = Vec::new();
        super::panic::run(&sf, &mut diags);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn baseline_roundtrip_filters_findings() {
        let d = Diagnostic {
            pass: "panic".to_string(),
            path: "src/coordinator/x.rs".to_string(),
            line: 7,
            message: "m".to_string(),
        };
        let mut base = HashSet::new();
        base.insert(d.baseline_key());
        assert!(apply_baseline(vec![d], &base).is_empty());
    }
}
