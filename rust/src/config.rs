//! Configuration: model sizes (mirroring `python/compile/configs.py` — the
//! manifest is the authoritative copy at runtime), quantization settings,
//! engine/scheduler settings, multi-replica router settings, and
//! simulated-GPU deployment profiles.

use crate::util::json::Value;

/// Llama-family model architecture. Mirrors python configs.SIZES; when
/// artifacts are present, prefer [`ModelConfig::from_manifest`] so Rust and
/// the lowered HLO can never drift.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    /// Size name (`tiny` / `small` / `base`), the manifest lookup key.
    pub name: String,
    /// Vocabulary size (tokenizer is trained to this).
    pub vocab: usize,
    /// Model (embedding) dimension.
    pub dim: usize,
    /// Decoder layer count.
    pub layers: usize,
    /// Attention head count (`dim % heads == 0`).
    pub heads: usize,
    /// FFN hidden dimension (SwiGLU inner width).
    pub ffn: usize,
    /// Maximum context length (KV rows per sequence).
    pub max_len: usize,
    /// Quantization group size along K for the W4A16 linears.
    pub group_size: usize,
    /// RoPE base frequency.
    pub rope_theta: f32,
    /// RMSNorm epsilon.
    pub norm_eps: f32,
}

impl ModelConfig {
    /// The 2-layer laptop-scale model every test defaults to.
    pub fn tiny() -> Self {
        ModelConfig {
            name: "tiny".into(), vocab: 512, dim: 128, layers: 2, heads: 4,
            ffn: 384, max_len: 128, group_size: 128,
            rope_theta: 10000.0, norm_eps: 1e-5,
        }
    }
    /// The 4-layer model whose `max_len` exceeds the largest prefill
    /// bucket (the configuration where chunked prefill is load-bearing).
    pub fn small() -> Self {
        ModelConfig {
            name: "small".into(), vocab: 1024, dim: 256, layers: 4, heads: 8,
            ffn: 768, max_len: 256, group_size: 128,
            rope_theta: 10000.0, norm_eps: 1e-5,
        }
    }
    /// The ~100M-parameter model for end-to-end paper-figure runs.
    pub fn base() -> Self {
        ModelConfig {
            name: "base".into(), vocab: 8192, dim: 768, layers: 12,
            heads: 12, ffn: 2048, max_len: 256, group_size: 128,
            rope_theta: 10000.0, norm_eps: 1e-5,
        }
    }

    /// Look a size up by its CLI/manifest name.
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "tiny" => Some(Self::tiny()),
            "small" => Some(Self::small()),
            "base" => Some(Self::base()),
            _ => None,
        }
    }

    /// Parse the `config` object of a manifest model entry.
    pub fn from_manifest(v: &Value) -> ModelConfig {
        ModelConfig {
            name: v.get("name").as_str().unwrap_or("?").to_string(),
            vocab: v.get("vocab").as_usize().unwrap(),
            dim: v.get("dim").as_usize().unwrap(),
            layers: v.get("layers").as_usize().unwrap(),
            heads: v.get("heads").as_usize().unwrap(),
            ffn: v.get("ffn").as_usize().unwrap(),
            max_len: v.get("max_len").as_usize().unwrap(),
            group_size: v.get("group_size").as_usize().unwrap(),
            rope_theta: v.get("rope_theta").as_f64().unwrap() as f32,
            norm_eps: v.get("norm_eps").as_f64().unwrap() as f32,
        }
    }

    /// Per-head dimension (`dim / heads`).
    pub fn head_dim(&self) -> usize {
        self.dim / self.heads
    }

    /// The 7 quantizable linears of one decoder layer: (name, K, N).
    pub fn linear_shapes(&self) -> Vec<(&'static str, usize, usize)> {
        let (d, f) = (self.dim, self.ffn);
        vec![
            ("wq", d, d), ("wk", d, d), ("wv", d, d), ("wo", d, d),
            ("w_gate", d, f), ("w_up", d, f), ("w_down", f, d),
        ]
    }

    /// Total parameter count (embeddings + decoder + head).
    pub fn param_count(&self) -> usize {
        let (d, f, v, l) = (self.dim, self.ffn, self.vocab, self.layers);
        v * d + l * (4 * d * d + 3 * d * f + 2 * d) + d + d * v
    }

    /// Model weight bytes under a precision, with FP16 byte-accounting
    /// (DESIGN.md §5): fp16 = 2 B/param; w4a16 = 0.5 B + group scale/zero
    /// overhead on the decoder linears, fp16 elsewhere.
    pub fn weight_bytes(&self, precision: Precision) -> usize {
        let (d, f, v, l) = (self.dim, self.ffn, self.vocab, self.layers);
        let lin_params = l * (4 * d * d + 3 * d * f);
        let other = v * d + l * 2 * d + d + d * v;
        match precision {
            Precision::Fp16 => 2 * (lin_params + other),
            Precision::W4a16 => {
                let groups: usize = self
                    .linear_shapes()
                    .iter()
                    .map(|&(_, k, n)| (k / self.group_size) * n)
                    .sum::<usize>()
                    * l;
                lin_params / 2 + groups * 4 + 2 * other
            }
        }
    }

    /// KV-cache bytes per token (fp16 accounting).
    pub fn kv_bytes_per_token(&self) -> usize {
        2 * self.layers * 2 * self.dim
    }
}

/// Serving weight precision (the paper's two deployment arms).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// FP16 weights everywhere (the baseline deployment).
    Fp16,
    /// 4-bit weights on the decoder linears, FP16 activations and
    /// embeddings/head (the SmoothQuant+ deployment).
    W4a16,
}

impl Precision {
    /// Manifest/CLI spelling (`fp16` / `w4a16`).
    pub fn as_str(&self) -> &'static str {
        match self {
            Precision::Fp16 => "fp16",
            Precision::W4a16 => "w4a16",
        }
    }
    /// Inverse of [`Precision::as_str`].
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "fp16" => Some(Precision::Fp16),
            "w4a16" => Some(Precision::W4a16),
            _ => None,
        }
    }
}

/// Storage precision for stashed KV-cache rows — the engine's host-side
/// per-block stash and the tiered demotion pool (see `runtime::kvq`).
/// `F32` keeps exact rows (restores are bit-identical); `Q8`/`Q4`
/// shrink the stash 4–8× via group-wise asymmetric quantization, at a
/// bounded per-group reconstruction error (restored streams may
/// legitimately diverge — gated on task metrics, not bit-identity).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum KvCacheMode {
    /// Exact f32 rows (the pre-quantization stash; the default).
    #[default]
    F32,
    /// Group-wise asymmetric INT8, one byte per value.
    Q8,
    /// Group-wise asymmetric INT4, two values per byte (the paper's
    /// weight grid, `quant::rtn::int4_grid`, applied to KV).
    Q4,
}

impl KvCacheMode {
    /// CLI spelling (`f32` / `q8` / `q4`).
    pub fn as_str(&self) -> &'static str {
        match self {
            KvCacheMode::F32 => "f32",
            KvCacheMode::Q8 => "q8",
            KvCacheMode::Q4 => "q4",
        }
    }
    /// Inverse of [`KvCacheMode::as_str`].
    pub fn parse(s: &str) -> Option<KvCacheMode> {
        match s {
            "f32" => Some(KvCacheMode::F32),
            "q8" => Some(KvCacheMode::Q8),
            "q4" => Some(KvCacheMode::Q4),
            _ => None,
        }
    }
}

/// Quantization method under test (the paper's baselines + SQ+).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QuantMethod {
    /// No quantization (FP16 reference).
    Fp16,
    /// Round-to-nearest group-wise INT4 without smoothing.
    Rtn,
    /// AWQ-style per-layer activation-aware scaling (mean-based, greedy).
    Awq,
    /// SmoothQuant+: global-alpha smoothing + group-wise INT4.
    SmoothQuantPlus,
}

impl QuantMethod {
    /// Display name used in tables and the CLI.
    pub fn as_str(&self) -> &'static str {
        match self {
            QuantMethod::Fp16 => "FP16",
            QuantMethod::Rtn => "RTN",
            QuantMethod::Awq => "AWQ",
            QuantMethod::SmoothQuantPlus => "SmoothQuant+",
        }
    }
    /// All methods, in the paper's comparison order.
    pub fn all() -> [QuantMethod; 4] {
        [QuantMethod::Fp16, QuantMethod::Rtn, QuantMethod::Awq,
         QuantMethod::SmoothQuantPlus]
    }
}

/// Quantization configuration.
#[derive(Debug, Clone)]
pub struct QuantConfig {
    /// Group size along K for group-wise INT4 scales/zeros.
    pub group_size: usize,
    /// Grid-search step for the smoothing strength alpha (paper: 0.05).
    pub alpha_step: f64,
    /// Number of calibration rows (token vectors) to retain per linear.
    pub calib_rows: usize,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig { group_size: 128, alpha_step: 0.05, calib_rows: 512 }
    }
}

/// Engine / scheduler configuration (the vLLM-shaped knobs).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Decode batch buckets available as compiled executables.
    pub decode_batches: Vec<usize>,
    /// Prefill buckets (batch, seq).
    pub prefill_buckets: Vec<(usize, usize)>,
    /// Max sequences resident in the running set.
    pub max_running: usize,
    /// Token budget per scheduler step (prefill admission control).
    pub max_batch_tokens: usize,
    /// KV block size in tokens (paged accounting granularity).
    pub block_size: usize,
    /// Total KV blocks in the simulated device pool.
    pub total_blocks: usize,
    /// Re-form the device batch at most every `reform_interval` steps
    /// (batch reformation ablation; 1 = vLLM-style every step).
    pub reform_interval: usize,
    /// Default max new tokens per request.
    pub max_new_tokens: usize,
    /// Content-hash prefix caching: share full KV blocks across
    /// sequences with equal prompt prefixes and skip their prefill.
    pub enable_prefix_caching: bool,
    /// Chunked prefill: split any prefill work (cold prompts, warm
    /// suffixes after a cache hit, recompute after preemption) into
    /// per-step chunks so a sequence makes prefill progress across
    /// engine steps, decodes co-schedule with prefill inside one token
    /// budget, and no single step can exceed the largest compiled
    /// prefill bucket. `false` restores the legacy all-at-once prefill
    /// (admission then clamps generation so post-preemption recompute
    /// still fits the largest bucket — the pre-chunking sharp edge).
    pub enable_chunked_prefill: bool,
    /// Per-sequence cap on prefill tokens advanced per engine step when
    /// chunked prefill is on. `0` means no per-sequence cap: chunks are
    /// still bounded by `max_batch_tokens` and, for cold chunks, by the
    /// largest prefill bucket.
    pub max_prefill_chunk: usize,
    /// Compiled chunk buckets `(batch, chunk_len, prefix_len)` — synced
    /// from the runtime like `prefill_buckets`. Non-empty caps
    /// continuation-chunk widths at the largest compiled `chunk_len`,
    /// so a chunk maps to one executable call; empty (no chunk
    /// artifacts, or tests without a runtime) leaves widths uncapped
    /// and the engine drives continuations token by token.
    pub chunk_buckets: Vec<(usize, usize, usize)>,
    /// Execute continuation chunks through the compiled chunked-prefill
    /// executable (one device call per chunk, batched positionwise
    /// where bucket pairs match). `false` forces the token-by-token
    /// decode-executable fallback — the pre-chunk-executable serving
    /// path, kept for ablation and golden bit-identity tests.
    pub enable_compiled_chunks: bool,
    /// Sliding eviction window on cached-but-unreferenced KV blocks
    /// (`high == 0` disables it — unbounded LRU, the pre-window
    /// behavior). See
    /// [`crate::coordinator::block_manager::BlockManager::set_cache_watermarks`].
    pub cache_watermarks: CacheWatermarks,
    /// Storage precision for stashed prefix-KV rows (host stash and
    /// tiered pool). The `F32` default keeps every golden stream
    /// bit-identical; `Q8`/`Q4` trade bounded reconstruction error for
    /// a 4–8× smaller stash.
    pub kv_cache_mode: KvCacheMode,
    /// Capacity (in blocks) of the host-side tiered KV pool that
    /// evicted cached blocks demote into instead of dropping their
    /// rows; a later hit on a demoted block restores by dequantize+copy
    /// instead of recompute. `0` (the default) disables tiering —
    /// eviction discards rows, the pre-tiering behavior.
    pub kv_pool_blocks: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            decode_batches: vec![1, 2, 4, 8],
            prefill_buckets: vec![(1, 32), (1, 128), (4, 32), (4, 128)],
            max_running: 8,
            max_batch_tokens: 512,
            block_size: 16,
            total_blocks: 256,
            reform_interval: 1,
            max_new_tokens: 32,
            enable_prefix_caching: true,
            enable_chunked_prefill: true,
            max_prefill_chunk: 0,
            chunk_buckets: vec![],
            enable_compiled_chunks: true,
            cache_watermarks: CacheWatermarks::default(),
            kv_cache_mode: KvCacheMode::F32,
            kv_pool_blocks: 0,
        }
    }
}

/// High/low watermark pair for the prefix cache's sliding eviction
/// window: when the count of cached-but-unreferenced blocks exceeds
/// `high`, the oldest-released are evicted until it is down to `low`.
/// `high == 0` disables the window.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheWatermarks {
    /// Trip point (maximum cached-unreferenced blocks; 0 = disabled).
    pub high: usize,
    /// Eviction target once tripped (clamped to `high`).
    pub low: usize,
}

impl CacheWatermarks {
    /// A `high`/`low` window (`low` clamped to `high` at the manager).
    pub fn new(high: usize, low: usize) -> CacheWatermarks {
        CacheWatermarks { high, low }
    }
    /// Is the window active?
    pub fn enabled(&self) -> bool {
        self.high > 0
    }
}

/// How the multi-replica router picks a replica for a new request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Score every replica by `cached prefix tokens (per the shared
    /// cache directory) − load penalty` and pick the best, ties to the
    /// lowest replica id. With no cache hits anywhere this degenerates
    /// to least-loaded.
    CacheAware,
    /// Pick the replica with the fewest queued + running sequences,
    /// ties to the lowest replica id.
    LeastLoaded,
    /// Rotate through replicas in submission order (the baseline the
    /// bench compares against).
    RoundRobin,
}

impl RoutingPolicy {
    /// CLI spelling (`cache-aware` / `least-loaded` / `round-robin`).
    pub fn as_str(&self) -> &'static str {
        match self {
            RoutingPolicy::CacheAware => "cache-aware",
            RoutingPolicy::LeastLoaded => "least-loaded",
            RoutingPolicy::RoundRobin => "round-robin",
        }
    }
    /// Inverse of [`RoutingPolicy::as_str`].
    pub fn parse(s: &str) -> Option<RoutingPolicy> {
        match s {
            "cache-aware" => Some(RoutingPolicy::CacheAware),
            "least-loaded" => Some(RoutingPolicy::LeastLoaded),
            "round-robin" => Some(RoutingPolicy::RoundRobin),
            _ => None,
        }
    }
}

/// Front-end router configuration (the data-parallel serving knobs;
/// see [`crate::coordinator::router`]).
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Replica count the router expects to own.
    pub replicas: usize,
    /// Replica-selection policy for new requests.
    pub routing: RoutingPolicy,
    /// Sliding eviction window applied to every replica's prefix cache
    /// at router construction (when enabled; a disabled window leaves
    /// each replica's own [`EngineConfig::cache_watermarks`] in force).
    pub watermarks: CacheWatermarks,
    /// Cache-aware scoring: how many cached prefix tokens one queued or
    /// running sequence is worth. Higher values favor idle replicas
    /// over warm ones; 0 routes purely on cache affinity.
    pub load_penalty_tokens: usize,
    /// Cache-aware fairness: after this many *consecutive* placements
    /// on one replica, the next cache-aware pick excludes that replica
    /// when any other candidate is alive — so a single hot prefix
    /// cannot starve a cold replica of work forever. 0 disables the
    /// cap (pure affinity scoring, the pre-PR 7 behavior).
    pub cache_spread_limit: usize,
    /// Admission control: maximum queued + running sequences per
    /// replica. A submission that would push every alive replica past
    /// this cap is shed (`FinishReason::Shed`). 0 = unbounded.
    pub max_replica_queue: usize,
    /// Admission control: global waiting budget — when the waiting
    /// queues across alive replicas already hold this many sequences, a
    /// new submission is shed instead of queued forever. 0 = unbounded.
    pub max_waiting: usize,
    /// Transient step failures tolerated per replica before it is
    /// declared Dead and its in-flight requests are replayed. Each
    /// tolerated failure quarantines the replica with backoff.
    pub max_step_retries: usize,
    /// Quarantine backoff after the first transient failure, measured
    /// in router steps; doubles per consecutive failure (deterministic
    /// exponential backoff). Clamped to at least 1.
    pub retry_backoff_steps: usize,
    /// Cross-replica KV migration: when cache-aware placement lands a
    /// request on a replica that holds *fewer* cached prefix tokens
    /// than some other alive replica, fetch the donor's stashed KV
    /// blocks in quantized wire form and import them on the receiver,
    /// so only the suffix is recomputed. `false` (the default)
    /// preserves the route-or-recompute behavior bit-for-bit.
    pub kv_migrate: bool,
    /// Cache-aware scoring: percentage of a *remote* replica's hit
    /// tokens credited to a candidate when migration could ship the
    /// blocks over (only with [`RouterConfig::kv_migrate`]). 100 treats
    /// a migratable prefix as free; 0 restores hit-or-nothing scoring.
    pub migrate_hit_discount: usize,
    /// Cache-aware scoring: percentage a *pooled* (demoted host-side)
    /// hit token is worth relative to a device-resident one. A pooled
    /// hit still skips recompute but pays a dequantize+copy restore, so
    /// it must score strictly below a device hit — keep this < 100.
    pub pooled_hit_discount: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            replicas: 1,
            routing: RoutingPolicy::CacheAware,
            watermarks: CacheWatermarks::default(),
            load_penalty_tokens: 16,
            cache_spread_limit: 0,
            max_replica_queue: 0,
            max_waiting: 0,
            max_step_retries: 2,
            retry_backoff_steps: 2,
            kv_migrate: false,
            migrate_hit_discount: 50,
            pooled_hit_discount: 75,
        }
    }
}

/// Simulated accelerator profile for the analytic performance model
/// (paper-scale Fig 7 curves) and the memory-budget admission control.
#[derive(Debug, Clone)]
pub struct GpuProfile {
    /// Profile name (reports / tables).
    pub name: String,
    /// Device memory capacity in bytes.
    pub mem_bytes: usize,
    /// HBM bandwidth, GB/s (roofline memory term).
    pub hbm_gbps: f64,
    /// Peak FP16 throughput, TFLOP/s (roofline compute term).
    pub fp16_tflops: f64,
    /// PCIe/NVLink interconnect for tensor-parallel all-reduce.
    pub link_gbps: f64,
    /// Per-message interconnect latency, microseconds.
    pub link_latency_us: f64,
}

impl GpuProfile {
    /// NVIDIA A100 40GB PCIe (the paper's testbed).
    pub fn a100_40g() -> Self {
        GpuProfile {
            name: "A100-40G-PCIe".into(),
            mem_bytes: 40 * (1 << 30),
            hbm_gbps: 1555.0,
            fp16_tflops: 312.0,
            link_gbps: 64.0, // PCIe gen4 x16
            link_latency_us: 10.0,
        }
    }
    /// Scaled-down profile for exercising admission control with the
    /// laptop-scale models (a "toy GPU" with a few hundred MB).
    pub fn sim_small(mem_mb: usize) -> Self {
        GpuProfile {
            name: format!("sim-{mem_mb}MB"),
            mem_bytes: mem_mb << 20,
            hbm_gbps: 100.0,
            fp16_tflops: 5.0,
            link_gbps: 16.0,
            link_latency_us: 10.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_python_table() {
        let b = ModelConfig::base();
        assert_eq!(b.dim, 768);
        assert_eq!(b.layers, 12);
        assert_eq!(b.head_dim(), 64);
        // ~100M params for the end-to-end driver
        let p = b.param_count();
        assert!(p > 90_000_000 && p < 120_000_000, "params {p}");
    }

    #[test]
    fn w4a16_is_about_4x_smaller_on_linears() {
        let c = ModelConfig::base();
        let fp = c.weight_bytes(Precision::Fp16);
        let q4 = c.weight_bytes(Precision::W4a16);
        // embeddings/lm_head stay fp16 so overall ratio is < 4x but the
        // reduction must be substantial
        assert!(fp as f64 / q4 as f64 > 2.3, "{fp} vs {q4}");
        let c = ModelConfig::tiny();
        assert!(c.weight_bytes(Precision::Fp16)
            > c.weight_bytes(Precision::W4a16));
    }

    #[test]
    fn precision_roundtrip() {
        for p in [Precision::Fp16, Precision::W4a16] {
            assert_eq!(Precision::parse(p.as_str()), Some(p));
        }
        assert_eq!(Precision::parse("int8"), None);
    }

    #[test]
    fn kv_cache_mode_roundtrip() {
        for m in [KvCacheMode::F32, KvCacheMode::Q8, KvCacheMode::Q4] {
            assert_eq!(KvCacheMode::parse(m.as_str()), Some(m));
        }
        assert_eq!(KvCacheMode::parse("q2"), None);
        // the defaults keep golden streams bit-identical: exact rows,
        // tiering off
        let e = EngineConfig::default();
        assert_eq!(e.kv_cache_mode, KvCacheMode::F32);
        assert_eq!(e.kv_pool_blocks, 0);
    }

    #[test]
    fn by_name() {
        assert!(ModelConfig::by_name("tiny").is_some());
        assert!(ModelConfig::by_name("huge").is_none());
    }

    #[test]
    fn routing_policy_roundtrip() {
        for p in [RoutingPolicy::CacheAware, RoutingPolicy::LeastLoaded,
                  RoutingPolicy::RoundRobin] {
            assert_eq!(RoutingPolicy::parse(p.as_str()), Some(p));
        }
        assert_eq!(RoutingPolicy::parse("random"), None);
        let rc = RouterConfig::default();
        assert_eq!(rc.replicas, 1);
        assert!(!rc.watermarks.enabled());
        assert!(CacheWatermarks::new(4, 2).enabled());
        // migration ships off by default (route-or-recompute unchanged)
        // and a pooled hit must score below a device-resident one
        assert!(!rc.kv_migrate);
        assert!(rc.pooled_hit_discount < 100);
        assert!(rc.migrate_hit_discount <= 100);
    }

    #[test]
    fn kv_bytes() {
        let c = ModelConfig::tiny();
        assert_eq!(c.kv_bytes_per_token(), 2 * 2 * 2 * 128);
    }
}
