//! Byte-level BPE tokenizer: trainer + encoder/decoder.
//!
//! The 256 byte values are the base vocabulary; training greedily merges
//! the most frequent adjacent pair until the target vocab size is reached
//! (the GPT-2 recipe, minus the regex pre-splitting — fine at our corpus
//! scale). Encoding applies merges in rank order.

use std::collections::HashMap;

/// A trained tokenizer: merge ranks + decoded piece table.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    /// (left, right) -> merged token id, rank-ordered by creation.
    merges: HashMap<(u32, u32), u32>,
    /// token id -> byte string.
    pieces: Vec<Vec<u8>>,
}

impl Tokenizer {
    /// Train on `text` to `vocab_size` tokens (>= 256).
    pub fn train(text: &str, vocab_size: usize) -> Tokenizer {
        assert!(vocab_size >= 256, "vocab must cover raw bytes");
        let mut pieces: Vec<Vec<u8>> =
            (0..=255u8).map(|b| vec![b]).collect();
        let mut merges = HashMap::new();
        let mut ids: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        while pieces.len() < vocab_size {
            // count adjacent pairs
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in ids.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            // deterministic argmax: highest count, then lowest pair ids
            let Some((&pair, &n)) = counts
                .iter()
                .max_by_key(|(&(a, b), &c)| (c, std::cmp::Reverse((a, b))))
            else {
                break;
            };
            if n < 2 {
                break; // nothing worth merging
            }
            let new_id = pieces.len() as u32;
            let mut piece = pieces[pair.0 as usize].clone();
            piece.extend_from_slice(&pieces[pair.1 as usize]);
            pieces.push(piece);
            merges.insert(pair, new_id);
            ids = merge_ids(&ids, pair, new_id);
        }
        Tokenizer { merges, pieces }
    }

    pub fn vocab_size(&self) -> usize {
        self.pieces.len()
    }

    /// Encode text to token ids (merges applied in rank order).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        loop {
            // find the lowest-rank applicable merge
            let mut best: Option<(u32, (u32, u32))> = None;
            for w in ids.windows(2) {
                if let Some(&m) = self.merges.get(&(w[0], w[1])) {
                    if best.map(|(b, _)| m < b).unwrap_or(true) {
                        best = Some((m, (w[0], w[1])));
                    }
                }
            }
            let Some((new_id, pair)) = best else { break };
            ids = merge_ids(&ids, pair, new_id);
        }
        ids
    }

    /// Decode ids back to (lossy-utf8) text.
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if let Some(p) = self.pieces.get(id as usize) {
                bytes.extend_from_slice(p);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Encode, capping token ids to `max_vocab` (model embedding bound —
    /// ids beyond it map into the byte range via modulo; only relevant if
    /// the tokenizer was trained larger than the model vocab).
    pub fn encode_for_model(&self, text: &str, max_vocab: usize)
        -> Vec<u32> {
        self.encode(text)
            .into_iter()
            .map(|t| if (t as usize) < max_vocab { t } else { t % 256 })
            .collect()
    }
}

fn merge_ids(ids: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(ids.len());
    let mut i = 0;
    while i < ids.len() {
        if i + 1 < ids.len() && ids[i] == pair.0 && ids[i + 1] == pair.1 {
            out.push(new_id);
            i += 2;
        } else {
            out.push(ids[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const CORPUS: &str = "def add(a, b):\n    return a + b\n\
                          def mul(a, b):\n    return a * b\n";

    #[test]
    fn roundtrip_exact() {
        let tok = Tokenizer::train(CORPUS, 300);
        for s in [CORPUS, "return a", "def f(x): pass", "héllo ⚙"] {
            assert_eq!(tok.decode(&tok.encode(s)), s);
        }
    }

    #[test]
    fn training_compresses() {
        let tok = Tokenizer::train(CORPUS, 320);
        let ids = tok.encode(CORPUS);
        assert!(
            ids.len() < CORPUS.len(),
            "{} !< {}",
            ids.len(),
            CORPUS.len()
        );
    }

    #[test]
    fn vocab_size_respected() {
        let tok = Tokenizer::train(CORPUS, 280);
        assert!(tok.vocab_size() <= 280);
        assert!(tok.vocab_size() > 256); // some merges happened
        let ids = tok.encode(CORPUS);
        assert!(ids.iter().all(|&t| (t as usize) < tok.vocab_size()));
    }

    #[test]
    fn deterministic_training() {
        let a = Tokenizer::train(CORPUS, 300);
        let b = Tokenizer::train(CORPUS, 300);
        assert_eq!(a.encode(CORPUS), b.encode(CORPUS));
    }

    #[test]
    fn model_vocab_cap() {
        let tok = Tokenizer::train(CORPUS, 400);
        let ids = tok.encode_for_model(CORPUS, 300);
        assert!(ids.iter().all(|&t| (t as usize) < 300));
    }

    #[test]
    fn empty_and_unknown() {
        let tok = Tokenizer::train(CORPUS, 260);
        assert!(tok.encode("").is_empty());
        assert_eq!(tok.decode(&[]), "");
        // raw bytes always encodable
        assert_eq!(tok.decode(&tok.encode("\u{0}\u{1}")), "\u{0}\u{1}");
    }
}
