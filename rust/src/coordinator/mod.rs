//! The vLLM-shaped serving coordinator (Layer 3).
//!
//! * [`sequence`] — request/sequence state machine.
//! * [`block_manager`] — paged KV-cache accounting: ref-counted blocks
//!   over a fixed device pool, watermark admission, preemption support.
//! * [`scheduler`] — continuous batching: FCFS waiting queue, prefill
//!   admission under a token budget, decode batch formation, preemption
//!   under KV pressure (recompute policy).
//! * [`sampler`] — greedy / temperature / top-k sampling, seeded.
//! * [`engine`] — the step loop tying scheduler → runtime → sampler →
//!   sequence updates together.
//! * [`metrics`] — TTFT / per-token latency / throughput accounting.

pub mod block_manager;
pub mod engine;
pub mod metrics;
pub mod sampler;
pub mod scheduler;
pub mod sequence;
