//! The vLLM-shaped serving coordinator (Layer 3).
//!
//! * [`sequence`] — request/sequence state machine.
//! * [`block_manager`] — paged KV-cache accounting: ref-counted blocks
//!   over a fixed device pool, watermark admission, preemption support,
//!   and content-hash prefix caching (shared full blocks, LRU eviction).
//! * [`scheduler`] — continuous batching with **chunked prefill**: FCFS
//!   waiting queue, per-step mixed plans (decode round + prefill chunks
//!   under one token budget, cache hits only budget the tokens past the
//!   hit), preemption under KV pressure (recompute policy — itself
//!   chunked, so recompute can never outgrow a compiled bucket).
//! * [`sampler`] — greedy / temperature / top-k sampling, seeded.
//! * [`engine`] — the step loop tying scheduler → runtime → sampler →
//!   sequence updates together; executes chunks (cold chunks through a
//!   right-sized prefill bucket, continuations through the decode
//!   executable) and registers filled blocks back into the cache after
//!   chunks *and* block-filling decode steps.
//! * [`metrics`] — TTFT / per-token latency / throughput / cache-savings
//!   / chunk accounting.
//! * [`replica`] — one engine bundle behind the [`replica::ReplicaCore`]
//!   interface the multi-replica front end drives; fallible step/submit
//!   ([`replica::ReplicaError`]) and the replica health states.
//! * [`router`] — the data-parallel front end: N replicas, cache-aware
//!   request routing over a shared content-hash directory, per-replica
//!   stats, replica failure detection with bounded retry, in-flight
//!   replay onto survivors, and load-shedding admission control.
//! * [`worker`] — the threaded serving loop: one worker thread per
//!   replica stepping continuously, an [`worker::AsyncRouter`] front
//!   end placing requests and folding worker events (tokens, finishes,
//!   cache updates, failures) back into routing/replay state over
//!   channels — no shared mutable state on the hot path.
//! * [`fault`] — deterministic fault injection
//!   ([`fault::FaultyCore`]) driving the tier-1 recovery tests.
//! * [`fake`] — deterministic replica cores ([`fake::FakeCore`],
//!   [`fake::EchoCore`]) with a content-determined fake model, shared
//!   by the router/server/worker test suites.
//!
//! `docs/ARCHITECTURE.md` at the repo root walks one request through
//! all of these modules end to end, with the block lifecycle diagram.
//!
//! # Prefix-cache design (across the three modules)
//!
//! A full block's identity is the chained hash of its token content
//! (`block_manager::block_hash`), so equal keys mean equal
//! position-aligned prefixes. Only full blocks are ever cached or
//! shared; the tail partial block is always private, and a hit never
//! covers the entire prompt (at least one token is recomputed for fresh
//! sampling logits) — the copy-on-write boundary. Cached blocks with no
//! live references are *evictable* free capacity reclaimed LRU — on
//! demand when the free list runs dry, and proactively by the sliding
//! eviction window (high/low watermarks on the evictable population)
//! when one is configured. The
//! engine stashes each cached block's host KV rows by physical block id
//! and copies them into a new sequence's cache on a hit, so reuse skips
//! real prefill compute, not just accounting.

pub mod block_manager;
pub mod engine;
pub mod fake;
pub mod fault;
pub mod metrics;
pub mod replica;
pub mod router;
pub mod sampler;
pub mod scheduler;
pub mod sequence;
pub mod worker;
