//! Token sampling: greedy, temperature, top-k — seeded and reproducible.

use crate::util::rng::Rng;

use super::sequence::SamplingParams;

/// Sample the next token from a logits row.
pub fn sample(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> u32 {
    if params.temperature <= 0.0 {
        return argmax(logits);
    }
    let k = if params.top_k == 0 {
        logits.len()
    } else {
        params.top_k.min(logits.len())
    };
    // top-k indices by logit; total_cmp gives NaN a defined order, so
    // a poisoned logits row cannot panic the replica mid-decode
    let mut idx: Vec<u32> = (0..logits.len() as u32).collect();
    idx.sort_unstable_by(|&a, &b| {
        logits[b as usize].total_cmp(&logits[a as usize])
    });
    idx.truncate(k);
    // softmax over the kept set at the given temperature
    let inv_t = 1.0 / params.temperature;
    let m = logits[idx[0] as usize];
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| (((logits[i as usize] - m) * inv_t) as f64).exp())
        .collect();
    idx[rng.weighted(&weights)]
}

/// Index of the largest logit (greedy decoding; ties pick the lowest).
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(t: f32, k: usize) -> SamplingParams {
        SamplingParams { temperature: t, top_k: k, ..Default::default() }
    }

    #[test]
    fn greedy_picks_max() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let mut rng = Rng::new(0);
        assert_eq!(sample(&logits, &params(0.0, 0), &mut rng), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = vec![5.0, 4.9, -100.0, -100.0];
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let t = sample(&logits, &params(1.0, 2), &mut rng);
            assert!(t == 0 || t == 1, "sampled {t}");
        }
    }

    #[test]
    fn low_temperature_concentrates() {
        let logits = vec![1.0, 0.0];
        let mut rng = Rng::new(2);
        let n = 1000;
        let zeros = (0..n)
            .filter(|_| sample(&logits, &params(0.05, 0), &mut rng) == 0)
            .count();
        assert!(zeros > 990, "{zeros}");
    }

    #[test]
    fn high_temperature_spreads() {
        let logits = vec![1.0, 0.0];
        let mut rng = Rng::new(3);
        let n = 2000;
        let ones = (0..n)
            .filter(|_| sample(&logits, &params(50.0, 0), &mut rng) == 1)
            .count();
        assert!(ones > 700 && ones < 1300, "{ones}");
    }

    #[test]
    fn deterministic_given_seed() {
        let logits: Vec<f32> = (0..64).map(|i| ((i * 37) % 11) as f32).collect();
        let a: Vec<u32> = {
            let mut rng = Rng::new(7);
            (0..20).map(|_| sample(&logits, &params(1.0, 8), &mut rng))
                .collect()
        };
        let b: Vec<u32> = {
            let mut rng = Rng::new(7);
            (0..20).map(|_| sample(&logits, &params(1.0, 8), &mut rng))
                .collect()
        };
        assert_eq!(a, b);
    }
}
