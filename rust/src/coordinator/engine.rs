//! The engine step loop: scheduler → PJRT runtime → sampler → state.
//!
//! One [`Engine::step`] executes one scheduler plan: either a prefill
//! batch (admitting waiting sequences, building their KV, sampling their
//! first token) or one decode step over the running batch. Preempted
//! sequences drop their KV and recompute on re-admission (prompt +
//! generated-so-far re-prefilled), vLLM's recompute policy.
//!
//! Prefix caching: sequences the scheduler admitted with a cached prefix
//! skip recomputing it — the engine copies the stashed host KV rows of
//! the shared blocks into the sequence's cache and *partially prefills*
//! from the first uncached token (driving the decode executable over the
//! suffix, which is mathematically the same causal forward). After any
//! prefill completes, the engine registers the sequence's newly filled
//! full blocks back into the cache and stashes their KV rows, keyed by
//! physical block id, so later admissions can reuse them. Evicted block
//! ids reported by the block manager drop their stashed rows.

use std::collections::HashMap;

use anyhow::Result;

use crate::config::EngineConfig;
use crate::runtime::kv::{self, SeqKv};
use crate::runtime::simtp::Deployment;
use crate::util::rng::Rng;

use super::block_manager::{BlockManager, CacheStats};
use super::metrics::Metrics;
use super::sampler;
use super::scheduler::{Scheduler, StepPlan};
use super::sequence::{FinishReason, SamplingParams, SeqState, Sequence};

/// What a step did (for tests/telemetry).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StepOutcome {
    Prefilled(usize),
    Decoded(usize),
    Idle,
}

/// Copy one full block's rows out of a sequence cache into the stash
/// layout `[L, 2, block_size, D]` (the `cached_kv` entry format).
fn stash_block(kvseq: &SeqKv, blk: usize, bs: usize, layers: usize,
               dim: usize) -> Vec<f32> {
    let mut rows = vec![0.0f32; layers * 2 * bs * dim];
    for layer in 0..layers {
        for lane in 0..2 {
            for p in 0..bs {
                let dst = (((layer * 2) + lane) * bs + p) * dim;
                rows[dst..dst + dim]
                    .copy_from_slice(kvseq.row(layer, lane, blk * bs + p));
            }
        }
    }
    rows
}

/// Inverse of [`stash_block`]: load stashed rows into block `blk` of a
/// sequence cache (the same layout arithmetic, so the two can't drift).
fn unstash_block(kvseq: &mut SeqKv, blk: usize, bs: usize, layers: usize,
                 dim: usize, rows: &[f32]) {
    debug_assert_eq!(rows.len(), layers * 2 * bs * dim);
    for layer in 0..layers {
        for lane in 0..2 {
            for p in 0..bs {
                let src = (((layer * 2) + lane) * bs + p) * dim;
                kvseq
                    .row_mut(layer, lane, blk * bs + p)
                    .copy_from_slice(&rows[src..src + dim]);
            }
        }
    }
}

pub struct Engine {
    pub dep: Deployment,
    pub ecfg: EngineConfig,
    sched: Scheduler,
    seqs: HashMap<u64, Sequence>,
    kvs: HashMap<u64, SeqKv>,
    /// Host KV rows of cached blocks, keyed by physical block id; layout
    /// `[L, 2, block_size, D]`. Entries live as long as the block stays
    /// cached (dropped on eviction).
    cached_kv: HashMap<usize, Vec<f32>>,
    finished: Vec<Sequence>,
    pub metrics: Metrics,
    next_id: u64,
    /// Engine-level seed mixed into per-token sampling streams.
    pub seed: u64,
}

impl Engine {
    /// Engine with an explicit block pool (tests, ablations).
    pub fn new(dep: Deployment, mut ecfg: EngineConfig) -> Engine {
        let max_decode =
            dep.runtime.decode_batches().into_iter().max().unwrap_or(1);
        ecfg.max_running = ecfg.max_running.min(max_decode);
        let bm = BlockManager::new(ecfg.block_size, ecfg.total_blocks);
        Engine {
            sched: Scheduler::new(ecfg.clone(), bm),
            dep,
            ecfg,
            seqs: HashMap::new(),
            kvs: HashMap::new(),
            cached_kv: HashMap::new(),
            finished: vec![],
            metrics: Metrics::new(),
            next_id: 0,
            seed: 0,
        }
    }

    /// Engine whose KV pool is sized from the deployment's simulated GPU
    /// memory minus the model's weight bytes (the paper's Fig. 7 setup:
    /// W4A16 frees weight memory, so the pool and batches grow).
    pub fn with_memory_budget(dep: Deployment, mut ecfg: EngineConfig)
        -> Engine {
        let cfg = &dep.runtime.cfg;
        let precision = dep.runtime.precision;
        let weight_bytes = cfg.weight_bytes(precision);
        let mem = dep.gpu.mem_bytes * dep.workers;
        let bm = BlockManager::from_memory(
            ecfg.block_size, mem * 92 / 100, weight_bytes,
            cfg.kv_bytes_per_token(),
        );
        let max_decode =
            dep.runtime.decode_batches().into_iter().max().unwrap_or(1);
        ecfg.max_running = ecfg.max_running.min(max_decode);
        Engine {
            sched: Scheduler::new(ecfg.clone(), bm),
            dep,
            ecfg,
            seqs: HashMap::new(),
            kvs: HashMap::new(),
            cached_kv: HashMap::new(),
            finished: vec![],
            metrics: Metrics::new(),
            next_id: 0,
            seed: 0,
        }
    }

    /// Largest prompt the compiled prefill buckets accept.
    pub fn max_prompt_len(&self) -> usize {
        self.dep
            .runtime
            .prefill_buckets()
            .into_iter()
            .map(|(_, s)| s)
            .max()
            .unwrap_or(0)
    }

    /// Submit a request; returns its id. Prompts longer than the prefill
    /// bucket are rejected (finished with `PromptTooLong`); generation is
    /// clamped so prompt + output fits the KV capacity.
    pub fn submit(&mut self, prompt: Vec<u32>, mut params: SamplingParams)
        -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.metrics.on_submit(prompt.len());
        let max_len = self.dep.runtime.cfg.max_len;
        let too_long =
            prompt.is_empty() || prompt.len() > self.max_prompt_len()
                || prompt.len() + 1 > max_len;
        params.max_new_tokens = params
            .max_new_tokens
            .min(max_len.saturating_sub(prompt.len()));
        let mut seq = Sequence::new(id, prompt, params);
        if too_long {
            seq.finish(FinishReason::PromptTooLong);
            self.metrics.on_finished(&seq);
            self.finished.push(seq);
            return id;
        }
        self.seqs.insert(id, seq);
        self.sched.add(id);
        id
    }

    pub fn has_work(&self) -> bool {
        self.sched.has_work()
    }
    pub fn kv_occupancy(&self) -> f64 {
        self.sched.bm.occupancy()
    }
    /// Block-level prefix-cache counters (hits, shared blocks, evictions).
    pub fn cache_stats(&self) -> CacheStats {
        self.sched.bm.stats.clone()
    }
    pub fn take_finished(&mut self) -> Vec<Sequence> {
        std::mem::take(&mut self.finished)
    }

    /// Execute one scheduler step.
    pub fn step(&mut self) -> Result<StepOutcome> {
        let plan = self.sched.plan(&self.seqs);
        // blocks whose cached content was reclaimed lose their rows
        for b in self.sched.bm.take_evicted() {
            self.cached_kv.remove(&b);
        }
        // drop KV of anything the scheduler preempted
        for id in self.sched.preempted.clone() {
            self.kvs.remove(&id);
            if let Some(s) = self.seqs.get_mut(&id) {
                if s.state == SeqState::Running {
                    s.preempt();
                }
            }
        }
        match plan {
            StepPlan::Idle => Ok(StepOutcome::Idle),
            StepPlan::Prefill { ids, cached } => {
                self.do_prefill(ids, cached)
            }
            StepPlan::Decode { ids } => self.do_decode(ids),
        }
    }

    fn do_prefill(&mut self, ids: Vec<u64>, cached: Vec<usize>)
        -> Result<StepOutcome> {
        let cfg = self.dep.runtime.cfg.clone();
        let vocab = cfg.vocab;
        // recompute semantics: preempted sequences re-prefill prompt +
        // generated output
        let full: Vec<Vec<u32>> =
            ids.iter().map(|id| self.seqs[id].full_tokens()).collect();
        let cold: Vec<usize> =
            (0..ids.len()).filter(|&i| cached[i] == 0).collect();
        let warm: Vec<usize> =
            (0..ids.len()).filter(|&i| cached[i] > 0).collect();

        // ---- cold sequences: one batched prefill over full prompts
        if !cold.is_empty() {
            let views: Vec<&[u32]> =
                cold.iter().map(|&i| &full[i][..]).collect();
            let res = self.dep.prefill(&views)?;
            let lens: Vec<usize> =
                cold.iter().map(|&i| full[i].len()).collect();
            let mut new_kvs: Vec<SeqKv> =
                cold.iter().map(|_| SeqKv::new(&cfg)).collect();
            {
                let mut refs: Vec<&mut SeqKv> =
                    new_kvs.iter_mut().collect();
                kv::fill_prefill_rows(&mut refs, &cfg, res.batch, res.seq,
                                      &res.kv_new, &lens);
            }
            for ((b, &i), kvseq) in
                cold.iter().enumerate().zip(new_kvs)
            {
                let id = ids[i];
                self.kvs.insert(id, kvseq);
                self.register_filled_blocks(id, &full[i]);
                let last = lens[b] - 1;
                let row =
                    &res.logits[(b * res.seq + last) * vocab..][..vocab];
                self.sample_first_token(id, 0, row);
            }
            self.metrics.prefill_tokens_executed +=
                lens.iter().sum::<usize>();
        }

        // ---- warm sequences: copy the cached prefix rows, then prefill
        // only the suffix by driving the decode executable token by token
        // (the same causal forward, starting at the first uncached
        // position)
        let bucket = self
            .dep
            .runtime
            .decode_batches()
            .into_iter()
            .find(|&b| b >= 1)
            .unwrap_or(1);
        for &i in &warm {
            let id = ids[i];
            let toks = &full[i];
            let c = cached[i];
            let mut kvseq = self.kv_from_cached_prefix(id, c);
            let mut last_logits: Vec<f32> = vec![];
            // assemble the padded device batch once; per-token we only
            // scatter the one new row into slot b=0 (mirrors the
            // assemble_batch layout) instead of re-copying MAX rows
            let lane_sz = cfg.max_len * cfg.dim;
            let mut kv_batch = kv::assemble_batch(&[&kvseq], &cfg, bucket);
            for pos in c..toks.len() {
                let res = self.dep.decode(&[toks[pos]], &[kvseq.len],
                                          &kv_batch)?;
                let row_pos = kvseq.len;
                {
                    let mut refs = [&mut kvseq];
                    kv::append_decode_rows(&mut refs, &cfg, res.batch,
                                           &res.kv_new);
                }
                for layer in 0..cfg.layers {
                    for lane in 0..2 {
                        // kv_new is [L, 2, B, 1, D], our row is b = 0
                        let src =
                            ((layer * 2) + lane) * res.batch * cfg.dim;
                        let dst = (((layer * 2) + lane) * bucket)
                            * lane_sz
                            + row_pos * cfg.dim;
                        kv_batch[dst..dst + cfg.dim].copy_from_slice(
                            &res.kv_new[src..src + cfg.dim],
                        );
                    }
                }
                if pos + 1 == toks.len() {
                    last_logits = res.logits[..vocab].to_vec();
                }
            }
            self.kvs.insert(id, kvseq);
            self.register_filled_blocks(id, toks);
            self.sample_first_token(id, c, &last_logits);
            self.metrics.prefill_tokens_executed += toks.len() - c;
            self.metrics.cached_prefix_tokens += c;
        }

        self.metrics.prefill_steps += 1;
        self.metrics.batch_sizes.push(ids.len() as f64);
        self.metrics.kv_occupancy.push(self.sched.bm.occupancy());
        Ok(StepOutcome::Prefilled(ids.len()))
    }

    /// A fresh SeqKv pre-loaded with the stashed rows of the sequence's
    /// `cached_tokens`-long shared prefix (whole blocks by construction).
    fn kv_from_cached_prefix(&self, id: u64, cached_tokens: usize)
        -> SeqKv {
        let cfg = &self.dep.runtime.cfg;
        let bs = self.sched.bm.block_size;
        debug_assert_eq!(cached_tokens % bs, 0);
        let table =
            self.sched.bm.table(id).expect("admitted seq has a table");
        let mut kvseq = SeqKv::new(cfg);
        for blk in 0..cached_tokens / bs {
            let rows = &self.cached_kv[&table[blk]];
            unstash_block(&mut kvseq, blk, bs, cfg.layers, cfg.dim, rows);
        }
        kvseq.len = cached_tokens;
        kvseq
    }

    /// Register this sequence's full blocks into the prefix cache and
    /// stash their freshly built KV rows (called right after prefill, so
    /// the rows exist and the sequence still owns its table).
    fn register_filled_blocks(&mut self, id: u64, tokens: &[u32]) {
        let newly = self.sched.bm.register_prefix(id, tokens);
        if newly.is_empty() {
            return;
        }
        let bs = self.sched.bm.block_size;
        let (layers, dim) =
            (self.dep.runtime.cfg.layers, self.dep.runtime.cfg.dim);
        let kvseq = &self.kvs[&id];
        for (blk, block_id) in newly {
            let rows = stash_block(kvseq, blk, bs, layers, dim);
            self.cached_kv.insert(block_id, rows);
        }
    }

    /// Post-prefill bookkeeping shared by the cold and warm paths: mark
    /// running, record the cache coverage, sample the first token.
    fn sample_first_token(&mut self, id: u64, cached_len: usize,
                          row: &[f32]) {
        let seq = self.seqs.get_mut(&id).unwrap();
        seq.state = SeqState::Running;
        seq.cached_prefix_len = cached_len;
        let mut rng = Rng::new(
            self.seed
                ^ seq.params.seed.wrapping_mul(0x9e3779b97f4a7c15)
                ^ (seq.id << 32)
                ^ seq.output.len() as u64,
        );
        let tok = sampler::sample(row, &seq.params, &mut rng);
        seq.record_token(tok);
        self.finish_if_done(id);
    }

    fn do_decode(&mut self, ids: Vec<u64>) -> Result<StepOutcome> {
        let cfg = self.dep.runtime.cfg.clone();
        let vocab = cfg.vocab;
        // KV-capacity guard: finish sequences whose cache is full
        let mut live = vec![];
        for id in ids {
            let len = self.kvs[&id].len;
            if len + 1 >= cfg.max_len {
                self.finish(id, FinishReason::MaxTokens);
            } else {
                live.push(id);
            }
        }
        if live.is_empty() {
            return Ok(StepOutcome::Idle);
        }
        let tokens: Vec<u32> =
            live.iter().map(|id| self.seqs[id].last_token()).collect();
        let lens: Vec<usize> = live.iter().map(|id| self.kvs[id].len)
            .collect();
        let kv_refs: Vec<&SeqKv> = live.iter().map(|id| &self.kvs[id])
            .collect();
        let bucket = self
            .dep
            .runtime
            .decode_batches()
            .into_iter()
            .find(|&b| b >= live.len())
            .unwrap_or(live.len());
        let kv_batch = kv::assemble_batch(&kv_refs, &cfg, bucket);
        let res = self.dep.decode(&tokens, &lens, &kv_batch)?;
        // append new KV rows
        {
            let mut refs: Vec<&mut SeqKv> = Vec::with_capacity(live.len());
            // split_mut over hashmap: collect ids then fetch disjoint
            let ptrs: Vec<*mut SeqKv> = live
                .iter()
                .map(|id| self.kvs.get_mut(id).unwrap() as *mut SeqKv)
                .collect();
            // SAFETY: ids are distinct keys, so the pointers are disjoint.
            for p in ptrs {
                refs.push(unsafe { &mut *p });
            }
            kv::append_decode_rows(&mut refs, &cfg, res.batch, &res.kv_new);
        }
        for (b, id) in live.iter().enumerate() {
            let row = &res.logits[b * vocab..(b + 1) * vocab];
            let seq = self.seqs.get_mut(id).unwrap();
            let mut rng = Rng::new(
                self.seed
                    ^ seq.params.seed.wrapping_mul(0x9e3779b97f4a7c15)
                    ^ (seq.id << 32)
                    ^ seq.output.len() as u64,
            );
            let tok = sampler::sample(row, &seq.params, &mut rng);
            seq.record_token(tok);
            self.finish_if_done(*id);
        }
        self.metrics.decode_steps += 1;
        self.metrics.batch_sizes.push(live.len() as f64);
        self.metrics.kv_occupancy.push(self.sched.bm.occupancy());
        Ok(StepOutcome::Decoded(live.len()))
    }

    fn finish_if_done(&mut self, id: u64) {
        if let Some(reason) = self.seqs[&id].should_finish() {
            self.finish(id, reason);
        }
    }

    fn finish(&mut self, id: u64, reason: FinishReason) {
        let mut seq = self.seqs.remove(&id).unwrap();
        seq.finish(reason);
        self.sched.on_finished(id);
        self.kvs.remove(&id);
        self.metrics.on_finished(&seq);
        self.finished.push(seq);
    }

    /// Drive until every submitted request finishes (or `max_steps`).
    pub fn run_to_completion(&mut self, max_steps: usize) -> Result<usize> {
        let mut steps = 0;
        while self.has_work() && steps < max_steps {
            self.step()?;
            steps += 1;
        }
        Ok(steps)
    }
}
