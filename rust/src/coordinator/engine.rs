//! The engine step loop: scheduler → PJRT runtime → sampler → state.
//!
//! One [`Engine::step`] executes one scheduler [`StepPlan`]: a set of
//! prefill *chunks* (admissions and continuations of partially
//! prefilled sequences) and/or one decode round over the running batch
//! — mixed steps are the normal case under chunked prefill. Preempted
//! sequences drop their KV and recompute on re-admission (prompt +
//! generated-so-far re-prefilled), vLLM's recompute policy; under
//! chunked prefill that recompute is itself chunked, so it can never
//! outgrow a compiled prefill bucket.
//!
//! # Chunk execution
//!
//! A chunk `[start, end)` builds KV rows for positions `start..end` of
//! the sequence's full content:
//!
//! * `start == 0` (cold): the chunk runs through the smallest compiled
//!   prefill bucket that fits it (the runtime's bucket selection); cold
//!   chunks of one step batch into a single prefill call.
//! * `start > 0` (cache-hit suffix, a later chunk, or recompute past
//!   the first bucket): the chunk executes through the compiled
//!   **chunked-prefill executable** — one device call for the whole
//!   chunk, against the sequence's KV prefix. Chunks of *different*
//!   sequences whose smallest-fitting `(chunk_len, prefix_len)` bucket
//!   pair matches batch **positionwise** into a single call (each batch
//!   slot carries its own start position). When no compiled chunk
//!   bucket fits — pre-chunk artifact sets, oversized shapes, or
//!   `enable_compiled_chunks = false` — the engine falls back to
//!   driving the decode executable over the chunk token by token (the
//!   pre-chunk-executable path), which is bit-identical in token
//!   streams but costs one device call per token. The `device_calls`
//!   metric makes the difference observable.
//!
//! When a chunk reaches the full content length the sequence's next
//! token is sampled from the chunk's final logits and it joins the
//! decode set.
//!
//! # Prefix cache
//!
//! Sequences admitted with a cached prefix skip recomputing it — the
//! engine copies the stashed host KV rows of the shared blocks into the
//! sequence's cache and the first chunk starts past the hit. After
//! every chunk, and after every decode step that lands on a block
//! boundary, the engine registers newly filled full blocks into the
//! cache and stashes their KV rows keyed by physical block id — so
//! long generations seed the cache too, and a preempted sequence's
//! recompute can hit blocks it registered itself while decoding.
//! Stashes are stored at [`crate::config::EngineConfig::kv_cache_mode`]
//! precision ([`crate::runtime::kvq`]): `F32` keeps exact rows
//! (bit-identical restores), `Q8`/`Q4` shrink them 4–8×.
//!
//! # Tiered KV pool
//!
//! With [`crate::config::EngineConfig::kv_pool_blocks`] > 0, evicted
//! blocks *demote*: the block manager keeps the content hash in a
//! bounded pool index and the engine moves the stashed rows into
//! `kv_pool` keyed by hash; a later admission hit on a pooled hash
//! restores the rows onto a fresh device block instead of recomputing
//! the prefix (`recompute_avoided_tokens` counts the savings). With
//! tiering off, evicted block ids drop their stashed rows — the
//! pre-pool behavior. The byte moves happen in
//! [`Engine::drain_cache_tiering`], ordered so a demote-then-restore
//! within one plan is resolved before any chunk reads the rows.

use std::collections::HashMap;

use anyhow::Result;

use crate::config::EngineConfig;
use crate::runtime::executor::DecodeResult;
use crate::runtime::kv::{self, SeqKv};
use crate::runtime::kvq::KvStash;
use crate::runtime::simtp::Deployment;
use crate::util::rng::Rng;

use super::block_manager::{chain_hashes, BlockManager, CacheEvent,
                           CacheStats};
use super::metrics::Metrics;
use super::sampler;
use super::scheduler::{PrefillChunk, Scheduler, StepPlan};
use super::sequence::{FinishReason, SamplingParams, SeqState, Sequence};

/// What a step did (for tests/telemetry).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StepOutcome {
    /// Executed work this step.
    Ran {
        /// Prefill tokens computed across all chunks.
        chunk_tokens: usize,
        /// Sequences whose prefill completed (first token sampled).
        completed_prefills: usize,
        /// Sequences decoded one token.
        decoded: usize,
    },
    /// Nothing schedulable.
    Idle,
}

/// Copy one full block's rows out of a sequence cache into the stash
/// layout `[L, 2, block_size, D]` (the `cached_kv` entry format).
fn stash_block(kvseq: &SeqKv, blk: usize, bs: usize, layers: usize,
               dim: usize) -> Vec<f32> {
    let mut rows = vec![0.0f32; layers * 2 * bs * dim];
    for layer in 0..layers {
        for lane in 0..2 {
            for p in 0..bs {
                let dst = (((layer * 2) + lane) * bs + p) * dim;
                rows[dst..dst + dim]
                    .copy_from_slice(kvseq.row(layer, lane, blk * bs + p));
            }
        }
    }
    rows
}

/// Inverse of [`stash_block`]: load stashed rows into block `blk` of a
/// sequence cache (the same layout arithmetic, so the two can't drift).
fn unstash_block(kvseq: &mut SeqKv, blk: usize, bs: usize, layers: usize,
                 dim: usize, rows: &[f32]) {
    debug_assert_eq!(rows.len(), layers * 2 * bs * dim);
    for layer in 0..layers {
        for lane in 0..2 {
            for p in 0..bs {
                let src = (((layer * 2) + lane) * bs + p) * dim;
                kvseq
                    .row_mut(layer, lane, blk * bs + p)
                    .copy_from_slice(&rows[src..src + dim]);
            }
        }
    }
}

/// The serving engine: owns the deployment, the scheduler, and all
/// per-sequence state (tokens, KV, metrics).
pub struct Engine {
    /// Model runtime plus simulated device topology.
    pub dep: Deployment,
    /// Engine configuration (buckets synced from the runtime).
    pub ecfg: EngineConfig,
    sched: Scheduler,
    seqs: HashMap<u64, Sequence>,
    kvs: HashMap<u64, SeqKv>,
    /// Host KV rows of cached blocks, keyed by physical block id; row
    /// layout `[L, 2, block_size, D]`, stored at `ecfg.kv_cache_mode`
    /// precision. Entries live as long as the block stays cached
    /// (dropped — or demoted into `kv_pool` — on eviction).
    cached_kv: HashMap<usize, KvStash>,
    /// Tiered-pool bytes: stashes of demoted blocks, keyed by content
    /// hash. The block manager owns the matching index (bound, LRU,
    /// membership); this map holds exactly the bytes for that index.
    kv_pool: HashMap<u64, KvStash>,
    finished: Vec<Sequence>,
    /// Tokens sampled since the last [`Engine::take_emitted`] drain, in
    /// emission order — the streaming surface. Appended exactly where
    /// `Sequence::record_token` runs, so the incremental stream and the
    /// final `output` cannot drift.
    emitted: Vec<(u64, u32)>,
    /// Step/latency/cache counters.
    pub metrics: Metrics,
    next_id: u64,
    /// Engine-level seed mixed into per-token sampling streams.
    pub seed: u64,
}

/// Make the config's bucket view truthful: the scheduler plans against
/// `ecfg.prefill_buckets` / `decode_batches`, so when the runtime knows
/// its compiled buckets they override the config defaults (chunk caps
/// and cold-batch caps must match what can actually execute).
fn sync_buckets(dep: &Deployment, ecfg: &mut EngineConfig) {
    let pb = dep.runtime.prefill_buckets();
    if !pb.is_empty() {
        ecfg.prefill_buckets = pb;
    }
    let db = dep.runtime.decode_batches();
    if let Some(cap) = db.iter().copied().max() {
        ecfg.max_running = ecfg.max_running.min(cap);
        ecfg.decode_batches = db;
    }
    // chunk buckets cap continuation-chunk widths so a chunk maps to
    // one compiled call; empty (pre-chunk artifacts) leaves the
    // scheduler uncapped and the engine on the per-token fallback
    ecfg.chunk_buckets = dep.runtime.chunk_buckets();
}

impl Engine {
    /// Engine with an explicit block pool (tests, ablations).
    pub fn new(dep: Deployment, mut ecfg: EngineConfig) -> Engine {
        sync_buckets(&dep, &mut ecfg);
        let mut bm = BlockManager::new(ecfg.block_size, ecfg.total_blocks);
        bm.set_kv_pool(ecfg.kv_pool_blocks);
        Engine {
            sched: Scheduler::new(ecfg.clone(), bm),
            dep,
            ecfg,
            seqs: HashMap::new(),
            kvs: HashMap::new(),
            cached_kv: HashMap::new(),
            kv_pool: HashMap::new(),
            finished: vec![],
            emitted: vec![],
            metrics: Metrics::new(),
            next_id: 0,
            seed: 0,
        }
    }

    /// Engine whose KV pool is sized from the deployment's simulated GPU
    /// memory minus the model's weight bytes (the paper's Fig. 7 setup:
    /// W4A16 frees weight memory, so the pool and batches grow).
    pub fn with_memory_budget(dep: Deployment, mut ecfg: EngineConfig)
        -> Engine {
        let cfg = &dep.runtime.cfg;
        let precision = dep.runtime.precision;
        let weight_bytes = cfg.weight_bytes(precision);
        let mem = dep.gpu.mem_bytes * dep.workers;
        let mut bm = BlockManager::from_memory(
            ecfg.block_size, mem * 92 / 100, weight_bytes,
            cfg.kv_bytes_per_token(),
        );
        bm.set_kv_pool(ecfg.kv_pool_blocks);
        sync_buckets(&dep, &mut ecfg);
        Engine {
            sched: Scheduler::new(ecfg.clone(), bm),
            dep,
            ecfg,
            seqs: HashMap::new(),
            kvs: HashMap::new(),
            cached_kv: HashMap::new(),
            kv_pool: HashMap::new(),
            finished: vec![],
            emitted: vec![],
            metrics: Metrics::new(),
            next_id: 0,
            seed: 0,
        }
    }

    /// Largest prompt the compiled prefill buckets accept in one call.
    /// Under chunked prefill longer prompts still serve (chunks are
    /// bucket-capped), but a prompt must at least fit the KV budget.
    pub fn max_prompt_len(&self) -> usize {
        self.dep
            .runtime
            .prefill_buckets()
            .into_iter()
            .map(|(_, s)| s)
            .max()
            .unwrap_or(0)
    }

    /// Longest admissible prompt: with chunked prefill the KV length
    /// budget governs; legacy mode also requires one-bucket prefill.
    fn admissible_prompt_len(&self) -> usize {
        let max_len = self.dep.runtime.cfg.max_len.saturating_sub(1);
        if self.ecfg.enable_chunked_prefill {
            max_len
        } else {
            max_len.min(self.max_prompt_len())
        }
    }

    /// Submit a request; returns its id. Prompts longer than the engine
    /// can admit are rejected (finished with `PromptTooLong`);
    /// generation is clamped so prompt + output fits the KV capacity —
    /// and, in legacy (unchunked) mode, so post-preemption recompute of
    /// prompt + output fits the largest compiled prefill bucket (the
    /// belt-and-braces fix for the recompute hazard; chunked mode needs
    /// no clamp because recompute is just another chunked prefill).
    pub fn submit(&mut self, prompt: Vec<u32>, mut params: SamplingParams)
        -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.metrics.on_submit(prompt.len());
        let max_len = self.dep.runtime.cfg.max_len;
        let too_long = prompt.is_empty()
            || prompt.len() > self.admissible_prompt_len();
        params.max_new_tokens = params
            .max_new_tokens
            .min(max_len.saturating_sub(prompt.len()));
        if !self.ecfg.enable_chunked_prefill {
            params.max_new_tokens = params.max_new_tokens.min(
                self.max_prompt_len().saturating_sub(prompt.len()),
            );
        }
        // a prompt whose blocks can never fit the pool would block the
        // FCFS head forever (admission checks full-content capacity):
        // fail fast instead of wedging the queue
        let pool_impossible = !too_long
            && self.sched.bm.blocks_for(prompt.len())
                + self.sched.bm.watermark_blocks
                > self.sched.bm.total_blocks;
        let mut seq = Sequence::new(id, prompt, params);
        seq.arrived_step = self.metrics.engine_steps;
        if too_long || pool_impossible {
            seq.finish(if too_long {
                FinishReason::PromptTooLong
            } else {
                FinishReason::PoolExhausted
            });
            self.metrics.on_finished(&seq);
            self.finished.push(seq);
            return id;
        }
        self.seqs.insert(id, seq);
        self.sched.add(id);
        id
    }

    /// Anything queued or in flight?
    pub fn has_work(&self) -> bool {
        self.sched.has_work()
    }
    /// Fraction of the KV block pool in use.
    pub fn kv_occupancy(&self) -> f64 {
        self.sched.bm.occupancy()
    }
    /// Block-level prefix-cache counters (hits, shared blocks, evictions).
    pub fn cache_stats(&self) -> CacheStats {
        self.sched.bm.stats.clone()
    }
    /// Queue depths `(waiting, running)` — the router's load signal.
    pub fn queue_depths(&self) -> (usize, usize) {
        (self.sched.waiting_len(), self.sched.running_len())
    }
    /// KV block size in tokens (the prefix-cache hash granularity).
    pub fn block_size(&self) -> usize {
        self.sched.bm.block_size
    }
    /// Cached blocks no live sequence references (the population the
    /// sliding eviction window bounds).
    pub fn cached_unreferenced_blocks(&self) -> usize {
        self.sched.bm.cached_unreferenced()
    }
    /// Blocks currently demoted into the tiered KV pool (≤ the
    /// configured `kv_pool_blocks` bound; 0 while tiering is off).
    pub fn kv_pool_len(&self) -> usize {
        self.sched.bm.kv_pool_len()
    }
    /// Start recording prefix-cache [`CacheEvent`]s (router attach).
    pub fn enable_cache_events(&mut self) {
        self.sched.bm.enable_cache_events = true;
    }
    /// Drain recorded prefix-cache events (router directory feed).
    pub fn take_cache_events(&mut self) -> Vec<CacheEvent> {
        self.sched.bm.take_cache_events()
    }
    /// Configure the sliding eviction window on this engine's prefix
    /// cache (see
    /// [`super::block_manager::BlockManager::set_cache_watermarks`]).
    pub fn set_cache_watermarks(&mut self, high: usize, low: usize) {
        self.sched.bm.set_cache_watermarks(high, low);
    }
    /// Drain finished sequences (response path).
    pub fn take_finished(&mut self) -> Vec<Sequence> {
        std::mem::take(&mut self.finished)
    }
    /// Drain tokens sampled since the last drain, as `(local id, token)`
    /// in emission order — the per-step streaming surface. A token
    /// appears here exactly once, in the same step that appended it to
    /// the sequence's `output`.
    pub fn take_emitted(&mut self) -> Vec<(u64, u32)> {
        std::mem::take(&mut self.emitted)
    }

    /// Replica teardown: remove and return every unfinished sequence
    /// (with its partial output, so a router can replay it on another
    /// replica), releasing all scheduler, pool, and prefix-cache state
    /// this engine held for them. The cache is cleared outright — a
    /// torn-down replica serves nobody, so its stashed KV rows are dead
    /// weight. Sorted by id (submission order) for deterministic
    /// replay.
    pub fn drain_inflight(&mut self) -> Vec<Sequence> {
        self.sched.drain();
        let mut out: Vec<Sequence> =
            self.seqs.drain().map(|(_, s)| s).collect();
        self.kvs.clear();
        self.sched.bm.clear_cache();
        self.sched.bm.take_evicted();
        // the tiered pool dies with the replica: drop the index drains
        // and the pooled bytes so a killed replica's demoted blocks can
        // never be restored
        self.sched.bm.take_pool_dropped();
        self.sched.bm.take_restored();
        self.kv_pool.clear();
        self.cached_kv.clear();
        // any tokens still in the stream buffer travel with the drained
        // sequences (their `output` already holds them)
        self.emitted.clear();
        out.sort_by_key(|s| s.id);
        out
    }

    /// Donor side of cross-replica KV migration: serialize the stashed
    /// rows this engine holds for a contiguous prefix of `tokens`, as
    /// `(block hash, wire bytes)` in chain order. Blocks come from the
    /// device-resident stash (`cached_kv`) or the demotion pool — both
    /// already hold the `KvStash` wire precision, so the export ships
    /// quantized bytes without a re-quantization round trip. The walk
    /// stops at the first hash held nowhere (the receiver needs a
    /// contiguous prefix) and is capped one block short of the content
    /// (the final token is always computed, matching the admission
    /// walk). Read-only on the cache: refcounts, LRU order and the
    /// pool index are untouched.
    pub fn export_kv_blocks(&mut self, tokens: &[u32])
        -> Vec<(u64, Vec<u8>)> {
        let bs = self.sched.bm.block_size;
        let cap = tokens.len().saturating_sub(1) / bs;
        let mut out = vec![];
        for h in chain_hashes(tokens, bs).into_iter().take(cap) {
            let stash = match self.sched.bm.lookup_hash(h) {
                Some(block_id) => self.cached_kv.get(&block_id),
                None if self.sched.bm.pool_contains(h) => {
                    self.kv_pool.get(&h)
                }
                None => None,
            };
            match stash {
                Some(s) => {
                    let wire = s.to_wire();
                    self.metrics.kv_migrations_out += 1;
                    self.metrics.migrated_bytes += wire.len();
                    out.push((h, wire));
                }
                None => break,
            }
        }
        out
    }

    /// Receiver side: adopt wire-form KV blocks into the local pool
    /// tier, so the next admission of the matching prefix restores
    /// them (dequantize + copy) instead of recomputing. All blocks are
    /// decoded before any is adopted — a malformed payload rejects the
    /// whole batch and the caller falls back to plain recompute.
    /// Hashes already held (device or pool) are skipped, not errors.
    /// Returns how many blocks were adopted.
    pub fn import_kv_blocks(&mut self, blocks: &[(u64, Vec<u8>)])
        -> Result<usize> {
        let decoded: Vec<(u64, KvStash)> = blocks
            .iter()
            .map(|(h, wire)| Ok((*h, KvStash::from_wire(wire)?)))
            .collect::<Result<_>>()?;
        let mut adopted = 0;
        for (h, stash) in decoded {
            if self.sched.bm.adopt_pooled(h) {
                let bytes = stash.bytes();
                self.kv_pool.insert(h, stash);
                self.metrics.kv_migrations_in += 1;
                self.metrics.migrated_bytes += bytes;
                adopted += 1;
            }
        }
        // adoption may overflow-drop older pooled hashes; reconcile the
        // byte map with the index before any admission walks it
        for h in self.sched.bm.take_pool_dropped() {
            self.kv_pool.remove(&h);
        }
        Ok(adopted)
    }

    /// Pool size for `--kv-pool auto`: the tiered pool lives in the 8%
    /// device-memory headroom that [`Engine::with_memory_budget`]
    /// leaves above the 92% it hands to device blocks — the same
    /// `GpuProfile` memory math, so the two tiers are sized from one
    /// budget instead of an unanchored count.
    pub fn auto_kv_pool_blocks(dep: &Deployment, block_size: usize)
        -> usize {
        let headroom = dep.gpu.mem_bytes * dep.workers * 8 / 100;
        let per_block =
            block_size * dep.runtime.cfg.kv_bytes_per_token();
        (headroom / per_block.max(1)).max(1)
    }

    /// Execute one scheduler step.
    pub fn step(&mut self) -> Result<StepOutcome> {
        let plan: StepPlan = self.sched.plan(&self.seqs);
        self.drain_cache_tiering();
        // drop KV of anything the scheduler preempted (it will recompute
        // on re-admission — possibly within this very plan)
        for id in self.sched.preempted.clone() {
            self.kvs.remove(&id);
            if let Some(s) = self.seqs.get_mut(&id) {
                if matches!(s.state,
                            SeqState::Running | SeqState::Prefilling) {
                    s.preempt();
                }
            }
        }
        // sequences that alone outgrow the pool cannot ever complete
        for id in self.sched.dropped.clone() {
            self.kvs.remove(&id);
            if self.seqs.contains_key(&id) {
                self.finish(id, FinishReason::PoolExhausted);
            }
        }
        if plan.is_idle() {
            return Ok(StepOutcome::Idle);
        }
        self.metrics.engine_steps += 1;
        let mut chunk_tokens = 0;
        let mut completed = 0;
        if !plan.chunks.is_empty() {
            (chunk_tokens, completed) = self.run_chunks(&plan.chunks)?;
            self.metrics.prefill_steps += 1;
        }
        let mut decoded = 0;
        if !plan.decode.is_empty() {
            decoded = self.do_decode(&plan.decode)?;
            if decoded > 0 {
                self.metrics.decode_steps += 1;
            }
        }
        if !plan.chunks.is_empty() && decoded > 0 {
            self.metrics.mixed_steps += 1;
        }
        self.metrics
            .batch_sizes
            .push((plan.chunks.len() + decoded) as f64);
        self.metrics.kv_occupancy.push(self.sched.bm.occupancy());
        Ok(StepOutcome::Ran { chunk_tokens,
                              completed_prefills: completed, decoded })
    }

    /// Reconcile stashed KV bytes with the block manager's tiering
    /// decisions, in decision order: evicted blocks demote their stash
    /// into the pool (or drop it, tiering off), pool drops (overflow,
    /// supersession, teardown) free pooled bytes, and restored blocks
    /// move pooled bytes back under their fresh device block id. Runs
    /// right after `sched.plan`, before any chunk reads rows, so a
    /// demotion from an earlier step that this plan's admission
    /// restores is resolved bytes-first. (The reverse order —
    /// restore-then-evict of one block inside a single batch — cannot
    /// arise: a restored block is refcounted by its admitting sequence,
    /// and the scheduler preempts only before it admits.)
    fn drain_cache_tiering(&mut self) {
        let tiering = self.ecfg.kv_pool_blocks > 0;
        for (b, h) in self.sched.bm.take_evicted() {
            match self.cached_kv.remove(&b) {
                Some(stash) if tiering => {
                    self.kv_pool.insert(h, stash);
                    self.metrics.kv_demotions += 1;
                }
                _ => {}
            }
        }
        for h in self.sched.bm.take_pool_dropped() {
            self.kv_pool.remove(&h);
        }
        for (b, h) in self.sched.bm.take_restored() {
            if let Some(stash) = self.kv_pool.remove(&h) {
                self.cached_kv.insert(b, stash);
                self.metrics.kv_restores += 1;
                self.metrics.recompute_avoided_tokens +=
                    self.sched.bm.block_size;
            }
        }
    }

    /// Execute a step's prefill chunks. Cold chunks (`start == 0`) batch
    /// through one prefill-bucket call; warm/continuation chunks run
    /// through the compiled chunk executable — grouped positionwise by
    /// matching bucket pair, one device call per group — with the
    /// token-by-token decode fallback when no chunk bucket fits.
    /// Returns (tokens computed, prefills completed).
    fn run_chunks(&mut self, chunks: &[PrefillChunk])
        -> Result<(usize, usize)> {
        let cfg = self.dep.runtime.cfg.clone();
        let vocab = cfg.vocab;
        // full content per chunk (recompute semantics: prompt + output)
        let full: Vec<Vec<u32>> = chunks
            .iter()
            // sqlint: allow(panic) plan chunk ids are live `seqs` keys (scheduler plans from this map)
            .map(|c| self.seqs[&c.id].full_tokens())
            .collect();

        // (re)admissions: state bookkeeping; warm admissions get a
        // fresh KV pre-loaded with their cached-prefix rows (cold
        // admissions build theirs in the batched prefill below)
        for c in chunks.iter().filter(|c| c.admitted) {
            if c.start > 0 {
                let kvseq = self.kv_from_cached_prefix(c.id, c.start);
                self.kvs.insert(c.id, kvseq);
            }
            // sqlint: allow(panic) plan chunk ids are live `seqs` keys
            let seq = self.seqs.get_mut(&c.id).unwrap();
            seq.state = SeqState::Prefilling;
            seq.prefill_progress = c.start;
            seq.cached_prefix_len = c.start;
            self.metrics.cached_prefix_tokens += c.start;
        }

        let mut completed = 0usize;
        let mut tokens = 0usize;

        // ---- cold chunks: one batched prefill through a bucket sized
        // for the widest chunk (the runtime picks the smallest fit)
        let cold: Vec<usize> = (0..chunks.len())
            .filter(|&i| chunks[i].start == 0)
            .collect();
        if !cold.is_empty() {
            let views: Vec<&[u32]> = cold
                .iter()
                .map(|&i| &full[i][..chunks[i].end])
                .collect();
            let res = self.dep.prefill(&views)?;
            self.metrics.device_calls += 1;
            let lens: Vec<usize> =
                cold.iter().map(|&i| chunks[i].end).collect();
            let mut new_kvs: Vec<SeqKv> =
                cold.iter().map(|_| SeqKv::new(&cfg)).collect();
            {
                let mut refs: Vec<&mut SeqKv> =
                    new_kvs.iter_mut().collect();
                kv::fill_prefill_rows(&mut refs, &cfg, res.batch, res.seq,
                                      &res.kv_new, &lens);
            }
            for ((b, &i), kvseq) in
                cold.iter().enumerate().zip(new_kvs)
            {
                let c = &chunks[i];
                debug_assert!(c.admitted); // cold chunks always are
                self.kvs.insert(c.id, kvseq);
                let last = c.end - 1;
                let row =
                    &res.logits[(b * res.seq + last) * vocab..][..vocab];
                completed += self.finish_chunk(c, &full[i], Some(row));
                tokens += c.end - c.start;
            }
        }

        // ---- warm/continuation chunks: compiled chunk executable
        // where a bucket fits (grouped positionwise by bucket pair),
        // decode-executable per token otherwise
        let warm: Vec<usize> =
            (0..chunks.len()).filter(|&i| chunks[i].start > 0).collect();
        let mut fallback: Vec<usize> = vec![];
        if self.ecfg.enable_compiled_chunks {
            // group chunks whose smallest-fitting (chunk_len, prefix)
            // bucket pair matches: their KV prefixes pad to the same
            // shape, so they share one call with per-slot starts
            let mut groups: Vec<((usize, usize), Vec<usize>)> = vec![];
            for &i in &warm {
                let c = &chunks[i];
                match self.dep.runtime.pick_chunk_bucket(
                    1, c.end - c.start, c.start,
                ) {
                    Some((_, cl, pl)) => {
                        match groups.iter_mut().find(|(k, _)| *k == (cl, pl))
                        {
                            Some((_, v)) => v.push(i),
                            None => groups.push(((cl, pl), vec![i])),
                        }
                    }
                    None => fallback.push(i),
                }
            }
            for ((cl, pl), idxs) in groups {
                // split a group wider than the biggest batch bucket
                let cap = self.dep.runtime.max_chunk_batch(cl, pl).max(1);
                for sub in idxs.chunks(cap) {
                    let (t, c) = self.run_chunk_group(sub, chunks, &full)?;
                    tokens += t;
                    completed += c;
                }
            }
        } else {
            fallback = warm;
        }
        for &i in &fallback {
            let (t, c) = self.run_chunk_fallback(&chunks[i], &full[i])?;
            tokens += t;
            completed += c;
        }

        self.metrics.prefill_chunks += chunks.len();
        self.metrics.prefill_tokens_executed += tokens;
        Ok((tokens, completed))
    }

    /// Execute a group of continuation chunks (same compiled bucket
    /// pair) in **one device call**: assemble their KV prefixes into
    /// the bucket's `[L, 2, B, P, D]` input, run the chunk executable
    /// with per-slot start positions, scatter the new rows back.
    fn run_chunk_group(&mut self, idxs: &[usize], chunks: &[PrefillChunk],
                       full: &[Vec<u32>]) -> Result<(usize, usize)> {
        let cfg = self.dep.runtime.cfg.clone();
        let vocab = cfg.vocab;
        let mut kvseqs: Vec<SeqKv> = idxs
            .iter()
            // sqlint: allow(panic) warm chunks registered their KV at admission
            .map(|&i| self.kvs.remove(&chunks[i].id).expect("chunk KV"))
            .collect();
        let starts: Vec<usize> =
            idxs.iter().map(|&i| chunks[i].start).collect();
        let widths: Vec<usize> = idxs
            .iter()
            .map(|&i| chunks[i].end - chunks[i].start)
            .collect();
        for (s, &st) in kvseqs.iter().zip(&starts) {
            debug_assert_eq!(s.len, st);
        }
        let (ab, _, ap) = self
            .dep
            .runtime
            .pick_chunk_bucket(
                idxs.len(),
                // sqlint: allow(panic) group is non-empty (formed from at least one chunk)
                widths.iter().copied().max().unwrap(),
                // sqlint: allow(panic) group is non-empty (formed from at least one chunk)
                starts.iter().copied().max().unwrap(),
            )
            // sqlint: allow(panic) grouping used this same bucket lookup; a fit exists
            .expect("caller grouped by a fitting bucket");
        let kv_batch = {
            let refs: Vec<&SeqKv> = kvseqs.iter().collect();
            kv::assemble_prefix_batch(&refs, &cfg, ab, ap)
        };
        let views: Vec<&[u32]> = idxs
            .iter()
            .map(|&i| &full[i][chunks[i].start..chunks[i].end])
            .collect();
        let res = self.dep.chunk(&views, &starts, &kv_batch)?;
        self.metrics.device_calls += 1;
        {
            let mut refs: Vec<&mut SeqKv> = kvseqs.iter_mut().collect();
            kv::append_chunk_rows(&mut refs, &cfg, res.batch, res.seq,
                                  &res.kv_new, &widths);
        }
        let mut completed = 0usize;
        let mut tokens = 0usize;
        for ((b, &i), kvseq) in idxs.iter().enumerate().zip(kvseqs) {
            let c = &chunks[i];
            self.kvs.insert(c.id, kvseq);
            let last = c.end - c.start - 1;
            let row =
                &res.logits[(b * res.seq + last) * vocab..][..vocab];
            let row = if c.end == full[i].len() { Some(row) } else { None };
            completed += self.finish_chunk(c, &full[i], row);
            tokens += c.end - c.start;
        }
        Ok((tokens, completed))
    }

    /// Per-token fallback for one continuation chunk: drive the decode
    /// executable over `[start, end)` — the pre-chunk-executable path,
    /// kept for stub builds, pre-chunk artifact sets, shapes no chunk
    /// bucket covers, and the `enable_compiled_chunks = false`
    /// ablation. Bit-identical token streams, T device calls.
    fn run_chunk_fallback(&mut self, c: &PrefillChunk, toks: &[u32])
        -> Result<(usize, usize)> {
        let cfg = self.dep.runtime.cfg.clone();
        let vocab = cfg.vocab;
        let bucket = self.dep.runtime.smallest_decode_batch(1);
        let lane_sz = cfg.max_len * cfg.dim;
        // sqlint: allow(panic) warm chunks registered their KV at admission
        let mut kvseq = self.kvs.remove(&c.id).expect("chunk KV");
        debug_assert_eq!(kvseq.len, c.start);
        // assemble the padded device batch once; per-token we only
        // scatter the one new row into slot b=0 (mirrors the
        // assemble_batch layout) instead of re-copying MAX rows
        let mut kv_batch = kv::assemble_batch(&[&kvseq], &cfg, bucket);
        let mut last_res: Option<DecodeResult> = None;
        for pos in c.start..c.end {
            let res =
                self.dep.decode(&[toks[pos]], &[kvseq.len], &kv_batch)?;
            self.metrics.device_calls += 1;
            let row_pos = kvseq.len;
            {
                let mut refs = [&mut kvseq];
                kv::append_decode_rows(&mut refs, &cfg, res.batch,
                                       &res.kv_new);
            }
            for layer in 0..cfg.layers {
                for lane in 0..2 {
                    // kv_new is [L, 2, B, 1, D], our row is b = 0
                    let src = ((layer * 2) + lane) * res.batch * cfg.dim;
                    let dst = (((layer * 2) + lane) * bucket) * lane_sz
                        + row_pos * cfg.dim;
                    kv_batch[dst..dst + cfg.dim].copy_from_slice(
                        &res.kv_new[src..src + cfg.dim],
                    );
                }
            }
            last_res = Some(res);
        }
        self.kvs.insert(c.id, kvseq);
        // borrow the final logits row out of the last decode result,
        // like the cold path does — no copy
        // sqlint: allow(panic) chunk ranges satisfy start < end by construction
        let last_res = last_res.expect("chunk ranges are non-empty");
        let row = if c.end == toks.len() {
            Some(&last_res.logits[..vocab])
        } else {
            None
        };
        let completed = self.finish_chunk(c, toks, row);
        Ok((c.end - c.start, completed))
    }

    /// Per-chunk bookkeeping: advance the cursor, register newly filled
    /// full blocks, and — when the chunk completes the prefill — sample
    /// the sequence's next token from `row`. Returns 1 on completion.
    fn finish_chunk(&mut self, c: &PrefillChunk, toks: &[u32],
                    row: Option<&[f32]>) -> usize {
        // sqlint: allow(panic) plan chunk ids are live `seqs` keys
        self.seqs.get_mut(&c.id).unwrap().prefill_progress = c.end;
        self.register_filled_blocks(c.id, &toks[..c.end]);
        if c.end == toks.len() {
            // sqlint: allow(panic) every completing chunk is handed its logits row
            let row = row.expect("completing chunk carries logits");
            self.sample_first_token(c.id, row);
            return 1;
        }
        0
    }

    /// A fresh SeqKv pre-loaded with the stashed rows of the sequence's
    /// `cached_tokens`-long shared prefix (whole blocks by construction).
    fn kv_from_cached_prefix(&self, id: u64, cached_tokens: usize)
        -> SeqKv {
        let cfg = &self.dep.runtime.cfg;
        let bs = self.sched.bm.block_size;
        debug_assert_eq!(cached_tokens % bs, 0);
        let table =
            // sqlint: allow(panic) admitted sequences hold a block table
            self.sched.bm.table(id).expect("admitted seq has a table");
        let mut kvseq = SeqKv::new(cfg);
        for blk in 0..cached_tokens / bs {
            // sqlint: allow(panic) admission stashed every cached-prefix block in cached_kv
            match &self.cached_kv[&table[blk]] {
                // exact rows borrow straight into the copy (the
                // bit-identity path costs no extra allocation)
                KvStash::F32(rows) => unstash_block(
                    &mut kvseq, blk, bs, cfg.layers, cfg.dim, rows,
                ),
                KvStash::Quant(q) => {
                    let rows = q.dequantize_rows();
                    unstash_block(
                        &mut kvseq, blk, bs, cfg.layers, cfg.dim, &rows,
                    );
                }
            }
        }
        kvseq.len = cached_tokens;
        kvseq
    }

    /// Register this sequence's full blocks among `tokens` into the
    /// prefix cache and stash their freshly built KV rows (called after
    /// every chunk and after block-filling decode steps, while the rows
    /// exist and the sequence still owns its table). Returns how many
    /// blocks were newly registered.
    fn register_filled_blocks(&mut self, id: u64, tokens: &[u32])
        -> usize {
        let newly = self.sched.bm.register_prefix(id, tokens);
        if newly.is_empty() {
            return 0;
        }
        let bs = self.sched.bm.block_size;
        let (layers, dim) =
            (self.dep.runtime.cfg.layers, self.dep.runtime.cfg.dim);
        // sqlint: allow(panic) called while the sequence owns its KV (register invariant)
        let kvseq = &self.kvs[&id];
        let n = newly.len();
        for (blk, block_id) in newly {
            let rows = stash_block(kvseq, blk, bs, layers, dim);
            let stash = KvStash::encode(rows, dim,
                                        self.ecfg.kv_cache_mode);
            self.cached_kv.insert(block_id, stash);
        }
        n
    }

    /// Post-prefill bookkeeping: mark running, sample the next token
    /// (the first of this pass), record the TTFT-in-steps proxy.
    fn sample_first_token(&mut self, id: u64, row: &[f32]) {
        let first = {
            // sqlint: allow(panic) sampling runs on ids from this step's own plan
            let seq = self.seqs.get_mut(&id).unwrap();
            seq.state = SeqState::Running;
            seq.output.is_empty()
        };
        if first {
            let waited = self.metrics.engine_steps
                // sqlint: allow(panic) sampling runs on ids from this step's own plan
                - self.seqs[&id].arrived_step;
            self.metrics.ttft_steps.push(waited as f64);
        }
        // sqlint: allow(panic) sampling runs on ids from this step's own plan
        let seq = self.seqs.get_mut(&id).unwrap();
        let mut rng = Rng::new(
            self.seed
                ^ seq.params.seed.wrapping_mul(0x9e3779b97f4a7c15)
                ^ (seq.id << 32)
                ^ seq.output.len() as u64,
        );
        let tok = sampler::sample(row, &seq.params, &mut rng);
        seq.record_token(tok);
        self.emitted.push((id, tok));
        self.finish_if_done(id);
    }

    fn do_decode(&mut self, ids: &[u64]) -> Result<usize> {
        let cfg = self.dep.runtime.cfg.clone();
        let vocab = cfg.vocab;
        let bs = self.sched.bm.block_size;
        // KV-capacity guard: finish sequences whose cache is full
        let mut live = vec![];
        for &id in ids {
            // sqlint: allow(panic) decode ids come from the plan; seqs/kvs stay in sync
            let len = self.kvs[&id].len;
            if len + 1 >= cfg.max_len {
                self.finish(id, FinishReason::MaxTokens);
            } else {
                live.push(id);
            }
        }
        if live.is_empty() {
            return Ok(0);
        }
        let tokens: Vec<u32> =
            live.iter().map(|id| self.seqs[id].last_token()).collect();
        let lens: Vec<usize> = live.iter().map(|id| self.kvs[id].len)
            .collect();
        let kv_refs: Vec<&SeqKv> = live.iter().map(|id| &self.kvs[id])
            .collect();
        let bucket = self.dep.runtime.smallest_decode_batch(live.len());
        let kv_batch = kv::assemble_batch(&kv_refs, &cfg, bucket);
        let res = self.dep.decode(&tokens, &lens, &kv_batch)?;
        self.metrics.device_calls += 1;
        // append new KV rows
        {
            let mut refs: Vec<&mut SeqKv> = Vec::with_capacity(live.len());
            // split_mut over hashmap: collect ids then fetch disjoint
            let ptrs: Vec<*mut SeqKv> = live
                .iter()
                // sqlint: allow(panic) decode ids come from the plan; seqs/kvs stay in sync
                .map(|id| self.kvs.get_mut(id).unwrap() as *mut SeqKv)
                .collect();
            // SAFETY: ids are distinct keys, so the pointers are disjoint.
            for p in ptrs {
                refs.push(unsafe { &mut *p });
            }
            kv::append_decode_rows(&mut refs, &cfg, res.batch, &res.kv_new);
        }
        // decode-time cache registration: a decode that just filled a
        // block makes it cacheable (generated content seeds the cache)
        for &id in &live {
            // sqlint: allow(panic) decode ids come from the plan; seqs/kvs stay in sync
            let n = self.kvs[&id].len;
            if n % bs == 0 {
                // sqlint: allow(panic) decode ids come from the plan; seqs/kvs stay in sync
                let toks = self.seqs[&id].full_tokens();
                self.metrics.decode_registered_blocks +=
                    self.register_filled_blocks(id, &toks[..n]);
            }
        }
        for (b, id) in live.iter().enumerate() {
            let row = &res.logits[b * vocab..(b + 1) * vocab];
            // sqlint: allow(panic) decode ids come from the plan; seqs/kvs stay in sync
            let seq = self.seqs.get_mut(id).unwrap();
            let mut rng = Rng::new(
                self.seed
                    ^ seq.params.seed.wrapping_mul(0x9e3779b97f4a7c15)
                    ^ (seq.id << 32)
                    ^ seq.output.len() as u64,
            );
            let tok = sampler::sample(row, &seq.params, &mut rng);
            seq.record_token(tok);
            self.emitted.push((*id, tok));
            self.finish_if_done(*id);
        }
        Ok(live.len())
    }

    fn finish_if_done(&mut self, id: u64) {
        // sqlint: allow(panic) finish checks run on ids from this step's own plan
        if let Some(reason) = self.seqs[&id].should_finish() {
            self.finish(id, reason);
        }
    }

    fn finish(&mut self, id: u64, reason: FinishReason) {
        // sqlint: allow(panic) finish() is only called with ids drawn from `seqs`
        let mut seq = self.seqs.remove(&id).unwrap();
        seq.finish(reason);
        self.sched.on_finished(id);
        self.kvs.remove(&id);
        self.metrics.on_finished(&seq);
        self.finished.push(seq);
    }

    /// Drive until every submitted request finishes (or `max_steps`).
    pub fn run_to_completion(&mut self, max_steps: usize) -> Result<usize> {
        let mut steps = 0;
        while self.has_work() && steps < max_steps {
            self.step()?;
            steps += 1;
        }
        Ok(steps)
    }
}
