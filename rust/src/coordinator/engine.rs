//! The engine step loop: scheduler → PJRT runtime → sampler → state.
//!
//! One [`Engine::step`] executes one scheduler plan: either a prefill
//! batch (admitting waiting sequences, building their KV, sampling their
//! first token) or one decode step over the running batch. Preempted
//! sequences drop their KV and recompute on re-admission (prompt +
//! generated-so-far re-prefilled), vLLM's recompute policy.

use std::collections::HashMap;

use anyhow::Result;

use crate::config::EngineConfig;
use crate::runtime::kv::{self, SeqKv};
use crate::runtime::simtp::Deployment;
use crate::util::rng::Rng;

use super::block_manager::BlockManager;
use super::metrics::Metrics;
use super::sampler;
use super::scheduler::{Scheduler, StepPlan};
use super::sequence::{FinishReason, SamplingParams, SeqState, Sequence};

/// What a step did (for tests/telemetry).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StepOutcome {
    Prefilled(usize),
    Decoded(usize),
    Idle,
}

pub struct Engine {
    pub dep: Deployment,
    pub ecfg: EngineConfig,
    sched: Scheduler,
    seqs: HashMap<u64, Sequence>,
    kvs: HashMap<u64, SeqKv>,
    finished: Vec<Sequence>,
    pub metrics: Metrics,
    next_id: u64,
    /// Engine-level seed mixed into per-token sampling streams.
    pub seed: u64,
}

impl Engine {
    /// Engine with an explicit block pool (tests, ablations).
    pub fn new(dep: Deployment, mut ecfg: EngineConfig) -> Engine {
        let max_decode =
            dep.runtime.decode_batches().into_iter().max().unwrap_or(1);
        ecfg.max_running = ecfg.max_running.min(max_decode);
        let bm = BlockManager::new(ecfg.block_size, ecfg.total_blocks);
        Engine {
            sched: Scheduler::new(ecfg.clone(), bm),
            dep,
            ecfg,
            seqs: HashMap::new(),
            kvs: HashMap::new(),
            finished: vec![],
            metrics: Metrics::new(),
            next_id: 0,
            seed: 0,
        }
    }

    /// Engine whose KV pool is sized from the deployment's simulated GPU
    /// memory minus the model's weight bytes (the paper's Fig. 7 setup:
    /// W4A16 frees weight memory, so the pool and batches grow).
    pub fn with_memory_budget(dep: Deployment, mut ecfg: EngineConfig)
        -> Engine {
        let cfg = &dep.runtime.cfg;
        let precision = dep.runtime.precision;
        let weight_bytes = cfg.weight_bytes(precision);
        let mem = dep.gpu.mem_bytes * dep.workers;
        let bm = BlockManager::from_memory(
            ecfg.block_size, mem * 92 / 100, weight_bytes,
            cfg.kv_bytes_per_token(),
        );
        let max_decode =
            dep.runtime.decode_batches().into_iter().max().unwrap_or(1);
        ecfg.max_running = ecfg.max_running.min(max_decode);
        Engine {
            sched: Scheduler::new(ecfg.clone(), bm),
            dep,
            ecfg,
            seqs: HashMap::new(),
            kvs: HashMap::new(),
            finished: vec![],
            metrics: Metrics::new(),
            next_id: 0,
            seed: 0,
        }
    }

    /// Largest prompt the compiled prefill buckets accept.
    pub fn max_prompt_len(&self) -> usize {
        self.dep
            .runtime
            .prefill_buckets()
            .into_iter()
            .map(|(_, s)| s)
            .max()
            .unwrap_or(0)
    }

    /// Submit a request; returns its id. Prompts longer than the prefill
    /// bucket are rejected (finished with `PromptTooLong`); generation is
    /// clamped so prompt + output fits the KV capacity.
    pub fn submit(&mut self, prompt: Vec<u32>, mut params: SamplingParams)
        -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.metrics.on_submit(prompt.len());
        let max_len = self.dep.runtime.cfg.max_len;
        let too_long =
            prompt.is_empty() || prompt.len() > self.max_prompt_len()
                || prompt.len() + 1 > max_len;
        params.max_new_tokens = params
            .max_new_tokens
            .min(max_len.saturating_sub(prompt.len()));
        let mut seq = Sequence::new(id, prompt, params);
        if too_long {
            seq.finish(FinishReason::PromptTooLong);
            self.metrics.on_finished(&seq);
            self.finished.push(seq);
            return id;
        }
        self.seqs.insert(id, seq);
        self.sched.add(id);
        id
    }

    pub fn has_work(&self) -> bool {
        self.sched.has_work()
    }
    pub fn kv_occupancy(&self) -> f64 {
        self.sched.bm.occupancy()
    }
    pub fn take_finished(&mut self) -> Vec<Sequence> {
        std::mem::take(&mut self.finished)
    }

    /// Execute one scheduler step.
    pub fn step(&mut self) -> Result<StepOutcome> {
        let plan = self.sched.plan(&self.seqs);
        // drop KV of anything the scheduler preempted
        for id in self.sched.preempted.clone() {
            self.kvs.remove(&id);
            if let Some(s) = self.seqs.get_mut(&id) {
                if s.state == SeqState::Running {
                    s.preempt();
                }
            }
        }
        match plan {
            StepPlan::Idle => Ok(StepOutcome::Idle),
            StepPlan::Prefill { ids } => self.do_prefill(ids),
            StepPlan::Decode { ids } => self.do_decode(ids),
        }
    }

    fn do_prefill(&mut self, ids: Vec<u64>) -> Result<StepOutcome> {
        // recompute semantics: preempted sequences re-prefill prompt +
        // generated output
        let prompts: Vec<Vec<u32>> = ids
            .iter()
            .map(|id| {
                let s = &self.seqs[id];
                let mut p = s.prompt.clone();
                p.extend(&s.output);
                p
            })
            .collect();
        let views: Vec<&[u32]> = prompts.iter().map(|p| &p[..]).collect();
        let res = self.dep.prefill(&views)?;
        let cfg = self.dep.runtime.cfg.clone();
        let vocab = cfg.vocab;
        // build KV for each admitted sequence
        let lens: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
        let mut new_kvs: Vec<SeqKv> =
            ids.iter().map(|_| SeqKv::new(&cfg)).collect();
        {
            let mut refs: Vec<&mut SeqKv> = new_kvs.iter_mut().collect();
            kv::fill_prefill_rows(&mut refs, &cfg, res.batch, res.seq,
                                  &res.kv_new, &lens);
        }
        for ((b, id), kvseq) in ids.iter().enumerate().zip(new_kvs) {
            self.kvs.insert(*id, kvseq);
            let last = lens[b] - 1;
            let row =
                &res.logits[(b * res.seq + last) * vocab..][..vocab];
            let seq = self.seqs.get_mut(id).unwrap();
            seq.state = SeqState::Running;
            let mut rng = Rng::new(
                self.seed
                    ^ seq.params.seed.wrapping_mul(0x9e3779b97f4a7c15)
                    ^ (seq.id << 32)
                    ^ seq.output.len() as u64,
            );
            let tok = sampler::sample(row, &seq.params, &mut rng);
            seq.record_token(tok);
            self.finish_if_done(*id);
        }
        self.metrics.prefill_steps += 1;
        self.metrics.batch_sizes.push(ids.len() as f64);
        self.metrics.kv_occupancy.push(self.sched.bm.occupancy());
        Ok(StepOutcome::Prefilled(ids.len()))
    }

    fn do_decode(&mut self, ids: Vec<u64>) -> Result<StepOutcome> {
        let cfg = self.dep.runtime.cfg.clone();
        let vocab = cfg.vocab;
        // KV-capacity guard: finish sequences whose cache is full
        let mut live = vec![];
        for id in ids {
            let len = self.kvs[&id].len;
            if len + 1 >= cfg.max_len {
                self.finish(id, FinishReason::MaxTokens);
            } else {
                live.push(id);
            }
        }
        if live.is_empty() {
            return Ok(StepOutcome::Idle);
        }
        let tokens: Vec<u32> =
            live.iter().map(|id| self.seqs[id].last_token()).collect();
        let lens: Vec<usize> = live.iter().map(|id| self.kvs[id].len)
            .collect();
        let kv_refs: Vec<&SeqKv> = live.iter().map(|id| &self.kvs[id])
            .collect();
        let bucket = self
            .dep
            .runtime
            .decode_batches()
            .into_iter()
            .find(|&b| b >= live.len())
            .unwrap_or(live.len());
        let kv_batch = kv::assemble_batch(&kv_refs, &cfg, bucket);
        let res = self.dep.decode(&tokens, &lens, &kv_batch)?;
        // append new KV rows
        {
            let mut refs: Vec<&mut SeqKv> = Vec::with_capacity(live.len());
            // split_mut over hashmap: collect ids then fetch disjoint
            let ptrs: Vec<*mut SeqKv> = live
                .iter()
                .map(|id| self.kvs.get_mut(id).unwrap() as *mut SeqKv)
                .collect();
            // SAFETY: ids are distinct keys, so the pointers are disjoint.
            for p in ptrs {
                refs.push(unsafe { &mut *p });
            }
            kv::append_decode_rows(&mut refs, &cfg, res.batch, &res.kv_new);
        }
        for (b, id) in live.iter().enumerate() {
            let row = &res.logits[b * vocab..(b + 1) * vocab];
            let seq = self.seqs.get_mut(id).unwrap();
            let mut rng = Rng::new(
                self.seed
                    ^ seq.params.seed.wrapping_mul(0x9e3779b97f4a7c15)
                    ^ (seq.id << 32)
                    ^ seq.output.len() as u64,
            );
            let tok = sampler::sample(row, &seq.params, &mut rng);
            seq.record_token(tok);
            self.finish_if_done(*id);
        }
        self.metrics.decode_steps += 1;
        self.metrics.batch_sizes.push(live.len() as f64);
        self.metrics.kv_occupancy.push(self.sched.bm.occupancy());
        Ok(StepOutcome::Decoded(live.len()))
    }

    fn finish_if_done(&mut self, id: u64) {
        if let Some(reason) = self.seqs[&id].should_finish() {
            self.finish(id, reason);
        }
    }

    fn finish(&mut self, id: u64, reason: FinishReason) {
        let mut seq = self.seqs.remove(&id).unwrap();
        seq.finish(reason);
        self.sched.on_finished(id);
        self.kvs.remove(&id);
        self.metrics.on_finished(&seq);
        self.finished.push(seq);
    }

    /// Drive until every submitted request finishes (or `max_steps`).
    pub fn run_to_completion(&mut self, max_steps: usize) -> Result<usize> {
        let mut steps = 0;
        while self.has_work() && steps < max_steps {
            self.step()?;
            steps += 1;
        }
        Ok(steps)
    }
}
