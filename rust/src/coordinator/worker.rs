//! Threaded serving loop: one worker thread per replica, a lock-free
//! channel seam, and the [`AsyncRouter`] front end.
//!
//! The synchronous [`Router`](super::router::Router) steps all
//! replicas from one thread — replica K's step waits for replica
//! K−1's. This module removes that serialization: each replica core
//! moves onto its own **worker thread** that steps continuously
//! whenever it has work, and the front end only exchanges messages
//! with it:
//!
//! ```text
//!              WorkerCmd (submit / shutdown)
//!   AsyncRouter ────────────────────────────▶ worker 0 ─ core 0
//!       │        ◀──────────────────────────  worker 1 ─ core 1
//!       │          (replica, WorkerEvent)      ...
//!       ▼
//!   RouterEvent (Token / Finished) → serving loop → clients
//! ```
//!
//! There is **no shared mutable state on the hot path**: the front end
//! owns the routing state (cache directory, health mirror, per-request
//! records), each worker owns its core outright, and everything
//! crossing the seam is a moved message over an `mpsc` channel. A
//! stalled consumer of [`AsyncRouter::poll`] therefore never blocks a
//! replica step, and one replica's death never stops another
//! mid-step.
//!
//! # Division of labor
//!
//! The *worker* handles what needs the core: local↔global id
//! translation, transient-step retry with exponential backoff
//! (sleeping its own thread, nobody else's), and death — on a
//! permanent failure (or retries exhausted) it salvages finished
//! sequences, drains its in-flight load, bounces still-queued
//! submissions, and reports [`WorkerEvent::Dead`] with everything the
//! front end needs to replay.
//!
//! The *front end* handles placement and global state: the shared
//! cache directory (fed by cache events riding each
//! [`WorkerEvent::Stepped`]), admission control, the health mirror
//! reported by stats, and **replay**: it retains each request's
//! prompt, budget, and streamed tokens, so when a worker dies — even
//! by raw panic, without a `Dead` event — every in-flight request is
//! re-placed on a survivor with the emitted tokens folded into the
//! replay prompt. Clients observe one continuous token stream with
//! contiguous indices across the death.
//!
//! Placement load is the front end's own outstanding count per worker
//! (placed − finished), the message-passing analogue of
//! `waiting + running`; admission control
//! ([`RouterConfig::max_waiting`] / `max_replica_queue`) runs against
//! it deterministically at submit time.

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::Duration;

use crate::config::{RouterConfig, RoutingPolicy};

use super::block_manager::CacheEvent;
use super::replica::{
    CoreStats, ReplicaCore, ReplicaError, ReplicaHealth, ReplicaStats,
};
use super::router::{
    pick_replica, CacheDirectory, HitTokens, PickState, RoutedFinish,
    RouterStats,
};
use super::sequence::{FinishReason, SamplingParams, Sequence};

/// Longest single backoff sleep a worker takes between transient-step
/// retries. Bounds how long a brown-out can stall one replica's drain
/// (and keeps the fault-injection tests fast).
const MAX_BACKOFF_MS: u64 = 50;

/// Front end → worker.
enum WorkerCmd {
    /// Place request `gid` on this worker's core. A non-empty
    /// `preload` carries migrated KV blocks (wire form) to import into
    /// the core's pool tier first, so admission restores them instead
    /// of recomputing; an import failure silently degrades to a cold
    /// submit — the request must serve either way.
    Submit {
        gid: u64,
        prompt: Vec<u32>,
        params: SamplingParams,
        preload: Vec<(u64, Vec<u8>)>,
    },
    /// Donor side of a KV migration: export the stashed blocks this
    /// core holds for a prefix of `tokens`, answered by
    /// [`WorkerEvent::Exported`] for request `gid`.
    Export { gid: u64, tokens: Vec<u32> },
    /// Drain everything in flight, then stop.
    Shutdown,
}

/// Worker → front end (always paired with the worker's replica index).
enum WorkerEvent {
    /// `submit` failed on the core; the request was never admitted
    /// here and must be re-placed.
    Rejected { gid: u64, transient: bool },
    /// One step's worth of results (also sent for submit-time
    /// finishes, which need no step). `err` carries a transient step
    /// failure being retried worker-side — a health signal only.
    Stepped {
        tokens: Vec<(u64, u32)>,
        finished: Vec<(u64, Sequence)>,
        cache: Vec<CacheEvent>,
        stats: CoreStats,
        err: Option<String>,
    },
    /// Answer to [`WorkerCmd::Export`]: the donor's stashed blocks for
    /// request `gid`'s prefix, in chain order. `failed` marks a
    /// transient export error (the front end falls back to plain
    /// recompute); a *permanent* export error never sends this — the
    /// worker dies and the `Dead` event resolves the handshake.
    Exported {
        gid: u64,
        blocks: Vec<(u64, Vec<u8>)>,
        failed: bool,
    },
    /// The core failed permanently (or exhausted retries): these
    /// in-flight sequences need replay; the worker thread is gone.
    Dead {
        error: String,
        inflight: Vec<(u64, Sequence)>,
    },
    /// Clean drain after [`WorkerCmd::Shutdown`]: nothing in flight,
    /// the worker thread is exiting.
    Stopped,
}

/// One replica's serving thread: owns the core, loops
/// recv-commands → step → flush-results until drained or dead.
struct Worker<C: ReplicaCore> {
    idx: usize,
    core: C,
    cmd_rx: mpsc::Receiver<WorkerCmd>,
    events: mpsc::Sender<(usize, WorkerEvent)>,
    /// Core-local sequence id → router-global request id.
    to_global: HashMap<u64, u64>,
    max_step_retries: usize,
    backoff_ms: u64,
    failures: u32,
    draining: bool,
}

impl<C: ReplicaCore> Worker<C> {
    fn run(mut self) {
        loop {
            if self.draining && !self.core.has_work() {
                self.flush(None);
                let _ = self
                    .events
                    .send((self.idx, WorkerEvent::Stopped));
                return;
            }
            // gather commands: block while idle (a worker with no work
            // burns no CPU), drain without blocking while busy
            if !self.draining && !self.core.has_work() {
                match self.cmd_rx.recv() {
                    Ok(cmd) => {
                        if !self.apply(cmd) {
                            return;
                        }
                    }
                    Err(_) => self.draining = true,
                }
            }
            loop {
                match self.cmd_rx.try_recv() {
                    Ok(cmd) => {
                        if !self.apply(cmd) {
                            return;
                        }
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        self.draining = true;
                        break;
                    }
                }
            }
            if self.core.has_work() {
                match self.core.step() {
                    Ok(_) => {
                        self.failures = 0;
                        self.flush(None);
                    }
                    Err(e) if e.is_transient() => {
                        self.failures += 1;
                        if self.failures as usize
                            > self.max_step_retries
                        {
                            self.die(e);
                            return;
                        }
                        // report the failure (health mirror), then
                        // back off on our own clock — sleeping here
                        // stalls only this replica
                        self.flush(Some(e.message().to_string()));
                        let shift = (self.failures - 1).min(16);
                        let ms = self
                            .backoff_ms
                            .checked_shl(shift)
                            .unwrap_or(u64::MAX)
                            .min(MAX_BACKOFF_MS);
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                    Err(e) => {
                        self.die(e);
                        return;
                    }
                }
            } else {
                // submit-time finishes (prompt_too_long, echo cores)
                // surface without a step
                self.flush(None);
            }
        }
    }

    /// Apply one command; `false` means the worker died doing it.
    fn apply(&mut self, cmd: WorkerCmd) -> bool {
        match cmd {
            WorkerCmd::Submit { gid, prompt, params, preload } => {
                if !preload.is_empty() {
                    // import errors degrade to a cold submit; the
                    // request serves either way and the donor already
                    // counted the export
                    let _ = self.core.import_blocks(&preload);
                }
                match self.core.submit(prompt, params) {
                    Ok(local) => {
                        self.to_global.insert(local, gid);
                        true
                    }
                    Err(e) => {
                        let transient = e.is_transient();
                        let _ = self.events.send((
                            self.idx,
                            WorkerEvent::Rejected { gid, transient },
                        ));
                        if transient {
                            true
                        } else {
                            self.die(e);
                            false
                        }
                    }
                }
            }
            WorkerCmd::Export { gid, tokens } => {
                match self.core.export_blocks(&tokens) {
                    Ok(blocks) => {
                        let _ = self.events.send((
                            self.idx,
                            WorkerEvent::Exported {
                                gid,
                                blocks,
                                failed: false,
                            },
                        ));
                        true
                    }
                    Err(e) if e.is_transient() => {
                        let _ = self.events.send((
                            self.idx,
                            WorkerEvent::Exported {
                                gid,
                                blocks: vec![],
                                failed: true,
                            },
                        ));
                        true
                    }
                    Err(e) => {
                        // donor dies mid-handshake: the Dead event
                        // resolves this and every other pending
                        // migration off this donor
                        self.die(e);
                        false
                    }
                }
            }
            WorkerCmd::Shutdown => {
                self.draining = true;
                true
            }
        }
    }

    /// Send everything the core produced since the last flush. Quiet
    /// flushes (nothing produced, no error) send nothing — channel
    /// traffic is bounded by actual work.
    fn flush(&mut self, err: Option<String>) {
        let tokens: Vec<(u64, u32)> = self
            .core
            .take_emitted()
            .into_iter()
            .filter_map(|(l, t)| {
                self.to_global.get(&l).map(|&g| (g, t))
            })
            .collect();
        let finished: Vec<(u64, Sequence)> = self
            .core
            .take_finished()
            .into_iter()
            .filter_map(|s| self.to_global.remove(&s.id).map(|g| (g, s)))
            .collect();
        let cache = self.core.take_cache_events();
        if tokens.is_empty()
            && finished.is_empty()
            && cache.is_empty()
            && err.is_none()
        {
            return;
        }
        let stats = self.core.core_stats();
        let _ = self.events.send((
            self.idx,
            WorkerEvent::Stepped { tokens, finished, cache, stats, err },
        ));
    }

    /// Permanent failure: salvage what already finished or streamed,
    /// hand the in-flight load back for replay, bounce submissions
    /// still queued behind us, and report death.
    fn die(&mut self, err: ReplicaError) {
        self.flush(None);
        let inflight: Vec<(u64, Sequence)> = self
            .core
            .drain_inflight()
            .into_iter()
            .filter_map(|s| self.to_global.remove(&s.id).map(|g| (g, s)))
            .collect();
        // teardown emits eviction events nobody will read
        let _ = self.core.take_cache_events();
        // submissions queued behind the failure can never run here
        while let Ok(cmd) = self.cmd_rx.try_recv() {
            if let WorkerCmd::Submit { gid, .. } = cmd {
                let _ = self.events.send((
                    self.idx,
                    WorkerEvent::Rejected { gid, transient: false },
                ));
            }
        }
        let _ = self.events.send((
            self.idx,
            WorkerEvent::Dead {
                error: err.message().to_string(),
                inflight,
            },
        ));
    }
}

/// Front-end bookkeeping for one worker.
struct WorkerHandle {
    cmd: mpsc::Sender<WorkerCmd>,
    thread: Option<std::thread::JoinHandle<()>>,
    /// Health mirror (the worker manages its own retries; this drives
    /// placement and stats).
    health: ReplicaHealth,
    /// Clean [`WorkerEvent::Stopped`] received.
    stopped: bool,
    /// Death fully processed (via `Dead` event or loss detection) —
    /// in-flight requests were replayed exactly once.
    dead_handled: bool,
    /// Requests placed here and not yet finished — the placement and
    /// admission-control load signal.
    outstanding: usize,
    requests_routed: usize,
    replayed_out: usize,
    /// Stats snapshot from the worker's most recent `Stepped`.
    stats: CoreStats,
}

/// Per-request record: everything needed to stream tokens with
/// contiguous indices and to replay the request if its worker dies —
/// even a worker that vanishes without handing its sequences back.
struct ReqState {
    /// The client's original prompt.
    prompt: Vec<u32>,
    /// The client's original token budget.
    max_new: usize,
    params: SamplingParams,
    /// Tokens generated by now-dead placements, in order (they ride in
    /// the replay prompt and are stitched back at finish).
    prior: Vec<u32>,
    /// Tokens streamed by the current placement.
    cur: Vec<u32>,
    /// Current placement.
    replica: Option<usize>,
    /// A KV migration was already attempted for this request — never
    /// initiate a second one (fallback re-placements must terminate).
    mig_tried: bool,
}

/// One in-flight KV migration handshake: request `gid` is parked until
/// the donor answers [`WorkerCmd::Export`] (or dies).
struct PendingMig {
    donor: usize,
    target: usize,
}

/// An event the front end surfaces to the serving loop.
#[derive(Debug)]
pub enum RouterEvent {
    /// One incrementally emitted token. `index` is the token's
    /// position in the request's output stream, contiguous from 0
    /// even across a mid-stream replica death and replay.
    Token {
        /// Router-assigned global request id.
        id: u64,
        /// Position in the request's output stream (0-based).
        index: usize,
        /// The sampled token.
        token: u32,
    },
    /// A finished request, stream already stitched (same shape the
    /// synchronous router reports).
    Finished(RoutedFinish),
}

/// The threaded multi-replica front end; see the module docs.
///
/// Unlike [`Router`](super::router::Router) this is not generic: the
/// cores move onto their worker threads at construction and only
/// messages remain.
pub struct AsyncRouter {
    /// Router configuration (`replicas` reflects the actual count).
    pub rcfg: RouterConfig,
    workers: Vec<WorkerHandle>,
    events_rx: mpsc::Receiver<(usize, WorkerEvent)>,
    directory: CacheDirectory,
    block_size: usize,
    requests: HashMap<u64, ReqState>,
    /// Request gid → in-flight migration handshake. Every entry is
    /// resolved by exactly one of: the donor's `Exported` event, the
    /// donor's `Dead` event, or `reap_lost` — placement can never hang
    /// on a migration.
    pending_mig: HashMap<u64, PendingMig>,
    next_id: u64,
    pick_state: PickState,
    out: Vec<RouterEvent>,
    shed: usize,
    replayed: usize,
    retries: usize,
    replica_failed: usize,
    migration_fallbacks: usize,
}

impl AsyncRouter {
    /// Spawn one worker thread per core (replica ids are the indices).
    /// Applies `rcfg.watermarks` and turns on cache-event recording
    /// (multi-replica only) before the cores move to their threads.
    /// All cores must share one KV block size.
    ///
    /// `C: Send` is required because each core crosses onto its
    /// thread; a core is owned by exactly one worker for the rest of
    /// its life.
    pub fn new<C>(cores: Vec<C>, mut rcfg: RouterConfig) -> AsyncRouter
    where
        C: ReplicaCore + Send + 'static,
    {
        assert!(!cores.is_empty(), "router needs at least one replica");
        let block_size = cores[0].block_size();
        let n = cores.len();
        rcfg.replicas = n;
        let (events_tx, events_rx) = mpsc::channel();
        let mut workers = Vec::with_capacity(n);
        for (i, mut core) in cores.into_iter().enumerate() {
            assert_eq!(core.block_size(), block_size,
                       "replicas disagree on block size");
            if n > 1 {
                core.enable_cache_events();
            }
            if rcfg.watermarks.enabled() {
                core.set_cache_watermarks(rcfg.watermarks);
            }
            let (cmd_tx, cmd_rx) = mpsc::channel();
            let worker = Worker {
                idx: i,
                core,
                cmd_rx,
                events: events_tx.clone(),
                to_global: HashMap::new(),
                max_step_retries: rcfg.max_step_retries,
                backoff_ms: rcfg.retry_backoff_steps.max(1) as u64,
                failures: 0,
                draining: false,
            };
            let thread = std::thread::spawn(move || worker.run());
            workers.push(WorkerHandle {
                cmd: cmd_tx,
                thread: Some(thread),
                health: ReplicaHealth::Healthy,
                stopped: false,
                dead_handled: false,
                outstanding: 0,
                requests_routed: 0,
                replayed_out: 0,
                stats: CoreStats::default(),
            });
        }
        // `events_tx` drops here: the channel disconnects exactly when
        // the last worker thread exits
        AsyncRouter {
            rcfg,
            workers,
            events_rx,
            directory: CacheDirectory::new(),
            block_size,
            requests: HashMap::new(),
            pending_mig: HashMap::new(),
            next_id: 0,
            pick_state: PickState::default(),
            out: vec![],
            shed: 0,
            replayed: 0,
            retries: 0,
            replica_failed: 0,
            migration_fallbacks: 0,
        }
    }

    /// The shared cache directory (tests assert purge-on-death).
    pub fn directory(&self) -> &CacheDirectory {
        &self.directory
    }
    /// Requests submitted so far (the next global id).
    pub fn requests_submitted(&self) -> u64 {
        self.next_id
    }
    /// Requests placed and not yet finished.
    pub fn outstanding(&self) -> usize {
        self.requests.len()
    }
    /// Anything still in flight, or events not yet polled?
    pub fn has_work(&self) -> bool {
        !self.requests.is_empty() || !self.out.is_empty()
    }

    /// Submit a request and return its global id. Admission control
    /// runs here, deterministically, against the front end's own
    /// outstanding counts — shed / no-survivor requests finish
    /// immediately and surface from the next [`AsyncRouter::poll`].
    pub fn submit(&mut self, prompt: Vec<u32>, params: SamplingParams)
        -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.requests.insert(id, ReqState {
            prompt,
            max_new: params.max_new_tokens,
            params,
            prior: vec![],
            cur: vec![],
            replica: None,
            mig_tried: false,
        });
        self.place(id, true, vec![]);
        id
    }

    /// Collect pending [`RouterEvent`]s, blocking up to `timeout` when
    /// none are immediately available. Never blocks a worker: this
    /// only reads the event channel.
    pub fn poll(&mut self, timeout: Duration) -> Vec<RouterEvent> {
        self.drain_events();
        if self.out.is_empty() && !timeout.is_zero() {
            if let Ok((i, ev)) = self.events_rx.recv_timeout(timeout) {
                self.absorb(i, ev);
                self.drain_events();
            }
        }
        self.reap_lost();
        std::mem::take(&mut self.out)
    }

    /// Per-replica stats rows from the front end's mirror (the worker
    /// snapshot rides each `Stepped` event).
    pub fn stats(&self) -> Vec<ReplicaStats> {
        self.workers
            .iter()
            .enumerate()
            .map(|(i, w)| ReplicaStats {
                id: i,
                requests_routed: w.requests_routed,
                health: w.health,
                replayed_out: w.replayed_out,
                core: w.stats.clone(),
            })
            .collect()
    }

    /// Router-level counters and the health roll-up.
    pub fn router_stats(&self) -> RouterStats {
        let alive = self
            .workers
            .iter()
            .filter(|w| w.health.is_alive())
            .count();
        RouterStats {
            shed: self.shed,
            replayed: self.replayed,
            retries: self.retries,
            replica_failed: self.replica_failed,
            alive,
            dead: self.workers.len() - alive,
            degraded: self.workers.len() > 1 && alive == 1,
            migration_fallbacks: self.migration_fallbacks,
        }
    }

    /// Drain every worker (in-flight requests run to completion),
    /// join every thread, and return the final events — finish lines
    /// for all remaining streams included.
    pub fn shutdown(mut self) -> Vec<RouterEvent> {
        for w in &self.workers {
            let _ = w.cmd.send(WorkerCmd::Shutdown);
        }
        loop {
            let all_done = self.workers.iter().all(|w| {
                w.stopped
                    || w.thread
                        .as_ref()
                        .map(|t| t.is_finished())
                        .unwrap_or(true)
            });
            if all_done {
                break;
            }
            match self
                .events_rx
                .recv_timeout(Duration::from_millis(50))
            {
                Ok((i, ev)) => {
                    self.absorb(i, ev);
                    self.drain_events();
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        for w in &mut self.workers {
            if let Some(t) = w.thread.take() {
                let _ = t.join();
            }
        }
        // every event ever sent is in the channel now
        self.drain_events();
        self.reap_lost();
        std::mem::take(&mut self.out)
    }

    /// Absorb every event already queued, without blocking.
    fn drain_events(&mut self) {
        loop {
            match self.events_rx.try_recv() {
                Ok((i, ev)) => self.absorb(i, ev),
                Err(_) => return,
            }
        }
    }

    /// Fold one worker event into routing state and the output queue.
    fn absorb(&mut self, i: usize, ev: WorkerEvent) {
        match ev {
            WorkerEvent::Rejected { gid, transient } => {
                self.retries += 1;
                if self.requests.contains_key(&gid) {
                    let w = &mut self.workers[i];
                    w.outstanding = w.outstanding.saturating_sub(1);
                }
                if transient {
                    self.quarantine_mirror(i);
                } else if self.workers[i].health.is_alive() {
                    // death confirmed by the Dead event that follows;
                    // stop placing here immediately
                    self.workers[i].health = ReplicaHealth::Dead;
                    self.directory.purge_replica(i);
                }
                if self.requests.contains_key(&gid) {
                    self.place(gid, false, vec![i]);
                }
            }
            WorkerEvent::Stepped {
                tokens,
                finished,
                cache,
                stats,
                err,
            } => {
                for ev in cache {
                    match ev {
                        CacheEvent::Registered { hash } => {
                            self.directory.on_registered(i, hash)
                        }
                        CacheEvent::Evicted { hash } => {
                            self.directory.on_evicted(i, hash)
                        }
                        CacheEvent::Demoted { hash } => {
                            self.directory.on_demoted(i, hash)
                        }
                        CacheEvent::Restored { hash } => {
                            self.directory.on_restored(i, hash)
                        }
                    }
                }
                for (gid, tok) in tokens {
                    if let Some(req) = self.requests.get_mut(&gid) {
                        req.cur.push(tok);
                        self.out.push(RouterEvent::Token {
                            id: gid,
                            index: req.prior.len() + req.cur.len() - 1,
                            token: tok,
                        });
                    }
                }
                for (gid, seq) in finished {
                    self.finish_routed(i, gid, seq);
                }
                self.workers[i].stats = stats;
                if err.is_some() {
                    self.quarantine_mirror(i);
                } else if matches!(self.workers[i].health,
                                   ReplicaHealth::Quarantined { .. }) {
                    self.workers[i].health = ReplicaHealth::Healthy;
                }
            }
            WorkerEvent::Exported { gid, blocks, failed } => {
                let Some(pm) = self.pending_mig.remove(&gid) else {
                    return; // already resolved (donor death raced)
                };
                if failed || blocks.is_empty() {
                    // transient donor error, or the directory hinted
                    // warmth the donor no longer holds: plain
                    // recompute through the normal placement path
                    self.migration_fallbacks += 1;
                    self.place(gid, false, vec![]);
                    return;
                }
                let Some((prompt, params)) = self.replay_shape(gid)
                else {
                    return;
                };
                let t = pm.target;
                let alive = self.workers[t].health.is_alive();
                if alive
                    && self.workers[t]
                        .cmd
                        .send(WorkerCmd::Submit {
                            gid,
                            prompt,
                            params,
                            preload: blocks,
                        })
                        .is_ok()
                {
                    self.workers[t].requests_routed += 1;
                    self.workers[t].outstanding += 1;
                    if let Some(req) = self.requests.get_mut(&gid) {
                        req.replica = Some(t);
                    }
                    return;
                }
                // the chosen receiver died during the handshake
                self.migration_fallbacks += 1;
                if alive {
                    self.workers[t].health = ReplicaHealth::Dead;
                    self.directory.purge_replica(t);
                }
                self.place(gid, false, vec![t]);
            }
            WorkerEvent::Dead { error: _, inflight } => {
                {
                    let w = &mut self.workers[i];
                    w.health = ReplicaHealth::Dead;
                    w.dead_handled = true;
                    w.outstanding = 0;
                    w.replayed_out += inflight.len();
                }
                self.replayed += inflight.len();
                self.directory.purge_replica(i);
                self.fail_donor_migrations(i);
                for (gid, seq) in inflight {
                    if let Some(req) = self.requests.get_mut(&gid) {
                        // the drained output is authoritative (it
                        // covers cores that do not stream); for
                        // streaming cores it equals `cur`
                        req.prior.extend_from_slice(&seq.output);
                        req.cur.clear();
                    }
                    self.place(gid, false, vec![i]);
                }
            }
            WorkerEvent::Stopped => {
                self.workers[i].stopped = true;
            }
        }
    }

    /// A worker thread that exited without `Stopped` or `Dead` lost
    /// its core to a raw panic. Every event it ever sent has already
    /// been drained (sends happen before thread exit), so the front
    /// end's own records are all that's left — replay from them.
    fn reap_lost(&mut self) {
        for i in 0..self.workers.len() {
            let gone = self.workers[i]
                .thread
                .as_ref()
                .map(|t| t.is_finished())
                .unwrap_or(true);
            if !gone
                || self.workers[i].stopped
                || self.workers[i].dead_handled
            {
                continue;
            }
            self.workers[i].health = ReplicaHealth::Dead;
            self.workers[i].dead_handled = true;
            self.workers[i].outstanding = 0;
            self.directory.purge_replica(i);
            self.fail_donor_migrations(i);
            let mut gids: Vec<u64> = self
                .requests
                .iter()
                .filter(|(_, r)| r.replica == Some(i))
                .map(|(&g, _)| g)
                .collect();
            // replay in global-id order: the HashMap's iteration order
            // must not leak into placement (the Dead-event path replays
            // in the core's sorted drain order; match it)
            gids.sort_unstable();
            self.workers[i].replayed_out += gids.len();
            self.replayed += gids.len();
            for gid in gids {
                if let Some(req) = self.requests.get_mut(&gid) {
                    // best effort: the streamed tokens are all we know
                    let cur = std::mem::take(&mut req.cur);
                    req.prior.extend(cur);
                }
                self.place(gid, false, vec![i]);
            }
        }
    }

    /// Mirror a transient failure (placement preference + stats; the
    /// worker manages its own retry/backoff clock).
    fn quarantine_mirror(&mut self, i: usize) {
        let failures = match self.workers[i].health {
            ReplicaHealth::Quarantined { failures, .. } => failures + 1,
            ReplicaHealth::Dead => return,
            ReplicaHealth::Healthy => 1,
        };
        self.workers[i].health =
            ReplicaHealth::Quarantined { failures, retry_at_step: 0 };
    }

    /// Candidate workers for a placement, in preference order (the
    /// synchronous router's rules over the mirror): alive and not in
    /// `tried`; healthy preferred over quarantined; under-cap
    /// preferred for fresh submissions.
    fn candidates(&self, fresh: bool, tried: &[usize]) -> Vec<usize> {
        let alive: Vec<usize> = (0..self.workers.len())
            .filter(|&i| self.workers[i].health.is_alive()
                && !tried.contains(&i))
            .collect();
        let pick_from = |pool: &[usize]| -> Vec<usize> {
            let healthy: Vec<usize> = pool
                .iter()
                .copied()
                .filter(|&i| {
                    self.workers[i].health == ReplicaHealth::Healthy
                })
                .collect();
            if healthy.is_empty() { pool.to_vec() } else { healthy }
        };
        let cap = self.rcfg.max_replica_queue;
        if fresh && cap > 0 {
            let under: Vec<usize> = alive
                .iter()
                .copied()
                .filter(|&i| self.workers[i].outstanding < cap)
                .collect();
            if !under.is_empty() {
                return pick_from(&under);
            }
        }
        pick_from(&alive)
    }

    /// Should a fresh submission be shed? Same config knobs as the
    /// synchronous router, evaluated against outstanding counts (the
    /// front end cannot see queue splits across the seam, so
    /// `max_waiting` bounds total outstanding — a slightly stricter,
    /// still deterministic reading).
    fn should_shed(&self) -> bool {
        let alive: Vec<&WorkerHandle> = self
            .workers
            .iter()
            .filter(|w| w.health.is_alive())
            .collect();
        if alive.is_empty() {
            return false; // ReplicaFailed path, not Shed
        }
        if self.rcfg.max_waiting > 0 {
            let total: usize =
                alive.iter().map(|w| w.outstanding).sum();
            if total >= self.rcfg.max_waiting {
                return true;
            }
        }
        let cap = self.rcfg.max_replica_queue;
        cap > 0 && alive.iter().all(|w| w.outstanding >= cap)
    }

    /// Place request `gid` on some alive worker (`fresh` = subject to
    /// admission control; replays and re-placements pass `false`).
    /// A worker whose command channel is gone is marked dead and
    /// skipped; with no candidate left the request finishes
    /// `ReplicaFailed`.
    fn place(&mut self, gid: u64, fresh: bool, mut tried: Vec<usize>) {
        if fresh && self.should_shed() {
            self.shed += 1;
            self.finish_unrouted(gid, FinishReason::Shed);
            return;
        }
        loop {
            let Some((full_prompt, params)) = self.replay_shape(gid)
            else {
                return;
            };
            let n = self.workers.len();
            let cands = self.candidates(fresh, &tried);
            let hits = match self.rcfg.routing {
                RoutingPolicy::CacheAware => self
                    .directory
                    .prefix_hits(&full_prompt, self.block_size, n),
                _ => vec![HitTokens::default(); n],
            };
            let loads: Vec<usize> =
                self.workers.iter().map(|w| w.outstanding).collect();
            let Some(r) = pick_replica(&self.rcfg,
                                       &mut self.pick_state, &cands, n,
                                       &hits, &loads)
            else {
                self.replica_failed += 1;
                self.finish_unrouted(gid, FinishReason::ReplicaFailed);
                return;
            };
            if tried.is_empty()
                && self.try_migrate(gid, r, &hits, &full_prompt)
            {
                // parked: the donor's Exported (or Dead) event places it
                return;
            }
            let cmd = WorkerCmd::Submit {
                gid,
                prompt: full_prompt,
                params,
                preload: vec![],
            };
            if self.workers[r].cmd.send(cmd).is_ok() {
                self.workers[r].requests_routed += 1;
                self.workers[r].outstanding += 1;
                if let Some(req) = self.requests.get_mut(&gid) {
                    req.replica = Some(r);
                }
                return;
            }
            // the worker is gone (its receiver dropped); its Dead
            // event — or reap_lost — replays whatever it held
            self.retries += 1;
            if self.workers[r].health.is_alive() {
                self.workers[r].health = ReplicaHealth::Dead;
                self.directory.purge_replica(r);
            }
            tried.push(r);
        }
    }

    /// The prompt and budget a placement of `gid` must carry (tokens
    /// streamed by dead placements folded into the replay prompt) —
    /// shared by `place` and the migration handshake's deferred
    /// submit.
    fn replay_shape(&self, gid: u64)
        -> Option<(Vec<u32>, SamplingParams)> {
        let req = self.requests.get(&gid)?;
        let mut p = req.prompt.clone();
        p.extend_from_slice(&req.prior);
        let mut params = req.params.clone();
        // unfinished ⇒ prior < budget, so remainder ≥ 1
        debug_assert!(req.prior.len() < req.max_new);
        params.max_new_tokens =
            req.max_new.saturating_sub(req.prior.len()).max(1);
        Some((p, params))
    }

    /// Try to start a KV migration for `gid` toward chosen receiver
    /// `r`: if some other alive replica holds strictly more of the
    /// prefix, ask it to export. `true` parks the request on the
    /// handshake (the caller must not submit); `false` means no donor
    /// — fall through to a plain submit.
    fn try_migrate(&mut self, gid: u64, r: usize,
                   hits: &[HitTokens], prompt: &[u32]) -> bool {
        if !self.rcfg.kv_migrate
            || !matches!(self.rcfg.routing, RoutingPolicy::CacheAware)
            || self.pending_mig.contains_key(&gid)
            || self.requests.get(&gid).map_or(true, |q| q.mig_tried)
        {
            return false;
        }
        let Some(d) = (0..self.workers.len())
            .filter(|&d| {
                d != r
                    && self.workers[d].health.is_alive()
                    && hits[d].total() > hits[r].total()
            })
            .max_by_key(|&d| (hits[d].total(), std::cmp::Reverse(d)))
        else {
            return false;
        };
        if let Some(req) = self.requests.get_mut(&gid) {
            // one attempt per request: fallback re-placements and
            // donor-death replays must terminate
            req.mig_tried = true;
        }
        let cmd = WorkerCmd::Export { gid, tokens: prompt.to_vec() };
        if self.workers[d].cmd.send(cmd).is_ok() {
            self.pending_mig
                .insert(gid, PendingMig { donor: d, target: r });
            return true;
        }
        // the donor vanished before we could ask; recompute instead
        self.migration_fallbacks += 1;
        if self.workers[d].health.is_alive() {
            self.workers[d].health = ReplicaHealth::Dead;
            self.directory.purge_replica(d);
        }
        false
    }

    /// Resolve every pending migration whose donor is worker `donor`
    /// (it died, or its thread was lost to a panic): each parked
    /// request falls back to plain recompute placement that never
    /// touches the dead donor.
    fn fail_donor_migrations(&mut self, donor: usize) {
        let mut gids: Vec<u64> = self
            .pending_mig
            .iter()
            .filter(|(_, pm)| pm.donor == donor)
            .map(|(&g, _)| g)
            .collect();
        // placement order must not leak HashMap iteration order
        gids.sort_unstable();
        for gid in gids {
            self.pending_mig.remove(&gid);
            self.migration_fallbacks += 1;
            self.place(gid, false, vec![donor]);
        }
    }

    /// Deliver a finished sequence from worker `i`, restoring the
    /// client's prompt/budget and stitching replayed streams.
    fn finish_routed(&mut self, i: usize, gid: u64, mut seq: Sequence) {
        let Some(req) = self.requests.remove(&gid) else { return };
        let w = &mut self.workers[i];
        w.outstanding = w.outstanding.saturating_sub(1);
        seq.prompt = req.prompt;
        seq.params.max_new_tokens = req.max_new;
        if !req.prior.is_empty() {
            let mut output = req.prior;
            output.extend_from_slice(&seq.output);
            seq.output = output;
        }
        self.out.push(RouterEvent::Finished(RoutedFinish {
            id: gid,
            replica: Some(i),
            seq,
        }));
    }

    /// Finish a request no worker is serving (shed at admission, or no
    /// survivor left). Tokens already streamed still stitch into the
    /// reported output.
    fn finish_unrouted(&mut self, gid: u64, reason: FinishReason) {
        let Some(req) = self.requests.remove(&gid) else { return };
        let mut params = req.params;
        params.max_new_tokens = req.max_new;
        let mut seq = Sequence::new(gid, req.prompt, params);
        seq.output = req.prior;
        seq.finish(reason);
        self.out.push(RouterEvent::Finished(RoutedFinish {
            id: gid,
            replica: None,
            seq,
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::coordinator::fake::{EchoCore, FakeCore};

    fn drain(router: &mut AsyncRouter)
        -> (Vec<RouterEvent>, Vec<RoutedFinish>) {
        let mut events = vec![];
        let mut fins = vec![];
        for _ in 0..1000 {
            for ev in router.poll(Duration::from_millis(50)) {
                match ev {
                    RouterEvent::Finished(f) => fins.push(f),
                    t => events.push(t),
                }
            }
            if !router.has_work() {
                break;
            }
        }
        (events, fins)
    }

    #[test]
    fn single_echo_worker_round_trips() {
        let mut r = AsyncRouter::new(vec![EchoCore::new()],
                                     RouterConfig::default());
        let id = r.submit(vec![7, 8], SamplingParams::default());
        let (tokens, fins) = drain(&mut r);
        assert_eq!(fins.len(), 1);
        assert_eq!(fins[0].id, id);
        assert_eq!(fins[0].replica, Some(0));
        assert_eq!(fins[0].seq.output, vec![7]);
        // the token streamed before (or with) the finish
        assert!(matches!(tokens[..],
                         [RouterEvent::Token { id: 0, index: 0,
                                               token: 7 }]));
        assert!(r.shutdown().is_empty());
    }

    #[test]
    fn fake_worker_streams_match_final_output() {
        let ecfg = EngineConfig {
            block_size: 4,
            ..Default::default()
        };
        let mut r = AsyncRouter::new(
            vec![FakeCore::new(ecfg, 64)],
            RouterConfig::default(),
        );
        let prompt: Vec<u32> = (0..9).collect();
        let id = r.submit(prompt, SamplingParams {
            max_new_tokens: 5,
            ..Default::default()
        });
        let (tokens, fins) = drain(&mut r);
        assert_eq!(fins.len(), 1);
        let streamed: Vec<u32> = tokens
            .iter()
            .map(|t| match t {
                RouterEvent::Token { id: tid, token, .. } => {
                    assert_eq!(*tid, id);
                    *token
                }
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(streamed, fins[0].seq.output);
        assert_eq!(streamed.len(), 5);
        // indices are contiguous from zero
        for (k, t) in tokens.iter().enumerate() {
            match t {
                RouterEvent::Token { index, .. } => {
                    assert_eq!(*index, k)
                }
                _ => unreachable!(),
            }
        }
        assert!(r.shutdown().is_empty());
    }

    #[test]
    fn shutdown_finishes_inflight_requests() {
        let ecfg = EngineConfig {
            block_size: 4,
            ..Default::default()
        };
        let mut r = AsyncRouter::new(
            vec![FakeCore::new(ecfg, 64)],
            RouterConfig::default(),
        );
        let id = r.submit((0..7).collect(), SamplingParams {
            max_new_tokens: 4,
            ..Default::default()
        });
        // no polling at all: shutdown alone must drain and deliver
        let events = r.shutdown();
        let fins: Vec<&RoutedFinish> = events
            .iter()
            .filter_map(|e| match e {
                RouterEvent::Finished(f) => Some(f),
                _ => None,
            })
            .collect();
        assert_eq!(fins.len(), 1);
        assert_eq!(fins[0].id, id);
        assert_eq!(fins[0].seq.output.len(), 4);
    }
}
