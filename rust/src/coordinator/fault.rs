//! Deterministic fault injection for the replica/router seam.
//!
//! [`FaultyCore`] wraps any [`ReplicaCore`] and fails its `step` /
//! `submit` calls on a deterministic [`FaultSpec`] schedule, leaving
//! every other method a pass-through. A failed call does **no** work on
//! the inner core — exactly the contract a real failure presents: the
//! step that errored produced nothing.
//!
//! This is the tier-1 test harness for the router's health machine
//! (Healthy → Quarantined → Dead), bounded retry-with-backoff,
//! in-flight replay, and load shedding: wrap the deterministic
//! `FakeCore` from the router property tests (or a real [`Engine`])
//! and every recovery path becomes reproducible without artifacts.
//!
//! [`Engine`]: super::engine::Engine

use crate::config::CacheWatermarks;

use super::block_manager::CacheEvent;
use super::engine::StepOutcome;
use super::replica::{CoreStats, ReplicaCore, ReplicaError};
use super::sequence::{SamplingParams, Sequence};

/// When and how a [`FaultyCore`] fails. All schedules count calls
/// 1-based, so `FailOnStepK { k: 1 }` fails the very first step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSpec {
    /// Permanent failure on step call `k` and every step after it —
    /// the replica "crashes" at a chosen point mid-stream.
    FailOnStepK {
        /// First failing step call (1-based).
        k: usize,
    },
    /// Transient failure on every `n`-th step call (the flaky device:
    /// fails, recovers, fails again).
    FailEveryN {
        /// Failure period in step calls (must be ≥ 1).
        n: usize,
    },
    /// Permanent failure on submit call `k` and every submit after it;
    /// steps keep succeeding until the router reacts.
    FailOnSubmit {
        /// First failing submit call (1-based).
        k: usize,
    },
    /// Transient failures on step calls `from .. from + fails`, healthy
    /// before and after — the recoverable brown-out.
    TransientThenRecover {
        /// First failing step call (1-based).
        from: usize,
        /// Number of consecutive failing step calls.
        fails: usize,
    },
    /// Every `export_blocks` call fails — the donor dies (or hiccups)
    /// mid-migration. Transient exports make the router fall back to
    /// plain recompute; permanent ones kill the donor replica. Steps
    /// and submits keep succeeding either way.
    FailOnExport {
        /// Transient (fall back) vs permanent (donor dies).
        transient: bool,
    },
}

/// A [`ReplicaCore`] wrapper that injects failures per a
/// [`FaultSpec`]; see the module docs.
pub struct FaultyCore<C: ReplicaCore> {
    inner: C,
    spec: FaultSpec,
    steps: usize,
    submits: usize,
}

impl<C: ReplicaCore> FaultyCore<C> {
    /// Wrap `inner` with the failure schedule `spec`.
    pub fn new(inner: C, spec: FaultSpec) -> FaultyCore<C> {
        FaultyCore { inner, spec, steps: 0, submits: 0 }
    }

    /// The wrapped core (assertions on post-failure state).
    pub fn inner(&self) -> &C {
        &self.inner
    }

    /// Step calls observed so far (failed ones included).
    pub fn steps_seen(&self) -> usize {
        self.steps
    }

    /// The error this step call must produce, if any.
    fn step_fault(&self) -> Option<ReplicaError> {
        match self.spec {
            FaultSpec::FailOnStepK { k } if self.steps >= k => {
                Some(ReplicaError::Permanent(format!(
                    "injected: failed at step {k}"
                )))
            }
            FaultSpec::FailEveryN { n } if self.steps % n.max(1) == 0 => {
                Some(ReplicaError::Transient(format!(
                    "injected: step {} (every {n})", self.steps
                )))
            }
            FaultSpec::TransientThenRecover { from, fails }
                if self.steps >= from && self.steps < from + fails =>
            {
                Some(ReplicaError::Transient(format!(
                    "injected: brown-out step {}", self.steps
                )))
            }
            _ => None,
        }
    }
}

impl<C: ReplicaCore> ReplicaCore for FaultyCore<C> {
    fn submit(&mut self, prompt: Vec<u32>, params: SamplingParams)
        -> Result<u64, ReplicaError> {
        self.submits += 1;
        if let FaultSpec::FailOnSubmit { k } = self.spec {
            if self.submits >= k {
                return Err(ReplicaError::Permanent(format!(
                    "injected: failed at submit {k}"
                )));
            }
        }
        self.inner.submit(prompt, params)
    }

    fn step(&mut self) -> Result<StepOutcome, ReplicaError> {
        self.steps += 1;
        if let Some(e) = self.step_fault() {
            return Err(e);
        }
        self.inner.step()
    }

    fn has_work(&self) -> bool {
        self.inner.has_work()
    }
    fn take_finished(&mut self) -> Vec<Sequence> {
        self.inner.take_finished()
    }
    fn take_emitted(&mut self) -> Vec<(u64, u32)> {
        self.inner.take_emitted()
    }
    fn drain_inflight(&mut self) -> Vec<Sequence> {
        self.inner.drain_inflight()
    }
    fn block_size(&self) -> usize {
        self.inner.block_size()
    }
    fn queue_depths(&self) -> (usize, usize) {
        self.inner.queue_depths()
    }
    fn enable_cache_events(&mut self) {
        self.inner.enable_cache_events()
    }
    fn take_cache_events(&mut self) -> Vec<CacheEvent> {
        self.inner.take_cache_events()
    }
    fn set_cache_watermarks(&mut self, wm: CacheWatermarks) {
        self.inner.set_cache_watermarks(wm)
    }
    fn export_blocks(&mut self, tokens: &[u32])
        -> Result<Vec<(u64, Vec<u8>)>, ReplicaError> {
        if let FaultSpec::FailOnExport { transient } = self.spec {
            return Err(if transient {
                ReplicaError::Transient("injected: export failed".into())
            } else {
                ReplicaError::Permanent(
                    "injected: donor died exporting".into(),
                )
            });
        }
        self.inner.export_blocks(tokens)
    }
    fn import_blocks(&mut self, blocks: &[(u64, Vec<u8>)])
        -> Result<usize, ReplicaError> {
        self.inner.import_blocks(blocks)
    }
    fn core_stats(&self) -> CoreStats {
        self.inner.core_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A do-nothing core for schedule unit tests.
    struct NullCore;
    impl ReplicaCore for NullCore {
        fn submit(&mut self, _: Vec<u32>, _: SamplingParams)
            -> Result<u64, ReplicaError> {
            Ok(0)
        }
        fn step(&mut self) -> Result<StepOutcome, ReplicaError> {
            Ok(StepOutcome::Idle)
        }
        fn has_work(&self) -> bool {
            false
        }
        fn take_finished(&mut self) -> Vec<Sequence> {
            vec![]
        }
        fn drain_inflight(&mut self) -> Vec<Sequence> {
            vec![]
        }
        fn block_size(&self) -> usize {
            4
        }
        fn queue_depths(&self) -> (usize, usize) {
            (0, 0)
        }
        fn enable_cache_events(&mut self) {}
        fn take_cache_events(&mut self) -> Vec<CacheEvent> {
            vec![]
        }
        fn set_cache_watermarks(&mut self, _: CacheWatermarks) {}
        fn core_stats(&self) -> CoreStats {
            CoreStats::default()
        }
    }

    #[test]
    fn fail_on_step_k_is_permanent_and_sticky() {
        let mut c =
            FaultyCore::new(NullCore, FaultSpec::FailOnStepK { k: 3 });
        assert!(c.step().is_ok());
        assert!(c.step().is_ok());
        let e = c.step().unwrap_err();
        assert!(!e.is_transient());
        assert!(c.step().is_err(), "crash must be sticky");
    }

    #[test]
    fn fail_every_n_is_transient_and_periodic() {
        let mut c =
            FaultyCore::new(NullCore, FaultSpec::FailEveryN { n: 2 });
        assert!(c.step().is_ok()); // 1
        let e = c.step().unwrap_err(); // 2
        assert!(e.is_transient());
        assert!(c.step().is_ok()); // 3
        assert!(c.step().is_err()); // 4
    }

    #[test]
    fn transient_window_recovers() {
        let mut c = FaultyCore::new(
            NullCore,
            FaultSpec::TransientThenRecover { from: 2, fails: 2 },
        );
        assert!(c.step().is_ok()); // 1
        assert!(c.step().unwrap_err().is_transient()); // 2
        assert!(c.step().unwrap_err().is_transient()); // 3
        assert!(c.step().is_ok()); // 4: recovered
        assert_eq!(c.steps_seen(), 4);
    }

    #[test]
    fn fail_on_submit_leaves_steps_alone() {
        let mut c =
            FaultyCore::new(NullCore, FaultSpec::FailOnSubmit { k: 2 });
        assert!(c.submit(vec![1], SamplingParams::default()).is_ok());
        assert!(c.submit(vec![1], SamplingParams::default()).is_err());
        assert!(c.step().is_ok());
    }

    #[test]
    fn fail_on_export_spares_steps_and_submits() {
        let mut t = FaultyCore::new(
            NullCore, FaultSpec::FailOnExport { transient: true },
        );
        assert!(t.export_blocks(&[1, 2, 3]).unwrap_err().is_transient());
        assert!(t.step().is_ok());
        assert!(t.submit(vec![1], SamplingParams::default()).is_ok());
        let mut p = FaultyCore::new(
            NullCore, FaultSpec::FailOnExport { transient: false },
        );
        assert!(!p.export_blocks(&[1]).unwrap_err().is_transient());
        // imports pass through (the receiver is not the faulty party)
        assert_eq!(p.import_blocks(&[]).unwrap(), 0);
    }
}
