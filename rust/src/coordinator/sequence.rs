//! Request / sequence state machine.
//!
//! # Invariants
//!
//! * `prefill_progress` is the *chunk cursor*: how many KV rows of the
//!   current prefill pass exist (computed **or** copied from cached
//!   blocks). It is distinct from [`Sequence::cached_prefix_len`], which
//!   records only how many of those rows came from the prefix cache at
//!   the most recent admission. `cached_prefix_len <= prefill_progress`
//!   always holds while prefilling.
//! * A sequence is [`SeqState::Prefilling`] iff it is admitted (holds
//!   blocks) but `prefill_progress` has not yet reached
//!   [`Sequence::context_len`]; it becomes [`SeqState::Running`] the
//!   moment its first token of the pass is sampled.
//! * Preemption (recompute policy) drops all KV: `preempt()` resets the
//!   chunk cursor and the cached-prefix count to zero; both are
//!   re-established at the next admission. Generated output is *kept* —
//!   it is re-prefilled as part of the content on re-admission.

use std::time::Instant;

/// Lifecycle of a request inside the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqState {
    /// In the waiting queue (not yet prefilled, or preempted).
    Waiting,
    /// Admitted (blocks held) with prefill still in progress: the chunk
    /// cursor has not reached the full content length yet.
    Prefilling,
    /// In the running set (KV resident, decoding).
    Running,
    /// Finished (EOS / max tokens); output available.
    Finished,
}

/// Why a sequence stopped generating.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated its `max_new_tokens` budget.
    MaxTokens,
    /// Emitted the end-of-sequence token.
    Eos,
    /// Prompt was longer than the model's max_len budget.
    PromptTooLong,
    /// The sequence alone exceeded the KV block pool: the scheduler
    /// could not make progress even after preempting everything else.
    PoolExhausted,
    /// Rejected at admission by the router's load-shedding policy
    /// (per-replica queue cap or global waiting budget exceeded).
    Shed,
    /// The replica serving this request died and no surviving replica
    /// could take over the replay.
    ReplicaFailed,
}

/// Sampling parameters for one request.
#[derive(Debug, Clone)]
pub struct SamplingParams {
    /// Generation budget (output tokens).
    pub max_new_tokens: usize,
    /// Softmax temperature; `<= 0` means greedy argmax.
    pub temperature: f32,
    /// Restrict sampling to the `top_k` highest logits (0 = no limit).
    pub top_k: usize,
    /// Token id treated as end-of-sequence (vocab-dependent); None = none.
    pub eos: Option<u32>,
    /// Per-request sampling seed (mixed with the engine seed).
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            max_new_tokens: 32,
            temperature: 0.0, // greedy
            top_k: 0,
            eos: None,
            seed: 0,
        }
    }
}

/// One request tracked end-to-end.
#[derive(Debug, Clone)]
pub struct Sequence {
    /// Engine-assigned id (submission order).
    pub id: u64,
    /// Prompt token ids as submitted.
    pub prompt: Vec<u32>,
    /// Generated token ids so far.
    pub output: Vec<u32>,
    /// Sampling parameters for this request.
    pub params: SamplingParams,
    /// Current lifecycle state.
    pub state: SeqState,
    /// Finish reason once [`SeqState::Finished`].
    pub finish: Option<FinishReason>,
    /// Times a preemption evicted this sequence (recompute policy).
    pub preemptions: usize,
    /// Tokens served from the prefix cache at the most recent
    /// admission (0 when the prefill was fully computed). On a
    /// re-admission after preemption this can exceed the prompt length:
    /// blocks registered while *decoding* make generated tokens
    /// cacheable too.
    pub cached_prefix_len: usize,
    /// Chunk cursor: KV rows of the current prefill pass that exist
    /// (copied from cache or computed). Advanced per executed chunk;
    /// reset by [`Sequence::preempt`]. See the module docs for the
    /// distinction from `cached_prefix_len`.
    pub prefill_progress: usize,
    /// Wall-clock arrival (submission) time.
    pub arrived: Instant,
    /// Engine step count at submission (TTFT-in-steps proxy).
    pub arrived_step: usize,
    /// Wall-clock time of the first generated token, if any.
    pub first_token_at: Option<Instant>,
    /// Wall-clock finish time, if finished.
    pub finished_at: Option<Instant>,
    /// Per-output-token completion times (for latency percentiles).
    pub token_times: Vec<Instant>,
}

impl Sequence {
    /// A new sequence in [`SeqState::Waiting`] with empty output.
    pub fn new(id: u64, prompt: Vec<u32>, params: SamplingParams)
        -> Sequence {
        Sequence {
            id,
            prompt,
            output: Vec::new(),
            params,
            state: SeqState::Waiting,
            finish: None,
            preemptions: 0,
            cached_prefix_len: 0,
            prefill_progress: 0,
            // sqlint: allow(determinism) wall-clock arrival stamp: latency metrics only, never scheduling
            arrived: Instant::now(),
            arrived_step: 0,
            first_token_at: None,
            finished_at: None,
            token_times: Vec::new(),
        }
    }

    /// Total tokens with KV resident once running (prompt + generated).
    pub fn context_len(&self) -> usize {
        self.prompt.len() + self.output.len()
    }

    /// Prompt plus generated tokens — the content (re)prefilled on
    /// admission (recompute policy) and hashed by the prefix cache.
    pub fn full_tokens(&self) -> Vec<u32> {
        let mut t = self.prompt.clone();
        t.extend(&self.output);
        t
    }

    /// The token to feed the next decode step (last generated, or last
    /// prompt token right after prefill).
    pub fn last_token(&self) -> u32 {
        *self
            .output
            .last()
            .or_else(|| self.prompt.last())
            // sqlint: allow(panic) engine rejects empty prompts at submit (PromptTooLong)
            .expect("empty sequence")
    }

    /// Append a generated token (records first-token/latency times).
    pub fn record_token(&mut self, tok: u32) {
        // sqlint: allow(determinism) wall-clock latency stamp: metrics/response only, never scheduling
        let now = Instant::now();
        if self.output.is_empty() {
            self.first_token_at = Some(now);
        }
        self.output.push(tok);
        self.token_times.push(now);
    }

    /// Whether the sequence should stop, and why.
    pub fn should_finish(&self) -> Option<FinishReason> {
        if let (Some(eos), Some(&last)) =
            (self.params.eos, self.output.last())
        {
            if last == eos {
                return Some(FinishReason::Eos);
            }
        }
        if self.output.len() >= self.params.max_new_tokens {
            return Some(FinishReason::MaxTokens);
        }
        None
    }

    /// Mark finished with `reason` (records the finish time).
    pub fn finish(&mut self, reason: FinishReason) {
        self.state = SeqState::Finished;
        self.finish = Some(reason);
        // sqlint: allow(determinism) wall-clock finish stamp: latency metrics only, never scheduling
        self.finished_at = Some(Instant::now());
    }

    /// Drop generated KV state for recompute-preemption: the content is
    /// re-prefilled from scratch on re-admission (prompt + generated
    /// tokens, so no output is lost). Valid while running *or* mid-way
    /// through a chunked prefill.
    pub fn preempt(&mut self) {
        assert!(
            matches!(self.state, SeqState::Running | SeqState::Prefilling),
            "preempt in state {:?}",
            self.state
        );
        self.state = SeqState::Waiting;
        self.preemptions += 1;
        self.prefill_progress = 0;
        self.cached_prefix_len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(prompt: &[u32], max_new: usize) -> Sequence {
        Sequence::new(
            1,
            prompt.to_vec(),
            SamplingParams { max_new_tokens: max_new, ..Default::default() },
        )
    }

    #[test]
    fn lifecycle() {
        let mut s = seq(&[1, 2, 3], 2);
        assert_eq!(s.state, SeqState::Waiting);
        assert_eq!(s.last_token(), 3);
        s.state = SeqState::Running;
        s.record_token(7);
        assert_eq!(s.last_token(), 7);
        assert!(s.first_token_at.is_some());
        assert!(s.should_finish().is_none());
        s.record_token(8);
        assert_eq!(s.should_finish(), Some(FinishReason::MaxTokens));
        s.finish(FinishReason::MaxTokens);
        assert_eq!(s.state, SeqState::Finished);
        assert_eq!(s.context_len(), 5);
    }

    #[test]
    fn eos_detection() {
        let mut s = seq(&[1], 10);
        s.params.eos = Some(42);
        s.state = SeqState::Running;
        s.record_token(5);
        assert!(s.should_finish().is_none());
        s.record_token(42);
        assert_eq!(s.should_finish(), Some(FinishReason::Eos));
    }

    #[test]
    fn preemption_counts_and_resets_cursor() {
        let mut s = seq(&[1, 2], 5);
        s.state = SeqState::Running;
        s.prefill_progress = 2;
        s.cached_prefix_len = 2;
        s.record_token(9);
        s.preempt();
        assert_eq!(s.state, SeqState::Waiting);
        assert_eq!(s.preemptions, 1);
        assert_eq!(s.output, vec![9]); // output preserved for recompute
        assert_eq!(s.prefill_progress, 0); // chunk cursor dropped with KV
        assert_eq!(s.cached_prefix_len, 0);
    }

    #[test]
    fn preempt_mid_prefill() {
        let mut s = seq(&[1, 2, 3, 4], 5);
        s.state = SeqState::Prefilling;
        s.prefill_progress = 2;
        s.preempt();
        assert_eq!(s.state, SeqState::Waiting);
        assert_eq!(s.prefill_progress, 0);
    }
}
