//! Request / sequence state machine.

use std::time::Instant;

/// Lifecycle of a request inside the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqState {
    /// In the waiting queue (not yet prefillled, or preempted).
    Waiting,
    /// In the running set (KV resident, decoding).
    Running,
    /// Finished (EOS / max tokens); output available.
    Finished,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    Eos,
    /// Prompt was longer than the model's max_len budget.
    PromptTooLong,
}

/// Sampling parameters for one request.
#[derive(Debug, Clone)]
pub struct SamplingParams {
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub top_k: usize,
    /// Token id treated as end-of-sequence (vocab-dependent); None = none.
    pub eos: Option<u32>,
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            max_new_tokens: 32,
            temperature: 0.0, // greedy
            top_k: 0,
            eos: None,
            seed: 0,
        }
    }
}

/// One request tracked end-to-end.
#[derive(Debug, Clone)]
pub struct Sequence {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub output: Vec<u32>,
    pub params: SamplingParams,
    pub state: SeqState,
    pub finish: Option<FinishReason>,
    /// Times a preemption evicted this sequence (recompute policy).
    pub preemptions: usize,
    /// Prompt tokens served from the prefix cache at the most recent
    /// admission (0 when the prefill was fully computed).
    pub cached_prefix_len: usize,
    pub arrived: Instant,
    pub first_token_at: Option<Instant>,
    pub finished_at: Option<Instant>,
    /// Per-output-token completion times (for latency percentiles).
    pub token_times: Vec<Instant>,
}

impl Sequence {
    pub fn new(id: u64, prompt: Vec<u32>, params: SamplingParams)
        -> Sequence {
        Sequence {
            id,
            prompt,
            output: Vec::new(),
            params,
            state: SeqState::Waiting,
            finish: None,
            preemptions: 0,
            cached_prefix_len: 0,
            arrived: Instant::now(),
            first_token_at: None,
            finished_at: None,
            token_times: Vec::new(),
        }
    }

    /// Total tokens with KV resident once running (prompt + generated).
    pub fn context_len(&self) -> usize {
        self.prompt.len() + self.output.len()
    }

    /// Prompt plus generated tokens — the content (re)prefilled on
    /// admission (recompute policy) and hashed by the prefix cache.
    pub fn full_tokens(&self) -> Vec<u32> {
        let mut t = self.prompt.clone();
        t.extend(&self.output);
        t
    }

    /// The token to feed the next decode step (last generated, or last
    /// prompt token right after prefill).
    pub fn last_token(&self) -> u32 {
        *self
            .output
            .last()
            .or_else(|| self.prompt.last())
            .expect("empty sequence")
    }

    pub fn record_token(&mut self, tok: u32) {
        let now = Instant::now();
        if self.output.is_empty() {
            self.first_token_at = Some(now);
        }
        self.output.push(tok);
        self.token_times.push(now);
    }

    pub fn should_finish(&self) -> Option<FinishReason> {
        if let (Some(eos), Some(&last)) =
            (self.params.eos, self.output.last())
        {
            if last == eos {
                return Some(FinishReason::Eos);
            }
        }
        if self.output.len() >= self.params.max_new_tokens {
            return Some(FinishReason::MaxTokens);
        }
        None
    }

    pub fn finish(&mut self, reason: FinishReason) {
        self.state = SeqState::Finished;
        self.finish = Some(reason);
        self.finished_at = Some(Instant::now());
    }

    /// Drop generated state for recompute-preemption: the prompt is
    /// re-extended with the tokens generated so far so no output is lost.
    pub fn preempt(&mut self) {
        assert_eq!(self.state, SeqState::Running);
        self.state = SeqState::Waiting;
        self.preemptions += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(prompt: &[u32], max_new: usize) -> Sequence {
        Sequence::new(
            1,
            prompt.to_vec(),
            SamplingParams { max_new_tokens: max_new, ..Default::default() },
        )
    }

    #[test]
    fn lifecycle() {
        let mut s = seq(&[1, 2, 3], 2);
        assert_eq!(s.state, SeqState::Waiting);
        assert_eq!(s.last_token(), 3);
        s.state = SeqState::Running;
        s.record_token(7);
        assert_eq!(s.last_token(), 7);
        assert!(s.first_token_at.is_some());
        assert!(s.should_finish().is_none());
        s.record_token(8);
        assert_eq!(s.should_finish(), Some(FinishReason::MaxTokens));
        s.finish(FinishReason::MaxTokens);
        assert_eq!(s.state, SeqState::Finished);
        assert_eq!(s.context_len(), 5);
    }

    #[test]
    fn eos_detection() {
        let mut s = seq(&[1], 10);
        s.params.eos = Some(42);
        s.state = SeqState::Running;
        s.record_token(5);
        assert!(s.should_finish().is_none());
        s.record_token(42);
        assert_eq!(s.should_finish(), Some(FinishReason::Eos));
    }

    #[test]
    fn preemption_counts() {
        let mut s = seq(&[1, 2], 5);
        s.state = SeqState::Running;
        s.record_token(9);
        s.preempt();
        assert_eq!(s.state, SeqState::Waiting);
        assert_eq!(s.preemptions, 1);
        assert_eq!(s.output, vec![9]); // output preserved for recompute
    }
}
