//! Paged KV-cache accounting with content-hash prefix caching (the vLLM
//! block-manager lineage, sized to what this engine needs).
//!
//! Physical KV rows live host-side per sequence ([`crate::runtime::kv`]);
//! *admission, sharing and preemption* are governed here. The simulated
//! device pool is divided into fixed-size blocks of `block_size` token
//! slots; each sequence owns a table of physical block ids; allocation
//! fails when the pool (minus a watermark) is exhausted, which triggers
//! scheduler preemption — the same control loop vLLM runs, driven by the
//! same arithmetic the paper's memory argument uses (W4A16 frees ~3/4 of
//! the weight memory, so the pool is larger and batches grow).
//!
//! Prefix-cache design (vLLM-style hash-based automatic prefix caching):
//!
//! * **Hash scheme** — a full block is keyed by the *chained* hash of its
//!   token content: `h_i = hash(h_{i-1}, tokens[i*bs..(i+1)*bs])` from a
//!   fixed seed, so equal keys mean equal position-aligned prefixes, and
//!   a repeated system prompt maps to the same chain of block ids.
//! * **Full blocks only / CoW rule** — only completely filled blocks are
//!   cached or shared; the tail partial block is always private to its
//!   sequence. A lookup also never covers the *entire* token list — at
//!   least one token is left to compute so sampling has fresh logits.
//!   This is the copy-on-write boundary: a sequence whose whole prompt is
//!   cached takes a private copy of the final block (recomputing it)
//!   instead of sharing it.
//! * **Sharing** — a cache hit bumps the block's refcount instead of
//!   allocating; `release` decrements it, so preempting or finishing one
//!   sharer never frees blocks another sequence still references.
//! * **Eviction** — cached blocks with refcount 0 are *evictable* free
//!   capacity, reclaimed LRU (least recently released first) when the
//!   free list runs dry. [`BlockManager::take_evicted`] reports reclaimed
//!   ids so the engine can drop the host KV rows it stashed for them.
//! * **Partially filled sequences** — under chunked prefill a sequence
//!   is admitted with a table covering only its cached prefix plus the
//!   first chunk ([`BlockManager::allocate_chunked`]); each later chunk
//!   grows the table like decode growth does
//!   ([`BlockManager::append_token`]). The admission *capacity check*
//!   still covers the full content so an impossible sequence blocks the
//!   FCFS head instead of thrashing the pool. Releasing a partially
//!   filled table (preempt-while-prefilling) follows the same refcount
//!   rules as any other release.
//! * **Single-walk admission** — an admission attempt walks the content
//!   hash chain exactly once, inside the allocate family: the allocator
//!   returns the hit (and the fill it honored) in [`Alloc::Ok`], and
//!   the scheduler's policy caps (step budget, bucket width caps) are
//!   parameters rather than caller-side pre-probes, so the hit the
//!   scheduler budgets against is by construction the hit the table
//!   reflects. `hash_walks` counts walks for the property tests.
//! * **Sliding-window eviction** — on top of the demand-driven LRU
//!   reclaim above, the cached-but-unreferenced population itself is
//!   bounded by a high/low watermark pair
//!   ([`BlockManager::set_cache_watermarks`]): whenever a release
//!   pushes the evictable count past `high`, the oldest-released
//!   blocks are evicted (back onto the free list) until the count is
//!   down to `low`. Refcounted blocks are never candidates — only the
//!   evictable LRU window shrinks — so a hot shared prefix survives
//!   while a long tail of one-off prompts cannot grow the cache
//!   without bound. `high == 0` disables the window (the pre-window
//!   behavior: unbounded until the free list runs dry).
//! * **Tiered demotion pool** — with [`BlockManager::set_kv_pool`]
//!   bound > 0, eviction (demand LRU *and* sliding window) *demotes*
//!   the block's content hash into a bounded host-side pool index
//!   instead of forgetting it: the hash stays serveable, and a later
//!   walk hit on a pooled hash is honored by grabbing a fresh device
//!   block and reporting the pair via [`BlockManager::take_restored`]
//!   so the engine moves the stashed (quantized) rows back instead of
//!   recomputing them. The pool itself is LRU-bounded: overflow drops
//!   the oldest pooled hash (reported via
//!   [`BlockManager::take_pool_dropped`], and as an `Evicted` cache
//!   event — that is the moment the content truly stops being
//!   serveable). Demotion emits *no* `Evicted` event, so a router
//!   directory keeps routing repeats at the replica that still holds
//!   the (pooled) rows. The manager owns only the *index*; the engine
//!   owns the bytes ([`crate::runtime::kvq`]).
//! * **Cache events** — when enabled
//!   ([`BlockManager::enable_cache_events`]), every registration and
//!   eviction is also recorded as a [`CacheEvent`] and drained via
//!   [`BlockManager::take_cache_events`]. The multi-replica router
//!   feeds these into its shared cache directory (content hash →
//!   replica hints) so cache-aware routing stays O(prompt blocks)
//!   instead of walking every replica's chain per request. Disabled by
//!   default so a standalone engine never accumulates an undrained
//!   event log.

use std::collections::{BTreeMap, HashMap};

/// One prefix-cache mutation, reported for the router's cache
/// directory: content `hash` became reusable (registered) or stopped
/// being reusable (evicted). Events are recorded only while
/// [`BlockManager::enable_cache_events`] is set and are drained in
/// order by [`BlockManager::take_cache_events`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheEvent {
    /// A full block of this content hash was registered into the cache.
    Registered {
        /// Chained content hash of the registered block.
        hash: u64,
    },
    /// The cached block of this content hash was reclaimed (LRU demand
    /// eviction or sliding-window eviction).
    Evicted {
        /// Chained content hash of the evicted block.
        hash: u64,
    },
    /// This content hash became serveable from the *pool tier* (a
    /// device block demoted its rows host-side, or a migration import
    /// adopted foreign rows into the pool). Still routable, but a hit
    /// pays a restore — the router's directory scores it at a discount.
    Demoted {
        /// Chained content hash now resident in the tiered pool.
        hash: u64,
    },
    /// A pooled hash was restored onto a device block at admission —
    /// back to full-price device residency for the directory.
    Restored {
        /// Chained content hash restored to the device cache.
        hash: u64,
    },
}

/// Outcome of an allocation request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Alloc {
    /// Allocation succeeded; the table is updated.
    Ok {
        /// Tokens of the content covered by prefix-cache hits at this
        /// admission (0 for [`BlockManager::append_token`] growth).
        /// Returned by the allocator so the scheduler budgets against
        /// *exactly* the hit the table honors — no separate probe walk.
        hit_tokens: usize,
        /// Tokens the table now covers: the admission fill (hit +
        /// first chunk, clamped by the caller's caps) or the grown
        /// context. The scheduler uses it verbatim as the chunk end.
        filled: usize,
    },
    /// Not enough free blocks now (caller may preempt and retry), the
    /// full content can never be admitted under the watermark, or a
    /// policy cap passed by the caller rejected the admission (a cold
    /// chunk with no compiled bucket, a legacy admission over the step
    /// budget).
    NoSpace,
}

/// One step of the admission walk: the block's content is serveable
/// either from a device-resident cached block (shared by refcount) or
/// from the tiered pool (restored into a fresh block at admission).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PrefixHit {
    /// Cached block id on device.
    Device(usize),
    /// Content hash resident in the tiered pool.
    Pooled(u64),
}

/// Seed of the block-content hash chain (arbitrary odd constant).
const HASH_SEED: u64 = 0x9e37_79b9_7f4a_7c15;

#[inline]
fn mix(mut h: u64) -> u64 {
    // splitmix64 finalizer
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^ (h >> 31)
}

/// Chained content hash of one full block given the previous block's
/// hash (or the fixed `HASH_SEED` for the first block).
pub fn block_hash(prev: u64, tokens: &[u32]) -> u64 {
    let mut h = mix(prev ^ 0x51_7e_ca_c4e);
    for &t in tokens {
        h = mix(h ^ t as u64);
    }
    h
}

/// Chained hashes of every *full* `block_size` block of `tokens`, from
/// the fixed seed — the exact chain [`BlockManager`] keys its cache
/// with. Free-function form so the router's cache directory can walk
/// the same chain without a block manager in hand.
pub fn chain_hashes(tokens: &[u32], block_size: usize) -> Vec<u64> {
    let mut h = HASH_SEED;
    (0..tokens.len() / block_size)
        .map(|i| {
            h = block_hash(h,
                           &tokens[i * block_size..(i + 1) * block_size]);
            h
        })
        .collect()
}

/// One physical block's bookkeeping.
#[derive(Debug, Clone, Default)]
struct Block {
    /// Number of sequence tables referencing this block.
    ref_count: usize,
    /// Content hash while this block holds cached (reusable) rows.
    hash: Option<u64>,
    /// Key into the evictable LRU while `ref_count == 0` and cached.
    lru_tick: u64,
}

/// Prefix-cache counters (block granularity unless noted).
#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    /// Cache hits: full prompt blocks reused instead of recomputed.
    pub hits: usize,
    /// Full prompt blocks that were looked up but not cached.
    pub misses: usize,
    /// Prompt tokens covered by hits across all admissions.
    pub hit_tokens: usize,
    /// Hits on blocks another live sequence still referenced — device
    /// blocks actually shared, i.e. pool memory saved.
    pub shared_blocks: usize,
    /// Cached blocks whose content was dropped to reclaim space.
    pub evictions: usize,
    /// Blocks registered into the cache after prefill.
    pub registered: usize,
    /// Evictions that demoted into the tiered pool instead of dropping
    /// content (a subset of `evictions`; 0 while tiering is off).
    pub demotions: usize,
    /// Admission hits served from the tiered pool: blocks restored to
    /// the device cache instead of recomputed.
    pub restores: usize,
}

/// Paged KV-block accounting for the simulated device pool (see the
/// module docs for the refcount / CoW / eviction rules).
#[derive(Debug, Clone)]
pub struct BlockManager {
    /// Tokens per block (paged accounting granularity).
    pub block_size: usize,
    /// Total blocks in the pool.
    pub total_blocks: usize,
    /// Per-block refcount/hash state, indexed by block id.
    blocks: Vec<Block>,
    /// Blocks holding no content (never used or fully freed); LIFO.
    free: Vec<usize>,
    /// Content hash -> block id, full blocks only (refcount may be 0).
    cache: HashMap<u64, usize>,
    /// Cached blocks with refcount 0, reclaimable LRU: tick -> block id.
    evictable: BTreeMap<u64, usize>,
    /// Sequence id -> physical block table.
    tables: HashMap<u64, Vec<usize>>,
    /// Monotonic counter ordering LRU entries.
    tick: u64,
    /// Cached `(block id, content hash)` pairs reclaimed since the last
    /// `take_evicted` (the engine drops — or, under tiering, demotes —
    /// the host KV rows it stashed for them).
    evicted: Vec<(usize, u64)>,
    /// Tiered-pool capacity in blocks (0 = tiering off: eviction drops
    /// content, the pre-pool behavior).
    kv_pool_blocks: usize,
    /// Pooled content hash -> its LRU tick. Disjoint from `cache` by
    /// construction: a hash lives on device *or* in the pool, never
    /// both.
    pool: HashMap<u64, u64>,
    /// Pool LRU order: tick -> pooled hash (shares the `tick` counter).
    pool_lru: BTreeMap<u64, u64>,
    /// Pooled hashes dropped (overflow, supersession, teardown) since
    /// the last `take_pool_dropped` — the engine frees their bytes.
    pool_dropped: Vec<u64>,
    /// `(block id, hash)` pairs restored from the pool at admission
    /// since the last `take_restored` — the engine moves the stashed
    /// rows back onto these device blocks.
    restored: Vec<(usize, u64)>,
    /// Blocks kept free as a scheduling watermark (headroom for decode
    /// growth of already-running sequences).
    pub watermark_blocks: usize,
    /// Hash-chain walks performed (admission probes + allocations).
    /// Observability for the single-walk admission contract: the
    /// scheduler property tests assert one walk per admission attempt.
    pub hash_walks: std::cell::Cell<u64>,
    /// Content-hash prefix caching on/off (off = the pre-cache manager).
    pub enable_prefix_caching: bool,
    /// Record [`CacheEvent`]s for registration/eviction (router cache
    /// directory feed). Off by default: without a consumer draining
    /// [`BlockManager::take_cache_events`] the log would only grow.
    pub enable_cache_events: bool,
    /// Undrained cache events, in mutation order.
    cache_events: Vec<CacheEvent>,
    /// Sliding-window high watermark on cached-but-unreferenced blocks
    /// (0 = window disabled). See the module docs.
    cache_high_watermark: usize,
    /// Sliding-window low watermark: once the window trips, evict
    /// oldest-first down to this count.
    cache_low_watermark: usize,
    /// Prefix-cache counters.
    pub stats: CacheStats,
}

impl BlockManager {
    /// A pool of `total_blocks` blocks of `block_size` tokens each.
    pub fn new(block_size: usize, total_blocks: usize) -> BlockManager {
        BlockManager {
            block_size,
            total_blocks,
            blocks: vec![Block::default(); total_blocks],
            // pop from the back: hand out low ids first
            free: (0..total_blocks).rev().collect(),
            cache: HashMap::new(),
            evictable: BTreeMap::new(),
            tables: HashMap::new(),
            tick: 0,
            evicted: vec![],
            kv_pool_blocks: 0,
            pool: HashMap::new(),
            pool_lru: BTreeMap::new(),
            pool_dropped: vec![],
            restored: vec![],
            watermark_blocks: (total_blocks / 100).max(1),
            hash_walks: std::cell::Cell::new(0),
            enable_prefix_caching: true,
            enable_cache_events: false,
            cache_events: vec![],
            cache_high_watermark: 0,
            cache_low_watermark: 0,
            stats: CacheStats::default(),
        }
    }

    /// Pool sized from a device memory budget: `(mem - weights) /
    /// (block_size * kv_bytes_per_token)`.
    pub fn from_memory(block_size: usize, mem_bytes: usize,
                       weight_bytes: usize, kv_bytes_per_token: usize)
        -> BlockManager {
        let free = mem_bytes.saturating_sub(weight_bytes);
        let per_block = block_size * kv_bytes_per_token;
        BlockManager::new(block_size, (free / per_block.max(1)).max(1))
    }

    /// Blocks needed to hold `tokens` token slots.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Free capacity: untouched blocks plus evictable cached blocks.
    pub fn free_blocks(&self) -> usize {
        self.free.len() + self.evictable.len()
    }
    /// Blocks currently referenced by at least one sequence table.
    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free_blocks()
    }
    /// Blocks held by sequence `id` (0 if not allocated).
    pub fn holds(&self, id: u64) -> usize {
        self.tables.get(&id).map_or(0, |t| t.len())
    }
    /// The sequence's physical block table (admitted sequences only).
    pub fn table(&self, id: u64) -> Option<&[usize]> {
        self.tables.get(&id).map(|t| &t[..])
    }
    /// Fraction of the pool referenced by live sequences.
    pub fn occupancy(&self) -> f64 {
        self.used_blocks() as f64 / self.total_blocks as f64
    }

    /// Chained hashes of every *full* block of `tokens`.
    fn hash_chain(&self, tokens: &[u32]) -> Vec<u64> {
        chain_hashes(tokens, self.block_size)
    }

    /// Configure the sliding eviction window on cached-but-unreferenced
    /// blocks: when their count exceeds `high`, the oldest-released are
    /// evicted until it is down to `low` (clamped to `high`). `high ==
    /// 0` disables the window. Takes effect at the next release.
    pub fn set_cache_watermarks(&mut self, high: usize, low: usize) {
        self.cache_high_watermark = high;
        self.cache_low_watermark = low.min(high);
    }

    /// Cached blocks currently referenced by no sequence — the
    /// population the sliding eviction window bounds.
    pub fn cached_unreferenced(&self) -> usize {
        self.evictable.len()
    }

    /// Drain the recorded [`CacheEvent`]s (registrations + evictions in
    /// mutation order). Empty unless
    /// [`BlockManager::enable_cache_events`] is set.
    pub fn take_cache_events(&mut self) -> Vec<CacheEvent> {
        std::mem::take(&mut self.cache_events)
    }

    /// The longest serveable prefix of `tokens`, capped so at least one
    /// token is always left to compute. Each covered block is either on
    /// device (a cached block to share) or in the tiered pool (a hash
    /// whose rows restore into a fresh block). This is *the* hash-chain
    /// walk: admission calls it exactly once per attempt (inside the
    /// allocate family), counted in `hash_walks`.
    fn prefix_hits(&self, tokens: &[u32]) -> Vec<PrefixHit> {
        if !self.enable_prefix_caching || tokens.len() <= 1 {
            return vec![];
        }
        self.hash_walks.set(self.hash_walks.get() + 1);
        let bs = self.block_size;
        let max_blocks = (tokens.len() - 1) / bs;
        let mut h = HASH_SEED;
        let mut out = vec![];
        for i in 0..max_blocks {
            h = block_hash(h, &tokens[i * bs..(i + 1) * bs]);
            if let Some(&b) = self.cache.get(&h) {
                out.push(PrefixHit::Device(b));
            } else if self.pool.contains_key(&h) {
                out.push(PrefixHit::Pooled(h));
            } else {
                break;
            }
        }
        out
    }

    /// Prompt tokens a cached prefix would cover for this content —
    /// device-cached and pool-restorable blocks both count (either way
    /// the prefill is skipped).
    pub fn cached_prefix_tokens(&self, tokens: &[u32]) -> usize {
        self.prefix_hits(tokens).len() * self.block_size
    }

    /// Device and evictable hit counts of a walk: pooled hits need a
    /// fresh block (charged like a miss), device hits with refcount 0
    /// must be rescued out of the evictable pool.
    fn walk_costs(&self, walk: &[PrefixHit]) -> (usize, usize) {
        let mut device = 0;
        let mut evictable = 0;
        for hit in walk {
            if let PrefixHit::Device(b) = *hit {
                device += 1;
                if self.blocks[b].ref_count == 0 {
                    evictable += 1;
                }
            }
        }
        (device, evictable)
    }

    /// Free-pool consumption of admitting `tokens`: fresh blocks
    /// (including blocks restored from the tiered pool) plus hits that
    /// must be rescued from the evictable pool.
    fn admission_cost(&self, tokens: &[u32]) -> usize {
        let walk = self.prefix_hits(tokens);
        let (device, evictable) = self.walk_costs(&walk);
        self.blocks_for(tokens.len()) - device + evictable
    }

    /// Can a *new* sequence of this content be admitted (leaving the
    /// watermark)?
    pub fn can_admit(&self, tokens: &[u32]) -> bool {
        self.admission_cost(tokens) + self.watermark_blocks
            <= self.free_blocks()
    }

    /// Evict the least-recently-released cached block: drop its content
    /// from the cache, report it (`(id, hash)` via `evicted`, hash via
    /// a [`CacheEvent`] or a pool demotion), and return its id. `None`
    /// when nothing is evictable. The caller decides whether the block
    /// is reused directly (demand eviction) or returned to the free
    /// list (sliding-window eviction). With tiering on, the hash
    /// demotes into the pool — still serveable, so *no* `Evicted`
    /// event; otherwise the content is forgotten and the event fires.
    fn evict_lru(&mut self) -> Option<usize> {
        let (&tick, &b) = self.evictable.iter().next()?;
        self.evictable.remove(&tick);
        let h = self.blocks[b].hash.take()
            // sqlint: allow(panic) evictable entries always point at cached blocks (eviction invariant)
            .expect("evictable blocks are cached");
        self.cache.remove(&h);
        if self.kv_pool_blocks > 0 {
            self.demote(h);
        } else if self.enable_cache_events {
            self.cache_events.push(CacheEvent::Evicted { hash: h });
        }
        self.stats.evictions += 1;
        self.evicted.push((b, h));
        Some(b)
    }

    /// Remove `h` from the pool index (both maps). False if not pooled.
    fn pool_remove(&mut self, h: u64) -> bool {
        match self.pool.remove(&h) {
            Some(t) => {
                self.pool_lru.remove(&t);
                true
            }
            None => false,
        }
    }

    /// Drop the least-recently-demoted pooled hash: report it via
    /// `pool_dropped` (the engine frees its bytes) and as an `Evicted`
    /// event — this is where pooled content truly stops being
    /// serveable.
    fn drop_pool_oldest(&mut self) -> Option<u64> {
        let (&t, &h) = self.pool_lru.iter().next()?;
        self.pool_lru.remove(&t);
        self.pool.remove(&h);
        self.pool_dropped.push(h);
        if self.enable_cache_events {
            self.cache_events.push(CacheEvent::Evicted { hash: h });
        }
        Some(h)
    }

    /// Demote an evicted hash into the tiered pool, bounding the pool
    /// by dropping oldest-first on overflow.
    fn demote(&mut self, h: u64) {
        // a stale pooled copy of this content (recomputed, registered,
        // evicted again) is simply superseded — the engine overwrites
        // the bytes when it processes the eviction
        self.pool_remove(h);
        self.tick += 1;
        self.pool.insert(h, self.tick);
        self.pool_lru.insert(self.tick, h);
        self.stats.demotions += 1;
        if self.enable_cache_events {
            self.cache_events.push(CacheEvent::Demoted { hash: h });
        }
        while self.pool.len() > self.kv_pool_blocks {
            self.drop_pool_oldest();
        }
    }

    /// Device-cache lookup by content hash — read-only: no refcount,
    /// LRU, or event side effects. This is the donor side of KV
    /// migration peeking at what it could export.
    pub fn lookup_hash(&self, h: u64) -> Option<usize> {
        self.cache.get(&h).copied()
    }

    /// Is this content hash resident in the tiered pool? Read-only —
    /// the pool LRU order is not refreshed.
    pub fn pool_contains(&self, h: u64) -> bool {
        self.pool.contains_key(&h)
    }

    /// Adopt a *foreign* content hash into the tiered pool — the
    /// receiver side of KV migration. The engine must already hold (or
    /// be about to store) the stashed rows for `h`. Refused (`false`)
    /// when tiering is off or the hash is already serveable from either
    /// tier; on success the adoption is announced as a
    /// [`CacheEvent::Demoted`] (pool-tier residency) so the router's
    /// directory learns the warmth moved, and the pool bound is
    /// enforced oldest-first like any demotion.
    pub fn adopt_pooled(&mut self, h: u64) -> bool {
        if self.kv_pool_blocks == 0
            || self.cache.contains_key(&h)
            || self.pool.contains_key(&h)
        {
            return false;
        }
        self.tick += 1;
        self.pool.insert(h, self.tick);
        self.pool_lru.insert(self.tick, h);
        if self.enable_cache_events {
            self.cache_events.push(CacheEvent::Demoted { hash: h });
        }
        while self.pool.len() > self.kv_pool_blocks {
            self.drop_pool_oldest();
        }
        true
    }

    /// Pop a content-free block, evicting the LRU cached block if the
    /// free list is dry. `None` only when the whole pool is referenced.
    fn grab_free_block(&mut self) -> Option<usize> {
        if let Some(b) = self.free.pop() {
            return Some(b);
        }
        self.evict_lru()
    }

    /// Sliding-window enforcement (see module docs): if the evictable
    /// population exceeds the high watermark, evict oldest-first down
    /// to the low watermark, returning the freed blocks to the free
    /// list. No-op while the window is disabled (`high == 0`).
    fn enforce_cache_window(&mut self) {
        if self.cache_high_watermark == 0
            || self.evictable.len() <= self.cache_high_watermark
        {
            return;
        }
        while self.evictable.len() > self.cache_low_watermark {
            let Some(b) = self.evict_lru() else { break };
            self.free.push(b);
        }
    }

    /// Allocate blocks for a newly admitted sequence covering its whole
    /// content, reusing cached prefix blocks. Returns the hit it
    /// honored in `Alloc::Ok` (no caller-side probe walk needed).
    pub fn allocate(&mut self, id: u64, tokens: &[u32]) -> Alloc {
        self.allocate_full(id, tokens, usize::MAX, usize::MAX)
    }

    /// Whole-content admission (the legacy unchunked policy), policy
    /// caps folded in so admission is **one hash-chain walk**:
    ///
    /// * `max_uncached` — reject (`NoSpace`) if more than this many
    ///   tokens would need computing (`len - hit`); the scheduler's
    ///   step token budget.
    /// * `cold_cap` — reject a *cold* admission (no cache hit) longer
    ///   than this; the scheduler's largest-fitting-prefill-bucket cap.
    ///
    /// On success the table covers the full content and `Alloc::Ok`
    /// carries the hit the caps were evaluated against.
    pub fn allocate_full(&mut self, id: u64, tokens: &[u32],
                         max_uncached: usize, cold_cap: usize) -> Alloc {
        let hits = self.prefix_hits(tokens);
        let hit = hits.len() * self.block_size;
        if hit == 0 && tokens.len() > cold_cap {
            return Alloc::NoSpace;
        }
        if tokens.len() - hit > max_uncached {
            return Alloc::NoSpace;
        }
        self.admit(id, tokens, hits, tokens.len())
    }

    /// Admission for chunked prefill in **one hash-chain walk**: the
    /// *capacity check* covers the sequence's full content (so a
    /// sequence that can never fit blocks the queue head under FCFS
    /// instead of admit/preempt thrashing), but the table physically
    /// allocated covers only the cached-prefix hits plus fresh blocks
    /// for the first chunk:
    ///
    /// * hit > 0 (warm): the chunk spans `hit .. hit + min(budget,
    ///   warm_cap)` clamped to the content length;
    /// * hit == 0 (cold): it spans `0 .. min(budget, cold_cap)`
    ///   clamped likewise, and `cold_cap == 0` rejects the admission
    ///   outright (no compiled prefill bucket can take one more cold
    ///   chunk this step).
    ///
    /// `Alloc::Ok` returns both the hit and the fill, so the hit the
    /// scheduler budgets against and the chunk bounds the engine
    /// executes are by construction the ones the allocator honored.
    /// Later chunks and decode growth extend the table via
    /// [`BlockManager::append_token`].
    pub fn allocate_chunked(&mut self, id: u64, tokens: &[u32],
                            budget: usize, cold_cap: usize,
                            warm_cap: usize) -> Alloc {
        let hits = self.prefix_hits(tokens);
        let hit = hits.len() * self.block_size;
        debug_assert!(hit < tokens.len());
        let fill = if hit == 0 {
            tokens.len().min(budget).min(cold_cap)
        } else {
            tokens.len().min(hit.saturating_add(budget.min(warm_cap)))
        };
        if fill <= hit {
            return Alloc::NoSpace; // cold_cap 0, or no budget at all
        }
        self.admit(id, tokens, hits, fill)
    }

    /// Post-walk admission shared by the allocate family: capacity-check
    /// the *full* content, then record a table of the walk's hits
    /// (device hits shared by refcount, pooled hits restored into fresh
    /// blocks) plus fresh private blocks through `fill`.
    fn admit(&mut self, id: u64, tokens: &[u32], walk: Vec<PrefixHit>,
             fill: usize) -> Alloc {
        assert!(!self.tables.contains_key(&id),
                "seq {id} already allocated");
        debug_assert!(fill <= tokens.len());
        let need = self.blocks_for(tokens.len());
        let (device_hits, evictable_hits) = self.walk_costs(&walk);
        if need - device_hits + evictable_hits + self.watermark_blocks
            > self.free_blocks()
        {
            return Alloc::NoSpace;
        }
        let hit_tokens = walk.len() * self.block_size;
        if self.enable_prefix_caching {
            self.stats.hits += walk.len();
            self.stats.hit_tokens += hit_tokens;
            self.stats.misses += tokens.len() / self.block_size
                - walk.len();
        }
        // reserve pooled hits out of the pool index up front: the block
        // grabs below can demote other blocks and overflow the pool,
        // which must never drop a hit this admission is about to
        // restore
        for hit in &walk {
            if let PrefixHit::Pooled(h) = *hit {
                let reserved = self.pool_remove(h);
                debug_assert!(reserved, "walk saw {h} in the pool");
            }
        }
        // pass 1: pin every device hit before any fresh grab, so a
        // demand eviction triggered by a pooled/fresh grab can never
        // reclaim a hit sitting later in the walk
        for hit in &walk {
            if let PrefixHit::Device(b) = *hit {
                if self.blocks[b].ref_count == 0 {
                    self.evictable.remove(&self.blocks[b].lru_tick);
                } else {
                    self.stats.shared_blocks += 1;
                }
                self.blocks[b].ref_count += 1;
            }
        }
        // pass 2: the table in walk order; a pooled hit re-enters the
        // device cache on a fresh block and is reported via
        // `take_restored` so the engine moves the stashed rows back. No
        // Registered event: the hash never left the directory.
        let now = self.blocks_for(fill).max(walk.len());
        let mut table = Vec::with_capacity(now);
        for hit in &walk {
            match *hit {
                PrefixHit::Device(b) => table.push(b),
                PrefixHit::Pooled(h) => {
                    let b = self.grab_free_block()
                        // sqlint: allow(panic) free-block accounting: can_allocate checked this same step
                        .expect("free-block accounting");
                    self.blocks[b].ref_count = 1;
                    debug_assert!(self.blocks[b].hash.is_none());
                    self.blocks[b].hash = Some(h);
                    self.cache.insert(h, b);
                    self.stats.restores += 1;
                    self.restored.push((b, h));
                    if self.enable_cache_events {
                        self.cache_events
                            .push(CacheEvent::Restored { hash: h });
                    }
                    table.push(b);
                }
            }
        }
        for _ in walk.len()..now {
            // sqlint: allow(panic) free-block accounting: can_allocate checked this same step
            let b = self.grab_free_block().expect("free-block accounting");
            self.blocks[b].ref_count = 1;
            debug_assert!(self.blocks[b].hash.is_none());
            table.push(b);
        }
        self.tables.insert(id, table);
        Alloc::Ok { hit_tokens, filled: fill }
    }

    /// Grow an allocated sequence's table to cover `new_context` tokens
    /// (decode growth by one, or the next prefill chunk of a partially
    /// filled sequence); newly grabbed blocks are always private.
    pub fn append_token(&mut self, id: u64, new_context: usize) -> Alloc {
        // sqlint: allow(panic) allocate() inserted this sequence's table
        let held = self.tables.get(&id).expect("seq not allocated").len();
        let need = self.blocks_for(new_context);
        let grown = Alloc::Ok { hit_tokens: 0, filled: new_context };
        if need <= held {
            return grown;
        }
        let extra = need - held;
        if extra > self.free_blocks() {
            return Alloc::NoSpace;
        }
        let mut grabbed = Vec::with_capacity(extra);
        for _ in 0..extra {
            // sqlint: allow(panic) free-block accounting: can_append checked this same step
            let b = self.grab_free_block().expect("free-block accounting");
            self.blocks[b].ref_count = 1;
            grabbed.push(b);
        }
        // sqlint: allow(panic) allocate() inserted this sequence's table
        self.tables.get_mut(&id).unwrap().extend(grabbed);
        grown
    }

    /// Release everything a sequence holds (finish or preemption).
    /// Shared blocks stay allocated while another sequence references
    /// them; cached blocks dropping to refcount 0 become evictable but
    /// keep their content for future hits.
    pub fn release(&mut self, id: u64) {
        let Some(table) = self.tables.remove(&id) else { return };
        for b in table {
            let blk = &mut self.blocks[b];
            assert!(blk.ref_count > 0, "double free of block {b}");
            blk.ref_count -= 1;
            if blk.ref_count > 0 {
                continue;
            }
            if blk.hash.is_some() {
                self.tick += 1;
                blk.lru_tick = self.tick;
                self.evictable.insert(self.tick, b);
            } else {
                self.free.push(b);
            }
        }
        // releases are the only place the evictable population grows,
        // so the sliding window is enforced exactly here
        self.enforce_cache_window();
        debug_assert!(self.free_blocks() <= self.total_blocks);
    }

    /// Register the full blocks of an admitted sequence's content into
    /// the cache (the engine calls this right after their KV rows are
    /// built). Returns `(block_index, block_id)` for *newly* cached
    /// blocks so the caller can stash their KV rows.
    pub fn register_prefix(&mut self, id: u64, tokens: &[u32])
        -> Vec<(usize, usize)> {
        if !self.enable_prefix_caching {
            return vec![];
        }
        let Some(table) = self.tables.get(&id) else { return vec![] };
        let hashes = self.hash_chain(tokens);
        // content can outgrow the table when growth was denied
        // (append_token returned NoSpace but the sequence kept its
        // tokens); only blocks the table physically covers are
        // registrable
        let covered = hashes.len().min(table.len());
        let mut newly = vec![];
        for (i, &h) in hashes[..covered].iter().enumerate() {
            let b = table[i];
            if self.blocks[b].hash.is_some() {
                continue; // already cached (a hit or earlier register)
            }
            if self.cache.contains_key(&h) {
                continue; // another block owns this content
            }
            newly.push((i, b));
        }
        for &(i, b) in &newly {
            // a pool-resident copy of this content is stale the moment
            // the device rows are registered (the walk stopped short of
            // the pooled entry and the sequence recomputed it):
            // supersede it so a hash is never serveable from two tiers
            if self.pool_remove(hashes[i]) {
                self.pool_dropped.push(hashes[i]);
            }
            self.blocks[b].hash = Some(hashes[i]);
            self.cache.insert(hashes[i], b);
            self.stats.registered += 1;
            if self.enable_cache_events {
                self.cache_events
                    .push(CacheEvent::Registered { hash: hashes[i] });
            }
        }
        newly
    }

    /// Cached `(block id, content hash)` pairs reclaimed since the last
    /// call. The engine drops the host KV rows it stashed for them —
    /// or, when the hash was demoted (tiering on), moves the stash into
    /// its pool keyed by the hash.
    pub fn take_evicted(&mut self) -> Vec<(usize, u64)> {
        std::mem::take(&mut self.evicted)
    }

    /// Pooled hashes dropped since the last call (pool overflow,
    /// supersession by a recomputed device copy, or teardown). The
    /// engine frees the pooled bytes for these.
    pub fn take_pool_dropped(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.pool_dropped)
    }

    /// `(block id, content hash)` pairs restored from the tiered pool
    /// at admission since the last call. The engine moves the pooled
    /// stash back under the device block id (dequantize happens lazily
    /// at first use).
    pub fn take_restored(&mut self) -> Vec<(usize, u64)> {
        std::mem::take(&mut self.restored)
    }

    /// Configure the tiered demotion pool: evictions demote their
    /// content hash into a pool of at most `blocks` entries (LRU,
    /// oldest dropped on overflow) instead of forgetting it. `0`
    /// disables tiering and drops any pooled entries immediately.
    /// Shrinking the bound drops overflow oldest-first.
    pub fn set_kv_pool(&mut self, blocks: usize) {
        self.kv_pool_blocks = blocks;
        while self.pool.len() > blocks {
            self.drop_pool_oldest();
        }
    }

    /// Entries currently in the tiered pool (≤ the configured bound).
    pub fn kv_pool_len(&self) -> usize {
        self.pool.len()
    }

    /// Drop the entire evictable prefix cache *and* the tiered pool
    /// (replica teardown): every cached-but-unreferenced block is
    /// evicted back onto the free list and every pooled hash is
    /// dropped, emitting the usual eviction events/ids — demotion is
    /// suppressed so teardown forgets content outright (a killed
    /// replica's pool must not be restorable). Blocks still referenced
    /// by live sequences are untouched, so call this after releasing
    /// every sequence for a fully free pool. Returns the number of
    /// device blocks reclaimed.
    pub fn clear_cache(&mut self) -> usize {
        let bound = self.kv_pool_blocks;
        self.kv_pool_blocks = 0; // suppress demotion during teardown
        let mut n = 0;
        while let Some(b) = self.evict_lru() {
            self.free.push(b);
            n += 1;
        }
        self.kv_pool_blocks = bound;
        while self.drop_pool_oldest().is_some() {}
        n
    }

    /// Invariant check: every block is in exactly one of {free,
    /// evictable, referenced}; stored refcounts match the tables; the
    /// cache map and per-block hashes agree.
    pub fn check_conservation(&self) -> bool {
        let mut rc = vec![0usize; self.total_blocks];
        // sqlint: allow(determinism) commutative refcount accumulation; order cannot change the result
        for t in self.tables.values() {
            for &b in t {
                rc[b] += 1;
            }
        }
        if (0..self.total_blocks)
            .any(|b| rc[b] != self.blocks[b].ref_count)
        {
            return false;
        }
        let mut seen = vec![false; self.total_blocks];
        for &b in &self.free {
            if seen[b] || self.blocks[b].hash.is_some() {
                return false;
            }
            seen[b] = true;
        }
        for (&t, &b) in &self.evictable {
            if seen[b]
                || self.blocks[b].hash.is_none()
                || self.blocks[b].lru_tick != t
            {
                return false;
            }
            seen[b] = true;
        }
        for b in 0..self.total_blocks {
            if rc[b] > 0 {
                if seen[b] {
                    return false;
                }
                seen[b] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return false;
        }
        // tiered-pool invariants: index maps mirror each other, the
        // bound holds (and an unset bound means an empty pool), and no
        // hash is serveable from two tiers at once
        if self.pool.len() != self.pool_lru.len()
            || self
                .pool
                .iter()
                .any(|(&h, &t)| self.pool_lru.get(&t) != Some(&h))
        {
            return false;
        }
        if self.pool.len() > self.kv_pool_blocks {
            return false;
        }
        if self.pool.keys().any(|h| self.cache.contains_key(h)) {
            return false;
        }
        self.cache.iter().all(|(&h, &b)| self.blocks[b].hash == Some(h))
            && self.blocks.iter().enumerate().all(|(b, blk)| {
                blk.hash
                    .map_or(true, |h| self.cache.get(&h) == Some(&b))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn toks(seed: u32, n: usize) -> Vec<u32> {
        (0..n as u32).map(|t| seed.wrapping_mul(97) + t).collect()
    }

    #[test]
    fn allocate_release_roundtrip() {
        let mut bm = BlockManager::new(16, 10);
        bm.watermark_blocks = 1;
        // 3 blocks, no cache hit, whole content filled
        assert_eq!(bm.allocate(1, &toks(1, 40)),
                   Alloc::Ok { hit_tokens: 0, filled: 40 });
        assert_eq!(bm.holds(1), 3);
        assert_eq!(bm.free_blocks(), 7);
        bm.release(1);
        assert_eq!(bm.free_blocks(), 10);
        assert!(bm.check_conservation());
    }

    #[test]
    fn watermark_blocks_admission() {
        let mut bm = BlockManager::new(16, 4);
        bm.watermark_blocks = 1;
        assert!(bm.can_admit(&toks(1, 48))); // 3 + 1 watermark = 4 <= 4
        assert!(!bm.can_admit(&toks(1, 64))); // 4 + 1 > 4
        assert_eq!(bm.allocate(1, &toks(1, 64)), Alloc::NoSpace);
        assert!(matches!(bm.allocate(1, &toks(1, 48)), Alloc::Ok { .. }));
    }

    #[test]
    fn allocate_full_policy_caps_reject_in_one_walk() {
        let mut bm = BlockManager::new(4, 16);
        bm.watermark_blocks = 0;
        let p = toks(4, 12);
        // cold admission longer than the cold cap is rejected
        assert_eq!(bm.allocate_full(1, &p, usize::MAX, 8), Alloc::NoSpace);
        assert_eq!(bm.holds(1), 0);
        // a warm admission ignores the cold cap and charges only the
        // uncached tokens against max_uncached
        assert!(matches!(bm.allocate_full(1, &p, usize::MAX, usize::MAX),
                         Alloc::Ok { .. }));
        bm.register_prefix(1, &p);
        bm.release(1);
        // hit = 8 (2 of 3 blocks; lookup never covers the whole
        // content), so 4 uncached tokens: budget 3 rejects, 4 admits
        assert_eq!(bm.allocate_full(2, &p, 3, 0), Alloc::NoSpace);
        assert_eq!(bm.allocate_full(2, &p, 4, 0),
                   Alloc::Ok { hit_tokens: 8, filled: 12 });
        assert!(bm.check_conservation());
    }

    #[test]
    fn append_grows_at_block_boundary() {
        let mut bm = BlockManager::new(4, 10);
        bm.watermark_blocks = 0;
        bm.allocate(1, &toks(1, 4)); // exactly 1 block
        assert_eq!(bm.holds(1), 1);
        // growth needs a 2nd block; Ok echoes the grown context
        assert_eq!(bm.append_token(1, 5),
                   Alloc::Ok { hit_tokens: 0, filled: 5 });
        assert_eq!(bm.holds(1), 2);
        assert_eq!(bm.append_token(1, 6),
                   Alloc::Ok { hit_tokens: 0, filled: 6 });
        assert_eq!(bm.holds(1), 2); // still 2 blocks
    }

    #[test]
    fn append_fails_when_exhausted() {
        let mut bm = BlockManager::new(4, 2);
        bm.watermark_blocks = 0;
        bm.allocate(1, &toks(1, 8)); // both blocks
        assert_eq!(bm.append_token(1, 9), Alloc::NoSpace);
        assert!(bm.check_conservation());
    }

    #[test]
    fn chunked_allocation_grows_per_chunk() {
        let mut bm = BlockManager::new(4, 10);
        bm.watermark_blocks = 0;
        let p = toks(3, 20); // 5 blocks total
        // admit with a 6-token chunk budget: covers 2 blocks, no hit
        assert_eq!(bm.allocate_chunked(1, &p, 6, usize::MAX, usize::MAX),
                   Alloc::Ok { hit_tokens: 0, filled: 6 });
        assert_eq!(bm.holds(1), 2);
        assert_eq!(bm.free_blocks(), 8);
        // next chunk to 14 tokens -> 4 blocks
        assert!(matches!(bm.append_token(1, 14), Alloc::Ok { .. }));
        assert_eq!(bm.holds(1), 4);
        // final chunk to the full 20 -> 5 blocks
        assert!(matches!(bm.append_token(1, 20), Alloc::Ok { .. }));
        assert_eq!(bm.holds(1), 5);
        assert!(bm.check_conservation());
        // preempt-while-partially-filled path: plain release
        bm.release(1);
        assert_eq!(bm.free_blocks(), 10);
        assert!(bm.check_conservation());
    }

    #[test]
    fn chunked_admission_checks_full_content_capacity() {
        // pool of 3 blocks: a 5-block sequence must NOT admit even with
        // a 1-block first chunk (it could never finish; FCFS head rule)
        let mut bm = BlockManager::new(4, 3);
        bm.watermark_blocks = 0;
        let p = toks(1, 20);
        assert_eq!(bm.allocate_chunked(1, &p, 4, usize::MAX, usize::MAX),
                   Alloc::NoSpace);
        assert_eq!(bm.holds(1), 0);
        assert!(bm.check_conservation());
    }

    #[test]
    fn chunked_allocation_reuses_cached_prefix() {
        let mut bm = BlockManager::new(4, 16);
        bm.watermark_blocks = 0;
        let p = toks(7, 16); // 4 blocks
        bm.allocate(1, &p);
        assert_eq!(bm.register_prefix(1, &p).len(), 4);
        // hit covers 3 blocks (lookup never covers the whole content);
        // a 2-token chunk budget past the hit fills to 14 -> 4 blocks:
        // 3 shared + 1 fresh — and Ok reports hit and fill together
        assert_eq!(bm.cached_prefix_tokens(&p), 12);
        let before = bm.free_blocks();
        assert_eq!(bm.allocate_chunked(2, &p, 2, usize::MAX, usize::MAX),
                   Alloc::Ok { hit_tokens: 12, filled: 14 });
        assert_eq!(bm.holds(2), 4);
        assert_eq!(bm.free_blocks(), before - 1);
        assert_eq!(bm.table(1).unwrap()[..3], bm.table(2).unwrap()[..3]);
        assert!(bm.check_conservation());
        bm.release(1);
        bm.release(2);
        assert!(bm.check_conservation());
    }

    #[test]
    fn from_memory_budget() {
        // 100 MB pool, 60 MB weights, 1 KB/token, block 16 -> 2560 blocks
        let bm = BlockManager::from_memory(16, 100 << 20, 60 << 20, 1024);
        assert_eq!(bm.total_blocks, (40 << 20) / (16 * 1024));
    }

    #[test]
    fn prefix_hit_shares_blocks_and_counts() {
        let mut bm = BlockManager::new(4, 16);
        bm.watermark_blocks = 0;
        let p = toks(7, 10); // 2 full blocks + partial
        assert_eq!(bm.allocate(1, &p),
                   Alloc::Ok { hit_tokens: 0, filled: 10 });
        assert_eq!(bm.cached_prefix_tokens(&p), 0); // nothing registered
        let newly = bm.register_prefix(1, &p);
        assert_eq!(newly.len(), 2); // both full blocks cached
        // identical content while seq 1 is still live: shared blocks —
        // and the allocator reports the hit it honored
        assert_eq!(bm.cached_prefix_tokens(&p), 8);
        let before = bm.free_blocks();
        assert_eq!(bm.allocate(2, &p),
                   Alloc::Ok { hit_tokens: 8, filled: 10 });
        // only the private tail block was newly consumed
        assert_eq!(bm.free_blocks(), before - 1);
        assert_eq!(bm.stats.hits, 2);
        assert_eq!(bm.stats.shared_blocks, 2);
        assert_eq!(bm.table(1).unwrap()[..2], bm.table(2).unwrap()[..2]);
        assert_ne!(bm.table(1).unwrap()[2], bm.table(2).unwrap()[2]);
        assert!(bm.check_conservation());
        // releasing one sharer keeps the other's blocks allocated
        bm.release(1);
        assert!(bm.check_conservation());
        assert_eq!(bm.holds(2), 3);
        bm.release(2);
        assert!(bm.check_conservation());
        assert_eq!(bm.free_blocks(), 16); // evictable counts as free
    }

    #[test]
    fn full_prompt_hit_leaves_one_block_to_compute() {
        let mut bm = BlockManager::new(4, 16);
        bm.watermark_blocks = 0;
        let p = toks(3, 8); // exactly 2 full blocks
        bm.allocate(1, &p);
        bm.register_prefix(1, &p);
        // the whole prompt is cached, but the lookup is capped so the
        // final block is recomputed privately (CoW boundary)
        assert_eq!(bm.cached_prefix_tokens(&p), 4);
        bm.allocate(2, &p);
        assert_ne!(bm.table(1).unwrap()[1], bm.table(2).unwrap()[1]);
        assert!(bm.check_conservation());
    }

    #[test]
    fn lru_eviction_reclaims_oldest_and_reports() {
        let mut bm = BlockManager::new(4, 3);
        bm.watermark_blocks = 0;
        let a = toks(1, 4);
        let b = toks(2, 4);
        bm.allocate(1, &a);
        bm.register_prefix(1, &a);
        bm.release(1); // a's block cached + evictable (LRU oldest)
        bm.allocate(2, &b);
        bm.register_prefix(2, &b);
        bm.release(2); // b's block cached + evictable
        assert_eq!(bm.free_blocks(), 3);
        // a three-block allocation must reclaim both cached blocks
        assert!(matches!(bm.allocate(3, &toks(9, 12)), Alloc::Ok { .. }));
        let ev = bm.take_evicted();
        assert_eq!(ev.len(), 2);
        assert_eq!(bm.stats.evictions, 2);
        // probe with extended content: a lookup never covers the whole
        // query, so the probe must be longer than the cached block
        let probe = |p: &[u32]| {
            let mut q = p.to_vec();
            q.push(999);
            q
        };
        assert_eq!(bm.cached_prefix_tokens(&probe(&a)), 0); // dropped
        assert_eq!(bm.cached_prefix_tokens(&probe(&b)), 0);
        assert!(bm.take_evicted().is_empty());
        assert!(bm.check_conservation());
    }

    #[test]
    fn lru_prefers_least_recently_released() {
        let mut bm = BlockManager::new(4, 2);
        bm.watermark_blocks = 0;
        let a = toks(1, 4);
        let b = toks(2, 4);
        bm.allocate(1, &a);
        bm.register_prefix(1, &a);
        bm.allocate(2, &b);
        bm.register_prefix(2, &b);
        bm.release(2); // b released first -> LRU oldest
        bm.release(1);
        // one fresh block: must evict b's, keep a's (probes extended —
        // a lookup never covers its whole query)
        bm.allocate(3, &toks(9, 3));
        let (mut pa, mut pb) = (a.clone(), b.clone());
        pa.push(999);
        pb.push(999);
        assert_eq!(bm.cached_prefix_tokens(&pa), 4);
        assert_eq!(bm.cached_prefix_tokens(&pb), 0);
        assert!(bm.check_conservation());
    }

    #[test]
    fn disabled_caching_never_hits() {
        let mut bm = BlockManager::new(4, 8);
        bm.enable_prefix_caching = false;
        bm.watermark_blocks = 0;
        let p = toks(5, 8);
        bm.allocate(1, &p);
        assert!(bm.register_prefix(1, &p).is_empty());
        bm.release(1);
        assert_eq!(bm.cached_prefix_tokens(&p), 0);
        let before = bm.free_blocks();
        bm.allocate(2, &p);
        assert_eq!(bm.free_blocks(), before - 2);
        assert_eq!(bm.stats.hits, 0);
        assert!(bm.check_conservation());
    }

    #[test]
    fn hash_chain_is_positional() {
        // identical block content at different chain positions must not
        // collide (the chain mixes the prefix in)
        let h0 = block_hash(HASH_SEED, &[7, 7, 7, 7]);
        let h1 = block_hash(h0, &[7, 7, 7, 7]);
        assert_ne!(h0, h1);
        // and the chain is deterministic
        assert_eq!(h0, block_hash(HASH_SEED, &[7, 7, 7, 7]));
    }

    #[test]
    fn sliding_window_bounds_cached_unreferenced() {
        // high 2 / low 1: releasing a third cached block must evict the
        // oldest-released down to the low watermark, onto the free list
        let mut bm = BlockManager::new(4, 16);
        bm.watermark_blocks = 0;
        bm.set_cache_watermarks(2, 1);
        bm.enable_cache_events = true;
        let prompts: Vec<Vec<u32>> = (0..3).map(|i| toks(i, 4)).collect();
        for (i, p) in prompts.iter().enumerate() {
            let id = i as u64;
            assert!(matches!(bm.allocate(id, p), Alloc::Ok { .. }));
            bm.register_prefix(id, p);
            bm.release(id);
            assert!(bm.cached_unreferenced() <= 2,
                    "window exceeded: {}", bm.cached_unreferenced());
            assert!(bm.check_conservation());
        }
        // third release tripped the window: down to low = 1
        assert_eq!(bm.cached_unreferenced(), 1);
        assert_eq!(bm.stats.evictions, 2);
        assert_eq!(bm.take_evicted().len(), 2);
        // oldest-first: prompts 0 and 1 evicted, prompt 2 survives
        // (probes extended — a lookup never covers its whole query)
        let probe = |p: &[u32]| {
            let mut q = p.to_vec();
            q.push(999);
            q
        };
        assert_eq!(bm.cached_prefix_tokens(&probe(&prompts[0])), 0);
        assert_eq!(bm.cached_prefix_tokens(&probe(&prompts[1])), 0);
        assert_eq!(bm.cached_prefix_tokens(&probe(&prompts[2])), 4);
        // events: 3 registrations then 2 evictions, in order
        let ev = bm.take_cache_events();
        assert_eq!(ev.len(), 5);
        assert!(matches!(ev[0], CacheEvent::Registered { .. }));
        assert!(matches!(ev[3], CacheEvent::Evicted { .. }));
        let reg: Vec<u64> = ev[..3]
            .iter()
            .map(|e| match e {
                CacheEvent::Registered { hash } => *hash,
                _ => unreachable!(),
            })
            .collect();
        let evi: Vec<u64> = ev[3..]
            .iter()
            .map(|e| match e {
                CacheEvent::Evicted { hash } => *hash,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(evi, reg[..2].to_vec(), "evictions not oldest-first");
        assert!(bm.take_cache_events().is_empty());
        assert!(bm.check_conservation());
    }

    #[test]
    fn sliding_window_never_touches_refcounted_blocks() {
        // a shared (refcounted) prefix block is not in the evictable
        // window, so even a high watermark of 0-ish pressure from other
        // releases must not evict it
        let mut bm = BlockManager::new(4, 16);
        bm.watermark_blocks = 0;
        bm.set_cache_watermarks(1, 0);
        let shared = toks(9, 8); // 2 full blocks
        bm.allocate(0, &shared);
        bm.register_prefix(0, &shared);
        // seq 0 stays live: its 2 cached blocks are referenced
        for i in 1..4u64 {
            let p = toks(20 + i as u32, 4);
            assert!(matches!(bm.allocate(i, &p), Alloc::Ok { .. }));
            bm.register_prefix(i, &p);
            bm.release(i);
            assert!(bm.cached_unreferenced() <= 1);
        }
        // the shared content is still cached (probe past the CoW cap)
        let mut probe = shared.clone();
        probe.push(999);
        assert_eq!(bm.cached_prefix_tokens(&probe), 8);
        assert!(bm.check_conservation());
        bm.release(0);
        assert!(bm.check_conservation());
    }

    #[test]
    fn chain_hashes_matches_manager_chain() {
        let bm = BlockManager::new(4, 8);
        let p = toks(3, 13);
        assert_eq!(chain_hashes(&p, 4), bm.hash_chain(&p));
        assert_eq!(chain_hashes(&p, 4).len(), 3); // full blocks only
        assert!(chain_hashes(&p[..3], 4).is_empty());
    }

    #[test]
    fn demote_then_restore_roundtrip() {
        // pool of 2 device blocks + tiered pool: evicting a's block
        // demotes its hash, and re-admitting the same content restores
        // it (fresh block + take_restored) instead of recomputing
        let mut bm = BlockManager::new(4, 2);
        bm.watermark_blocks = 0;
        bm.set_kv_pool(4);
        bm.enable_cache_events = true;
        let a = toks(1, 4);
        bm.allocate(1, &a);
        bm.register_prefix(1, &a);
        bm.release(1);
        // demand eviction: a 2-block allocation reclaims a's block
        bm.allocate(2, &toks(2, 8));
        let ev = bm.take_evicted();
        assert_eq!(ev.len(), 1);
        assert_eq!(bm.kv_pool_len(), 1);
        assert_eq!(bm.stats.demotions, 1);
        // demotion keeps the hash serveable: the walk still covers it
        let mut probe = a.clone();
        probe.push(999);
        assert_eq!(bm.cached_prefix_tokens(&probe), 4);
        // no Evicted event fired — demotion announces pool residency
        // (Demoted) so the directory can discount it, never a drop
        let events = bm.take_cache_events();
        assert!(events
            .iter()
            .all(|e| matches!(e,
                CacheEvent::Registered { .. }
                | CacheEvent::Demoted { .. })));
        assert_eq!(events
            .iter()
            .filter(|e| matches!(e, CacheEvent::Demoted { .. }))
            .count(), 1);
        bm.release(2);
        // re-admit content starting with a: the pooled hash restores
        let r = bm.allocate(3, &probe);
        assert_eq!(r, Alloc::Ok { hit_tokens: 4, filled: 5 });
        let restored = bm.take_restored();
        assert_eq!(restored.len(), 1);
        assert_eq!(restored[0].1, ev[0].1, "hash must round-trip");
        assert_eq!(bm.stats.restores, 1);
        assert_eq!(bm.kv_pool_len(), 0);
        // the restore re-announced device residency
        assert_eq!(bm.take_cache_events(),
                   vec![CacheEvent::Restored { hash: ev[0].1 }]);
        assert!(bm.check_conservation());
        bm.release(3);
        assert!(bm.check_conservation());
    }

    #[test]
    fn pool_overflow_drops_oldest_and_reports() {
        let mut bm = BlockManager::new(4, 1);
        bm.watermark_blocks = 0;
        bm.set_kv_pool(2);
        bm.enable_cache_events = true;
        // cycle three contents through the single device block; each
        // admission demand-evicts the previous into the pool
        let prompts: Vec<Vec<u32>> = (0..3).map(|i| toks(i, 4)).collect();
        for (i, p) in prompts.iter().enumerate() {
            bm.allocate(i as u64, p);
            bm.register_prefix(i as u64, p);
            bm.release(i as u64);
            assert!(bm.kv_pool_len() <= 2);
            assert!(bm.check_conservation());
        }
        // evicting prompt 2's block (still cached on device) is not
        // needed — the pool holds prompts 0 and 1; force one more
        // demotion to overflow
        bm.allocate(9, &toks(9, 4));
        assert_eq!(bm.kv_pool_len(), 2, "bound holds");
        // prompt 0's hash was oldest: dropped and reported
        let dropped = bm.take_pool_dropped();
        assert_eq!(dropped.len(), 1);
        let mut probe = prompts[0].clone();
        probe.push(999);
        assert_eq!(bm.cached_prefix_tokens(&probe), 0, "truly gone");
        // the drop (and only the drop) fired an Evicted event
        let evicted: Vec<_> = bm
            .take_cache_events()
            .into_iter()
            .filter(|e| matches!(e, CacheEvent::Evicted { .. }))
            .collect();
        assert_eq!(evicted, vec![CacheEvent::Evicted { hash: dropped[0] }]);
        assert!(bm.check_conservation());
    }

    #[test]
    fn adopt_pooled_registers_foreign_hash_for_restore() {
        // the receiver side of KV migration: adopting a hash the
        // replica never computed makes the walk serve it like any
        // pooled hit, without touching refcounts or device blocks
        let mut bm = BlockManager::new(4, 4);
        bm.watermark_blocks = 0;
        bm.set_kv_pool(2);
        bm.enable_cache_events = true;
        let p = toks(5, 9); // 2 full blocks + partial
        let chain = chain_hashes(&p, 4);
        assert_eq!(bm.cached_prefix_tokens(&p), 0);
        for &h in &chain {
            assert!(bm.adopt_pooled(h));
            assert!(bm.pool_contains(h));
            assert!(bm.lookup_hash(h).is_none(), "pool tier only");
        }
        // double-adoption is refused; tiering-off adoption is refused
        assert!(!bm.adopt_pooled(chain[0]));
        assert_eq!(bm.kv_pool_len(), 2);
        // the adoption announced pool-tier residency per block
        let demoted = bm
            .take_cache_events()
            .into_iter()
            .filter(|e| matches!(e, CacheEvent::Demoted { .. }))
            .count();
        assert_eq!(demoted, 2);
        // admission restores both adopted blocks instead of recomputing
        assert_eq!(bm.cached_prefix_tokens(&p), 8);
        assert_eq!(bm.allocate(1, &p),
                   Alloc::Ok { hit_tokens: 8, filled: 9 });
        assert_eq!(bm.take_restored().len(), 2);
        assert_eq!(bm.kv_pool_len(), 0);
        assert!(bm.check_conservation());
        // adoption with tiering off is a no-op
        let mut off = BlockManager::new(4, 4);
        assert!(!off.adopt_pooled(chain[0]));
        assert_eq!(off.kv_pool_len(), 0);
    }

    #[test]
    fn clear_cache_drops_pool_without_demoting() {
        let mut bm = BlockManager::new(4, 4);
        bm.watermark_blocks = 0;
        bm.set_kv_pool(8);
        let (a, b) = (toks(1, 4), toks(2, 4));
        bm.allocate(1, &a);
        bm.register_prefix(1, &a);
        bm.release(1);
        bm.allocate(2, &b);
        bm.register_prefix(2, &b);
        bm.release(2);
        // demote both cached blocks via demand eviction (whole pool
        // grabbed), then release so the device pool is free again
        bm.allocate(3, &toks(7, 16));
        bm.release(3);
        assert_eq!(bm.kv_pool_len(), 2);
        // leave one cached-but-unreferenced block on device as well
        let c = toks(3, 4);
        bm.allocate(4, &c);
        bm.register_prefix(4, &c);
        bm.release(4);
        // teardown: the evictable block is freed WITHOUT demoting (so
        // exactly the two pooled hashes are dropped), the pool empties
        let n = bm.clear_cache();
        assert_eq!(n, 1);
        assert_eq!(bm.kv_pool_len(), 0);
        assert_eq!(bm.take_pool_dropped().len(), 2);
        assert_eq!(bm.free_blocks(), 4);
        let mut probe = a;
        probe.push(999);
        assert_eq!(bm.cached_prefix_tokens(&probe), 0,
                   "teardown must forget pooled content");
        assert!(bm.check_conservation());
    }

    #[test]
    fn register_supersedes_stale_pool_entry() {
        // content demoted to the pool, then recomputed (walk disabled so
        // admission doesn't restore it) and re-registered: the device
        // copy wins and the pooled copy is reported dropped
        let mut bm = BlockManager::new(4, 1);
        bm.watermark_blocks = 0;
        bm.set_kv_pool(4);
        let a = toks(1, 4);
        bm.allocate(1, &a);
        bm.register_prefix(1, &a);
        bm.release(1);
        bm.allocate(2, &toks(2, 4)); // demand-evicts a's block -> pool
        bm.release(2);
        assert_eq!(bm.kv_pool_len(), 1);
        bm.enable_prefix_caching = false; // force a blind recompute
        bm.allocate(3, &a);
        bm.enable_prefix_caching = true;
        bm.register_prefix(3, &a);
        // the stale pooled copy of a's hash was superseded
        assert_eq!(bm.kv_pool_len(), 0);
        assert_eq!(bm.take_pool_dropped().len(), 1);
        assert!(bm.check_conservation());
        bm.release(3);
        assert!(bm.check_conservation());
    }

    #[test]
    fn conservation_under_random_workload() {
        for enable in [false, true] {
            prop::check("block conservation", 25, |rng| {
                let bs = 1 + rng.below(8);
                let mut bm =
                    BlockManager::new(bs, 8 + rng.below(64));
                bm.enable_prefix_caching = enable;
                bm.watermark_blocks = rng.below(3);
                // sometimes run with a sliding eviction window on
                let high = rng.below(2) * (2 + rng.below(8));
                bm.set_cache_watermarks(high, high / 2);
                // ... and sometimes with a tiered demotion pool
                let pool = rng.below(2) * (1 + rng.below(8));
                bm.set_kv_pool(pool);
                // a small pool of shared prefixes to force hits
                let prefixes: Vec<Vec<u32>> = (0..3)
                    .map(|i| toks(i, bs * (1 + rng.below(3))))
                    .collect();
                let mut live: Vec<(u64, Vec<u32>)> = vec![];
                let mut next_id = 0u64;
                for _ in 0..200 {
                    match rng.below(4) {
                        0 => {
                            let mut p =
                                prefixes[rng.below(3)].clone();
                            p.extend(toks(
                                90 + next_id as u32,
                                1 + rng.below(2 * bs),
                            ));
                            if matches!(bm.allocate(next_id, &p),
                                        Alloc::Ok { .. }) {
                                live.push((next_id, p));
                            } else {
                                bm.release(next_id); // no-op: not held
                            }
                            next_id += 1;
                        }
                        1 => {
                            if !live.is_empty() {
                                let i = rng.below(live.len());
                                live[i].1.push(7);
                                let n = live[i].1.len();
                                let id = live[i].0;
                                let _ = bm.append_token(id, n);
                            }
                        }
                        2 => {
                            if !live.is_empty() {
                                let i = rng.below(live.len());
                                let (id, p) = &live[i];
                                bm.register_prefix(*id, p);
                            }
                        }
                        _ => {
                            if !live.is_empty() {
                                let i = rng.below(live.len());
                                let (id, _) = live.swap_remove(i);
                                bm.release(id);
                            }
                        }
                    }
                    assert!(bm.check_conservation(),
                            "conservation violated");
                    assert!(bm.free_blocks() <= bm.total_blocks);
                    if high > 0 {
                        assert!(bm.cached_unreferenced() <= high,
                                "sliding window exceeded");
                    }
                    assert!(bm.kv_pool_len() <= pool,
                            "tiered pool bound exceeded");
                    if rng.below(8) == 0 {
                        // engine-side drains happen at arbitrary times
                        bm.take_evicted();
                        bm.take_pool_dropped();
                        bm.take_restored();
                    }
                }
                // drain: refcounts return to zero, whole pool free
                for (id, _) in live {
                    bm.release(id);
                }
                assert!(bm.check_conservation());
                assert_eq!(bm.free_blocks(), bm.total_blocks);
                // teardown forgets pooled content too
                bm.clear_cache();
                assert_eq!(bm.kv_pool_len(), 0);
                assert!(bm.check_conservation());
            });
        }
    }
}
