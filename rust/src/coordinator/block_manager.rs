//! Paged KV-cache accounting (the vLLM block manager, simplified to what
//! this engine needs).
//!
//! Physical KV rows live host-side per sequence ([`crate::runtime::kv`]),
//! but *admission and preemption* are governed here: the simulated device
//! pool is divided into fixed-size blocks of `block_size` token slots;
//! a sequence owns ceil(context/block_size) blocks; allocation fails when
//! the pool (minus a watermark) is exhausted, which triggers scheduler
//! preemption — the same control loop vLLM runs, driven by the same
//! arithmetic the paper's memory argument uses (W4A16 frees ~3/4 of the
//! weight memory, so the pool is larger and batches grow).

use std::collections::HashMap;

/// Outcome of an allocation request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Alloc {
    Ok,
    /// Not enough free blocks now (caller may preempt and retry).
    NoSpace,
}

#[derive(Debug, Clone)]
pub struct BlockManager {
    pub block_size: usize,
    pub total_blocks: usize,
    free_blocks: usize,
    /// seq id -> blocks held.
    held: HashMap<u64, usize>,
    /// blocks kept free as a scheduling watermark (headroom for decode
    /// growth of already-running sequences).
    pub watermark_blocks: usize,
}

impl BlockManager {
    pub fn new(block_size: usize, total_blocks: usize) -> BlockManager {
        BlockManager {
            block_size,
            total_blocks,
            free_blocks: total_blocks,
            held: HashMap::new(),
            watermark_blocks: (total_blocks / 100).max(1),
        }
    }

    /// Pool sized from a device memory budget: `(mem - weights) /
    /// (block_size * kv_bytes_per_token)`.
    pub fn from_memory(block_size: usize, mem_bytes: usize,
                       weight_bytes: usize, kv_bytes_per_token: usize)
        -> BlockManager {
        let free = mem_bytes.saturating_sub(weight_bytes);
        let per_block = block_size * kv_bytes_per_token;
        BlockManager::new(block_size, (free / per_block.max(1)).max(1))
    }

    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    pub fn free_blocks(&self) -> usize {
        self.free_blocks
    }
    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free_blocks
    }
    pub fn holds(&self, id: u64) -> usize {
        self.held.get(&id).copied().unwrap_or(0)
    }
    pub fn occupancy(&self) -> f64 {
        self.used_blocks() as f64 / self.total_blocks as f64
    }

    /// Can a *new* sequence of `tokens` be admitted (leaving watermark)?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) + self.watermark_blocks <= self.free_blocks
    }

    /// Allocate blocks for a newly admitted sequence.
    pub fn allocate(&mut self, id: u64, tokens: usize) -> Alloc {
        assert!(!self.held.contains_key(&id), "seq {id} already allocated");
        let need = self.blocks_for(tokens);
        if need + self.watermark_blocks > self.free_blocks {
            return Alloc::NoSpace;
        }
        self.free_blocks -= need;
        self.held.insert(id, need);
        Alloc::Ok
    }

    /// Grow a running sequence by one token; may need one more block.
    pub fn append_token(&mut self, id: u64, new_context: usize) -> Alloc {
        let held = *self.held.get(&id).expect("seq not allocated");
        let need = self.blocks_for(new_context);
        if need <= held {
            return Alloc::Ok;
        }
        let extra = need - held;
        if extra > self.free_blocks {
            return Alloc::NoSpace;
        }
        self.free_blocks -= extra;
        self.held.insert(id, need);
        Alloc::Ok
    }

    /// Release everything a sequence holds (finish or preemption).
    pub fn release(&mut self, id: u64) {
        if let Some(n) = self.held.remove(&id) {
            self.free_blocks += n;
        }
        debug_assert!(self.free_blocks <= self.total_blocks);
    }

    /// Invariant check: free + Σheld == total.
    pub fn check_conservation(&self) -> bool {
        self.free_blocks + self.held.values().sum::<usize>()
            == self.total_blocks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn allocate_release_roundtrip() {
        let mut bm = BlockManager::new(16, 10);
        bm.watermark_blocks = 1;
        assert_eq!(bm.allocate(1, 40), Alloc::Ok); // 3 blocks
        assert_eq!(bm.holds(1), 3);
        assert_eq!(bm.free_blocks(), 7);
        bm.release(1);
        assert_eq!(bm.free_blocks(), 10);
        assert!(bm.check_conservation());
    }

    #[test]
    fn watermark_blocks_admission() {
        let mut bm = BlockManager::new(16, 4);
        bm.watermark_blocks = 1;
        assert!(bm.can_admit(48)); // 3 + 1 watermark = 4 <= 4
        assert!(!bm.can_admit(64)); // 4 + 1 > 4
        assert_eq!(bm.allocate(1, 64), Alloc::NoSpace);
        assert_eq!(bm.allocate(1, 48), Alloc::Ok);
    }

    #[test]
    fn append_grows_at_block_boundary() {
        let mut bm = BlockManager::new(4, 10);
        bm.watermark_blocks = 0;
        bm.allocate(1, 4); // exactly 1 block
        assert_eq!(bm.holds(1), 1);
        assert_eq!(bm.append_token(1, 5), Alloc::Ok); // needs 2nd block
        assert_eq!(bm.holds(1), 2);
        assert_eq!(bm.append_token(1, 6), Alloc::Ok); // still 2 blocks
        assert_eq!(bm.holds(1), 2);
    }

    #[test]
    fn append_fails_when_exhausted() {
        let mut bm = BlockManager::new(4, 2);
        bm.watermark_blocks = 0;
        bm.allocate(1, 8); // both blocks
        assert_eq!(bm.append_token(1, 9), Alloc::NoSpace);
        assert!(bm.check_conservation());
    }

    #[test]
    fn from_memory_budget() {
        // 100 MB pool, 60 MB weights, 1 KB/token, block 16 -> 2560 blocks
        let bm = BlockManager::from_memory(16, 100 << 20, 60 << 20, 1024);
        assert_eq!(bm.total_blocks, (40 << 20) / (16 * 1024));
    }

    #[test]
    fn conservation_under_random_workload() {
        prop::check("block conservation", 30, |rng| {
            let mut bm = BlockManager::new(1 + rng.below(8),
                                           8 + rng.below(64));
            bm.watermark_blocks = rng.below(3);
            let mut live: Vec<(u64, usize)> = vec![];
            let mut next_id = 0u64;
            for _ in 0..200 {
                match rng.below(3) {
                    0 => {
                        let toks = 1 + rng.below(40);
                        if bm.allocate(next_id, toks) == Alloc::Ok {
                            live.push((next_id, toks));
                        } else {
                            bm.release(next_id); // no-op: not held
                        }
                        next_id += 1;
                    }
                    1 => {
                        if !live.is_empty() {
                            let i = rng.below(live.len());
                            let (id, ref mut t) = live[i];
                            *t += 1;
                            let t = *t;
                            let _ = bm.append_token(id, t);
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let i = rng.below(live.len());
                            let (id, _) = live.swap_remove(i);
                            bm.release(id);
                        }
                    }
                }
                assert!(bm.check_conservation(), "conservation violated");
                assert!(bm.free_blocks() <= bm.total_blocks);
            }
        });
    }
}
