//! The `Replica` abstraction: one complete serving engine (scheduler +
//! block manager + runtime + metrics) behind the narrow interface the
//! multi-replica [`super::router`] drives.
//!
//! [`ReplicaCore`] is the contract: submit requests, step, drain
//! finished sequences and prefix-cache events, report load and stats.
//! [`Engine`] is the production core; the router property tests
//! implement the same trait over a deterministic fake model (scheduler
//! + block manager only, no PJRT runtime), which is what makes the
//! whole multi-replica stack testable in tier-1 CI without artifacts.
//!
//! [`Replica`] wraps a core with its replica id and the router-side
//! accounting (requests routed here), and snapshots [`ReplicaStats`]
//! for the server's `{"cmd":"stats"}` admin endpoint and the router
//! bench.

use anyhow::Result;

use crate::config::CacheWatermarks;

use super::block_manager::{CacheEvent, CacheStats};
use super::engine::Engine;
use super::sequence::{SamplingParams, Sequence};

/// Point-in-time counters of one replica core (everything the routing
/// policies and the stats endpoint need, cheap enough to snapshot per
/// request).
#[derive(Debug, Clone, Default)]
pub struct CoreStats {
    /// Sequences in the waiting queue.
    pub waiting: usize,
    /// Sequences admitted (prefilling or decoding).
    pub running: usize,
    /// Fraction of the KV block pool referenced by live sequences.
    pub kv_occupancy: f64,
    /// Prefix-cache counters (hits, misses, evictions, ...).
    pub cache: CacheStats,
    /// Prefill tokens actually run through the model (cold work).
    pub prefill_tokens_executed: usize,
    /// Prompt tokens served from cached blocks instead of recomputed.
    pub cached_prefix_tokens: usize,
    /// TTFT-in-engine-steps p50 (deterministic latency proxy).
    pub ttft_steps_p50: f64,
}

impl CoreStats {
    /// Block-level cache hit rate (`hits / (hits + misses)`; 0 when no
    /// lookups happened yet).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache.hits + self.cache.misses;
        if total == 0 {
            0.0
        } else {
            self.cache.hits as f64 / total as f64
        }
    }
}

/// One replica engine as the router sees it. [`Engine`] is the
/// production implementation; tests substitute a deterministic fake
/// core so router behavior is tier-1-testable without PJRT artifacts.
pub trait ReplicaCore {
    /// Submit a request; returns the core's *local* sequence id.
    fn submit(&mut self, prompt: Vec<u32>, params: SamplingParams) -> u64;
    /// Execute one scheduler step.
    fn step(&mut self) -> Result<()>;
    /// Anything queued or in flight?
    fn has_work(&self) -> bool;
    /// Drain finished sequences (their `id` is the local id).
    fn take_finished(&mut self) -> Vec<Sequence>;
    /// KV block size in tokens — the prefix-cache hash granularity.
    /// Every replica behind one router must agree on it.
    fn block_size(&self) -> usize;
    /// Queued + running sequences (the routing load signal).
    fn load(&self) -> usize;
    /// Start recording prefix-cache events (called once on router
    /// attach; events feed the shared cache directory).
    fn enable_cache_events(&mut self);
    /// Drain recorded prefix-cache events in mutation order.
    fn take_cache_events(&mut self) -> Vec<CacheEvent>;
    /// Configure the sliding eviction window on the prefix cache.
    fn set_cache_watermarks(&mut self, wm: CacheWatermarks);
    /// Snapshot the counters the stats endpoint and benches report.
    fn core_stats(&self) -> CoreStats;
}

impl ReplicaCore for Engine {
    fn submit(&mut self, prompt: Vec<u32>, params: SamplingParams) -> u64 {
        Engine::submit(self, prompt, params)
    }
    fn step(&mut self) -> Result<()> {
        Engine::step(self).map(|_| ())
    }
    fn has_work(&self) -> bool {
        Engine::has_work(self)
    }
    fn take_finished(&mut self) -> Vec<Sequence> {
        Engine::take_finished(self)
    }
    fn block_size(&self) -> usize {
        Engine::block_size(self)
    }
    fn load(&self) -> usize {
        let (w, r) = self.queue_depths();
        w + r
    }
    fn enable_cache_events(&mut self) {
        Engine::enable_cache_events(self)
    }
    fn take_cache_events(&mut self) -> Vec<CacheEvent> {
        Engine::take_cache_events(self)
    }
    fn set_cache_watermarks(&mut self, wm: CacheWatermarks) {
        Engine::set_cache_watermarks(self, wm.high, wm.low)
    }
    fn core_stats(&self) -> CoreStats {
        let (waiting, running) = self.queue_depths();
        CoreStats {
            waiting,
            running,
            kv_occupancy: self.kv_occupancy(),
            cache: self.cache_stats(),
            prefill_tokens_executed: self.metrics.prefill_tokens_executed,
            cached_prefix_tokens: self.metrics.cached_prefix_tokens,
            ttft_steps_p50: self.metrics.ttft_steps.summary().p50,
        }
    }
}

/// One replica slot owned by the router: the core plus its id and the
/// router-side routing counters.
pub struct Replica<C: ReplicaCore> {
    /// Router-assigned replica id (index; stable for a router's life).
    pub id: usize,
    core: C,
    /// Requests the router has placed on this replica.
    pub requests_routed: usize,
}

impl<C: ReplicaCore> Replica<C> {
    /// Wrap `core` as replica `id`.
    pub fn new(id: usize, core: C) -> Replica<C> {
        Replica { id, core, requests_routed: 0 }
    }
    /// The wrapped core (read-only).
    pub fn core(&self) -> &C {
        &self.core
    }
    /// The wrapped core (the router steps/submits through this).
    pub fn core_mut(&mut self) -> &mut C {
        &mut self.core
    }
    /// Snapshot this replica's stats row.
    pub fn stats(&self) -> ReplicaStats {
        ReplicaStats {
            id: self.id,
            requests_routed: self.requests_routed,
            core: self.core.core_stats(),
        }
    }
}

/// One row of the `{"cmd":"stats"}` admin response / router bench.
#[derive(Debug, Clone)]
pub struct ReplicaStats {
    /// Replica id.
    pub id: usize,
    /// Requests the router placed here.
    pub requests_routed: usize,
    /// The core's counters at snapshot time.
    pub core: CoreStats,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn core_stats_hit_rate() {
        let mut s = CoreStats::default();
        assert_eq!(s.cache_hit_rate(), 0.0);
        s.cache.hits = 3;
        s.cache.misses = 1;
        assert_eq!(s.cache_hit_rate(), 0.75);
    }
}
