//! The `Replica` abstraction: one complete serving engine (scheduler +
//! block manager + runtime + metrics) behind the narrow interface the
//! multi-replica [`super::router`] drives.
//!
//! [`ReplicaCore`] is the contract: submit requests, step, drain
//! finished sequences and prefix-cache events, report load and stats.
//! Both `submit` and `step` are **fallible** — a core reports a
//! [`ReplicaError`] instead of unwinding, and the router's health
//! machinery (quarantine, retry, replacement) decides what happens
//! next. [`Engine`] is the production core; the router property tests
//! implement the same trait over a deterministic fake model (scheduler
//! + block manager only, no PJRT runtime), which is what makes the
//! whole multi-replica stack testable in tier-1 CI without artifacts,
//! and [`super::fault::FaultyCore`] wraps any core with a deterministic
//! failure schedule for the fault-injection tests.
//!
//! [`Replica`] wraps a core with its replica id and the router-side
//! accounting (requests routed here, health, replay counts), and
//! snapshots [`ReplicaStats`] for the server's `{"cmd":"stats"}` /
//! `{"cmd":"metrics"}` admin endpoints and the router bench.

use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::config::CacheWatermarks;

use super::block_manager::{CacheEvent, CacheStats};
use super::engine::{Engine, StepOutcome};
use super::sequence::{SamplingParams, Sequence};

/// Why a replica core refused or failed an operation. The distinction
/// drives the router's health machine: transient errors are retried
/// with backoff (Healthy → Quarantined), permanent errors kill the
/// replica immediately (→ Dead, in-flight requests replayed).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReplicaError {
    /// The operation failed but the replica may recover (e.g. a device
    /// hiccup); worth retrying after backoff.
    Transient(String),
    /// The replica is gone or its internal invariants are broken (a
    /// caught panic, a poisoned pool); never retried.
    Permanent(String),
}

impl ReplicaError {
    /// Is this error worth retrying?
    pub fn is_transient(&self) -> bool {
        matches!(self, ReplicaError::Transient(_))
    }
    /// The underlying error description.
    pub fn message(&self) -> &str {
        match self {
            ReplicaError::Transient(m) | ReplicaError::Permanent(m) => m,
        }
    }
}

impl fmt::Display for ReplicaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReplicaError::Transient(m) => {
                write!(f, "transient replica error: {m}")
            }
            ReplicaError::Permanent(m) => {
                write!(f, "permanent replica error: {m}")
            }
        }
    }
}

impl std::error::Error for ReplicaError {}

/// Router-side health state of one replica (the failure lifecycle;
/// `docs/ARCHITECTURE.md` has the diagram).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaHealth {
    /// Serving normally; routable.
    Healthy,
    /// Hit transient step failures; not stepped again until the router
    /// step counter reaches `retry_at_step` (deterministic exponential
    /// backoff), and only routed to when no healthy replica exists.
    Quarantined {
        /// Consecutive transient failures observed so far.
        failures: u32,
        /// Router step count at which the next retry is due.
        retry_at_step: u64,
    },
    /// Permanently failed (or retries exhausted): never stepped or
    /// routed to again; its in-flight requests were replayed. The slot
    /// is kept so replica ids stay stable.
    Dead,
}

impl ReplicaHealth {
    /// Wire/metric spelling (`healthy` / `quarantined` / `dead`).
    pub fn as_str(&self) -> &'static str {
        match self {
            ReplicaHealth::Healthy => "healthy",
            ReplicaHealth::Quarantined { .. } => "quarantined",
            ReplicaHealth::Dead => "dead",
        }
    }
    /// Is the replica permanently out of service?
    pub fn is_dead(&self) -> bool {
        matches!(self, ReplicaHealth::Dead)
    }
    /// Can the replica still serve (healthy or quarantined)?
    pub fn is_alive(&self) -> bool {
        !self.is_dead()
    }
}

/// Point-in-time counters of one replica core (everything the routing
/// policies and the stats endpoint need, cheap enough to snapshot per
/// request).
#[derive(Debug, Clone, Default)]
pub struct CoreStats {
    /// Sequences in the waiting queue.
    pub waiting: usize,
    /// Sequences admitted (prefilling or decoding).
    pub running: usize,
    /// Fraction of the KV block pool referenced by live sequences.
    pub kv_occupancy: f64,
    /// Prefix-cache counters (hits, misses, evictions, ...).
    pub cache: CacheStats,
    /// Prefill tokens actually run through the model (cold work).
    pub prefill_tokens_executed: usize,
    /// Prompt tokens served from cached blocks instead of recomputed.
    pub cached_prefix_tokens: usize,
    /// TTFT-in-engine-steps p50 (deterministic latency proxy).
    pub ttft_steps_p50: f64,
    /// Blocks currently demoted into the tiered KV pool (occupancy; ≤
    /// the configured bound, 0 while tiering is off). Demotion/restore
    /// *counters* ride in `cache` ([`CacheStats::demotions`] /
    /// [`CacheStats::restores`]).
    pub pool_blocks: usize,
    /// Prefill tokens whose recompute a tiered-pool restore avoided
    /// (`cache.restores * block_size` — exact by construction).
    pub recompute_avoided_tokens: usize,
    /// KV blocks adopted from a donor replica (cross-replica
    /// migration, receiver side).
    pub kv_migrations_in: usize,
    /// KV blocks exported to other replicas (donor side).
    pub kv_migrations_out: usize,
    /// Wire bytes of migrated KV blocks, both directions summed.
    pub migrated_bytes: usize,
}

impl CoreStats {
    /// Block-level cache hit rate (`hits / (hits + misses)`; 0 when no
    /// lookups happened yet).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache.hits + self.cache.misses;
        if total == 0 {
            0.0
        } else {
            self.cache.hits as f64 / total as f64
        }
    }
}

/// One replica engine as the router sees it. [`Engine`] is the
/// production implementation; tests substitute a deterministic fake
/// core so router behavior is tier-1-testable without PJRT artifacts.
pub trait ReplicaCore {
    /// Submit a request; returns the core's *local* sequence id, or a
    /// [`ReplicaError`] when the core cannot accept work at all (the
    /// router then retries on another replica).
    fn submit(&mut self, prompt: Vec<u32>, params: SamplingParams)
        -> Result<u64, ReplicaError>;
    /// Execute one scheduler step. Errors instead of unwinding; the
    /// transient/permanent split drives the router's health machine.
    fn step(&mut self) -> Result<StepOutcome, ReplicaError>;
    /// Anything queued or in flight?
    fn has_work(&self) -> bool;
    /// Drain finished sequences (their `id` is the local id).
    fn take_finished(&mut self) -> Vec<Sequence>;
    /// Drain tokens sampled since the last drain, as
    /// `(local id, token)` in emission order — the incremental
    /// streaming surface. A token appears exactly once, in the same
    /// step that appended it to the sequence's output, so concatenating
    /// a sequence's emitted tokens reproduces its final `output`
    /// bit-for-bit. Cores that cannot surface tokens incrementally may
    /// keep the default (tokens then stream only at finish).
    fn take_emitted(&mut self) -> Vec<(u64, u32)> {
        vec![]
    }
    /// Replica teardown: remove and return every *unfinished* sequence
    /// (with its partial output, so the router can replay it
    /// elsewhere), releasing all pool and cache state it held. After
    /// this the core reports no work.
    fn drain_inflight(&mut self) -> Vec<Sequence>;
    /// KV block size in tokens — the prefix-cache hash granularity.
    /// Every replica behind one router must agree on it.
    fn block_size(&self) -> usize;
    /// Queue depths `(waiting, running)` — the admission-control and
    /// routing load signals.
    fn queue_depths(&self) -> (usize, usize);
    /// Queued + running sequences (the routing load signal).
    fn load(&self) -> usize {
        let (w, r) = self.queue_depths();
        w + r
    }
    /// Start recording prefix-cache events (called once on router
    /// attach; events feed the shared cache directory).
    fn enable_cache_events(&mut self);
    /// Drain recorded prefix-cache events in mutation order.
    fn take_cache_events(&mut self) -> Vec<CacheEvent>;
    /// Configure the sliding eviction window on the prefix cache.
    fn set_cache_watermarks(&mut self, wm: CacheWatermarks);
    /// Donor side of cross-replica KV migration: serialize the stashed
    /// blocks this core holds for a *contiguous* prefix of `tokens`
    /// (device stash or demotion pool), as `(block hash, wire bytes)`
    /// in chain order. Read-only — refcounts, LRU order and the pool
    /// index are untouched. Cores without stashed KV (or with
    /// migration unsupported) keep the default and export nothing.
    fn export_blocks(&mut self, tokens: &[u32])
        -> Result<Vec<(u64, Vec<u8>)>, ReplicaError> {
        let _ = tokens;
        Ok(vec![])
    }
    /// Receiver side: adopt wire-form KV blocks into the local pool
    /// tier so the next admission restores them instead of
    /// recomputing. Returns how many blocks were adopted (already-held
    /// hashes are skipped, not errors). A decode failure is an error:
    /// the router falls back to plain recompute.
    fn import_blocks(&mut self, blocks: &[(u64, Vec<u8>)])
        -> Result<usize, ReplicaError> {
        let _ = blocks;
        Ok(0)
    }
    /// Snapshot the counters the stats endpoint and benches report.
    fn core_stats(&self) -> CoreStats;
}

/// Render a caught panic payload as an error message.
fn panic_msg(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        format!("panic: {s}")
    } else if let Some(s) = p.downcast_ref::<String>() {
        format!("panic: {s}")
    } else {
        "panic: <non-string payload>".to_string()
    }
}

/// The production core. Internal panics (pool-invariant violations,
/// bookkeeping bugs) are caught and surfaced as
/// [`ReplicaError::Permanent`] instead of unwinding through the
/// router; runtime (`anyhow`) step errors surface as
/// [`ReplicaError::Transient`] — a device hiccup may clear, and a core
/// whose internal state the failure corrupted will fail again and
/// escalate to Dead through the router's bounded retries.
impl ReplicaCore for Engine {
    fn submit(&mut self, prompt: Vec<u32>, params: SamplingParams)
        -> Result<u64, ReplicaError> {
        catch_unwind(AssertUnwindSafe(|| Engine::submit(self, prompt,
                                                        params)))
            .map_err(|p| ReplicaError::Permanent(panic_msg(p)))
    }
    fn step(&mut self) -> Result<StepOutcome, ReplicaError> {
        match catch_unwind(AssertUnwindSafe(|| Engine::step(self))) {
            Ok(Ok(outcome)) => Ok(outcome),
            Ok(Err(e)) => Err(ReplicaError::Transient(format!("{e:#}"))),
            Err(p) => Err(ReplicaError::Permanent(panic_msg(p))),
        }
    }
    fn has_work(&self) -> bool {
        Engine::has_work(self)
    }
    fn take_finished(&mut self) -> Vec<Sequence> {
        Engine::take_finished(self)
    }
    fn take_emitted(&mut self) -> Vec<(u64, u32)> {
        Engine::take_emitted(self)
    }
    fn drain_inflight(&mut self) -> Vec<Sequence> {
        Engine::drain_inflight(self)
    }
    fn block_size(&self) -> usize {
        Engine::block_size(self)
    }
    fn queue_depths(&self) -> (usize, usize) {
        Engine::queue_depths(self)
    }
    fn enable_cache_events(&mut self) {
        Engine::enable_cache_events(self)
    }
    fn take_cache_events(&mut self) -> Vec<CacheEvent> {
        Engine::take_cache_events(self)
    }
    fn set_cache_watermarks(&mut self, wm: CacheWatermarks) {
        Engine::set_cache_watermarks(self, wm.high, wm.low)
    }
    fn export_blocks(&mut self, tokens: &[u32])
        -> Result<Vec<(u64, Vec<u8>)>, ReplicaError> {
        catch_unwind(AssertUnwindSafe(|| Engine::export_kv_blocks(self,
                                                                  tokens)))
            .map_err(|p| ReplicaError::Permanent(panic_msg(p)))
    }
    fn import_blocks(&mut self, blocks: &[(u64, Vec<u8>)])
        -> Result<usize, ReplicaError> {
        match catch_unwind(AssertUnwindSafe(|| {
            Engine::import_kv_blocks(self, blocks)
        })) {
            Ok(Ok(n)) => Ok(n),
            Ok(Err(e)) => Err(ReplicaError::Transient(format!("{e:#}"))),
            Err(p) => Err(ReplicaError::Permanent(panic_msg(p))),
        }
    }
    fn core_stats(&self) -> CoreStats {
        let (waiting, running) = self.queue_depths();
        CoreStats {
            waiting,
            running,
            kv_occupancy: self.kv_occupancy(),
            cache: self.cache_stats(),
            prefill_tokens_executed: self.metrics.prefill_tokens_executed,
            cached_prefix_tokens: self.metrics.cached_prefix_tokens,
            ttft_steps_p50: self.metrics.ttft_steps.summary().p50,
            pool_blocks: self.kv_pool_len(),
            recompute_avoided_tokens:
                self.metrics.recompute_avoided_tokens,
            kv_migrations_in: self.metrics.kv_migrations_in,
            kv_migrations_out: self.metrics.kv_migrations_out,
            migrated_bytes: self.metrics.migrated_bytes,
        }
    }
}

/// One replica slot owned by the router: the core plus its id and the
/// router-side routing/health accounting.
pub struct Replica<C: ReplicaCore> {
    /// Router-assigned replica id (index; stable for a router's life,
    /// even after death — the slot is kept).
    pub id: usize,
    core: C,
    /// Requests the router has placed on this replica (replays onto it
    /// included).
    pub requests_routed: usize,
    /// Health state (owned by the router's failure handling).
    pub health: ReplicaHealth,
    /// In-flight requests replayed *off* this replica when it died.
    pub replayed_out: usize,
}

impl<C: ReplicaCore> Replica<C> {
    /// Wrap `core` as replica `id` (healthy).
    pub fn new(id: usize, core: C) -> Replica<C> {
        Replica {
            id,
            core,
            requests_routed: 0,
            health: ReplicaHealth::Healthy,
            replayed_out: 0,
        }
    }
    /// The wrapped core (read-only).
    pub fn core(&self) -> &C {
        &self.core
    }
    /// The wrapped core (the router steps/submits through this).
    pub fn core_mut(&mut self) -> &mut C {
        &mut self.core
    }
    /// Snapshot this replica's stats row.
    pub fn stats(&self) -> ReplicaStats {
        ReplicaStats {
            id: self.id,
            requests_routed: self.requests_routed,
            health: self.health,
            replayed_out: self.replayed_out,
            core: self.core.core_stats(),
        }
    }
}

/// One row of the `{"cmd":"stats"}` admin response / router bench.
#[derive(Debug, Clone)]
pub struct ReplicaStats {
    /// Replica id.
    pub id: usize,
    /// Requests the router placed here.
    pub requests_routed: usize,
    /// Health state at snapshot time.
    pub health: ReplicaHealth,
    /// In-flight requests replayed off this replica at its death.
    pub replayed_out: usize,
    /// The core's counters at snapshot time.
    pub core: CoreStats,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn core_stats_hit_rate() {
        let mut s = CoreStats::default();
        assert_eq!(s.cache_hit_rate(), 0.0);
        s.cache.hits = 3;
        s.cache.misses = 1;
        assert_eq!(s.cache_hit_rate(), 0.75);
    }

    #[test]
    fn replica_error_classification() {
        let t = ReplicaError::Transient("device hiccup".into());
        let p = ReplicaError::Permanent("panic: pool invariant".into());
        assert!(t.is_transient());
        assert!(!p.is_transient());
        assert_eq!(t.message(), "device hiccup");
        assert!(format!("{p}").contains("permanent"));
    }

    #[test]
    fn health_lifecycle_spellings() {
        assert_eq!(ReplicaHealth::Healthy.as_str(), "healthy");
        let q = ReplicaHealth::Quarantined { failures: 1,
                                             retry_at_step: 4 };
        assert_eq!(q.as_str(), "quarantined");
        assert!(q.is_alive());
        assert!(ReplicaHealth::Dead.is_dead());
        assert!(!ReplicaHealth::Dead.is_alive());
    }
}
