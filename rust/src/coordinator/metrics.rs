//! Engine metrics: throughput, time-to-first-token, inter-token latency,
//! KV occupancy, preemption counts, and prefix-cache savings (prefill
//! tokens actually executed vs. served from cached blocks).

use std::time::Instant;

use crate::util::stats::{Accum, Summary};

use super::sequence::Sequence;

#[derive(Debug, Default)]
pub struct Metrics {
    pub started_at: Option<Instant>,
    pub requests_in: usize,
    pub requests_done: usize,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    pub prefill_steps: usize,
    pub decode_steps: usize,
    pub preemptions: usize,
    /// Prefill tokens actually run through the model (cache hits skip
    /// theirs; recompute-preemption re-runs its share).
    pub prefill_tokens_executed: usize,
    /// Prompt tokens served from shared cache blocks instead of
    /// recomputed.
    pub cached_prefix_tokens: usize,
    pub ttft_s: Accum,
    pub inter_token_s: Accum,
    pub e2e_s: Accum,
    pub batch_sizes: Accum,
    pub kv_occupancy: Accum,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn on_submit(&mut self, prompt_len: usize) {
        self.started_at.get_or_insert_with(Instant::now);
        self.requests_in += 1;
        self.prompt_tokens += prompt_len;
    }

    pub fn on_finished(&mut self, seq: &Sequence) {
        self.requests_done += 1;
        self.output_tokens += seq.output.len();
        if let (Some(f), Some(done)) = (seq.first_token_at, seq.finished_at) {
            self.ttft_s
                .push(f.duration_since(seq.arrived).as_secs_f64());
            self.e2e_s
                .push(done.duration_since(seq.arrived).as_secs_f64());
        }
        for w in seq.token_times.windows(2) {
            self.inter_token_s
                .push(w[1].duration_since(w[0]).as_secs_f64());
        }
        self.preemptions += seq.preemptions;
    }

    pub fn elapsed_s(&self) -> f64 {
        self.started_at
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0)
    }

    /// Generated tokens per second of wall clock.
    pub fn output_tok_per_s(&self) -> f64 {
        let e = self.elapsed_s();
        if e > 0.0 {
            self.output_tokens as f64 / e
        } else {
            0.0
        }
    }

    pub fn report(&self) -> MetricsReport {
        MetricsReport {
            requests_done: self.requests_done,
            output_tokens: self.output_tokens,
            elapsed_s: self.elapsed_s(),
            output_tok_per_s: self.output_tok_per_s(),
            ttft: self.ttft_s.summary(),
            inter_token: self.inter_token_s.summary(),
            e2e: self.e2e_s.summary(),
            mean_batch: self.batch_sizes.mean(),
            mean_kv_occupancy: self.kv_occupancy.mean(),
            preemptions: self.preemptions,
            prefill_tokens_executed: self.prefill_tokens_executed,
            cached_prefix_tokens: self.cached_prefix_tokens,
        }
    }
}

#[derive(Debug, Clone)]
pub struct MetricsReport {
    pub requests_done: usize,
    pub output_tokens: usize,
    pub elapsed_s: f64,
    pub output_tok_per_s: f64,
    pub ttft: Summary,
    pub inter_token: Summary,
    pub e2e: Summary,
    pub mean_batch: f64,
    pub mean_kv_occupancy: f64,
    pub preemptions: usize,
    pub prefill_tokens_executed: usize,
    pub cached_prefix_tokens: usize,
}

impl MetricsReport {
    pub fn print(&self, label: &str) {
        println!(
            "[{label}] done={} out_tokens={} elapsed={:.2}s \
             throughput={:.1} tok/s mean_batch={:.2} kv_occ={:.0}% \
             preempt={}",
            self.requests_done, self.output_tokens, self.elapsed_s,
            self.output_tok_per_s, self.mean_batch,
            self.mean_kv_occupancy * 100.0, self.preemptions
        );
        println!(
            "[{label}] ttft p50={:.1}ms p99={:.1}ms | inter-token \
             p50={:.1}ms p99={:.1}ms | e2e p50={:.1}ms",
            self.ttft.p50 * 1e3, self.ttft.p99 * 1e3,
            self.inter_token.p50 * 1e3, self.inter_token.p99 * 1e3,
            self.e2e.p50 * 1e3
        );
        println!(
            "[{label}] prefill tokens executed={} cached={}",
            self.prefill_tokens_executed, self.cached_prefix_tokens
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sequence::{FinishReason, SamplingParams};

    #[test]
    fn accounting() {
        let mut m = Metrics::new();
        m.on_submit(10);
        m.on_submit(5);
        assert_eq!(m.requests_in, 2);
        assert_eq!(m.prompt_tokens, 15);
        let mut s = Sequence::new(1, vec![1, 2], SamplingParams::default());
        s.record_token(3);
        s.record_token(4);
        s.finish(FinishReason::MaxTokens);
        m.on_finished(&s);
        assert_eq!(m.requests_done, 1);
        assert_eq!(m.output_tokens, 2);
        let r = m.report();
        assert_eq!(r.requests_done, 1);
        assert!(r.ttft.n == 1 && r.inter_token.n == 1);
    }
}
