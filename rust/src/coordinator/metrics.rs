//! Engine metrics: throughput, time-to-first-token, inter-token latency,
//! KV occupancy, preemption counts, prefix-cache savings (prefill tokens
//! actually executed vs. served from cached blocks), and chunked-prefill
//! accounting (chunks executed, mixed prefill+decode steps, and a
//! deterministic TTFT proxy measured in engine steps).

use std::time::Instant;

use crate::util::stats::{Accum, Summary};

use super::sequence::Sequence;

/// Mutable counters the engine updates as it steps.
#[derive(Debug, Default)]
pub struct Metrics {
    /// First-submission wall-clock anchor.
    pub started_at: Option<Instant>,
    /// Requests submitted.
    pub requests_in: usize,
    /// Requests finished.
    pub requests_done: usize,
    /// Prompt tokens across submissions.
    pub prompt_tokens: usize,
    /// Generated tokens across finished requests.
    pub output_tokens: usize,
    /// Steps that executed at least one prefill chunk.
    pub prefill_steps: usize,
    /// Steps that executed a decode round.
    pub decode_steps: usize,
    /// Non-idle engine steps (prefill, decode, or mixed).
    pub engine_steps: usize,
    /// Steps that ran prefill chunks *and* a decode round (only the
    /// chunked scheduler produces these).
    pub mixed_steps: usize,
    /// Prefill chunks executed (one sequence advancing once).
    pub prefill_chunks: usize,
    /// Device executions issued (prefill calls + decode calls + chunk
    /// calls). The chunked-prefill executable's whole win is here: a
    /// T-token continuation chunk costs 1 call on the compiled path vs
    /// T on the per-token fallback, and positionwise batching drops it
    /// below one call per chunk.
    pub device_calls: usize,
    /// Preemptions across finished requests (recompute policy).
    pub preemptions: usize,
    /// Prefill tokens actually run through the model (cache hits skip
    /// theirs; recompute-preemption re-runs its share).
    pub prefill_tokens_executed: usize,
    /// Prompt tokens served from shared cache blocks instead of
    /// recomputed.
    pub cached_prefix_tokens: usize,
    /// Full blocks registered into the prefix cache during *decode*
    /// (generated content seeding the cache).
    pub decode_registered_blocks: usize,
    /// Evicted blocks whose stash was demoted into the tiered KV pool
    /// (0 while tiering is off).
    pub kv_demotions: usize,
    /// Blocks restored from the tiered pool at admission (dequantize +
    /// copy instead of recompute).
    pub kv_restores: usize,
    /// Prefill tokens whose recompute was avoided by a tiered-pool
    /// restore (`kv_restores * block_size` — the exact accounting the
    /// tiering tests pin).
    pub recompute_avoided_tokens: usize,
    /// KV blocks adopted from donor replicas (migration, receiver
    /// side).
    pub kv_migrations_in: usize,
    /// KV blocks exported to other replicas (migration, donor side).
    pub kv_migrations_out: usize,
    /// Wire bytes of migrated KV blocks (both directions summed).
    pub migrated_bytes: usize,
    /// Time to first token, seconds (wall clock).
    pub ttft_s: Accum,
    /// Engine steps from submission to first token — a deterministic
    /// TTFT proxy independent of host speed (chunked prefill should
    /// lower it for decode-bound traffic, since admissions no longer
    /// monopolize whole steps).
    pub ttft_steps: Accum,
    /// Gap between consecutive output tokens, seconds.
    pub inter_token_s: Accum,
    /// End-to-end request latency, seconds.
    pub e2e_s: Accum,
    /// Scheduled batch size per step.
    pub batch_sizes: Accum,
    /// KV pool occupancy per step.
    pub kv_occupancy: Accum,
}

impl Metrics {
    /// Fresh zeroed counters.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record a submission of `prompt_len` tokens.
    pub fn on_submit(&mut self, prompt_len: usize) {
        // sqlint: allow(determinism) wall-clock serving-time stamp feeds metrics only, never scheduling
        self.started_at.get_or_insert_with(Instant::now);
        self.requests_in += 1;
        self.prompt_tokens += prompt_len;
    }

    /// Fold a finished sequence into the latency/throughput accums.
    pub fn on_finished(&mut self, seq: &Sequence) {
        self.requests_done += 1;
        self.output_tokens += seq.output.len();
        if let (Some(f), Some(done)) = (seq.first_token_at, seq.finished_at) {
            self.ttft_s
                .push(f.duration_since(seq.arrived).as_secs_f64());
            self.e2e_s
                .push(done.duration_since(seq.arrived).as_secs_f64());
        }
        for w in seq.token_times.windows(2) {
            self.inter_token_s
                .push(w[1].duration_since(w[0]).as_secs_f64());
        }
        self.preemptions += seq.preemptions;
    }

    /// Seconds since the first submission.
    pub fn elapsed_s(&self) -> f64 {
        self.started_at
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0)
    }

    /// Generated tokens per second of wall clock.
    pub fn output_tok_per_s(&self) -> f64 {
        let e = self.elapsed_s();
        if e > 0.0 {
            self.output_tokens as f64 / e
        } else {
            0.0
        }
    }

    /// Snapshot the counters into an immutable report.
    pub fn report(&self) -> MetricsReport {
        MetricsReport {
            requests_done: self.requests_done,
            output_tokens: self.output_tokens,
            elapsed_s: self.elapsed_s(),
            output_tok_per_s: self.output_tok_per_s(),
            ttft: self.ttft_s.summary(),
            ttft_steps: self.ttft_steps.summary(),
            inter_token: self.inter_token_s.summary(),
            e2e: self.e2e_s.summary(),
            mean_batch: self.batch_sizes.mean(),
            mean_kv_occupancy: self.kv_occupancy.mean(),
            preemptions: self.preemptions,
            prefill_tokens_executed: self.prefill_tokens_executed,
            cached_prefix_tokens: self.cached_prefix_tokens,
            prefill_chunks: self.prefill_chunks,
            device_calls: self.device_calls,
            mixed_steps: self.mixed_steps,
            decode_registered_blocks: self.decode_registered_blocks,
            kv_demotions: self.kv_demotions,
            kv_restores: self.kv_restores,
            recompute_avoided_tokens: self.recompute_avoided_tokens,
            kv_migrations_in: self.kv_migrations_in,
            kv_migrations_out: self.kv_migrations_out,
            migrated_bytes: self.migrated_bytes,
        }
    }
}

/// Immutable snapshot of [`Metrics`] (what benches/serving report).
#[derive(Debug, Clone)]
pub struct MetricsReport {
    /// Requests finished.
    pub requests_done: usize,
    /// Generated tokens across finished requests.
    pub output_tokens: usize,
    /// Seconds since the first submission.
    pub elapsed_s: f64,
    /// Generated tokens per second of wall clock.
    pub output_tok_per_s: f64,
    /// Time-to-first-token distribution, seconds.
    pub ttft: Summary,
    /// TTFT measured in engine steps (deterministic proxy).
    pub ttft_steps: Summary,
    /// Inter-token latency distribution, seconds.
    pub inter_token: Summary,
    /// End-to-end latency distribution, seconds.
    pub e2e: Summary,
    /// Mean scheduled batch size.
    pub mean_batch: f64,
    /// Mean KV pool occupancy.
    pub mean_kv_occupancy: f64,
    /// Preemptions across finished requests.
    pub preemptions: usize,
    /// Prefill tokens actually run through the model.
    pub prefill_tokens_executed: usize,
    /// Prompt tokens served from the prefix cache.
    pub cached_prefix_tokens: usize,
    /// Prefill chunks executed.
    pub prefill_chunks: usize,
    /// Device executions issued (prefill + decode + chunk calls).
    pub device_calls: usize,
    /// Steps that mixed prefill chunks with a decode round.
    pub mixed_steps: usize,
    /// Blocks registered into the prefix cache during decode.
    pub decode_registered_blocks: usize,
    /// Evicted blocks demoted into the tiered KV pool.
    pub kv_demotions: usize,
    /// Blocks restored from the tiered pool instead of recomputed.
    pub kv_restores: usize,
    /// Prefill tokens saved by tiered-pool restores.
    pub recompute_avoided_tokens: usize,
    /// KV blocks adopted from donor replicas.
    pub kv_migrations_in: usize,
    /// KV blocks exported to other replicas.
    pub kv_migrations_out: usize,
    /// Wire bytes of migrated KV blocks, both directions.
    pub migrated_bytes: usize,
}

impl MetricsReport {
    /// Human-readable dump (benches and `serve_trace`).
    pub fn print(&self, label: &str) {
        println!(
            "[{label}] done={} out_tokens={} elapsed={:.2}s \
             throughput={:.1} tok/s mean_batch={:.2} kv_occ={:.0}% \
             preempt={}",
            self.requests_done, self.output_tokens, self.elapsed_s,
            self.output_tok_per_s, self.mean_batch,
            self.mean_kv_occupancy * 100.0, self.preemptions
        );
        println!(
            "[{label}] ttft p50={:.1}ms p99={:.1}ms ({:.1} steps p50) | \
             inter-token p50={:.1}ms p99={:.1}ms | e2e p50={:.1}ms",
            self.ttft.p50 * 1e3, self.ttft.p99 * 1e3,
            self.ttft_steps.p50,
            self.inter_token.p50 * 1e3, self.inter_token.p99 * 1e3,
            self.e2e.p50 * 1e3
        );
        println!(
            "[{label}] prefill tokens executed={} cached={} chunks={} \
             device_calls={} mixed_steps={} decode_registered_blocks={}",
            self.prefill_tokens_executed, self.cached_prefix_tokens,
            self.prefill_chunks, self.device_calls, self.mixed_steps,
            self.decode_registered_blocks
        );
        println!(
            "[{label}] kv tier: demotions={} restores={} \
             recompute_avoided_tokens={}",
            self.kv_demotions, self.kv_restores,
            self.recompute_avoided_tokens
        );
        println!(
            "[{label}] kv migration: in={} out={} bytes={}",
            self.kv_migrations_in, self.kv_migrations_out,
            self.migrated_bytes
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sequence::{FinishReason, SamplingParams};

    #[test]
    fn accounting() {
        let mut m = Metrics::new();
        m.on_submit(10);
        m.on_submit(5);
        assert_eq!(m.requests_in, 2);
        assert_eq!(m.prompt_tokens, 15);
        let mut s = Sequence::new(1, vec![1, 2], SamplingParams::default());
        s.record_token(3);
        s.record_token(4);
        s.finish(FinishReason::MaxTokens);
        m.on_finished(&s);
        assert_eq!(m.requests_done, 1);
        assert_eq!(m.output_tokens, 2);
        let r = m.report();
        assert_eq!(r.requests_done, 1);
        assert!(r.ttft.n == 1 && r.inter_token.n == 1);
    }

    #[test]
    fn chunk_counters_roundtrip() {
        let mut m = Metrics::new();
        m.prefill_chunks = 5;
        m.mixed_steps = 2;
        m.decode_registered_blocks = 3;
        m.device_calls = 7;
        m.ttft_steps.push(4.0);
        m.kv_demotions = 4;
        m.kv_restores = 2;
        m.recompute_avoided_tokens = 32;
        m.kv_migrations_in = 3;
        m.kv_migrations_out = 5;
        m.migrated_bytes = 640;
        let r = m.report();
        assert_eq!(r.prefill_chunks, 5);
        assert_eq!(r.mixed_steps, 2);
        assert_eq!(r.decode_registered_blocks, 3);
        assert_eq!(r.device_calls, 7);
        assert_eq!(r.ttft_steps.n, 1);
        assert_eq!(r.kv_demotions, 4);
        assert_eq!(r.kv_restores, 2);
        assert_eq!(r.recompute_avoided_tokens, 32);
        assert_eq!(r.kv_migrations_in, 3);
        assert_eq!(r.kv_migrations_out, 5);
        assert_eq!(r.migrated_bytes, 640);
    }
}
