//! Continuous-batching scheduler (the vLLM policy shape) with
//! first-class **chunked prefill**:
//!
//! * FCFS waiting queue. With chunked prefill enabled (the default) a
//!   step is *mixed*: one decode round over the fully-prefilled running
//!   sequences plus prefill chunks — continuations for partially
//!   prefilled sequences and first chunks for new admissions — all
//!   inside one `max_batch_tokens` budget (each scheduled decode costs
//!   one budget token; each chunk costs its width).
//! * Any prefill work — a cold prompt, the suffix past a prefix-cache
//!   hit, or post-preemption recompute of prompt+output — is split into
//!   chunks of at most `max_prefill_chunk` tokens (0 = only the budget
//!   and bucket caps apply). A chunk starting at position 0 additionally
//!   never exceeds the largest compiled prefill bucket, which
//!   *structurally* fixes the recompute hazard: recompute is just
//!   another chunked prefill, so no single step can outgrow a bucket.
//! * Admission consults the prefix cache: a sequence whose leading full
//!   blocks are cached shares them (refcounted) instead of allocating,
//!   and its first chunk starts past the hit — so warm traffic admits
//!   in larger batches. Block allocation covers only the admitted
//!   chunk; later chunks grow the table
//!   ([`super::block_manager::BlockManager::append_token`]).
//! * KV growth for every scheduled decode is reserved up front; on
//!   pressure the *most recently admitted* running sequence is preempted
//!   (LIFO, vLLM's recompute policy) — partially prefilled sequences
//!   included — releasing its blocks (shared ones just drop a
//!   reference) and requeueing it at the waiting front. A sequence that
//!   cannot make progress even alone — and likewise a waiting-queue
//!   head whose content could never be admitted at all (recompute
//!   content grows past the pool) — is *dropped* (reported via
//!   [`Scheduler::dropped`]; the engine finishes it with
//!   [`super::sequence::FinishReason::PoolExhausted`]) instead of
//!   wedging the FCFS queue.
//! * With `enable_chunked_prefill = false` the legacy policy runs:
//!   whole-content prefill steps take priority over decode steps and
//!   are never mixed. The engine's admission clamp then bounds
//!   `max_new_tokens` so recompute still fits the largest bucket (the
//!   belt-and-braces fix for the pre-chunking sharp edge).
//!
//! The scheduler owns sequence *ids* only; token/KV state lives in the
//! engine maps. Per-sequence prefill progress is read from
//! [`Sequence::prefill_progress`], which the engine advances after
//! executing each chunk.

use std::collections::{HashMap, VecDeque};

use crate::config::EngineConfig;

use super::block_manager::{Alloc, BlockManager};
use super::sequence::{SeqState, Sequence};

/// One unit of prefill work: build KV rows `start..end` of sequence
/// `id`'s full token content (prompt + generated output) this step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PrefillChunk {
    /// Sequence to advance.
    pub id: u64,
    /// First row computed by this chunk (equals the prefix-cache hit
    /// length on the first chunk of an admission, the sequence's chunk
    /// cursor otherwise).
    pub start: usize,
    /// One past the last row computed; `end == ` full content length
    /// means this chunk completes the prefill (the engine samples the
    /// sequence's next token from the chunk's final logits).
    pub end: usize,
    /// First chunk since (re)admission: the engine initializes the
    /// sequence's KV, copying the `start` cached-prefix rows (0 = cold).
    pub admitted: bool,
}

/// What the engine should execute this step: prefill chunks and/or one
/// decode round. Both can be non-empty (a *mixed* step).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct StepPlan {
    /// Prefill chunks to run (disjoint sequence ids).
    pub chunks: Vec<PrefillChunk>,
    /// Sequences to decode one token (all fully prefilled; KV growth
    /// already reserved).
    pub decode: Vec<u64>,
}

impl StepPlan {
    /// No work this step.
    pub fn is_idle(&self) -> bool {
        self.chunks.is_empty() && self.decode.is_empty()
    }
}

/// Continuous-batching scheduler; see the module docs for the policy.
#[derive(Debug)]
pub struct Scheduler {
    /// Engine/scheduler knobs (buckets, budgets, chunking).
    pub cfg: EngineConfig,
    /// The paged-KV accountant admission and preemption run against.
    pub bm: BlockManager,
    waiting: VecDeque<u64>,
    running: Vec<u64>, // admission order; preemption pops from the back
    /// ids preempted this step and requeued (engine must drop their KV).
    pub preempted: Vec<u64>,
    /// ids dropped this step: alone they exceed the pool, so they are
    /// not requeued (engine finishes them with `PoolExhausted`).
    pub dropped: Vec<u64>,
}

impl Scheduler {
    /// A scheduler over `bm` with `cfg`'s policy knobs.
    pub fn new(cfg: EngineConfig, mut bm: BlockManager) -> Scheduler {
        bm.enable_prefix_caching = cfg.enable_prefix_caching;
        bm.set_cache_watermarks(cfg.cache_watermarks.high,
                                cfg.cache_watermarks.low);
        Scheduler { cfg, bm, waiting: VecDeque::new(), running: vec![],
                    preempted: vec![], dropped: vec![] }
    }

    /// Enqueue a sequence id at the back of the waiting queue.
    pub fn add(&mut self, id: u64) {
        self.waiting.push_back(id);
    }

    /// Sequences in the waiting queue.
    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }
    /// Sequences admitted (prefilling or decoding).
    pub fn running_len(&self) -> usize {
        self.running.len()
    }
    /// Admitted sequence ids in admission order.
    pub fn running_ids(&self) -> &[u64] {
        &self.running
    }
    /// Anything queued or admitted?
    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    /// Remove a finished sequence and release its blocks.
    pub fn on_finished(&mut self, id: u64) {
        self.running.retain(|&r| r != id);
        self.waiting.retain(|&r| r != id);
        self.bm.release(id);
    }

    /// Replica teardown: empty both queues, releasing every drained
    /// sequence's blocks back to the pool, and return the drained ids
    /// (waiting first, then running, each in queue order). The prefix
    /// cache is left intact — the caller decides its fate.
    pub fn drain(&mut self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.waiting.drain(..).collect();
        ids.extend(self.running.drain(..));
        self.preempted.clear();
        self.dropped.clear();
        for &id in &ids {
            self.bm.release(id);
        }
        ids
    }

    /// Decide the next step. `seqs` provides token content, context
    /// lengths, states, and chunk cursors.
    pub fn plan(&mut self, seqs: &HashMap<u64, Sequence>) -> StepPlan {
        self.preempted.clear();
        self.dropped.clear();
        if self.cfg.enable_chunked_prefill {
            self.plan_chunked(seqs)
        } else {
            self.plan_legacy(seqs)
        }
    }

    /// Preempt the most recently admitted running sequence (LIFO).
    /// Returns `false` when the victim was the *only* running sequence:
    /// it cannot make progress even alone, so it is dropped (released,
    /// reported in `dropped`, not requeued) and the caller should give
    /// up for this step.
    fn preempt_one(&mut self) -> bool {
        let Some(victim) = self.running.pop() else {
            // nothing left to preempt: tell the caller to give up the
            // step rather than panicking the replica
            return false;
        };
        self.bm.release(victim);
        if self.running.is_empty() {
            self.dropped.push(victim);
            return false;
        }
        self.waiting.push_front(victim);
        self.preempted.push(victim);
        true
    }

    /// Drop waiting-queue heads that could never be admitted: content
    /// grown by decoding before a preemption can exceed what the whole
    /// pool holds, and such a sequence would wedge the FCFS head
    /// forever (the engine rejects impossible *prompts* at submit, but
    /// recompute content grows). `blocks_for(content) + watermark >
    /// total` means no admission can ever succeed — the table needs
    /// that many distinct physical blocks regardless of cache sharing.
    fn drop_impossible_heads(&mut self,
                             seqs: &HashMap<u64, Sequence>) {
        while let Some(&id) = self.waiting.front() {
            // sqlint: allow(panic) queue ids are live `seqs` keys (finish removes from both)
            let need = self.bm.blocks_for(seqs[&id].context_len())
                + self.bm.watermark_blocks;
            if need <= self.bm.total_blocks {
                return;
            }
            self.waiting.pop_front();
            self.dropped.push(id);
        }
    }

    /// Width cap for cold chunks when `count` of them run in one
    /// batched prefill call: the engine needs a *single* bucket with
    /// `batch >= count && seq >= width`, so the cap is the largest seq
    /// among buckets whose batch dimension fits `count` (0 = no bucket
    /// can; with the compiled cross-product bucket grid this is
    /// constant, but partial custom grids make it shrink with count).
    fn cold_width_cap(&self, count: usize) -> usize {
        self.cfg
            .prefill_buckets
            .iter()
            .filter(|&&(b, _)| b >= count)
            .map(|&(_, s)| s)
            .max()
            .unwrap_or(if self.cfg.prefill_buckets.is_empty() {
                usize::MAX // no bucket info (tests without a runtime)
            } else {
                0
            })
    }

    /// Width cap for warm/continuation chunks: the largest compiled
    /// chunk-executable length, so a chunk maps to one device call.
    /// Uncapped when no chunk buckets exist (pre-chunk artifacts, or
    /// tests without a runtime) — the engine then drives the chunk
    /// through the decode executable token by token as before.
    fn warm_width_cap(&self) -> usize {
        self.cfg
            .chunk_buckets
            .iter()
            .map(|&(_, s, _)| s)
            .max()
            .unwrap_or(usize::MAX)
    }

    /// Chunked policy: decode round + chunk continuations + admissions
    /// inside one token budget (see module docs).
    fn plan_chunked(&mut self, seqs: &HashMap<u64, Sequence>) -> StepPlan {
        let chunk_cap = if self.cfg.max_prefill_chunk == 0 {
            usize::MAX
        } else {
            self.cfg.max_prefill_chunk
        };
        let max_decode = self
            .cfg
            .decode_batches
            .iter()
            .copied()
            .max()
            .unwrap_or(1);

        // ---- decode round over fully-prefilled sequences: reserve +1
        // token each, preempting LIFO (possibly a mid-prefill victim,
        // whose blocks free up) until everything scheduled fits
        let mut decode: Vec<u64> = vec![];
        loop {
            let batch: Vec<u64> = self
                .running
                .iter()
                .copied()
                .filter(|id| seqs[id].state == SeqState::Running)
                .take(max_decode)
                .collect();
            let mut ok = true;
            for &id in &batch {
                // sqlint: allow(panic) queue ids are live `seqs` keys (finish removes from both)
                let ctx = seqs[&id].context_len();
                if self.bm.append_token(id, ctx + 1) == Alloc::NoSpace {
                    ok = false;
                    break;
                }
            }
            if ok {
                decode = batch;
                break;
            }
            if !self.preempt_one() {
                return StepPlan::default();
            }
        }

        // decodes count against the token budget, but never starve
        // prefill entirely: at least one chunk token stays schedulable
        let mut budget = self
            .cfg
            .max_batch_tokens
            .saturating_sub(decode.len())
            .max(1);
        let mut chunks: Vec<PrefillChunk> = vec![];

        // ---- continuation chunks for partially prefilled sequences
        // (FCFS in admission order); if nothing at all is schedulable
        // while prefills are stuck on the pool, preempt LIFO and retry
        let warm_cap = self.warm_width_cap();
        loop {
            for id in self.running.clone() {
                if budget == 0 {
                    break;
                }
                // sqlint: allow(panic) queue ids are live `seqs` keys (finish removes from both)
                let q = &seqs[&id];
                if q.state != SeqState::Prefilling {
                    continue;
                }
                let start = q.prefill_progress;
                let target = q.context_len();
                // a Prefilling sequence has always run at least one
                // chunk, so no prefill-bucket width cap applies; the
                // chunk-executable width cap keeps it one device call
                debug_assert!(0 < start && start < target);
                let mut end = target
                    .min(start.saturating_add(chunk_cap.min(warm_cap)))
                    .min(start.saturating_add(budget));
                if end <= start {
                    continue;
                }
                if self.bm.append_token(id, end) == Alloc::NoSpace {
                    // shrink the chunk to what held + free blocks can
                    // cover (partial progress beats stalling)
                    let cover = (self.bm.holds(id)
                        + self.bm.free_blocks())
                        * self.bm.block_size;
                    end = end.min(cover);
                    if end <= start
                        || self.bm.append_token(id, end)
                            == Alloc::NoSpace
                    {
                        continue; // no progress possible this step
                    }
                }
                budget -= end - start;
                chunks.push(PrefillChunk { id, start, end,
                                           admitted: false });
            }
            if !chunks.is_empty() || !decode.is_empty() {
                break;
            }
            // nothing schedulable at all while prefills are stuck on
            // the pool (decode is empty here, so no reservation can be
            // invalidated): preempt LIFO and retry
            let stuck = self
                .running
                .iter()
                .any(|id| seqs[id].state == SeqState::Prefilling);
            if !stuck || !self.preempt_one() {
                break;
            }
        }

        // ---- admissions: first chunks for waiting sequences. Cold
        // chunks (no cache hit) batch through ONE prefill executable,
        // so their count and widths must jointly fit a single compiled
        // bucket (batch >= count && seq >= widest). One allocator call
        // per attempt does the hash-chain walk, the capacity check, and
        // the allocation; it hands back the hit it honored plus the
        // fill, which become the chunk bounds — no separate probe.
        self.drop_impossible_heads(seqs);
        let mut cold = 0usize;
        let mut cold_w = 0usize; // widest cold chunk admitted this step
        while let Some(&id) = self.waiting.front() {
            if self.running.len() >= self.cfg.max_running || budget == 0 {
                break;
            }
            // sqlint: allow(panic) queue ids are live `seqs` keys (finish removes from both)
            let toks = seqs[&id].full_tokens();
            let cap = self.cold_width_cap(cold + 1);
            // 0 = no bucket fits one more cold chunk of any width
            let cold_cap = if cap < cold_w.max(1) { 0 } else { cap };
            let (start, end) = match self.bm.allocate_chunked(
                id, &toks, chunk_cap.min(budget), cold_cap, warm_cap,
            ) {
                Alloc::Ok { hit_tokens, filled } => (hit_tokens, filled),
                // pool or bucket rejection: keep FCFS head-of-line
                // order — don't skip ahead
                Alloc::NoSpace => break,
            };
            debug_assert!(start < end && end <= toks.len());
            budget -= end - start;
            if start == 0 {
                cold += 1;
                cold_w = cold_w.max(end);
            }
            self.waiting.pop_front();
            self.running.push(id);
            chunks.push(PrefillChunk { id, start, end, admitted: true });
        }

        StepPlan { chunks, decode }
    }

    /// Legacy (pre-chunking) policy: whole-content prefill admission
    /// takes priority; decode steps are separate, never mixed.
    fn plan_legacy(&mut self, seqs: &HashMap<u64, Sequence>) -> StepPlan {
        self.drop_impossible_heads(seqs);
        let slots = self.cfg.max_running.saturating_sub(self.running.len());
        if !self.waiting.is_empty() && slots > 0 {
            let mut chunks = vec![];
            let mut tokens = 0usize;
            let mut cold = 0usize;
            let mut cold_w = 0usize;
            while let Some(&id) = self.waiting.front() {
                if chunks.len() >= slots {
                    break;
                }
                // sqlint: allow(panic) queue ids are live `seqs` keys (finish removes from both)
                let toks = seqs[&id].full_tokens();
                // one allocator call per attempt: the step token budget
                // (only tokens past the cached prefix cost compute; the
                // first admission is exempt) and the cold bucket cap
                // are evaluated against the hit found by the same walk
                // that allocates
                let max_uncached = if chunks.is_empty() {
                    usize::MAX
                } else {
                    self.cfg.max_batch_tokens.saturating_sub(tokens)
                };
                // cold admissions run whole in one batched prefill
                // call: count + widths must jointly fit one bucket
                let cap = self.cold_width_cap(cold + 1);
                let cold_cap = if cap < cold_w { 0 } else { cap };
                let hit = match self.bm.allocate_full(
                    id, &toks, max_uncached, cold_cap,
                ) {
                    Alloc::Ok { hit_tokens, .. } => hit_tokens,
                    Alloc::NoSpace => break,
                };
                tokens += toks.len() - hit;
                if hit == 0 {
                    cold += 1;
                    cold_w = cold_w.max(toks.len());
                }
                chunks.push(PrefillChunk {
                    id,
                    start: hit,
                    end: toks.len(),
                    admitted: true,
                });
                self.waiting.pop_front();
            }
            if !chunks.is_empty() {
                self.running
                    .extend(chunks.iter().map(|c| c.id));
                return StepPlan { chunks, decode: vec![] };
            }
        }
        // ---- decode the running set (reserve growth; preempt on
        // pressure)
        if self.running.is_empty() {
            return StepPlan::default();
        }
        let max_decode = self
            .cfg
            .decode_batches
            .iter()
            .copied()
            .max()
            .unwrap_or(1);
        loop {
            let batch: Vec<u64> =
                self.running.iter().copied().take(max_decode).collect();
            let mut ok = true;
            for &id in &batch {
                // sqlint: allow(panic) queue ids are live `seqs` keys (finish removes from both)
                let ctx = seqs[&id].context_len();
                if self.bm.append_token(id, ctx + 1) == Alloc::NoSpace {
                    ok = false;
                    break;
                }
            }
            if ok {
                return StepPlan { chunks: vec![], decode: batch };
            }
            if !self.preempt_one() {
                return StepPlan::default();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sequence::SamplingParams;
    use crate::util::prop;

    fn mk_seqs(lens: &[usize]) -> HashMap<u64, Sequence> {
        lens.iter()
            .enumerate()
            .map(|(i, &l)| {
                (i as u64,
                 Sequence::new(i as u64, vec![1; l],
                               SamplingParams::default()))
            })
            .collect()
    }

    fn cfg() -> EngineConfig {
        EngineConfig {
            max_running: 4,
            max_batch_tokens: 64,
            decode_batches: vec![1, 2, 4],
            prefill_buckets: vec![(1, 32), (4, 32)],
            ..Default::default()
        }
    }

    /// Apply a plan the way the engine does: advance cursors, flip
    /// states, register blocks, record decode tokens.
    fn apply(s: &mut Scheduler, seqs: &mut HashMap<u64, Sequence>,
             plan: &StepPlan) {
        for c in &plan.chunks {
            let toks = seqs[&c.id].full_tokens();
            let q = seqs.get_mut(&c.id).unwrap();
            q.prefill_progress = c.end;
            if c.end >= toks.len() {
                q.state = SeqState::Running;
                q.record_token(7);
            } else {
                q.state = SeqState::Prefilling;
            }
            s.bm.register_prefix(c.id, &toks[..c.end]);
        }
        for id in &plan.decode {
            seqs.get_mut(id).unwrap().record_token(7);
        }
    }

    #[test]
    fn prefill_first_then_decode() {
        let mut seqs = mk_seqs(&[8, 8, 8]);
        let mut s = Scheduler::new(cfg(), BlockManager::new(16, 64));
        for id in 0..3 {
            s.add(id);
        }
        let plan = s.plan(&seqs);
        let ids: Vec<u64> = plan.chunks.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        for c in &plan.chunks {
            assert!(c.admitted);
            assert_eq!((c.start, c.end), (0, 8)); // cold, fits one chunk
        }
        assert!(plan.decode.is_empty());
        apply(&mut s, &mut seqs, &plan);
        let plan = s.plan(&seqs);
        assert!(plan.chunks.is_empty());
        assert_eq!(plan.decode, vec![0, 1, 2]);
    }

    #[test]
    fn token_budget_limits_admission() {
        let seqs = mk_seqs(&[30, 30, 30]);
        let mut s = Scheduler::new(cfg(), BlockManager::new(16, 64));
        for id in 0..3 {
            s.add(id);
        }
        // 30 + 30 <= 64 but the third only gets the 4 remaining budget
        // tokens as a partial first chunk
        let plan = s.plan(&seqs);
        assert_eq!(plan.chunks.len(), 3);
        assert_eq!(plan.chunks[0].end, 30);
        assert_eq!(plan.chunks[1].end, 30);
        assert_eq!((plan.chunks[2].start, plan.chunks[2].end), (0, 4));
        let total: usize =
            plan.chunks.iter().map(|c| c.end - c.start).sum();
        assert_eq!(total, 64);
    }

    #[test]
    fn legacy_mode_token_budget_limits_prefill_batch() {
        let seqs = mk_seqs(&[30, 30, 30]);
        let mut s = Scheduler::new(
            EngineConfig { enable_chunked_prefill: false, ..cfg() },
            BlockManager::new(16, 64),
        );
        for id in 0..3 {
            s.add(id);
        }
        // legacy: 30 + 30 <= 64 but +30 more would exceed; no partials
        let plan = s.plan(&seqs);
        assert_eq!(plan.chunks.len(), 2);
        assert!(plan.chunks.iter().all(|c| c.end - c.start == 30));
        assert!(plan.decode.is_empty());
    }

    #[test]
    fn chunk_cap_splits_prefill_across_steps() {
        let mut seqs = mk_seqs(&[30]);
        let mut s = Scheduler::new(
            EngineConfig { max_prefill_chunk: 12, ..cfg() },
            BlockManager::new(16, 64),
        );
        s.add(0);
        let mut bounds = vec![];
        for _ in 0..4 {
            let plan = s.plan(&seqs);
            if plan.is_idle() {
                break;
            }
            if let Some(c) = plan.chunks.first() {
                bounds.push((c.start, c.end));
            }
            apply(&mut s, &mut seqs, &plan);
        }
        assert_eq!(bounds, vec![(0, 12), (12, 24), (24, 30)]);
        assert_eq!(seqs[&0].state, SeqState::Running);
    }

    #[test]
    fn cold_chunk_never_exceeds_largest_bucket() {
        // prompt longer than the largest prefill bucket (32): the cold
        // first chunk is bucket-capped, the rest continues start>0 —
        // the structural fix for the recompute hazard
        let mut seqs = mk_seqs(&[50]);
        let mut s = Scheduler::new(cfg(), BlockManager::new(16, 64));
        s.add(0);
        let plan = s.plan(&seqs);
        assert_eq!(plan.chunks.len(), 1);
        assert_eq!((plan.chunks[0].start, plan.chunks[0].end), (0, 32));
        apply(&mut s, &mut seqs, &plan);
        let plan = s.plan(&seqs);
        assert_eq!((plan.chunks[0].start, plan.chunks[0].end), (32, 50));
        assert!(!plan.chunks[0].admitted);
        apply(&mut s, &mut seqs, &plan);
        assert_eq!(seqs[&0].state, SeqState::Running);
        assert!(s.bm.check_conservation());
    }

    #[test]
    fn cold_batch_jointly_fits_one_bucket() {
        // Non-cross-product bucket grid (1,128) + (4,32): two 100-token
        // cold prompts must NOT admit together (no single bucket has
        // batch >= 2 && seq >= 100) — the second waits, and each
        // admitted cold batch fits one compiled bucket exactly.
        let mut seqs = mk_seqs(&[100, 100]);
        seqs.get_mut(&1).unwrap().prompt = vec![2; 100]; // no cache hit
        let mut s = Scheduler::new(
            EngineConfig {
                max_running: 4,
                max_batch_tokens: 512,
                decode_batches: vec![1, 2, 4],
                prefill_buckets: vec![(1, 128), (4, 32)],
                ..Default::default()
            },
            BlockManager::new(16, 64),
        );
        s.add(0);
        s.add(1);
        let plan = s.plan(&seqs);
        assert_eq!(plan.chunks.len(), 1);
        assert_eq!((plan.chunks[0].id, plan.chunks[0].end), (0, 100));
        apply(&mut s, &mut seqs, &plan);
        // next step: seq 0 decodes, seq 1 admits alone via (1,128)
        let plan = s.plan(&seqs);
        assert_eq!(plan.decode, vec![0]);
        assert_eq!(plan.chunks.len(), 1);
        assert_eq!((plan.chunks[0].id, plan.chunks[0].end), (1, 100));
        // and three short cold prompts batch through (4,32) together
        let mut seqs = mk_seqs(&[20, 20, 20]);
        let mut s = Scheduler::new(
            EngineConfig {
                max_running: 4,
                max_batch_tokens: 512,
                decode_batches: vec![1, 2, 4],
                prefill_buckets: vec![(1, 128), (4, 32)],
                ..Default::default()
            },
            BlockManager::new(16, 64),
        );
        for id in 0..3 {
            s.add(id);
        }
        let plan = s.plan(&seqs);
        assert_eq!(plan.chunks.len(), 3);
    }

    #[test]
    fn mixed_step_decodes_while_chunking() {
        let mut seqs = mk_seqs(&[8, 40]);
        let mut s = Scheduler::new(
            EngineConfig { max_prefill_chunk: 16, ..cfg() },
            BlockManager::new(16, 64),
        );
        s.add(0);
        let plan = s.plan(&seqs); // seq 0 admits whole
        apply(&mut s, &mut seqs, &plan);
        s.add(1);
        let plan = s.plan(&seqs);
        // seq 0 decodes while seq 1 runs its first chunk: a mixed step
        assert_eq!(plan.decode, vec![0]);
        assert_eq!(plan.chunks.len(), 1);
        assert_eq!(plan.chunks[0].id, 1);
        assert_eq!((plan.chunks[0].start, plan.chunks[0].end), (0, 16));
        apply(&mut s, &mut seqs, &plan);
        let plan = s.plan(&seqs);
        assert_eq!(plan.decode, vec![0]);
        assert_eq!((plan.chunks[0].start, plan.chunks[0].end), (16, 32));
    }

    #[test]
    fn cached_prefix_relaxes_token_budget() {
        // register a 32-token prompt's blocks via a first sequence, then
        // two identical prompts admit together under a budget their full
        // lengths would blow (only post-hit tokens are budgeted).
        let shared: Vec<u32> = (0..32).collect();
        let mut seqs: HashMap<u64, Sequence> = (0..3u64)
            .map(|id| {
                (id,
                 Sequence::new(id, shared.clone(),
                               SamplingParams::default()))
            })
            .collect();
        let mut s = Scheduler::new(
            EngineConfig {
                max_running: 4,
                max_batch_tokens: 40,
                decode_batches: vec![1, 2, 4],
                prefill_buckets: vec![(4, 32)],
                ..Default::default()
            },
            BlockManager::new(16, 64),
        );
        s.add(0);
        let plan = s.plan(&seqs);
        assert_eq!(plan.chunks.len(), 1);
        assert_eq!((plan.chunks[0].start, plan.chunks[0].end), (0, 32));
        apply(&mut s, &mut seqs, &plan);
        s.on_finished(0);
        s.add(1);
        s.add(2);
        let plan = s.plan(&seqs);
        // 16 + 16 post-hit tokens <= 40; full 32 + 32 would not fit
        let ids: Vec<u64> = plan.chunks.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![1, 2]);
        for c in &plan.chunks {
            assert_eq!((c.start, c.end), (16, 32));
        }
        assert!(s.bm.check_conservation());
        assert_eq!(s.bm.table(1).unwrap()[0], s.bm.table(2).unwrap()[0]);
    }

    #[test]
    fn fcfs_no_starvation_head_of_line() {
        // a head request that does not fit *right now* (but could once
        // the pool drains) blocks admission rather than being skipped
        let mut seqs = mk_seqs(&[32, 96, 2]);
        // distinct content so the big head can't ride seq 0's cache
        seqs.get_mut(&1).unwrap().prompt = vec![2; 96];
        let mut s = Scheduler::new(cfg(), BlockManager::new(16, 8));
        s.bm.watermark_blocks = 1;
        s.add(0);
        let plan = s.plan(&seqs); // seq 0 prefills whole (2 blocks)
        apply(&mut s, &mut seqs, &plan);
        s.add(1); // needs 6 blocks + watermark > free, but <= pool
        s.add(2);
        let plan = s.plan(&seqs);
        assert!(plan.chunks.is_empty()); // seq 2 must NOT skip ahead
        assert_eq!(plan.decode, vec![0]);
        assert_eq!(s.waiting_len(), 2);
        assert!(s.dropped.is_empty());
    }

    #[test]
    fn impossible_head_is_dropped_not_wedged() {
        // a waiting sequence whose content can never fit the pool at
        // all (recompute content grown past it, or an oversized direct
        // add) is dropped so the queue behind it still serves
        let seqs = mk_seqs(&[1000, 2]);
        let mut s = Scheduler::new(cfg(), BlockManager::new(16, 8));
        s.add(0);
        s.add(1);
        let plan = s.plan(&seqs);
        assert_eq!(s.dropped, vec![0]);
        assert_eq!(plan.chunks.len(), 1); // seq 1 admits
        assert_eq!(plan.chunks[0].id, 1);
        assert_eq!(s.waiting_len(), 0);
        assert!(s.bm.check_conservation());
    }

    #[test]
    fn preemption_lifo_under_pressure() {
        let mut seqs = mk_seqs(&[16, 16]);
        let mut s = Scheduler::new(cfg(), BlockManager::new(4, 9));
        s.bm.watermark_blocks = 0;
        s.add(0);
        s.add(1);
        // both admitted: 4 + 4 = 8 of 9 blocks
        let plan = s.plan(&seqs);
        assert_eq!(plan.chunks.len(), 2);
        apply(&mut s, &mut seqs, &plan);
        // grow both: each wants a new block at ctx 18 -> only 1 free;
        // seq 1 is preempted (LIFO). Its prompt blocks are cached
        // (identical prompts), so the chunked scheduler immediately
        // re-admits it warm in the same plan — recompute via a
        // one-token suffix chunk instead of a full re-prefill.
        let plan = s.plan(&seqs);
        assert_eq!(plan.decode, vec![0]);
        assert_eq!(s.preempted, vec![1]);
        for &id in &s.preempted {
            seqs.get_mut(&id).unwrap().preempt();
        }
        assert_eq!(plan.chunks.len(), 1);
        let c = &plan.chunks[0];
        assert!(c.admitted && c.id == 1);
        assert_eq!((c.start, c.end), (16, 17)); // warm recompute chunk
        assert_eq!(s.waiting_len(), 0);
        assert!(s.bm.check_conservation());
    }

    #[test]
    fn sole_oversized_sequence_is_dropped() {
        // one sequence that alone outgrows the pool: reported dropped,
        // not requeued (the engine finishes it with PoolExhausted)
        let mut seqs = mk_seqs(&[8]);
        let mut s = Scheduler::new(cfg(), BlockManager::new(4, 3));
        s.bm.watermark_blocks = 0;
        s.add(0);
        let plan = s.plan(&seqs);
        assert_eq!(plan.chunks.len(), 1);
        apply(&mut s, &mut seqs, &plan);
        // grow until the pool (3 blocks = 12 slots) is outgrown
        let mut dropped = false;
        for _ in 0..8 {
            let plan = s.plan(&seqs);
            if !s.dropped.is_empty() {
                assert_eq!(s.dropped, vec![0]);
                assert!(plan.is_idle());
                dropped = true;
                break;
            }
            apply(&mut s, &mut seqs, &plan);
        }
        assert!(dropped, "oversized sequence never dropped");
        assert_eq!(s.running_len(), 0);
        assert_eq!(s.bm.holds(0), 0);
        assert!(s.bm.check_conservation());
    }

    #[test]
    fn finished_releases_blocks() {
        let mut seqs = mk_seqs(&[8]);
        let mut s = Scheduler::new(cfg(), BlockManager::new(16, 8));
        s.add(0);
        let plan = s.plan(&seqs);
        apply(&mut s, &mut seqs, &plan);
        assert!(s.bm.holds(0) > 0);
        s.on_finished(0);
        assert_eq!(s.bm.holds(0), 0);
        assert!(!s.has_work());
    }

    #[test]
    fn random_workload_invariants() {
        for chunk in [0usize, 7, 16] {
            prop::check("scheduler invariants", 10, |rng| {
                let mut seqs = HashMap::new();
                let mut s = Scheduler::new(
                    EngineConfig {
                        max_running: 1 + rng.below(6),
                        max_batch_tokens: 32 + rng.below(96),
                        decode_batches: vec![1, 2, 4, 8],
                        prefill_buckets: vec![(4, 32)],
                        max_prefill_chunk: chunk,
                        ..Default::default()
                    },
                    BlockManager::new(1 + rng.below(8),
                                      16 + rng.below(64)),
                );
                let mut next = 0u64;
                for _ in 0..120 {
                    if rng.below(3) == 0 {
                        let l = 1 + rng.below(24);
                        seqs.insert(
                            next,
                            Sequence::new(next, vec![1; l],
                                          SamplingParams::default()),
                        );
                        s.add(next);
                        next += 1;
                    }
                    let plan = s.plan(&seqs);
                    for &id in &s.preempted {
                        let q = seqs.get_mut(&id).unwrap();
                        if q.state == SeqState::Running
                            || q.state == SeqState::Prefilling
                        {
                            q.preempt();
                        }
                    }
                    for &id in &s.dropped {
                        seqs.get_mut(&id).unwrap().finish(
                            super::super::sequence::FinishReason
                                ::PoolExhausted,
                        );
                    }
                    for c in &plan.chunks {
                        // chunk invariants: in-range, block-covered
                        let q = &seqs[&c.id];
                        assert!(c.start < c.end);
                        assert!(c.end <= q.context_len());
                        assert!(s.bm.holds(c.id) * s.bm.block_size
                            >= c.end);
                    }
                    apply(&mut s, &mut seqs, &plan);
                    for id in plan.decode {
                        assert!(s.bm.holds(id) > 0);
                        let q = seqs.get_mut(&id).unwrap();
                        // randomly finish
                        if rng.below(8) == 0 {
                            q.finish(
                                super::super::sequence::FinishReason
                                    ::MaxTokens,
                            );
                            s.on_finished(id);
                        }
                    }
                    assert!(s.bm.check_conservation());
                    assert!(s.running_len() <= s.cfg.max_running);
                }
            });
        }
    }
}
