//! Continuous-batching scheduler (the vLLM policy shape):
//!
//! * FCFS waiting queue; prefill takes priority when new sequences can be
//!   admitted (block-manager watermark + token budget + a free running
//!   slot), otherwise the running set decodes one step as a batch.
//! * Admission consults the prefix cache: a sequence whose leading full
//!   blocks are cached shares them (refcounted) instead of allocating,
//!   and only the tokens past the hit count against the prefill token
//!   budget — so warm traffic admits in larger batches. The per-sequence
//!   hit length rides along in [`StepPlan::Prefill`] for the engine's
//!   partial prefill.
//! * KV growth for every scheduled decode is reserved up front; on
//!   pressure the *most recently admitted* running sequence is preempted
//!   (LIFO, vLLM's recompute policy), releasing its blocks (shared ones
//!   just drop a reference) and requeueing it at the waiting front.
//!
//! The scheduler owns sequence *ids* only; token/KV state lives in the
//! engine maps.

use std::collections::{HashMap, VecDeque};

use crate::config::EngineConfig;

use super::block_manager::{Alloc, BlockManager};
use super::sequence::Sequence;
#[cfg(test)]
use super::sequence::SeqState;

/// What the engine should execute this step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StepPlan {
    /// `cached[i]` is the prompt-prefix length of `ids[i]` already
    /// covered by shared cache blocks (prefill starts past it).
    Prefill { ids: Vec<u64>, cached: Vec<usize> },
    Decode { ids: Vec<u64> },
    Idle,
}

#[derive(Debug)]
pub struct Scheduler {
    pub cfg: EngineConfig,
    pub bm: BlockManager,
    waiting: VecDeque<u64>,
    running: Vec<u64>, // admission order; preemption pops from the back
    /// ids preempted this step (engine must drop their KV).
    pub preempted: Vec<u64>,
}

impl Scheduler {
    pub fn new(cfg: EngineConfig, mut bm: BlockManager) -> Scheduler {
        bm.enable_prefix_caching = cfg.enable_prefix_caching;
        Scheduler { cfg, bm, waiting: VecDeque::new(), running: vec![],
                    preempted: vec![] }
    }

    pub fn add(&mut self, id: u64) {
        self.waiting.push_back(id);
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }
    pub fn running_len(&self) -> usize {
        self.running.len()
    }
    pub fn running_ids(&self) -> &[u64] {
        &self.running
    }
    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    /// Remove a finished sequence and release its blocks.
    pub fn on_finished(&mut self, id: u64) {
        self.running.retain(|&r| r != id);
        self.waiting.retain(|&r| r != id);
        self.bm.release(id);
    }

    /// Decide the next step. `seqs` provides prompt/context lengths.
    pub fn plan(&mut self, seqs: &HashMap<u64, Sequence>) -> StepPlan {
        self.preempted.clear();
        // ---- try prefill admission (vLLM prefers draining the queue)
        let max_prefill_batch = self
            .cfg
            .prefill_buckets
            .iter()
            .map(|&(b, _)| b)
            .max()
            .unwrap_or(1);
        let slots = self.cfg.max_running.saturating_sub(self.running.len());
        if !self.waiting.is_empty() && slots > 0 {
            let mut ids = vec![];
            let mut cached = vec![];
            let mut tokens = 0usize;
            while let Some(&id) = self.waiting.front() {
                if ids.len() >= max_prefill_batch.min(slots) {
                    break;
                }
                let toks = seqs[&id].full_tokens();
                // only tokens past the cached prefix cost prefill compute
                let hit = self.bm.cached_prefix_tokens(&toks);
                if !ids.is_empty()
                    && tokens + (toks.len() - hit)
                        > self.cfg.max_batch_tokens
                {
                    break;
                }
                // allocate doubles as the admission check (one hash
                // walk); on NoSpace keep FCFS head-of-line order —
                // don't skip ahead
                if self.bm.allocate(id, &toks) == Alloc::NoSpace {
                    break;
                }
                tokens += toks.len() - hit;
                ids.push(id);
                cached.push(hit);
                self.waiting.pop_front();
            }
            if !ids.is_empty() {
                self.running.extend(&ids);
                return StepPlan::Prefill { ids, cached };
            }
        }
        // ---- decode the running set (reserve growth; preempt on pressure)
        if self.running.is_empty() {
            return StepPlan::Idle;
        }
        let max_decode = self
            .cfg
            .decode_batches
            .iter()
            .copied()
            .max()
            .unwrap_or(1);
        // reserve +1 token for each scheduled sequence, preempting from
        // the back until everything scheduled fits
        loop {
            let batch: Vec<u64> =
                self.running.iter().copied().take(max_decode).collect();
            let mut ok = true;
            for &id in &batch {
                let ctx = seqs[&id].context_len();
                if self.bm.append_token(id, ctx + 1) == Alloc::NoSpace {
                    ok = false;
                    break;
                }
            }
            if ok {
                if batch.is_empty() {
                    return StepPlan::Idle;
                }
                return StepPlan::Decode { ids: batch };
            }
            // preempt the most recent admission (never the oldest alone)
            let victim = *self.running.last().unwrap();
            if self.running.len() == 1 {
                // cannot make progress: the single sequence exceeds the
                // pool; the engine will finish it with an error
                self.preempted.push(victim);
                self.running.clear();
                self.bm.release(victim);
                return StepPlan::Idle;
            }
            self.running.pop();
            self.bm.release(victim);
            self.waiting.push_front(victim);
            self.preempted.push(victim);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sequence::SamplingParams;
    use crate::util::prop;

    fn mk_seqs(lens: &[usize]) -> HashMap<u64, Sequence> {
        lens.iter()
            .enumerate()
            .map(|(i, &l)| {
                (i as u64,
                 Sequence::new(i as u64, vec![1; l],
                               SamplingParams::default()))
            })
            .collect()
    }

    fn cfg() -> EngineConfig {
        EngineConfig {
            max_running: 4,
            max_batch_tokens: 64,
            decode_batches: vec![1, 2, 4],
            prefill_buckets: vec![(1, 32), (4, 32)],
            ..Default::default()
        }
    }

    #[test]
    fn prefill_first_then_decode() {
        let seqs = mk_seqs(&[8, 8, 8]);
        let mut s = Scheduler::new(cfg(), BlockManager::new(16, 64));
        for id in 0..3 {
            s.add(id);
        }
        match s.plan(&seqs) {
            StepPlan::Prefill { ids, cached } => {
                assert_eq!(ids, vec![0, 1, 2]);
                assert_eq!(cached, vec![0, 0, 0]); // cold cache
            }
            p => panic!("want prefill, got {p:?}"),
        }
        match s.plan(&seqs) {
            StepPlan::Decode { ids } => assert_eq!(ids, vec![0, 1, 2]),
            p => panic!("want decode, got {p:?}"),
        }
    }

    #[test]
    fn token_budget_limits_prefill_batch() {
        let seqs = mk_seqs(&[30, 30, 30]);
        let mut s = Scheduler::new(cfg(), BlockManager::new(16, 64));
        for id in 0..3 {
            s.add(id);
        }
        match s.plan(&seqs) {
            // 30 + 30 <= 64 but +30 more would exceed
            StepPlan::Prefill { ids, .. } => assert_eq!(ids.len(), 2),
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn cached_prefix_relaxes_token_budget() {
        // register a 32-token prompt's blocks via a first sequence, then
        // two identical prompts admit together under a budget their full
        // lengths would blow (only post-hit tokens are budgeted).
        let shared: Vec<u32> = (0..32).collect();
        let mut seqs: HashMap<u64, Sequence> = (0..3u64)
            .map(|id| {
                (id,
                 Sequence::new(id, shared.clone(),
                               SamplingParams::default()))
            })
            .collect();
        let mut s = Scheduler::new(
            EngineConfig {
                max_running: 4,
                max_batch_tokens: 40,
                decode_batches: vec![1, 2, 4],
                prefill_buckets: vec![(4, 32)],
                ..Default::default()
            },
            BlockManager::new(16, 64),
        );
        s.add(0);
        match s.plan(&seqs) {
            StepPlan::Prefill { ids, cached } => {
                assert_eq!(ids, vec![0]);
                assert_eq!(cached, vec![0]);
            }
            p => panic!("{p:?}"),
        }
        // engine side: register the filled blocks, then finish
        let toks = seqs[&0].full_tokens();
        assert_eq!(s.bm.register_prefix(0, &toks).len(), 2);
        seqs.get_mut(&0).unwrap().state = SeqState::Running;
        s.on_finished(0);
        s.add(1);
        s.add(2);
        match s.plan(&seqs) {
            StepPlan::Prefill { ids, cached } => {
                // 16 + 16 post-hit tokens <= 40; full 32 + 32 would not fit
                assert_eq!(ids, vec![1, 2]);
                assert_eq!(cached, vec![16, 16]);
            }
            p => panic!("{p:?}"),
        }
        assert!(s.bm.check_conservation());
        assert_eq!(s.bm.table(1).unwrap()[0], s.bm.table(2).unwrap()[0]);
    }

    #[test]
    fn fcfs_no_starvation_head_of_line() {
        // a huge head request blocks admission rather than being skipped
        let seqs = mk_seqs(&[1000, 2]);
        let mut s = Scheduler::new(cfg(), BlockManager::new(16, 8));
        s.add(0);
        s.add(1);
        assert_eq!(s.plan(&seqs), StepPlan::Idle);
        assert_eq!(s.waiting_len(), 2);
    }

    #[test]
    fn preemption_lifo_under_pressure() {
        let mut seqs = mk_seqs(&[16, 16]);
        let mut s = Scheduler::new(cfg(), BlockManager::new(4, 9));
        s.bm.watermark_blocks = 0;
        s.add(0);
        s.add(1);
        // both admitted: 4 + 4 = 8 of 9 blocks
        match s.plan(&seqs) {
            StepPlan::Prefill { ids, .. } => assert_eq!(ids.len(), 2),
            p => panic!("{p:?}"),
        }
        // grow both: each wants a new block at ctx 17 -> only 1 free
        for q in seqs.values_mut() {
            q.state = SeqState::Running;
        }
        match s.plan(&seqs) {
            StepPlan::Decode { ids } => {
                assert_eq!(ids, vec![0]); // seq 1 preempted (LIFO)
            }
            p => panic!("{p:?}"),
        }
        assert_eq!(s.preempted, vec![1]);
        assert_eq!(s.waiting_len(), 1);
        assert!(s.bm.check_conservation());
    }

    #[test]
    fn finished_releases_blocks() {
        let seqs = mk_seqs(&[8]);
        let mut s = Scheduler::new(cfg(), BlockManager::new(16, 8));
        s.add(0);
        s.plan(&seqs);
        assert!(s.bm.holds(0) > 0);
        s.on_finished(0);
        assert_eq!(s.bm.holds(0), 0);
        assert!(!s.has_work());
    }

    #[test]
    fn random_workload_invariants() {
        prop::check("scheduler invariants", 15, |rng| {
            let mut seqs = HashMap::new();
            let mut s = Scheduler::new(
                EngineConfig {
                    max_running: 1 + rng.below(6),
                    max_batch_tokens: 32 + rng.below(96),
                    decode_batches: vec![1, 2, 4, 8],
                    prefill_buckets: vec![(4, 32)],
                    ..Default::default()
                },
                BlockManager::new(1 + rng.below(8), 16 + rng.below(64)),
            );
            let mut next = 0u64;
            for _ in 0..120 {
                if rng.below(3) == 0 {
                    let l = 1 + rng.below(24);
                    seqs.insert(
                        next,
                        Sequence::new(next, vec![1; l],
                                      SamplingParams::default()),
                    );
                    s.add(next);
                    next += 1;
                }
                match s.plan(&seqs) {
                    StepPlan::Prefill { ids, .. } => {
                        assert!(!ids.is_empty());
                        for id in ids {
                            seqs.get_mut(&id).unwrap().state =
                                SeqState::Running;
                        }
                    }
                    StepPlan::Decode { ids } => {
                        assert!(!ids.is_empty());
                        // running set ⊆ allocated set
                        for &id in &ids {
                            assert!(s.bm.holds(id) > 0);
                            let q = seqs.get_mut(&id).unwrap();
                            q.record_token(7);
                            // randomly finish
                            if rng.below(8) == 0 {
                                q.finish(
                                    super::super::sequence::FinishReason
                                        ::MaxTokens,
                                );
                                s.on_finished(id);
                            }
                        }
                    }
                    StepPlan::Idle => {}
                }
                for &id in &s.preempted {
                    if let Some(q) = seqs.get_mut(&id) {
                        if q.state == SeqState::Running {
                            q.preempt();
                        }
                    }
                }
                assert!(s.bm.check_conservation());
                assert!(s.running_len() <= s.cfg.max_running);
            }
        });
    }
}
