//! Deterministic replica cores for tests and benchmarks — no PJRT
//! runtime, no artifacts, so everything built on them runs in tier-1
//! CI.
//!
//! * [`FakeCore`] is the real [`Scheduler`] + `BlockManager` driven
//!   exactly the way [`Engine`](super::engine::Engine) drives them,
//!   with [`fake_next_token`] standing in for the model: the next
//!   token is a pure function of the content so far, so token streams
//!   cannot depend on routing, chunking, preemption, batching, replica
//!   replay, or *thread interleaving* — any divergence between two
//!   serving loops over FakeCores is a real scheduling/recovery bug.
//!   That property is what makes the async-vs-sync stream-identity
//!   goldens possible.
//! * [`EchoCore`] finishes every request at submission (echoing the
//!   first prompt token) — the minimal core for server-lifecycle tests
//!   where engine behavior is irrelevant.
//!
//! Both implement [`ReplicaCore`] including the incremental
//! [`take_emitted`](ReplicaCore::take_emitted) streaming surface, and
//! both are `Send`, so they can drive the per-replica worker threads
//! in [`worker`](super::worker) as well as the synchronous loop.

// sqlint: allow-file(panic) test-double core — a panic is an injected fault
use std::collections::HashMap;

use crate::config::{CacheWatermarks, EngineConfig};
use crate::runtime::kvq::KvStash;

use super::block_manager::{chain_hashes, BlockManager, CacheEvent};
use super::engine::StepOutcome;
use super::replica::{CoreStats, ReplicaCore, ReplicaError};
use super::scheduler::Scheduler;
use super::sequence::{
    FinishReason, SamplingParams, SeqState, Sequence,
};

/// Deterministic fake model: the next token is a pure function of the
/// content so far (FNV-1a over the tokens, mod 997).
pub fn fake_next_token(content: &[u32]) -> u32 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in content {
        h ^= t as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    (h % 997) as u32
}

/// One replica core: the real scheduler + block manager driven exactly
/// the way `Engine` drives them, with the fake model supplying tokens.
pub struct FakeCore {
    /// The scheduler (public so tests can probe `sched.bm` cache
    /// state directly against the router's shared directory).
    pub sched: Scheduler,
    seqs: HashMap<u64, Sequence>,
    finished: Vec<Sequence>,
    emitted: Vec<(u64, u32)>,
    next_id: u64,
    prefill_tokens_executed: usize,
    cached_prefix_tokens: usize,
    kv_migrations_in: usize,
    kv_migrations_out: usize,
    migrated_bytes: usize,
}

impl FakeCore {
    /// Build over a fresh `BlockManager` with `total_blocks` blocks.
    pub fn new(ecfg: EngineConfig, total_blocks: usize) -> FakeCore {
        let mut bm = BlockManager::new(ecfg.block_size, total_blocks);
        bm.set_kv_pool(ecfg.kv_pool_blocks);
        FakeCore {
            sched: Scheduler::new(ecfg, bm),
            seqs: HashMap::new(),
            finished: vec![],
            emitted: vec![],
            next_id: 0,
            prefill_tokens_executed: 0,
            cached_prefix_tokens: 0,
            kv_migrations_in: 0,
            kv_migrations_out: 0,
            migrated_bytes: 0,
        }
    }

    fn finish_if_done(&mut self, id: u64) {
        if let Some(r) = self.seqs[&id].should_finish() {
            let mut q = self.seqs.remove(&id).unwrap();
            q.finish(r);
            self.sched.on_finished(id);
            self.finished.push(q);
        }
    }
}

impl ReplicaCore for FakeCore {
    fn submit(&mut self, prompt: Vec<u32>, params: SamplingParams)
        -> Result<u64, ReplicaError> {
        let id = self.next_id;
        self.next_id += 1;
        self.seqs.insert(id, Sequence::new(id, prompt, params));
        self.sched.add(id);
        Ok(id)
    }

    fn step(&mut self) -> Result<StepOutcome, ReplicaError> {
        let plan = self.sched.plan(&self.seqs);
        // The fake model holds no stash bytes, so tiering needs no byte
        // moves here — but the report vecs must still be drained (the
        // engine does the same in `drain_cache_tiering`), and the
        // demotion/restore *counters* live in `bm.stats` regardless.
        self.sched.bm.take_evicted();
        self.sched.bm.take_pool_dropped();
        self.sched.bm.take_restored();
        for v in self.sched.preempted.clone() {
            let q = self.seqs.get_mut(&v).unwrap();
            if matches!(q.state,
                        SeqState::Running | SeqState::Prefilling) {
                q.preempt();
            }
        }
        for v in self.sched.dropped.clone() {
            if let Some(mut q) = self.seqs.remove(&v) {
                q.finish(FinishReason::PoolExhausted);
                self.sched.on_finished(v);
                self.finished.push(q);
            }
        }
        let mut chunk_tokens = 0;
        let mut completed_prefills = 0;
        for c in &plan.chunks {
            let toks = self.seqs[&c.id].full_tokens();
            {
                let q = self.seqs.get_mut(&c.id).unwrap();
                q.prefill_progress = c.end;
                if c.admitted {
                    q.cached_prefix_len = c.start;
                    self.cached_prefix_tokens += c.start;
                }
            }
            self.prefill_tokens_executed += c.end - c.start;
            chunk_tokens += c.end - c.start;
            self.sched.bm.register_prefix(c.id, &toks[..c.end]);
            let q = self.seqs.get_mut(&c.id).unwrap();
            if c.end == toks.len() {
                completed_prefills += 1;
                q.state = SeqState::Running;
                let tok = fake_next_token(&toks);
                q.record_token(tok);
                self.emitted.push((c.id, tok));
                self.finish_if_done(c.id);
            } else {
                q.state = SeqState::Prefilling;
            }
        }
        let decoded = plan.decode.len();
        for id in plan.decode.clone() {
            let q = self.seqs.get_mut(&id).unwrap();
            let tok = fake_next_token(&q.full_tokens());
            q.record_token(tok);
            self.emitted.push((id, tok));
            self.finish_if_done(id);
        }
        if chunk_tokens == 0 && decoded == 0 {
            Ok(StepOutcome::Idle)
        } else {
            Ok(StepOutcome::Ran {
                chunk_tokens,
                completed_prefills,
                decoded,
            })
        }
    }

    fn has_work(&self) -> bool {
        self.sched.has_work()
    }
    fn take_finished(&mut self) -> Vec<Sequence> {
        std::mem::take(&mut self.finished)
    }
    fn take_emitted(&mut self) -> Vec<(u64, u32)> {
        std::mem::take(&mut self.emitted)
    }
    fn drain_inflight(&mut self) -> Vec<Sequence> {
        self.sched.drain();
        let mut out: Vec<Sequence> =
            self.seqs.drain().map(|(_, s)| s).collect();
        self.sched.bm.clear_cache();
        self.sched.bm.take_evicted();
        self.sched.bm.take_pool_dropped();
        self.sched.bm.take_restored();
        // the drained sequences' outputs already hold any tokens still
        // buffered in the stream log
        self.emitted.clear();
        out.sort_by_key(|s| s.id);
        out
    }
    fn block_size(&self) -> usize {
        self.sched.bm.block_size
    }
    fn queue_depths(&self) -> (usize, usize) {
        (self.sched.waiting_len(), self.sched.running_len())
    }
    fn enable_cache_events(&mut self) {
        self.sched.bm.enable_cache_events = true;
    }
    fn take_cache_events(&mut self) -> Vec<CacheEvent> {
        self.sched.bm.take_cache_events()
    }
    fn set_cache_watermarks(&mut self, wm: CacheWatermarks) {
        self.sched.bm.set_cache_watermarks(wm.high, wm.low);
    }
    fn export_blocks(&mut self, tokens: &[u32])
        -> Result<Vec<(u64, Vec<u8>)>, ReplicaError> {
        // the fake model builds no KV rows, so exports ship empty f32
        // stashes: valid wire payloads whose whole value is the hash —
        // exactly what the receiver's pool index (and the fake restore
        // path) consumes. Same contiguity walk and one-block-short cap
        // as the engine.
        let bs = self.sched.bm.block_size;
        let cap = tokens.len().saturating_sub(1) / bs;
        let mut out = vec![];
        for h in chain_hashes(tokens, bs).into_iter().take(cap) {
            if self.sched.bm.lookup_hash(h).is_none()
                && !self.sched.bm.pool_contains(h)
            {
                break;
            }
            let wire = KvStash::F32(vec![]).to_wire();
            self.kv_migrations_out += 1;
            self.migrated_bytes += wire.len();
            out.push((h, wire));
        }
        Ok(out)
    }
    fn import_blocks(&mut self, blocks: &[(u64, Vec<u8>)])
        -> Result<usize, ReplicaError> {
        let mut adopted = 0;
        for (h, wire) in blocks {
            KvStash::from_wire(wire).map_err(|e| {
                ReplicaError::Transient(format!("bad kv wire: {e:#}"))
            })?;
            if self.sched.bm.adopt_pooled(*h) {
                self.kv_migrations_in += 1;
                self.migrated_bytes += wire.len();
                adopted += 1;
            }
        }
        self.sched.bm.take_pool_dropped();
        Ok(adopted)
    }
    fn core_stats(&self) -> CoreStats {
        CoreStats {
            waiting: self.sched.waiting_len(),
            running: self.sched.running_len(),
            kv_occupancy: self.sched.bm.occupancy(),
            cache: self.sched.bm.stats.clone(),
            prefill_tokens_executed: self.prefill_tokens_executed,
            cached_prefix_tokens: self.cached_prefix_tokens,
            ttft_steps_p50: 0.0,
            pool_blocks: self.sched.bm.kv_pool_len(),
            recompute_avoided_tokens: self.sched.bm.stats.restores
                * self.sched.bm.block_size,
            kv_migrations_in: self.kv_migrations_in,
            kv_migrations_out: self.kv_migrations_out,
            migrated_bytes: self.migrated_bytes,
        }
    }
}

/// A stub core that finishes every request at submission (echoing one
/// token) — enough to drive the full server lifecycle without a PJRT
/// runtime or even a scheduler.
pub struct EchoCore {
    next: u64,
    finished: Vec<Sequence>,
    emitted: Vec<(u64, u32)>,
}

impl EchoCore {
    /// A fresh echo core.
    pub fn new() -> EchoCore {
        EchoCore { next: 0, finished: vec![], emitted: vec![] }
    }
}

impl Default for EchoCore {
    fn default() -> EchoCore {
        EchoCore::new()
    }
}

impl ReplicaCore for EchoCore {
    fn submit(&mut self, prompt: Vec<u32>, params: SamplingParams)
        -> Result<u64, ReplicaError> {
        let id = self.next;
        self.next += 1;
        let first = prompt.first().copied().unwrap_or(0);
        let mut seq = Sequence::new(id, prompt, params);
        seq.record_token(first);
        self.emitted.push((id, first));
        seq.finish(FinishReason::MaxTokens);
        self.finished.push(seq);
        Ok(id)
    }
    fn step(&mut self) -> Result<StepOutcome, ReplicaError> {
        Ok(StepOutcome::Idle)
    }
    fn has_work(&self) -> bool {
        false
    }
    fn take_finished(&mut self) -> Vec<Sequence> {
        std::mem::take(&mut self.finished)
    }
    fn take_emitted(&mut self) -> Vec<(u64, u32)> {
        std::mem::take(&mut self.emitted)
    }
    fn drain_inflight(&mut self) -> Vec<Sequence> {
        vec![]
    }
    fn block_size(&self) -> usize {
        4
    }
    fn queue_depths(&self) -> (usize, usize) {
        (0, 0)
    }
    fn enable_cache_events(&mut self) {}
    fn take_cache_events(&mut self) -> Vec<CacheEvent> {
        vec![]
    }
    fn set_cache_watermarks(&mut self, _: CacheWatermarks) {}
    fn core_stats(&self) -> CoreStats {
        CoreStats::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fake_core_streams_every_recorded_token_exactly_once() {
        let mut core = FakeCore::new(EngineConfig {
            block_size: 4,
            ..Default::default()
        }, 64);
        let id = core
            .submit(vec![1, 2, 3], SamplingParams {
                max_new_tokens: 3,
                ..Default::default()
            })
            .unwrap();
        let mut streamed: Vec<u32> = vec![];
        let mut fin = None;
        for _ in 0..100 {
            core.step().unwrap();
            streamed.extend(
                core.take_emitted().into_iter().map(|(_, t)| t),
            );
            if let Some(q) = core.take_finished().pop() {
                fin = Some(q);
                break;
            }
        }
        let fin = fin.expect("request never finished");
        assert_eq!(fin.id, id);
        // the incremental stream is exactly the final output
        assert_eq!(streamed, fin.output);
        assert_eq!(streamed.len(), 3);
        // a second drain is empty
        assert!(core.take_emitted().is_empty());
    }

    #[test]
    fn echo_core_emits_its_token_at_submission() {
        let mut core = EchoCore::new();
        let id = core
            .submit(vec![9, 8], SamplingParams::default())
            .unwrap();
        assert_eq!(core.take_emitted(), vec![(id, 9)]);
        let fins = core.take_finished();
        assert_eq!(fins.len(), 1);
        assert_eq!(fins[0].output, vec![9]);
        assert_eq!(fins[0].finish, Some(FinishReason::MaxTokens));
    }
}
