//! Multi-replica front end: one [`Router`] owns N [`Replica`]s (data
//! parallelism — the scaling axis the paper's single-GPU W4A16 result
//! opens up) and places every request with a cache-aware policy.
//!
//! # Routing
//!
//! [`RoutingPolicy::CacheAware`] (the default) scores every replica as
//!
//! ```text
//! score(r) = cached_prefix_tokens(r, prompt)
//!          − load_penalty_tokens · (queued(r) + running(r))
//! ```
//!
//! and picks the max, ties broken by the lowest replica id — so a
//! shared-prefix burst lands on the replica already holding the prefix
//! KV (strictly less cold prefill work than spraying it round-robin),
//! while a replica that is merely warm never starves the others: once
//! its queue grows, the load penalty hands cold traffic to idle
//! replicas. With no hits anywhere the score degenerates to
//! least-loaded, which is also available directly
//! ([`RoutingPolicy::LeastLoaded`]), as is round-robin
//! ([`RoutingPolicy::RoundRobin`], the bench baseline).
//!
//! # The cache directory
//!
//! `cached_prefix_tokens(r, prompt)` is answered by a shared
//! [`CacheDirectory`] — a map from block content hash to the replica
//! ids caching that block — not by walking N block managers. Replicas
//! record a [`CacheEvent`] per registration/eviction (sliding-window
//! evictions included); the router drains those events after every
//! step, so one routing decision costs a single hash-chain walk over
//! the prompt's full blocks regardless of replica count. The directory
//! is a *hint*: a stale entry can only misroute, never corrupt —
//! admission inside the chosen replica re-walks its own chain with the
//! usual single-walk machinery.
//!
//! # Ids
//!
//! The router assigns *global* request ids in submission order and maps
//! them to `(replica, local id)`; finished sequences surface as
//! [`RoutedFinish`] carrying both the global id and the replica that
//! served it (reported on the wire as `"replica"`). A router over one
//! replica is bit-identical to driving that replica's core directly:
//! global ids equal local ids and `step` is a pass-through — the golden
//! tests pin this.

use std::collections::HashMap;

use anyhow::Result;

use crate::config::{RouterConfig, RoutingPolicy};

use super::block_manager::{chain_hashes, CacheEvent};
use super::replica::{Replica, ReplicaCore, ReplicaStats};
use super::sequence::{SamplingParams, Sequence};

/// Read-only (to the router's policies) map from block content hash to
/// the replicas whose prefix caches hold that block, maintained from
/// replica [`CacheEvent`]s. See the module docs.
#[derive(Debug, Default)]
pub struct CacheDirectory {
    /// Content hash → sorted replica ids holding it.
    map: HashMap<u64, Vec<usize>>,
}

impl CacheDirectory {
    /// Empty directory.
    pub fn new() -> CacheDirectory {
        CacheDirectory::default()
    }

    /// Distinct content hashes currently hinted.
    pub fn len(&self) -> usize {
        self.map.len()
    }
    /// No hints at all?
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Record that `replica` registered a block of `hash`.
    pub fn on_registered(&mut self, replica: usize, hash: u64) {
        let ids = self.map.entry(hash).or_default();
        if let Err(i) = ids.binary_search(&replica) {
            ids.insert(i, replica);
        }
    }

    /// Record that `replica` evicted its block of `hash`.
    pub fn on_evicted(&mut self, replica: usize, hash: u64) {
        let empty = match self.map.get_mut(&hash) {
            Some(ids) => {
                if let Ok(i) = ids.binary_search(&replica) {
                    ids.remove(i);
                }
                ids.is_empty()
            }
            None => false,
        };
        if empty {
            self.map.remove(&hash);
        }
    }

    /// Per-replica cached-prefix length (tokens) for `tokens`, under
    /// the same rules as
    /// [`super::block_manager::BlockManager`] lookups: full
    /// `block_size` blocks only, capped so at least one token is left
    /// to compute. One chain walk total — each replica's hit is the
    /// longest prefix of blocks whose hint set contains it.
    pub fn prefix_hits(&self, tokens: &[u32], block_size: usize,
                       n_replicas: usize) -> Vec<usize> {
        let mut hit = vec![0usize; n_replicas];
        if tokens.len() <= 1 || self.map.is_empty() {
            return hit;
        }
        let max_blocks = (tokens.len() - 1) / block_size;
        let mut alive = vec![true; n_replicas];
        let hashes = chain_hashes(&tokens[..max_blocks * block_size],
                                  block_size);
        for (k, h) in hashes.iter().enumerate() {
            let ids = self.map.get(h);
            let mut any = false;
            for r in 0..n_replicas {
                if !alive[r] {
                    continue;
                }
                match ids {
                    Some(ids) if ids.binary_search(&r).is_ok() => {
                        hit[r] = (k + 1) * block_size;
                        any = true;
                    }
                    _ => alive[r] = false,
                }
            }
            if !any {
                break;
            }
        }
        hit
    }
}

/// A finished request as the router reports it: the router-assigned
/// global id, the replica that served it, and the sequence (whose own
/// `id` field is the replica-local id).
#[derive(Debug)]
pub struct RoutedFinish {
    /// Router-assigned global request id (submission order).
    pub id: u64,
    /// Replica that served the request.
    pub replica: usize,
    /// The finished sequence (output, finish reason, timings).
    pub seq: Sequence,
}

/// The multi-replica front end; see the module docs.
pub struct Router<C: ReplicaCore> {
    /// Router configuration (`replicas` reflects the actual count).
    pub rcfg: RouterConfig,
    replicas: Vec<Replica<C>>,
    directory: CacheDirectory,
    /// KV block size shared by every replica (asserted at construction).
    block_size: usize,
    /// Global id → (replica id, local id) for in-flight requests.
    routes: HashMap<u64, (usize, u64)>,
    /// Per-replica local id → global id.
    local_to_global: Vec<HashMap<u64, u64>>,
    finished: Vec<RoutedFinish>,
    next_id: u64,
    rr_next: usize,
}

impl<C: ReplicaCore> Router<C> {
    /// A router over `cores` (replica ids are their indices). Applies
    /// `rcfg.watermarks` to every replica when enabled and turns on
    /// cache-event recording so the directory stays fed. All cores
    /// must share one KV block size.
    pub fn new(cores: Vec<C>, mut rcfg: RouterConfig) -> Router<C> {
        assert!(!cores.is_empty(), "router needs at least one replica");
        let block_size = cores[0].block_size();
        let n = cores.len();
        rcfg.replicas = n;
        let mut replicas: Vec<Replica<C>> = cores
            .into_iter()
            .enumerate()
            .map(|(i, c)| Replica::new(i, c))
            .collect();
        for r in &mut replicas {
            assert_eq!(r.core().block_size(), block_size,
                       "replicas disagree on block size");
            // a single-replica router never consults the directory
            // (route() short-circuits), so don't make its block
            // manager record events nobody reads on the hot path
            if n > 1 {
                r.core_mut().enable_cache_events();
            }
            if rcfg.watermarks.enabled() {
                r.core_mut().set_cache_watermarks(rcfg.watermarks);
            }
        }
        Router {
            rcfg,
            replicas,
            directory: CacheDirectory::new(),
            block_size,
            routes: HashMap::new(),
            local_to_global: (0..n).map(|_| HashMap::new()).collect(),
            finished: vec![],
            next_id: 0,
            rr_next: 0,
        }
    }

    /// A single-replica router with default config — the drop-in shape
    /// the server uses when no data parallelism is requested.
    pub fn single(core: C) -> Router<C> {
        Router::new(vec![core], RouterConfig::default())
    }

    /// The replicas, in id order (stats, benches, tests).
    pub fn replicas(&self) -> &[Replica<C>] {
        &self.replicas
    }
    /// The shared cache directory (tests assert it mirrors the
    /// replicas' caches).
    pub fn directory(&self) -> &CacheDirectory {
        &self.directory
    }
    /// Any replica with queued or in-flight work?
    pub fn has_work(&self) -> bool {
        self.replicas.iter().any(|r| r.core().has_work())
    }
    /// Requests submitted so far (the next global id).
    pub fn requests_submitted(&self) -> u64 {
        self.next_id
    }

    /// Pick a replica for `prompt` under the configured policy.
    /// Deterministic: ties always break to the lowest replica id.
    fn route(&mut self, prompt: &[u32]) -> usize {
        let n = self.replicas.len();
        if n == 1 {
            return 0;
        }
        match self.rcfg.routing {
            RoutingPolicy::RoundRobin => {
                let r = self.rr_next;
                self.rr_next = (self.rr_next + 1) % n;
                r
            }
            RoutingPolicy::LeastLoaded => self.least_loaded(),
            RoutingPolicy::CacheAware => {
                let hits = self.directory.prefix_hits(
                    prompt, self.block_size, n,
                );
                let penalty = self.rcfg.load_penalty_tokens as i64;
                let mut best = 0usize;
                let mut best_score = i64::MIN;
                for (i, r) in self.replicas.iter().enumerate() {
                    let score = hits[i] as i64
                        - penalty * r.core().load() as i64;
                    if score > best_score {
                        best = i;
                        best_score = score;
                    }
                }
                best
            }
        }
    }

    fn least_loaded(&self) -> usize {
        let mut best = 0usize;
        let mut best_load = usize::MAX;
        for (i, r) in self.replicas.iter().enumerate() {
            let load = r.core().load();
            if load < best_load {
                best = i;
                best_load = load;
            }
        }
        best
    }

    /// Submit a request: route it, place it, and return its global id.
    pub fn submit(&mut self, prompt: Vec<u32>, params: SamplingParams)
        -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let r = self.route(&prompt);
        let local = self.replicas[r].core_mut().submit(prompt, params);
        self.replicas[r].requests_routed += 1;
        self.routes.insert(id, (r, local));
        self.local_to_global[r].insert(local, id);
        id
    }

    /// Step every replica that has work (one engine step each, in id
    /// order), then absorb their cache events and finished sequences.
    pub fn step(&mut self) -> Result<()> {
        for r in &mut self.replicas {
            if r.core().has_work() {
                r.core_mut().step()?;
            }
        }
        self.absorb();
        Ok(())
    }

    /// Drain replica-side cache events into the directory and finished
    /// sequences into the router's finished list.
    fn absorb(&mut self) {
        for i in 0..self.replicas.len() {
            for ev in self.replicas[i].core_mut().take_cache_events() {
                match ev {
                    CacheEvent::Registered { hash } => {
                        self.directory.on_registered(i, hash)
                    }
                    CacheEvent::Evicted { hash } => {
                        self.directory.on_evicted(i, hash)
                    }
                }
            }
            for seq in self.replicas[i].core_mut().take_finished() {
                let id = self.local_to_global[i]
                    .remove(&seq.id)
                    .expect("finished sequence was never routed");
                self.routes.remove(&id);
                self.finished.push(RoutedFinish { id, replica: i, seq });
            }
        }
    }

    /// Drain finished requests (absorbs replica state first, so
    /// requests that finish at submission — e.g. `prompt_too_long` —
    /// surface without an intervening step).
    pub fn take_finished(&mut self) -> Vec<RoutedFinish> {
        self.absorb();
        std::mem::take(&mut self.finished)
    }

    /// Drive until every submitted request finishes (or `max_steps`).
    /// Returns the steps taken.
    pub fn run_to_completion(&mut self, max_steps: usize)
        -> Result<usize> {
        let mut steps = 0;
        while self.has_work() && steps < max_steps {
            self.step()?;
            steps += 1;
        }
        Ok(steps)
    }

    /// Per-replica stats rows, in replica id order.
    pub fn stats(&self) -> Vec<ReplicaStats> {
        self.replicas.iter().map(|r| r.stats()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn directory_tracks_registration_and_eviction() {
        let mut d = CacheDirectory::new();
        assert!(d.is_empty());
        d.on_registered(1, 42);
        d.on_registered(0, 42);
        d.on_registered(0, 42); // idempotent
        assert_eq!(d.len(), 1);
        d.on_evicted(1, 42);
        assert_eq!(d.len(), 1);
        d.on_evicted(0, 42);
        assert!(d.is_empty());
        d.on_evicted(0, 42); // idempotent on absent
    }

    #[test]
    fn directory_prefix_hits_walks_the_chain() {
        // replica 0 caches blocks 0 and 1 of a 3-block prompt, replica
        // 1 only block 0: hits are 8 and 4 tokens; an uncached replica
        // gets 0; the CoW cap leaves the last block uncounted even if
        // hinted
        let bs = 4;
        let prompt: Vec<u32> = (0..12).collect();
        let hashes = chain_hashes(&prompt, bs);
        let mut d = CacheDirectory::new();
        d.on_registered(0, hashes[0]);
        d.on_registered(0, hashes[1]);
        d.on_registered(0, hashes[2]);
        d.on_registered(1, hashes[0]);
        assert_eq!(d.prefix_hits(&prompt, bs, 3), vec![8, 4, 0]);
        // one token past the last block: all three blocks countable
        let mut longer = prompt.clone();
        longer.push(99);
        assert_eq!(d.prefix_hits(&longer, bs, 2), vec![12, 4]);
        // a gap breaks the chain: drop block 1, block 2's hint is
        // unreachable
        d.on_evicted(0, hashes[1]);
        assert_eq!(d.prefix_hits(&longer, bs, 2), vec![4, 4]);
        // short/empty prompts never hit
        assert_eq!(d.prefix_hits(&prompt[..1], bs, 2), vec![0, 0]);
    }
}
