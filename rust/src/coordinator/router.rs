//! Multi-replica front end: one [`Router`] owns N [`Replica`]s (data
//! parallelism — the scaling axis the paper's single-GPU W4A16 result
//! opens up) and places every request with a cache-aware policy.
//!
//! # Routing
//!
//! [`RoutingPolicy::CacheAware`] (the default) scores every replica as
//!
//! ```text
//! score(r) = cached_prefix_tokens(r, prompt)
//!          − load_penalty_tokens · (queued(r) + running(r))
//! ```
//!
//! and picks the max, ties broken by the lowest replica id — so a
//! shared-prefix burst lands on the replica already holding the prefix
//! KV (strictly less cold prefill work than spraying it round-robin),
//! while a replica that is merely warm never starves the others: once
//! its queue grows, the load penalty hands cold traffic to idle
//! replicas. With no hits anywhere the score degenerates to
//! least-loaded, which is also available directly
//! ([`RoutingPolicy::LeastLoaded`]), as is round-robin
//! ([`RoutingPolicy::RoundRobin`], the bench baseline).
//!
//! # The cache directory
//!
//! `cached_prefix_tokens(r, prompt)` is answered by a shared
//! [`CacheDirectory`] — a map from block content hash to the replica
//! ids caching that block — not by walking N block managers. Replicas
//! record a [`CacheEvent`] per registration/eviction (sliding-window
//! evictions included); the router drains those events after every
//! step, so one routing decision costs a single hash-chain walk over
//! the prompt's full blocks regardless of replica count. The directory
//! is a *hint*: a stale entry can only misroute, never corrupt —
//! admission inside the chosen replica re-walks its own chain with the
//! usual single-walk machinery.
//!
//! # Ids
//!
//! The router assigns *global* request ids in submission order and maps
//! them to `(replica, local id)`; finished sequences surface as
//! [`RoutedFinish`] carrying both the global id and the replica that
//! served it (reported on the wire as `"replica"`; `None` for requests
//! that never reached a replica — shed, or failed with no survivor). A
//! router over one replica is bit-identical to driving that replica's
//! core directly: global ids equal local ids and `step` is a
//! pass-through — the golden tests pin this.
//!
//! # Fault tolerance
//!
//! Every replica carries a [`ReplicaHealth`] state: **Healthy →
//! Quarantined → Dead**. A transient step failure quarantines the
//! replica with deterministic exponential backoff (measured in router
//! steps); a successful retry restores it to Healthy, while exceeding
//! [`RouterConfig::max_step_retries`] — or any permanent failure —
//! kills it. Killing a replica delivers whatever it already finished,
//! purges its entries from the cache directory (routing never scores a
//! dead replica), then drains its in-flight sequences and **replays**
//! each one onto a surviving replica: the re-submission's prompt is
//! the original prompt plus the tokens already emitted, its budget is
//! the remainder, and at finish the router stitches the stream back
//! together — so the client sees one uninterrupted stream with no lost
//! or duplicated tokens. Replays route through the normal policy, so
//! cache-aware placement lands them where their prefix is warm.
//!
//! Admission control sheds load instead of queueing forever: a fresh
//! submission is rejected with `FinishReason::Shed` when the global
//! waiting budget ([`RouterConfig::max_waiting`]) is exhausted or every
//! alive replica is at its queue cap
//! ([`RouterConfig::max_replica_queue`]). Replays bypass shedding —
//! they were admitted once. When no alive replica remains, requests
//! finish with `FinishReason::ReplicaFailed`. [`Router::router_stats`]
//! surfaces the shed/replay/retry counters and the degraded flag
//! (exactly one alive replica left out of several).

use std::collections::HashMap;

use anyhow::Result;

use crate::config::{RouterConfig, RoutingPolicy};

use super::block_manager::{chain_hashes, CacheEvent};
use super::replica::{Replica, ReplicaCore, ReplicaHealth, ReplicaStats};
use super::sequence::{FinishReason, SamplingParams, Sequence};

/// Per-replica cached-prefix hit for one prompt, split by residency
/// tier: `device` tokens restore for free at admission, `pooled`
/// tokens need a dequantize+copy restore first — the cache-aware
/// policy scores the latter at [`RouterConfig::pooled_hit_discount`].
/// `device + pooled` is the contiguous hit length the directory walk
/// found.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HitTokens {
    /// Tokens whose blocks are device-resident on the replica.
    pub device: usize,
    /// Tokens whose blocks sit in the replica's demotion pool.
    pub pooled: usize,
}

impl HitTokens {
    /// Contiguous hit length, tiers combined.
    pub fn total(&self) -> usize {
        self.device + self.pooled
    }
    /// Tier-weighted score: pooled tokens count at
    /// `pooled_hit_discount`% of a device token.
    pub fn discounted(&self, pooled_hit_discount: usize) -> usize {
        self.device + self.pooled * pooled_hit_discount / 100
    }
}

/// Read-only (to the router's policies) map from block content hash to
/// the replicas whose prefix caches hold that block, maintained from
/// replica [`CacheEvent`]s. Each entry also tracks the block's
/// residency tier on that replica (`pooled`: demoted to the host pool
/// vs device-resident), so routing can discount pooled hits. See the
/// module docs.
#[derive(Debug, Default)]
pub struct CacheDirectory {
    /// Content hash → `(replica id, pooled)`, sorted by replica id.
    map: HashMap<u64, Vec<(usize, bool)>>,
}

impl CacheDirectory {
    /// Empty directory.
    pub fn new() -> CacheDirectory {
        CacheDirectory::default()
    }

    /// Distinct content hashes currently hinted.
    pub fn len(&self) -> usize {
        self.map.len()
    }
    /// No hints at all?
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Upsert `replica`'s entry for `hash` with tier `pooled`.
    fn set(&mut self, replica: usize, hash: u64, pooled: bool) {
        let ids = self.map.entry(hash).or_default();
        match ids.binary_search_by_key(&replica, |e| e.0) {
            Ok(i) => ids[i].1 = pooled,
            Err(i) => ids.insert(i, (replica, pooled)),
        }
    }

    /// Record that `replica` registered a block of `hash`
    /// (device-resident).
    pub fn on_registered(&mut self, replica: usize, hash: u64) {
        self.set(replica, hash, false);
    }

    /// Record that `replica`'s block of `hash` now lives in its
    /// demotion pool (evict-demote, or a migration adoption) — still
    /// serveable, at restore cost.
    pub fn on_demoted(&mut self, replica: usize, hash: u64) {
        self.set(replica, hash, true);
    }

    /// Record that `replica` restored its pooled block of `hash` back
    /// onto the device.
    pub fn on_restored(&mut self, replica: usize, hash: u64) {
        self.set(replica, hash, false);
    }

    /// Record that `replica` stopped holding `hash` in any tier.
    pub fn on_evicted(&mut self, replica: usize, hash: u64) {
        let empty = match self.map.get_mut(&hash) {
            Some(ids) => {
                if let Ok(i) =
                    ids.binary_search_by_key(&replica, |e| e.0)
                {
                    ids.remove(i);
                }
                ids.is_empty()
            }
            None => false,
        };
        if empty {
            self.map.remove(&hash);
        }
    }

    /// Remove every hint for `replica` (replica death): routing must
    /// never score a dead replica's cache again.
    pub fn purge_replica(&mut self, replica: usize) {
        self.map.retain(|_, ids| {
            if let Ok(i) = ids.binary_search_by_key(&replica, |e| e.0) {
                ids.remove(i);
            }
            !ids.is_empty()
        });
    }

    /// Does any hint still name `replica`? (Purge observability for
    /// the recovery-invariant tests.)
    pub fn mentions_replica(&self, replica: usize) -> bool {
        self.map
            .values()
            .any(|ids| ids.binary_search_by_key(&replica, |e| e.0)
                .is_ok())
    }

    /// Per-replica cached-prefix hit (tokens, split by tier) for
    /// `tokens`, under the same rules as
    /// [`super::block_manager::BlockManager`] lookups: full
    /// `block_size` blocks only, capped so at least one token is left
    /// to compute. One chain walk total — each replica's hit is the
    /// longest prefix of blocks whose hint set contains it, in either
    /// tier.
    pub fn prefix_hits(&self, tokens: &[u32], block_size: usize,
                       n_replicas: usize) -> Vec<HitTokens> {
        let mut hit = vec![HitTokens::default(); n_replicas];
        if tokens.len() <= 1 || self.map.is_empty() {
            return hit;
        }
        let max_blocks = (tokens.len() - 1) / block_size;
        let mut alive = vec![true; n_replicas];
        let hashes = chain_hashes(&tokens[..max_blocks * block_size],
                                  block_size);
        for h in hashes.iter() {
            let ids = self.map.get(h);
            let mut any = false;
            for r in 0..n_replicas {
                if !alive[r] {
                    continue;
                }
                match ids.map(|ids| {
                    ids.binary_search_by_key(&r, |e| e.0)
                        .map(|i| ids[i].1)
                }) {
                    Some(Ok(pooled)) => {
                        if pooled {
                            hit[r].pooled += block_size;
                        } else {
                            hit[r].device += block_size;
                        }
                        any = true;
                    }
                    _ => alive[r] = false,
                }
            }
            if !any {
                break;
            }
        }
        hit
    }
}

/// A finished request as the router reports it: the router-assigned
/// global id, the replica that served it (`None` when no replica ever
/// did — shed at admission, or failed with no survivor), and the
/// sequence (whose own `id` field is the replica-local id).
#[derive(Debug)]
pub struct RoutedFinish {
    /// Router-assigned global request id (submission order).
    pub id: u64,
    /// Replica that served the request; `None` for shed /
    /// no-survivor-failed requests that never reached one.
    pub replica: Option<usize>,
    /// The finished sequence (output, finish reason, timings). For a
    /// request that survived a replica death the stream is already
    /// stitched: `output` holds pre-death and post-replay tokens in
    /// order, `prompt` is the original prompt.
    pub seq: Sequence,
}

/// Router-level failure/shedding counters and the health roll-up —
/// the `{"cmd":"stats"}` `"router"` object.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// Fresh submissions rejected by admission control.
    pub shed: usize,
    /// In-flight requests replayed off dead replicas.
    pub replayed: usize,
    /// Retry attempts: quarantined-step retries plus failed-submit
    /// re-placements.
    pub retries: usize,
    /// Requests finished `ReplicaFailed` (no survivor to take them).
    pub replica_failed: usize,
    /// Replicas still alive (healthy or quarantined).
    pub alive: usize,
    /// Replicas dead.
    pub dead: usize,
    /// Degraded mode: more than one replica configured, exactly one
    /// still alive — the last line of service before total failure.
    pub degraded: bool,
    /// KV migrations that aborted (donor died or erred mid-handshake,
    /// import rejected) and degraded to plain recompute. The request
    /// always still serves — this counts the lost optimization.
    pub migration_fallbacks: usize,
}

/// Per-global-id bookkeeping for a request replayed across a replica
/// death: enough to stitch the client-visible stream back together.
#[derive(Debug)]
pub(crate) struct ReplayState {
    /// Length of the *original* prompt (replay prompts are longer: the
    /// emitted tokens ride along).
    pub(crate) prompt_len: usize,
    /// Tokens emitted before the death(s), in order.
    pub(crate) emitted: Vec<u32>,
}

/// Mutable placement state shared across picks: the round-robin cursor
/// and the consecutive-placement counter behind
/// [`RouterConfig::cache_spread_limit`]. One instance per front end
/// (the synchronous [`Router`] and the threaded
/// [`super::worker::AsyncRouter`] each own one).
#[derive(Debug, Default)]
pub(crate) struct PickState {
    /// Next replica the round-robin policy prefers.
    pub(crate) rr_next: usize,
    /// Replica of the most recent placement.
    pub(crate) last_pick: Option<usize>,
    /// How many consecutive placements landed on `last_pick`.
    pub(crate) consec: usize,
}

impl PickState {
    /// Record a placement on `r`.
    fn note(&mut self, r: usize) {
        if self.last_pick == Some(r) {
            self.consec += 1;
        } else {
            self.last_pick = Some(r);
            self.consec = 1;
        }
    }
}

/// Pure placement decision shared by the synchronous [`Router`] and the
/// threaded front-end: pick a replica from `cands` under `rcfg.routing`,
/// given per-replica directory prefix hits (tier-split tokens) and load
/// counts (queued + running). Deterministic: ties always break to the
/// lowest replica id. `None` iff `cands` is empty.
///
/// The cache-aware hit term is tier-weighted: device-resident tokens
/// count in full, pooled tokens at
/// [`RouterConfig::pooled_hit_discount`]% (restore beats recompute,
/// but a free device hit beats both — so a device hit always wins a
/// same-length tie). With [`RouterConfig::kv_migrate`] on, a replica's
/// term is floored at [`RouterConfig::migrate_hit_discount`]% of the
/// best term *anywhere*: warmth held by an excluded/loaded replica is
/// reachable by shipping its blocks, so remote hit tokens count at a
/// discount instead of zero.
///
/// The cache-aware policy additionally honors
/// [`RouterConfig::cache_spread_limit`]: once `st` records that many
/// consecutive placements on one replica, that replica is excluded from
/// this pick when any other candidate remains — bounding how long a
/// skewed (single-hot-prefix) workload can starve the cold replicas.
pub(crate) fn pick_replica(rcfg: &RouterConfig, st: &mut PickState,
                           cands: &[usize], n_replicas: usize,
                           hits: &[HitTokens], loads: &[usize])
    -> Option<usize> {
    let r = match cands {
        [] => return None,
        [only] => *only,
        _ => match rcfg.routing {
            RoutingPolicy::RoundRobin => {
                let r = (0..n_replicas)
                    .map(|off| (st.rr_next + off) % n_replicas)
                    .find(|r| cands.contains(r))
                    // sqlint: allow(panic) guarded: the `[] => return None` arm handled empty cands
                    .expect("cands is non-empty");
                st.rr_next = (r + 1) % n_replicas;
                r
            }
            RoutingPolicy::LeastLoaded => cands
                .iter()
                .copied()
                .min_by_key(|&i| (loads[i], i))
                // sqlint: allow(panic) guarded: the `[] => return None` arm handled empty cands
                .expect("cands is non-empty"),
            RoutingPolicy::CacheAware => {
                let spread = rcfg.cache_spread_limit;
                let mut pool: Vec<usize> = cands.to_vec();
                if spread > 0 && st.consec >= spread {
                    if let Some(last) = st.last_pick {
                        if pool.len() > 1 {
                            pool.retain(|&i| i != last);
                        }
                    }
                }
                // tier-weighted local terms; migration floors every
                // candidate at a discount of the best term anywhere
                // (dead replicas are purged from the directory, so
                // their hits are already 0)
                let raw: Vec<usize> = hits
                    .iter()
                    .map(|h| h.discounted(rcfg.pooled_hit_discount))
                    .collect();
                let floor = if rcfg.kv_migrate {
                    raw.iter().copied().max().unwrap_or(0)
                        * rcfg.migrate_hit_discount / 100
                } else {
                    0
                };
                let penalty = rcfg.load_penalty_tokens as i64;
                let mut best = pool[0];
                let mut best_score = i64::MIN;
                for &i in &pool {
                    let score = raw[i].max(floor) as i64
                        - penalty * loads[i] as i64;
                    if score > best_score {
                        best = i;
                        best_score = score;
                    }
                }
                best
            }
        },
    };
    st.note(r);
    Some(r)
}

/// The multi-replica front end; see the module docs.
pub struct Router<C: ReplicaCore> {
    /// Router configuration (`replicas` reflects the actual count).
    pub rcfg: RouterConfig,
    replicas: Vec<Replica<C>>,
    directory: CacheDirectory,
    /// KV block size shared by every replica (asserted at construction).
    block_size: usize,
    /// Global id → (replica id, local id) for in-flight requests.
    routes: HashMap<u64, (usize, u64)>,
    /// Per-replica local id → global id.
    local_to_global: Vec<HashMap<u64, u64>>,
    /// Stream-stitching state for requests replayed across a death.
    replays: HashMap<u64, ReplayState>,
    /// Incrementally emitted `(global id, token)` pairs not yet
    /// drained by [`Router::take_emitted`].
    emitted: Vec<(u64, u32)>,
    finished: Vec<RoutedFinish>,
    next_id: u64,
    pick_state: PickState,
    /// Router step counter (the clock quarantine backoff runs on).
    steps: u64,
    shed: usize,
    replayed: usize,
    retries: usize,
    replica_failed: usize,
    migration_fallbacks: usize,
}

impl<C: ReplicaCore> Router<C> {
    /// A router over `cores` (replica ids are their indices). Applies
    /// `rcfg.watermarks` to every replica when enabled and turns on
    /// cache-event recording so the directory stays fed. All cores
    /// must share one KV block size.
    pub fn new(cores: Vec<C>, mut rcfg: RouterConfig) -> Router<C> {
        assert!(!cores.is_empty(), "router needs at least one replica");
        let block_size = cores[0].block_size();
        let n = cores.len();
        rcfg.replicas = n;
        let mut replicas: Vec<Replica<C>> = cores
            .into_iter()
            .enumerate()
            .map(|(i, c)| Replica::new(i, c))
            .collect();
        for r in &mut replicas {
            assert_eq!(r.core().block_size(), block_size,
                       "replicas disagree on block size");
            // a single-replica router never consults the directory
            // (route() short-circuits), so don't make its block
            // manager record events nobody reads on the hot path
            if n > 1 {
                r.core_mut().enable_cache_events();
            }
            if rcfg.watermarks.enabled() {
                r.core_mut().set_cache_watermarks(rcfg.watermarks);
            }
        }
        Router {
            rcfg,
            replicas,
            directory: CacheDirectory::new(),
            block_size,
            routes: HashMap::new(),
            local_to_global: (0..n).map(|_| HashMap::new()).collect(),
            replays: HashMap::new(),
            emitted: vec![],
            finished: vec![],
            next_id: 0,
            pick_state: PickState::default(),
            steps: 0,
            shed: 0,
            replayed: 0,
            retries: 0,
            replica_failed: 0,
            migration_fallbacks: 0,
        }
    }

    /// A single-replica router with default config — the drop-in shape
    /// the server uses when no data parallelism is requested.
    pub fn single(core: C) -> Router<C> {
        Router::new(vec![core], RouterConfig::default())
    }

    /// The replicas, in id order (stats, benches, tests). Dead
    /// replicas keep their slot.
    pub fn replicas(&self) -> &[Replica<C>] {
        &self.replicas
    }
    /// The shared cache directory (tests assert it mirrors the
    /// replicas' caches).
    pub fn directory(&self) -> &CacheDirectory {
        &self.directory
    }
    /// Any alive replica with queued or in-flight work?
    pub fn has_work(&self) -> bool {
        self.replicas
            .iter()
            .any(|r| r.health.is_alive() && r.core().has_work())
    }
    /// Requests submitted so far (the next global id).
    pub fn requests_submitted(&self) -> u64 {
        self.next_id
    }

    /// Candidate replicas for a placement, in preference order:
    /// healthy before quarantined, under-cap before capped (fresh
    /// submissions only), never dead, never in `tried`. Empty when no
    /// alive replica remains outside `tried`.
    fn candidates(&self, fresh: bool, tried: &[usize]) -> Vec<usize> {
        let alive: Vec<usize> = (0..self.replicas.len())
            .filter(|&i| self.replicas[i].health.is_alive()
                && !tried.contains(&i))
            .collect();
        let pick_from = |pool: &[usize]| -> Vec<usize> {
            let healthy: Vec<usize> = pool
                .iter()
                .copied()
                .filter(|&i| {
                    self.replicas[i].health == ReplicaHealth::Healthy
                })
                .collect();
            if healthy.is_empty() { pool.to_vec() } else { healthy }
        };
        let cap = self.rcfg.max_replica_queue;
        if fresh && cap > 0 {
            let under: Vec<usize> = alive
                .iter()
                .copied()
                .filter(|&i| self.replicas[i].core().load() < cap)
                .collect();
            if !under.is_empty() {
                return pick_from(&under);
            }
        }
        pick_from(&alive)
    }

    /// Pick a replica for `prompt` from `cands` under the configured
    /// policy (delegates to [`pick_replica`], which the threaded
    /// front-end shares). Deterministic: ties always break to the
    /// lowest replica id. `None` iff `cands` is empty.
    fn pick(&mut self, cands: &[usize], prompt: &[u32])
        -> Option<usize> {
        let n = self.replicas.len();
        let hits = match self.rcfg.routing {
            RoutingPolicy::CacheAware => {
                self.directory.prefix_hits(prompt, self.block_size, n)
            }
            _ => vec![HitTokens::default(); n],
        };
        let loads: Vec<usize> =
            self.replicas.iter().map(|r| r.core().load()).collect();
        pick_replica(&self.rcfg, &mut self.pick_state, cands, n, &hits,
                     &loads)
    }

    /// Should a fresh submission be shed? (Replays bypass this — they
    /// were admitted once already.)
    fn should_shed(&self) -> bool {
        let alive: Vec<&Replica<C>> = self
            .replicas
            .iter()
            .filter(|r| r.health.is_alive())
            .collect();
        if alive.is_empty() {
            return false; // that's the ReplicaFailed path, not Shed
        }
        if self.rcfg.max_waiting > 0 {
            let waiting: usize =
                alive.iter().map(|r| r.core().queue_depths().0).sum();
            if waiting >= self.rcfg.max_waiting {
                return true;
            }
        }
        let cap = self.rcfg.max_replica_queue;
        cap > 0 && alive.iter().all(|r| r.core().load() >= cap)
    }

    /// Finish a request that never reached a replica (shed /
    /// no-survivor), delivering it through the normal finished path so
    /// any replay state still stitches the stream.
    fn finish_unrouted(&mut self, id: u64, prompt: Vec<u32>,
                       params: SamplingParams, reason: FinishReason) {
        let mut seq = Sequence::new(id, prompt, params);
        seq.finish(reason);
        self.push_finished(id, None, seq);
    }

    /// Place request `id` on some alive replica (`fresh` = a new
    /// client submission, subject to admission control; replays pass
    /// `false`). Retries on submit failure: a transiently failing
    /// replica is quarantined and skipped, a permanently failing one
    /// is killed (which replays *its* in-flight load too); when every
    /// candidate is exhausted the request finishes `ReplicaFailed`.
    fn place(&mut self, id: u64, prompt: Vec<u32>,
             params: SamplingParams, fresh: bool) {
        if fresh && self.should_shed() {
            self.shed += 1;
            self.finish_unrouted(id, prompt, params, FinishReason::Shed);
            return;
        }
        let mut tried: Vec<usize> = vec![];
        loop {
            let cands = self.candidates(fresh, &tried);
            let Some(r) = self.pick(&cands, &prompt) else {
                self.replica_failed += 1;
                self.finish_unrouted(id, prompt, params,
                                     FinishReason::ReplicaFailed);
                return;
            };
            if tried.is_empty() {
                self.maybe_migrate(r, &prompt);
            }
            match self.replicas[r]
                .core_mut()
                .submit(prompt.clone(), params.clone())
            {
                Ok(local) => {
                    self.replicas[r].requests_routed += 1;
                    self.routes.insert(id, (r, local));
                    self.local_to_global[r].insert(local, id);
                    return;
                }
                Err(e) => {
                    self.retries += 1;
                    tried.push(r);
                    if e.is_transient() {
                        self.note_transient(r);
                    } else {
                        self.kill(r);
                    }
                }
            }
        }
    }

    /// Inline donor→receiver KV migration for the synchronous router:
    /// with [`RouterConfig::kv_migrate`] on and some *other* alive
    /// replica holding a longer contiguous directory hit for `prompt`
    /// than the chosen receiver `r`, export the donor's stashed blocks
    /// (wire form, already quantized) and import them into `r`'s pool
    /// tier before submitting — admission on `r` then restores them
    /// and only the suffix runs through the model. Every failure
    /// degrades to plain recompute (`migration_fallbacks` counts it);
    /// a permanent donor failure additionally kills the donor, exactly
    /// like a permanent submit failure would.
    fn maybe_migrate(&mut self, r: usize, prompt: &[u32]) {
        if !self.rcfg.kv_migrate
            || self.rcfg.routing != RoutingPolicy::CacheAware
        {
            return;
        }
        let n = self.replicas.len();
        let hits =
            self.directory.prefix_hits(prompt, self.block_size, n);
        let donor = (0..n)
            .filter(|&i| i != r && self.replicas[i].health.is_alive()
                && hits[i].total() > hits[r].total())
            .max_by_key(|&i| (hits[i].total(), std::cmp::Reverse(i)));
        let Some(d) = donor else { return };
        let blocks =
            match self.replicas[d].core_mut().export_blocks(prompt) {
                Ok(b) => b,
                Err(e) => {
                    // a failed optimization must never wedge the
                    // request: fall back to recompute, and treat a
                    // permanent export error as the donor dying
                    self.migration_fallbacks += 1;
                    if !e.is_transient() {
                        self.kill(d);
                    }
                    return;
                }
            };
        if blocks.is_empty() {
            // directory hinted warmth the donor no longer holds
            self.migration_fallbacks += 1;
            return;
        }
        if self.replicas[r].core_mut().import_blocks(&blocks).is_err()
        {
            self.migration_fallbacks += 1;
        }
    }

    /// Submit a request: admission-check it, route it, place it, and
    /// return its global id. Over-budget submissions finish
    /// immediately with `Shed`; with no alive replica they finish
    /// `ReplicaFailed` (both surface through
    /// [`Router::take_finished`]).
    pub fn submit(&mut self, prompt: Vec<u32>, params: SamplingParams)
        -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.place(id, prompt, params, true);
        id
    }

    /// Record a transient failure: quarantine with deterministic
    /// exponential backoff, or kill once the bounded retries are
    /// exhausted.
    fn note_transient(&mut self, i: usize) {
        let failures = match self.replicas[i].health {
            ReplicaHealth::Quarantined { failures, .. } => failures + 1,
            _ => 1,
        };
        if failures as usize > self.rcfg.max_step_retries {
            self.kill(i);
            return;
        }
        let backoff = (self.rcfg.retry_backoff_steps.max(1) as u64)
            << (failures - 1).min(16);
        self.replicas[i].health = ReplicaHealth::Quarantined {
            failures,
            retry_at_step: self.steps + backoff,
        };
    }

    /// Kill replica `i`: deliver what it already finished, purge its
    /// directory entries, drain its in-flight sequences, and replay
    /// each onto a survivor (emitted tokens appended to the prompt,
    /// budget reduced by the same amount — the stream stitches back
    /// together at finish). Idempotent.
    fn kill(&mut self, i: usize) {
        if self.replicas[i].health.is_dead() {
            return;
        }
        self.replicas[i].health = ReplicaHealth::Dead;
        // responses that exist are delivered, not replayed
        for seq in self.replicas[i].core_mut().take_finished() {
            if let Some(gid) = self.local_to_global[i].remove(&seq.id) {
                self.routes.remove(&gid);
                self.push_finished(gid, Some(i), seq);
            }
        }
        let inflight = self.replicas[i].core_mut().drain_inflight();
        // teardown emits eviction events nobody will read — discard,
        // then purge every hint so routing never scores this replica
        self.replicas[i].core_mut().take_cache_events();
        self.directory.purge_replica(i);
        self.replicas[i].replayed_out += inflight.len();
        self.replayed += inflight.len();
        for seq in inflight {
            let Some(gid) = self.local_to_global[i].remove(&seq.id)
            else {
                continue;
            };
            self.routes.remove(&gid);
            let st = self.replays.entry(gid).or_insert(ReplayState {
                prompt_len: seq.prompt.len(),
                emitted: vec![],
            });
            st.emitted.extend_from_slice(&seq.output);
            let mut params = seq.params.clone();
            // unfinished ⇒ output < budget, so the remainder is ≥ 1
            debug_assert!(seq.output.len() < params.max_new_tokens);
            params.max_new_tokens -= seq.output.len();
            self.place(gid, seq.full_tokens(), params, false);
        }
        self.local_to_global[i].clear();
    }

    /// Step every alive replica that has work (one engine step each,
    /// in id order), then absorb their cache events and finished
    /// sequences. Replica failures are handled here — quarantine,
    /// retry, kill-and-replay — so this never propagates an error;
    /// the `Result` stays for call-site compatibility.
    pub fn step(&mut self) -> Result<()> {
        self.steps += 1;
        for i in 0..self.replicas.len() {
            let quarantined = match self.replicas[i].health {
                ReplicaHealth::Dead => continue,
                ReplicaHealth::Quarantined { retry_at_step, .. } => {
                    if self.steps < retry_at_step {
                        continue; // backing off
                    }
                    true
                }
                ReplicaHealth::Healthy => false,
            };
            if !self.replicas[i].core().has_work() {
                if quarantined {
                    // nothing to retry against and nothing can fail
                    // while idle: presume recovered
                    self.replicas[i].health = ReplicaHealth::Healthy;
                }
                continue;
            }
            if quarantined {
                self.retries += 1;
            }
            match self.replicas[i].core_mut().step() {
                Ok(_) => {
                    self.replicas[i].health = ReplicaHealth::Healthy;
                }
                Err(e) if e.is_transient() => self.note_transient(i),
                Err(_) => self.kill(i),
            }
        }
        self.absorb();
        Ok(())
    }

    /// Drain replica-side cache events into the directory and finished
    /// sequences into the router's finished list.
    fn absorb(&mut self) {
        for i in 0..self.replicas.len() {
            if self.replicas[i].health.is_dead() {
                continue;
            }
            for ev in self.replicas[i].core_mut().take_cache_events() {
                match ev {
                    CacheEvent::Registered { hash } => {
                        self.directory.on_registered(i, hash)
                    }
                    CacheEvent::Evicted { hash } => {
                        self.directory.on_evicted(i, hash)
                    }
                    CacheEvent::Demoted { hash } => {
                        self.directory.on_demoted(i, hash)
                    }
                    CacheEvent::Restored { hash } => {
                        self.directory.on_restored(i, hash)
                    }
                }
            }
            // tokens before finishes: a sequence that finished this
            // step still has its id mapping until the loop below
            for (local, tok) in
                self.replicas[i].core_mut().take_emitted()
            {
                if let Some(&gid) = self.local_to_global[i].get(&local) {
                    self.emitted.push((gid, tok));
                }
            }
            for seq in self.replicas[i].core_mut().take_finished() {
                let gid = self.local_to_global[i]
                    .remove(&seq.id)
                    // sqlint: allow(panic) every finished sequence was placed by route() first
                    .expect("finished sequence was never routed");
                self.routes.remove(&gid);
                self.push_finished(gid, Some(i), seq);
            }
        }
    }

    /// Deliver a finished sequence, stitching the stream for requests
    /// that were replayed across a replica death: prompt back to the
    /// original, output = pre-death emissions ++ post-replay tokens,
    /// budget restored to the client's.
    fn push_finished(&mut self, id: u64, replica: Option<usize>,
                     mut seq: Sequence) {
        if let Some(st) = self.replays.remove(&id) {
            seq.prompt.truncate(st.prompt_len);
            seq.params.max_new_tokens += st.emitted.len();
            let mut output = st.emitted;
            output.extend_from_slice(&seq.output);
            seq.output = output;
        }
        self.finished.push(RoutedFinish { id, replica, seq });
    }

    /// Drain finished requests (absorbs replica state first, so
    /// requests that finish at submission — e.g. `prompt_too_long` or
    /// `shed` — surface without an intervening step).
    pub fn take_finished(&mut self) -> Vec<RoutedFinish> {
        self.absorb();
        std::mem::take(&mut self.finished)
    }

    /// Drain incrementally emitted tokens as `(global id, token)` in
    /// emission order — the streaming surface the serving loops read.
    /// A request replayed across a replica death never re-emits here:
    /// its pre-death tokens ride in the replay *prompt*, so the
    /// concatenation of a request's drained tokens is exactly its
    /// final stitched `output` (for cores that implement
    /// [`ReplicaCore::take_emitted`]).
    pub fn take_emitted(&mut self) -> Vec<(u64, u32)> {
        self.absorb();
        std::mem::take(&mut self.emitted)
    }

    /// Drive until every submitted request finishes (or `max_steps`).
    /// Returns the steps taken.
    pub fn run_to_completion(&mut self, max_steps: usize)
        -> Result<usize> {
        let mut steps = 0;
        while self.has_work() && steps < max_steps {
            self.step()?;
            steps += 1;
        }
        Ok(steps)
    }

    /// Per-replica stats rows, in replica id order (dead replicas
    /// included — their slot and counters survive).
    pub fn stats(&self) -> Vec<ReplicaStats> {
        self.replicas.iter().map(|r| r.stats()).collect()
    }

    /// Router-level counters and the health roll-up.
    pub fn router_stats(&self) -> RouterStats {
        let alive = self
            .replicas
            .iter()
            .filter(|r| r.health.is_alive())
            .count();
        RouterStats {
            shed: self.shed,
            replayed: self.replayed,
            retries: self.retries,
            replica_failed: self.replica_failed,
            alive,
            dead: self.replicas.len() - alive,
            degraded: self.replicas.len() > 1 && alive == 1,
            migration_fallbacks: self.migration_fallbacks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn directory_tracks_registration_and_eviction() {
        let mut d = CacheDirectory::new();
        assert!(d.is_empty());
        d.on_registered(1, 42);
        d.on_registered(0, 42);
        d.on_registered(0, 42); // idempotent
        assert_eq!(d.len(), 1);
        d.on_evicted(1, 42);
        assert_eq!(d.len(), 1);
        d.on_evicted(0, 42);
        assert!(d.is_empty());
        d.on_evicted(0, 42); // idempotent on absent
    }

    /// Device-only hit of `t` tokens.
    fn dev(t: usize) -> HitTokens {
        HitTokens { device: t, pooled: 0 }
    }

    #[test]
    fn directory_prefix_hits_walks_the_chain() {
        // replica 0 caches blocks 0 and 1 of a 3-block prompt, replica
        // 1 only block 0: hits are 8 and 4 tokens; an uncached replica
        // gets 0; the CoW cap leaves the last block uncounted even if
        // hinted
        let bs = 4;
        let prompt: Vec<u32> = (0..12).collect();
        let hashes = chain_hashes(&prompt, bs);
        let mut d = CacheDirectory::new();
        d.on_registered(0, hashes[0]);
        d.on_registered(0, hashes[1]);
        d.on_registered(0, hashes[2]);
        d.on_registered(1, hashes[0]);
        assert_eq!(d.prefix_hits(&prompt, bs, 3),
                   vec![dev(8), dev(4), dev(0)]);
        // one token past the last block: all three blocks countable
        let mut longer = prompt.clone();
        longer.push(99);
        assert_eq!(d.prefix_hits(&longer, bs, 2), vec![dev(12), dev(4)]);
        // a gap breaks the chain: drop block 1, block 2's hint is
        // unreachable
        d.on_evicted(0, hashes[1]);
        assert_eq!(d.prefix_hits(&longer, bs, 2), vec![dev(4), dev(4)]);
        // short/empty prompts never hit
        assert_eq!(d.prefix_hits(&prompt[..1], bs, 2),
                   vec![dev(0), dev(0)]);
    }

    #[test]
    fn directory_tracks_residency_tiers() {
        // demote splits a hit across tiers without shrinking it;
        // restore flips it back; evict from either tier removes it
        let bs = 4;
        let prompt: Vec<u32> = (0..9).collect();
        let hashes = chain_hashes(&prompt, bs);
        let mut d = CacheDirectory::new();
        d.on_registered(0, hashes[0]);
        d.on_registered(0, hashes[1]);
        d.on_demoted(0, hashes[1]);
        assert_eq!(d.prefix_hits(&prompt, bs, 1),
                   vec![HitTokens { device: 4, pooled: 4 }]);
        d.on_restored(0, hashes[1]);
        assert_eq!(d.prefix_hits(&prompt, bs, 1), vec![dev(8)]);
        // a block only ever seen as demoted (migration adoption) hints
        // too
        let mut d2 = CacheDirectory::new();
        d2.on_demoted(1, hashes[0]);
        assert_eq!(d2.prefix_hits(&prompt, bs, 2),
                   vec![dev(0), HitTokens { device: 0, pooled: 4 }]);
        d2.on_evicted(1, hashes[0]);
        assert!(d2.is_empty());
    }

    #[test]
    fn device_hit_wins_a_tie_against_pooled() {
        // the pooled-discount property the ROADMAP asks for: equal hit
        // *lengths*, one device-resident, one demoted — the device hit
        // must win even though the pooled replica has the lower id
        // (lowest-id tiebreak would otherwise take it)
        let rcfg = RouterConfig {
            routing: RoutingPolicy::CacheAware,
            cache_spread_limit: 0,
            ..Default::default()
        };
        assert!(rcfg.pooled_hit_discount < 100);
        let hits = [HitTokens { device: 0, pooled: 8 }, dev(8)];
        let mut st = PickState::default();
        let r = pick_replica(&rcfg, &mut st, &[0, 1], 2, &hits,
                             &[0, 0]);
        assert_eq!(r, Some(1));
        // at 100% the discount is a no-op and the tiebreak takes over
        let flat = RouterConfig { pooled_hit_discount: 100, ..rcfg };
        let mut st = PickState::default();
        let r = pick_replica(&flat, &mut st, &[0, 1], 2, &hits,
                             &[0, 0]);
        assert_eq!(r, Some(0));
    }

    #[test]
    fn migration_floor_reroutes_toward_less_loaded_cold_replicas() {
        // replica 0 is the (excluded) warm donor; replica 1 has a small
        // local hit but a queue, replica 2 is cold and idle. Without
        // migration the local hit wins; with it, both candidates are
        // floored at a discount of the donor's hit, so the load
        // penalty hands the request to the idle replica — whose
        // suffix-only prefill the migration then actually delivers.
        let base = RouterConfig {
            routing: RoutingPolicy::CacheAware,
            load_penalty_tokens: 4,
            cache_spread_limit: 0,
            ..Default::default()
        };
        let hits = [dev(32), dev(6), dev(0)];
        let loads = [0, 1, 0];
        let mut st = PickState::default();
        let off = pick_replica(&base, &mut st, &[1, 2], 3, &hits,
                               &loads);
        assert_eq!(off, Some(1));
        let on = RouterConfig {
            kv_migrate: true,
            migrate_hit_discount: 50,
            ..base
        };
        let mut st = PickState::default();
        let got = pick_replica(&on, &mut st, &[1, 2], 3, &hits,
                               &loads);
        assert_eq!(got, Some(2));
    }

    #[test]
    fn directory_purge_removes_every_hint() {
        let mut d = CacheDirectory::new();
        d.on_registered(0, 1);
        d.on_registered(1, 1);
        d.on_registered(1, 2);
        assert!(d.mentions_replica(1));
        d.purge_replica(1);
        assert!(!d.mentions_replica(1));
        assert!(d.mentions_replica(0));
        // hash 2 had only replica 1: entry dropped entirely
        assert_eq!(d.len(), 1);
        d.purge_replica(1); // idempotent
        assert_eq!(d.len(), 1);
    }
}
