//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure against `cases` seeded
//! random inputs; on failure it retries with the same seed to print the
//! failing case number and seed so the run is reproducible:
//!
//! ```no_run
//! use sqplus::util::prop;
//! prop::check("addition commutes", 100, |rng| {
//!     let (a, b) = (rng.f64(), rng.f64());
//!     prop::assert_close(a + b, b + a, 1e-12, "a+b == b+a");
//! });
//! ```

use super::rng::Rng;

/// Base seed; override with SQPLUS_PROP_SEED to reproduce a CI failure.
fn base_seed() -> u64 {
    std::env::var("SQPLUS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5eed_cafe)
}

/// Case-count override: SQPLUS_PROP_CASES replaces every `check`'s
/// `cases` argument when set — the nightly sweep cranks it up without
/// touching test code, and a local repro can wind it down to 1.
fn cases_override() -> Option<u32> {
    std::env::var("SQPLUS_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
}

/// Run `body` for `cases` independent seeded RNGs (the count is
/// overridden by SQPLUS_PROP_CASES when set). Panics (with the case
/// seed) on the first failing case.
pub fn check<F: Fn(&mut Rng)>(name: &str, cases: u32, body: F) {
    let base = base_seed();
    let cases = cases_override().unwrap_or(cases);
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| body(&mut rng)),
        );
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {case}/{cases} \
                 (SQPLUS_PROP_SEED={base}, case seed {seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

pub fn assert_close(a: f64, b: f64, tol: f64, what: &str) {
    let denom = 1.0_f64.max(a.abs()).max(b.abs());
    assert!(
        (a - b).abs() / denom <= tol,
        "{what}: {a} vs {b} (tol {tol})"
    );
}

/// All-close over slices with combined absolute+relative tolerance.
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol,
            "{what}: index {i}: {x} vs {y} (tol {tol})"
        );
    }
}

/// Max |a-b| over slices (diagnostic helper for tolerances).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("abs is non-negative", 50, |rng| {
            let x = rng.normal();
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        check("impossible", 10, |rng| {
            assert!(rng.f64() < 0.0, "uniform can't be negative");
        });
    }

    #[test]
    fn allclose() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-7, 2.0], 1e-5, 1e-6, "ok");
    }

    #[test]
    #[should_panic]
    fn allclose_rejects() {
        assert_allclose(&[1.0], &[1.1], 1e-5, 1e-6, "should fail");
    }
}
