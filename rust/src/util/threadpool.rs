//! Scoped data-parallel helpers over `std::thread` (no external deps).
//!
//! The hot paths that need parallelism (reference-forward matmuls,
//! quantization sweeps, the alpha grid search) are all embarrassingly
//! parallel loops, so a fork-join `parallel_for` over index chunks is
//! sufficient; there is no work-stealing queue to maintain.

/// Number of worker threads to use (capped, leaves a core for the OS).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).clamp(1, 16))
        .unwrap_or(4)
}

/// Run `f(i)` for every `i in 0..n`, split across threads in contiguous
/// chunks. `f` must be `Sync` (it is shared by reference across workers).
pub fn parallel_for<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    parallel_for_threads(n, default_threads(), f)
}

pub fn parallel_for_threads<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let f = &f;
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            s.spawn(move || {
                for i in lo..hi {
                    f(i);
                }
            });
        }
    });
}

/// Map `f` over `0..n` in parallel, collecting results in index order.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots: Vec<std::sync::Mutex<&mut T>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_for(n, |i| {
            let mut slot = slots[i].lock().unwrap();
            **slot = f(i);
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_every_index_once() {
        let n = 1000;
        let hits: Vec<AtomicUsize> =
            (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(100, |i| i * i);
        assert_eq!(out[7], 49);
        assert_eq!(out[99], 9801);
    }

    #[test]
    fn single_thread_and_empty() {
        parallel_for_threads(0, 4, |_| panic!("no work"));
        let count = AtomicUsize::new(0);
        parallel_for_threads(3, 1, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 3);
    }
}
