//! Scoped data-parallel helpers over `std::thread` (no external deps).
//!
//! The hot paths that need parallelism (reference-forward matmuls, the
//! fused W4A16 kernel, quantization sweeps, the alpha grid search) are all
//! embarrassingly parallel loops, so a fork-join `parallel_for` over index
//! chunks is sufficient; there is no work-stealing queue to maintain.

/// Number of worker threads to use (capped, leaves a core for the OS).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).clamp(1, 16))
        .unwrap_or(4)
}

/// Run `f(i)` for every `i in 0..n`, split across threads in contiguous
/// chunks. `f` must be `Sync` (it is shared by reference across workers).
pub fn parallel_for<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    parallel_for_threads(n, default_threads(), f)
}

pub fn parallel_for_threads<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let f = &f;
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            s.spawn(move || {
                for i in lo..hi {
                    f(i);
                }
            });
        }
    });
}

/// Map `f` over `0..n` in parallel, collecting results in index order.
///
/// Each worker collects its contiguous chunk into a local `Vec` which the
/// caller thread splices back in order, so `T` needs no `Default + Clone`
/// bound (loss closures can return arbitrary result structs) and no
/// per-element synchronization is paid.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = default_threads().clamp(1, n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let chunk = n.div_ceil(threads);
    let mut out = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..threads {
            let f = &f;
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            handles.push(s.spawn(move || (lo..hi).map(f).collect::<Vec<T>>()));
        }
        for h in handles {
            out.extend(h.join().expect("parallel_map worker panicked"));
        }
    });
    out
}

/// Raw mutable pointer that may cross thread boundaries, for fork-join
/// loops whose tasks write disjoint regions of one output buffer (threaded
/// matmuls, group-parallel quantization).
///
/// SAFETY contract (the caller's): no two tasks may write overlapping
/// regions, and the buffer must outlive the fork-join scope.
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }
    pub fn get(&self) -> *mut T {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_every_index_once() {
        let n = 1000;
        let hits: Vec<AtomicUsize> =
            (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(100, |i| i * i);
        assert_eq!(out[7], 49);
        assert_eq!(out[99], 9801);
        assert_eq!(out.len(), 100);
    }

    #[test]
    fn map_supports_non_default_types() {
        // NonZeroUsize has no Default impl; the old Mutex-slot collector
        // could not return it.
        use std::num::NonZeroUsize;
        let out = parallel_map(64, |i| NonZeroUsize::new(i + 1).unwrap());
        assert_eq!(out[0].get(), 1);
        assert_eq!(out[63].get(), 64);
    }

    #[test]
    fn map_empty_and_single() {
        let out: Vec<String> = parallel_map(0, |_| unreachable!());
        assert!(out.is_empty());
        let one = parallel_map(1, |i| format!("v{i}"));
        assert_eq!(one, vec!["v0".to_string()]);
    }

    #[test]
    fn single_thread_and_empty() {
        parallel_for_threads(0, 4, |_| panic!("no work"));
        let count = AtomicUsize::new(0);
        parallel_for_threads(3, 1, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn send_ptr_disjoint_writes() {
        let mut buf = vec![0usize; 256];
        let p = SendPtr::new(buf.as_mut_ptr());
        parallel_for(256, |i| unsafe {
            *p.get().add(i) = i * 3;
        });
        assert!(buf.iter().enumerate().all(|(i, &v)| v == i * 3));
    }
}
