//! In-tree substrates.
//!
//! The offline build environment only provides the `xla` (PJRT bridge) and
//! `anyhow` crates, so the usual ecosystem pieces are implemented here:
//! JSON ([`json`]), seeded RNG ([`rng`]), a scoped thread pool
//! ([`threadpool`]), summary statistics ([`stats`]), a CLI argument parser
//! ([`cli`]), a miniature property-testing harness ([`prop`]) and a
//! criterion-style bench harness ([`bench`]).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
