//! Seeded, reproducible RNG: SplitMix64 core with uniform / normal /
//! exponential / Poisson-process helpers. Every stochastic component in the
//! repo (weight init, samplers, workload traces, property tests) threads an
//! explicit `Rng` so runs are replayable from a single seed.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes; stable across
/// platforms (pure integer arithmetic).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9e3779b97f4a7c15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Derive an independent child stream (for per-request/per-layer seeds).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal (Box-Muller; one value per call, cached pair omitted
    /// for determinism simplicity).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos())
            as f32
    }

    /// Exponential with rate `lambda` (inter-arrival times of a Poisson
    /// process).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// k distinct indices out of [0, n) (partial shuffle).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let m: f64 =
            (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((m - 0.25).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(11);
        let ks = r.choose_k(50, 10);
        let mut s = ks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        assert!(ks.iter().all(|&i| i < 50));
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(13);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 1.0, 8.0])] += 1;
        }
        assert!(counts[2] > counts[0] * 4);
        assert!(counts[2] > counts[1] * 4);
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(5);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
