//! Criterion-style bench harness (criterion is unavailable offline).
//!
//! Each `rust/benches/*.rs` target is a `harness = false` binary that uses
//! [`Bench`] for timed measurement and [`Table`] to print the paper-shaped
//! rows it regenerates. Results can be dumped as JSON for EXPERIMENTS.md.

use std::time::Instant;

use super::stats::Accum;

/// Measure a closure: warmup iterations, then timed iterations, reporting a
/// summary in seconds.
pub struct Bench {
    pub name: String,
    pub warmup: usize,
    pub iters: usize,
}

#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    pub mean_s: f64,
    pub p50_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub iters: usize,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench { name: name.to_string(), warmup: 2, iters: 10 }
    }
    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }
    pub fn iters(mut self, n: usize) -> Self {
        self.iters = n;
        self
    }

    pub fn run<F: FnMut()>(&self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut acc = Accum::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            acc.push(t0.elapsed().as_secs_f64());
        }
        let s = acc.summary();
        let r = BenchResult {
            mean_s: s.mean,
            p50_s: s.p50,
            min_s: s.min,
            max_s: s.max,
            iters: self.iters,
        };
        eprintln!(
            "bench {:<40} mean {:>10.3}ms  p50 {:>10.3}ms  min {:>10.3}ms  \
             ({} iters)",
            self.name,
            r.mean_s * 1e3,
            r.p50_s * 1e3,
            r.min_s * 1e3,
            r.iters
        );
        r
    }
}

/// Fixed-width table printer for regenerated paper tables/figures.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table arity");
        self.rows.push(cells.to_vec());
    }
    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>()
            + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Convenience: format a fraction as "12.34%".
pub fn pct(x: f64) -> String {
    format!("{:.2}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = Bench::new("noop").warmup(1).iters(5).run(|| {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.iters, 5);
        assert!(r.min_s >= 0.0 && r.mean_s >= r.min_s);
    }

    #[test]
    fn table_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // should not panic
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.5122), "51.22%");
    }
}
