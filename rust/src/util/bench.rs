//! Criterion-style bench harness (criterion is unavailable offline).
//!
//! Each `rust/benches/*.rs` target is a `harness = false` binary that uses
//! [`Bench`] for timed measurement and [`Table`] to print the paper-shaped
//! rows it regenerates. Results can be dumped as JSON for EXPERIMENTS.md,
//! and the micro benches persist machine-readable results per run through
//! [`JsonReport`] so successive PRs have a perf trajectory to compare.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use super::json::{self, Value};
use super::stats::Accum;

/// Measure a closure: warmup iterations, then timed iterations, reporting a
/// summary in seconds.
pub struct Bench {
    pub name: String,
    pub warmup: usize,
    pub iters: usize,
}

#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    pub mean_s: f64,
    pub p50_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub iters: usize,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench { name: name.to_string(), warmup: 2, iters: 10 }
    }
    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }
    pub fn iters(mut self, n: usize) -> Self {
        self.iters = n;
        self
    }

    pub fn run<F: FnMut()>(&self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut acc = Accum::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            acc.push(t0.elapsed().as_secs_f64());
        }
        let s = acc.summary();
        let r = BenchResult {
            mean_s: s.mean,
            p50_s: s.p50,
            min_s: s.min,
            max_s: s.max,
            iters: self.iters,
        };
        eprintln!(
            "bench {:<40} mean {:>10.3}ms  p50 {:>10.3}ms  min {:>10.3}ms  \
             ({} iters)",
            self.name,
            r.mean_s * 1e3,
            r.p50_s * 1e3,
            r.min_s * 1e3,
            r.iters
        );
        r
    }
}

/// Fixed-width table printer for regenerated paper tables/figures.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table arity");
        self.rows.push(cells.to_vec());
    }
    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>()
            + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Convenience: format a fraction as "12.34%".
pub fn pct(x: f64) -> String {
    format!("{:.2}%", x * 100.0)
}

/// Machine-readable bench results, merged into one JSON file keyed by
/// section (one section per bench binary). `micro_quant`/`micro_kernel`
/// write `BENCH_micro.json` at the crate root every run, giving future
/// PRs a perf trajectory to diff against.
pub struct JsonReport {
    path: PathBuf,
    section: String,
    entries: BTreeMap<String, Value>,
}

impl JsonReport {
    /// Report into the shared `BENCH_micro.json` under `section`.
    pub fn micro(section: &str) -> JsonReport {
        JsonReport::at("BENCH_micro.json", section)
    }

    pub fn at(path: impl Into<PathBuf>, section: &str) -> JsonReport {
        JsonReport {
            path: path.into(),
            section: section.to_string(),
            entries: BTreeMap::new(),
        }
    }

    /// Record a timed measurement.
    pub fn add(&mut self, name: &str, r: &BenchResult) {
        self.entries.insert(
            name.to_string(),
            Value::obj(vec![
                ("mean_s", Value::num(r.mean_s)),
                ("p50_s", Value::num(r.p50_s)),
                ("min_s", Value::num(r.min_s)),
                ("max_s", Value::num(r.max_s)),
                ("iters", Value::num(r.iters as f64)),
            ]),
        );
    }

    /// Record a scalar metric (a ratio, a GB/s figure, an eval count).
    pub fn metric(&mut self, name: &str, v: f64) {
        self.entries.insert(name.to_string(), Value::num(v));
    }

    /// Merge this section into the file, preserving other sections. An
    /// existing file that no longer parses (e.g. a run killed mid-write)
    /// is set aside as `<file>.corrupt` with a warning rather than
    /// silently dropping the other sections' history.
    pub fn write(&self) -> std::io::Result<()> {
        let mut root = BTreeMap::new();
        if let Ok(text) = std::fs::read_to_string(&self.path) {
            match json::parse(&text) {
                Ok(Value::Obj(o)) => root = o,
                _ => {
                    let bak = PathBuf::from(
                        format!("{}.corrupt", self.path.display()),
                    );
                    eprintln!(
                        "warning: {} is not a JSON object; moving it \
                         to {} and starting fresh",
                        self.path.display(),
                        bak.display()
                    );
                    std::fs::rename(&self.path, &bak).ok();
                }
            }
        }
        root.insert(self.section.clone(),
                    Value::Obj(self.entries.clone()));
        std::fs::write(&self.path, format!("{}\n", Value::Obj(root)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = Bench::new("noop").warmup(1).iters(5).run(|| {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.iters, 5);
        assert!(r.min_s >= 0.0 && r.mean_s >= r.min_s);
    }

    #[test]
    fn table_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // should not panic
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.5122), "51.22%");
    }

    #[test]
    fn json_report_merges_sections() {
        let dir = std::env::temp_dir().join("sqplus_test_bench");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        std::fs::remove_file(&path).ok();

        let mut a = JsonReport::at(&path, "alpha");
        a.add(
            "warm",
            &BenchResult {
                mean_s: 0.5,
                p50_s: 0.4,
                min_s: 0.3,
                max_s: 0.9,
                iters: 5,
            },
        );
        a.metric("speedup", 2.5);
        a.write().unwrap();

        let mut b = JsonReport::at(&path, "beta");
        b.metric("gbps", 11.0);
        b.write().unwrap();

        let root =
            json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(root.get("alpha").get("speedup").as_f64(), Some(2.5));
        assert_eq!(
            root.get("alpha").get("warm").get("p50_s").as_f64(),
            Some(0.4)
        );
        assert_eq!(
            root.get("alpha").get("warm").get("iters").as_usize(),
            Some(5)
        );
        // section written by a different report survives
        assert_eq!(root.get("beta").get("gbps").as_f64(), Some(11.0));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn json_report_sets_aside_corrupt_file() {
        let dir = std::env::temp_dir().join("sqplus_test_bench");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_corrupt.json");
        let bak = dir.join("BENCH_corrupt.json.corrupt");
        std::fs::remove_file(&bak).ok();
        std::fs::write(&path, "{\"truncated\": ").unwrap();

        let mut r = JsonReport::at(&path, "gamma");
        r.metric("x", 1.0);
        r.write().unwrap();

        // fresh valid file written, corrupt original preserved
        let root =
            json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(root.get("gamma").get("x").as_f64(), Some(1.0));
        assert!(bak.exists());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&bak).ok();
    }
}
