//! Tiny CLI argument parser: `--key value`, `--key=value`, `--flag`, and
//! positional arguments. Subcommand-style dispatch is handled by the
//! binaries themselves.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// (name, help) pairs registered via the typed getters, for --help.
    known: Vec<(String, String)>,
}

impl Args {
    /// Parse from an iterator of raw arguments (no argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut a = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.options.insert(body.to_string(), v);
                } else {
                    a.flags.push(body.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&mut self, name: &str, help: &str) -> bool {
        self.known.push((format!("--{name}"), help.to_string()));
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&mut self, name: &str, default: &str, help: &str) -> String {
        self.known
            .push((format!("--{name} <v> [{default}]"), help.to_string()));
        self.options.get(name).cloned().unwrap_or_else(|| default.into())
    }

    pub fn opt_usize(&mut self, name: &str, default: usize, help: &str)
        -> usize {
        self.opt(name, &default.to_string(), help)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    pub fn opt_f64(&mut self, name: &str, default: f64, help: &str) -> f64 {
        self.opt(name, &default.to_string(), help)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects a number"))
    }

    pub fn opt_u64(&mut self, name: &str, default: u64, help: &str) -> u64 {
        self.opt(name, &default.to_string(), help)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    /// Print collected help for every option touched so far.
    pub fn help(&self, header: &str) -> String {
        let mut s = format!("{header}\n\noptions:\n");
        for (name, help) in &self.known {
            s.push_str(&format!("  {name:<28} {help}\n"));
        }
        s
    }

    pub fn wants_help(&self) -> bool {
        self.flags.iter().any(|f| f == "help" || f == "h")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn options_and_flags() {
        // NB: a bare `--flag` followed by a non-`--` token would consume it
        // as a value; flags therefore go after positionals or other flags.
        let mut a = parse("serve pos1 --model base --steps=100 --verbose");
        assert_eq!(a.positional, vec!["serve", "pos1"]);
        assert_eq!(a.opt("model", "tiny", ""), "base");
        assert_eq!(a.opt_usize("steps", 1, ""), 100);
        assert!(a.flag("verbose", ""));
        assert!(!a.flag("quiet", ""));
    }

    #[test]
    fn defaults() {
        let mut a = parse("");
        assert_eq!(a.opt("alpha", "0.5", ""), "0.5");
        assert_eq!(a.opt_f64("rate", 2.5, ""), 2.5);
    }

    #[test]
    fn flag_before_positional() {
        // `--flag value` treats value as the option's value; `--flag --x`
        // treats flag as boolean.
        let mut a = parse("--dry-run --out file.txt");
        assert!(a.flag("dry-run", ""));
        assert_eq!(a.opt("out", "", ""), "file.txt");
    }

    #[test]
    fn help_rendering() {
        let mut a = parse("");
        a.opt("model", "tiny", "model size");
        let h = a.help("sqplus");
        assert!(h.contains("--model"));
        assert!(h.contains("model size"));
    }
}
