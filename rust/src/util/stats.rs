//! Summary statistics: mean, percentiles, histograms, and a streaming
//! accumulator. Used by the metrics pipeline and the bench harness.

/// Streaming accumulator for scalar samples.
#[derive(Debug, Clone, Default)]
pub struct Accum {
    samples: Vec<f64>,
}

impl Accum {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }
    pub fn extend(&mut self, xs: &[f64]) {
        self.samples.extend_from_slice(xs);
    }
    pub fn len(&self) -> usize {
        self.samples.len()
    }
    /// The raw samples (e.g. to merge accumulators across replicas).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.sum() / self.samples.len() as f64
    }
    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }
    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
    pub fn stddev(&self) -> f64 {
        let m = self.mean();
        if self.samples.len() < 2 {
            return 0.0;
        }
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }
    /// Percentile via linear interpolation between order statistics
    /// (matches numpy's default). `p` in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        percentile(&mut self.samples.clone(), p)
    }
    pub fn summary(&self) -> Summary {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: s.len(),
            mean: self.mean(),
            min: *s.first().unwrap_or(&0.0),
            p50: percentile_sorted(&s, 50.0),
            p90: percentile_sorted(&s, 90.0),
            p99: percentile_sorted(&s, 99.0),
            max: *s.last().unwrap_or(&0.0),
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

pub fn percentile(xs: &mut [f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(xs, p)
}

fn percentile_sorted(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let rank = p / 100.0 * (xs.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        xs[lo]
    } else {
        let w = rank - lo as f64;
        xs[lo] * (1.0 - w) + xs[hi] * w
    }
}

/// Fixed-bin histogram over [lo, hi); out-of-range values clamp to the
/// first/last bin. Used by the Fig 1/2 magnitude plots.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins] }
    }
    pub fn add(&mut self, x: f64) {
        let n = self.bins.len();
        let t = ((x - self.lo) / (self.hi - self.lo) * n as f64) as isize;
        let i = t.clamp(0, n as isize - 1) as usize;
        self.bins[i] += 1;
    }
    pub fn total(&self) -> u64 {
        self.bins.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_percentiles() {
        let mut a = Accum::new();
        a.extend(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(a.mean(), 3.0);
        assert_eq!(a.percentile(0.0), 1.0);
        assert_eq!(a.percentile(100.0), 5.0);
        assert_eq!(a.percentile(50.0), 3.0);
        assert_eq!(a.percentile(25.0), 2.0);
    }

    #[test]
    fn interpolated_percentile() {
        let mut xs = vec![0.0, 10.0];
        assert_eq!(percentile(&mut xs, 50.0), 5.0);
        assert_eq!(percentile(&mut xs, 90.0), 9.0);
    }

    #[test]
    fn summary_fields() {
        let mut a = Accum::new();
        for i in 1..=100 {
            a.push(i as f64);
        }
        let s = a.summary();
        assert_eq!(s.n, 100);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!(s.p90 > 90.0 && s.p90 < 91.0);
    }

    #[test]
    fn stddev_sane() {
        let mut a = Accum::new();
        a.extend(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((a.stddev() - 2.138).abs() < 0.01);
    }

    #[test]
    fn histogram_bins_and_clamping() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(0.5);
        h.add(9.99);
        h.add(-5.0); // clamps to bin 0
        h.add(50.0); // clamps to last bin
        assert_eq!(h.bins[0], 2);
        assert_eq!(h.bins[9], 2);
        assert_eq!(h.total(), 4);
    }

    #[test]
    fn empty_accum() {
        let a = Accum::new();
        assert_eq!(a.mean(), 0.0);
        assert_eq!(a.summary().n, 0);
    }
}
