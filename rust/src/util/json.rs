//! Minimal JSON: a `Value` tree, a recursive-descent parser and a
//! serializer. Covers the full JSON grammar (RFC 8259) minus `\u` surrogate
//! pairs outside the BMP being combined (they are passed through as two
//! escaped code units on write).
//!
//! Used for `artifacts/manifest.json`, engine/server wire format, and
//! bench result dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON document node. Object keys are kept sorted (BTreeMap) so output
/// is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]` style access; returns Null for missing keys/non-objects.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Value {
        Value::Num(n.into())
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write_value(self, f)
    }
}

fn write_escaped(s: &str, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

fn write_value(v: &Value, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    match v {
        Value::Null => f.write_str("null"),
        Value::Bool(b) => write!(f, "{b}"),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                write!(f, "{}", *n as i64)
            } else {
                write!(f, "{n}")
            }
        }
        Value::Str(s) => write_escaped(s, f),
        Value::Arr(a) => {
            f.write_str("[")?;
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    f.write_str(",")?;
                }
                write_value(x, f)?;
            }
            f.write_str("]")
        }
        Value::Obj(o) => {
            f.write_str("{")?;
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    f.write_str(",")?;
                }
                write_escaped(k, f)?;
                f.write_str(":")?;
                write_value(x, f)?;
            }
            f.write_str("}")
        }
    }
}

/// Parse a JSON document. Errors carry a byte offset.
pub fn parse(input: &str) -> Result<Value, String> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }
    fn lit(&mut self, s: &str, v: Value) -> Result<Value, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte {}", self.i)),
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.eat(b'{')?;
        let mut o = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(o));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            o.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(o));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or("unterminated string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| "bad \\u")?;
                            let n = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u")?;
                            self.i += 4;
                            s.push(char::from_u32(n).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at {}", self.i)),
                    }
                }
                c => {
                    // collect the full UTF-8 sequence starting at c
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err("bad utf8".into());
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or(format!("bad number at byte {start}"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.25", "\"hi\""] {
            let v = parse(s).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""Aé\t""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé\t");
        let v = parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("1e3").unwrap().as_f64().unwrap(), 1000.0);
        assert_eq!(parse("-2.5e-2").unwrap().as_f64().unwrap(), -0.025);
        // integers print without a fraction
        assert_eq!(Value::Num(5.0).to_string(), "5");
        assert_eq!(Value::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn get_missing_is_null() {
        let v = parse(r#"{"a":1}"#).unwrap();
        assert_eq!(*v.get("zzz"), Value::Null);
        assert_eq!(*v.get("a").get("deeper"), Value::Null);
    }
}
