//! Accuracy evaluation: the pass@1 proxy and token-level agreement
//! (DESIGN.md §5 — the stand-in for HumanEval pass@1 on untrained-weight
//! models; "lossless" ⇔ the quantized model reproduces the FP16 model).
//!
//! Two complementary metrics against the FP16 reference:
//! * **exact match** — greedy generations identical over the task set
//!   (the pass@1-shaped, all-or-nothing signal);
//! * **token agreement** — teacher-forced next-token argmax agreement
//!   over eval prompts (smooth, per-position signal).
//!
//! Both run on the pure-Rust reference forward so they do not require
//! artifacts; engine-level generation equality is covered by the
//! integration tests.
//!
//! Either side may be a w4a16-layout *deploy* store: `RefModel` detects
//! packed linears by name and routes them through the fused host W4A16
//! kernel, so quantized serving accuracy can be evaluated on the packed
//! path itself rather than a fake-quant stand-in.

use crate::config::ModelConfig;
use crate::coordinator::sampler::argmax;
use crate::model::store::WeightStore;
use crate::reffwd::{NoHook, RefModel};
use crate::util::threadpool::parallel_map;

/// Accuracy scores for one candidate store against the FP16 reference
/// (see the module docs for the metric definitions).
#[derive(Debug, Clone, Default)]
pub struct EvalReport {
    /// Fraction of prompts whose greedy generation matches FP16 exactly.
    pub exact_match: f64,
    /// Teacher-forced next-token argmax agreement.
    pub token_agreement: f64,
    /// Mean negative log-likelihood the candidate assigns to the
    /// reference model's greedy tokens (cross-model perplexity proxy).
    pub nll: f64,
    /// Number of eval prompts the averages above were taken over.
    pub n_prompts: usize,
}

/// Greedy-generate `max_new` tokens from `prompt`.
pub fn greedy_generate(cfg: &ModelConfig, w: &WeightStore, prompt: &[u32],
                       max_new: usize) -> Vec<u32> {
    let m = RefModel::new(cfg, w);
    let capped = &prompt[..prompt.len().min(cfg.max_len - max_new - 1)];
    let (logits, mut cache) = m.prefill(capped, &mut NoHook);
    let mut out = vec![argmax(logits.row(capped.len() - 1))];
    for _ in 1..max_new {
        let lg = m.decode(*out.last().unwrap(), &mut cache, &mut NoHook);
        out.push(argmax(&lg));
    }
    out
}

/// Compare `candidate` against `reference` over `prompts`.
pub fn evaluate(cfg: &ModelConfig, reference: &WeightStore,
                candidate: &WeightStore, prompts: &[Vec<u32>],
                max_new: usize) -> EvalReport {
    let n = prompts.len();
    let results = parallel_map(n, |i| {
        let p = &prompts[i];
        // --- greedy exact match
        let want = greedy_generate(cfg, reference, p, max_new);
        let got = greedy_generate(cfg, candidate, p, max_new);
        let exact = (want == got) as u32;
        // --- teacher-forced agreement + NLL along the reference path
        let mut forced = p.clone();
        forced.truncate(cfg.max_len - 1);
        forced.extend(&want);
        forced.truncate(cfg.max_len - 1);
        let mr = RefModel::new(cfg, reference);
        let mc = RefModel::new(cfg, candidate);
        let (lr, _) = mr.prefill(&forced, &mut NoHook);
        let (lc, _) = mc.prefill(&forced, &mut NoHook);
        let mut agree = 0usize;
        let mut total = 0usize;
        let mut nll = 0.0f64;
        for pos in 0..forced.len() - 1 {
            let a = argmax(lr.row(pos));
            let b = argmax(lc.row(pos));
            agree += (a == b) as usize;
            total += 1;
            // candidate's NLL of the reference's argmax token
            let row = lc.row(pos);
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let z: f64 = row.iter().map(|&x| ((x - m) as f64).exp()).sum();
            nll -= (((row[a as usize] - m) as f64).exp() / z).ln();
        }
        (exact, agree, total, nll)
    });
    let mut exact = 0u32;
    let mut agree = 0usize;
    let mut total = 0usize;
    let mut nll = 0.0f64;
    for (e, a, t, l) in results {
        exact += e;
        agree += a;
        total += t;
        nll += l;
    }
    EvalReport {
        exact_match: exact as f64 / n.max(1) as f64,
        token_agreement: agree as f64 / total.max(1) as f64,
        nll: nll / total.max(1) as f64,
        n_prompts: n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{QuantConfig, QuantMethod};
    use crate::model::init::{init_weights, InitSpec};
    use crate::quant::{calib, pipeline};

    fn prompts(n: usize, len: usize, vocab: usize) -> Vec<Vec<u32>> {
        (0..n)
            .map(|i| {
                (0..len).map(|t| ((i * 131 + t * 29) % vocab) as u32)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn reference_vs_itself_is_perfect() {
        let cfg = ModelConfig::tiny();
        let w = init_weights(&cfg, &InitSpec::default());
        let r = evaluate(&cfg, &w, &w, &prompts(4, 8, cfg.vocab), 4);
        assert_eq!(r.exact_match, 1.0);
        assert_eq!(r.token_agreement, 1.0);
        assert_eq!(r.n_prompts, 4);
    }

    #[test]
    fn method_ordering_sqplus_beats_rtn() {
        // the Table-1 shape at tiny scale. Argmax agreement over a few
        // short prompts is too noisy for a single-seed unit test, so the
        // asserted signal is the smooth one: the quantized model's NLL of
        // the reference trajectory. (The full argmax-agreement tables are
        // regenerated by `cargo bench --bench table1_accuracy` at small/
        // base scale with 164 prompts.)
        let cfg = ModelConfig::tiny();
        let w = init_weights(&cfg, &InitSpec::with_outliers(0, 6, 60.0));
        let cal_prompts = prompts(4, 10, cfg.vocab);
        let cal = calib::collect(&cfg, &w, &cal_prompts, 24, 0);
        let qcfg = QuantConfig::default();
        let ev_prompts = prompts(12, 10, cfg.vocab);
        let rtn = pipeline::quantize_model(&cfg, &w, &cal,
                                           QuantMethod::Rtn, &qcfg);
        let sqp = pipeline::quantize_model(
            &cfg, &w, &cal, QuantMethod::SmoothQuantPlus, &qcfg);
        let r_rtn = evaluate(&cfg, &w, &rtn.effective, &ev_prompts, 4);
        let r_sqp = evaluate(&cfg, &w, &sqp.effective, &ev_prompts, 4);
        assert!(
            r_sqp.nll <= r_rtn.nll,
            "SQ+ nll {} !<= RTN nll {}",
            r_sqp.nll,
            r_rtn.nll
        );
    }

    #[test]
    fn packed_candidate_evaluates_like_effective() {
        // exercising packed mode end-to-end: the deploy store (fused
        // W4A16 kernel path) must score essentially the same as its
        // fake-quant effective twin
        let cfg = ModelConfig::tiny();
        let w = init_weights(&cfg, &InitSpec::with_outliers(0, 4, 60.0));
        let cal_prompts = prompts(3, 10, cfg.vocab);
        let cal = calib::collect(&cfg, &w, &cal_prompts, 16, 0);
        let out = pipeline::quantize_model(&cfg, &w, &cal,
                                           QuantMethod::Rtn,
                                           &QuantConfig::default());
        let deploy = out.deploy.unwrap();
        let ev = prompts(6, 8, cfg.vocab);
        let r_eff = evaluate(&cfg, &w, &out.effective, &ev, 4);
        let r_pkd = evaluate(&cfg, &w, &deploy, &ev, 4);
        assert_eq!(r_pkd.n_prompts, 6);
        assert!(r_pkd.nll.is_finite());
        // the two candidates are the same function up to kernel f32
        // reassociation; scores must be near-identical
        assert!((r_pkd.nll - r_eff.nll).abs() < 1e-2,
                "nll packed {} vs effective {}", r_pkd.nll, r_eff.nll);
        assert!((r_pkd.token_agreement - r_eff.token_agreement).abs()
                    <= 0.05,
                "agreement packed {} vs effective {}",
                r_pkd.token_agreement, r_eff.token_agreement);
    }

    #[test]
    fn greedy_generate_len() {
        let cfg = ModelConfig::tiny();
        let w = init_weights(&cfg, &InitSpec::benign(0));
        let out = greedy_generate(&cfg, &w, &[1, 2, 3], 5);
        assert_eq!(out.len(), 5);
        assert!(out.iter().all(|&t| (t as usize) < cfg.vocab));
    }
}
