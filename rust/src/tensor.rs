//! Host-side tensors: a dense row-major `f32` tensor plus a packed `u8`
//! tensor for INT4 nibbles. Implements exactly the ops the library needs
//! (threaded matmul, per-channel scaling, norms) rather than a general
//! ndarray.
//!
//! # Packed-nibble layout
//!
//! [`U8Tensor`] stores a `[K, N]` INT4 weight as `u8[K/2, N]`: byte
//! `(k2, j)` holds input-channel rows `2*k2` (low nibble) and `2*k2 + 1`
//! (high nibble) of column `j` — two consecutive input-channel rows per
//! byte, low nibble first. This is the layout the Pallas kernel unpacks in
//! VMEM and the one the host-side fused kernel
//! (`crate::quant::kernel::matmul_w4a16`) streams through without ever
//! materializing the dequantized f32 weight.

use crate::util::threadpool::{parallel_for, SendPtr};

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }
    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![1.0; shape.iter().product()] }
    }
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data }
    }
    pub fn numel(&self) -> usize {
        self.data.len()
    }
    pub fn rank(&self) -> usize {
        self.shape.len()
    }
    /// Rows/cols of a rank-2 tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.shape.len(), 2, "expected rank-2, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }
    pub fn row(&self, i: usize) -> &[f32] {
        let (_, n) = self.dims2();
        &self.data[i * n..(i + 1) * n]
    }
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let n = self.shape[self.shape.len() - 1];
        &mut self.data[i * n..(i + 1) * n]
    }

    /// `self[M,K] @ other[K,N]` -> `[M,N]`, threaded over row blocks with a
    /// K-blocked inner loop (cache-friendly, auto-vectorizable).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        let (m, k) = self.dims2();
        let (k2, n) = other.dims2();
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = Tensor::zeros(&[m, n]);
        // SAFETY: each row block of `out` is written by exactly one task.
        let out_ptr = SendPtr::new(out.data.as_mut_ptr());
        let a = &self.data;
        let b = &other.data;
        const KB: usize = 64;
        parallel_for(m, |i| {
            let orow = unsafe {
                std::slice::from_raw_parts_mut(out_ptr.get().add(i * n), n)
            };
            for k0 in (0..k).step_by(KB) {
                let k1 = (k0 + KB).min(k);
                for kk in k0..k1 {
                    let aik = a[i * k + kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..kk * n + n];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += aik * bv;
                    }
                }
            }
        });
        out
    }

    /// Transpose a rank-2 tensor.
    pub fn t(&self) -> Tensor {
        let (m, n) = self.dims2();
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..m {
            for j in 0..n {
                out.data[j * m + i] = self.data[i * n + j];
            }
        }
        out
    }

    /// Gram matrix `selfᵀ self` ([K,K] from [T,K]), threaded.
    pub fn gram(&self) -> Tensor {
        self.t().matmul(self)
    }

    /// Scale column j (last-dim index) by s[j], in place.
    pub fn scale_cols(&mut self, s: &[f32]) {
        let n = *self.shape.last().unwrap();
        assert_eq!(s.len(), n);
        for row in self.data.chunks_mut(n) {
            for (x, &f) in row.iter_mut().zip(s) {
                *x *= f;
            }
        }
    }

    /// Scale row i (first-dim index) by s[i], in place (rank-2).
    pub fn scale_rows(&mut self, s: &[f32]) {
        let (m, n) = self.dims2();
        assert_eq!(s.len(), m);
        for i in 0..m {
            for x in &mut self.data[i * n..(i + 1) * n] {
                *x *= s[i];
            }
        }
    }

    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor::from_vec(
            &self.shape,
            self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        )
    }

    /// Fused `||self - other||²_F`: the same value as
    /// `self.sub(other).frob_sq()` (identical f32 subtraction and f64
    /// accumulation order) without allocating the difference tensor.
    pub fn sq_diff(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum()
    }

    /// Per-column max |x| of a rank-2 tensor -> len N.
    pub fn col_absmax(&self) -> Vec<f32> {
        let (m, n) = self.dims2();
        let mut out = vec![0.0f32; n];
        for i in 0..m {
            for j in 0..n {
                out[j] = out[j].max(self.data[i * n + j].abs());
            }
        }
        out
    }

    /// Per-column mean |x| of a rank-2 tensor -> len N.
    pub fn col_absmean(&self) -> Vec<f32> {
        let (m, n) = self.dims2();
        let mut out = vec![0.0f64; n];
        for i in 0..m {
            for j in 0..n {
                out[j] += self.data[i * n + j].abs() as f64;
            }
        }
        out.iter().map(|&x| (x / m.max(1) as f64) as f32).collect()
    }

    /// Per-row max |x| of a rank-2 tensor -> len M (input-channel absmax of
    /// a [K,N] weight).
    pub fn row_absmax(&self) -> Vec<f32> {
        let (m, n) = self.dims2();
        (0..m)
            .map(|i| {
                self.data[i * n..(i + 1) * n]
                    .iter()
                    .fold(0.0f32, |a, &x| a.max(x.abs()))
            })
            .collect()
    }
}

/// Packed-nibble tensor (two INT4 values per byte along the first axis).
#[derive(Debug, Clone, PartialEq)]
pub struct U8Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl U8Tensor {
    pub fn zeros(shape: &[usize]) -> U8Tensor {
        U8Tensor { shape: shape.to_vec(), data: vec![0; shape.iter().product()] }
    }
    pub fn from_vec(shape: &[usize], data: Vec<u8>) -> U8Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        U8Tensor { shape: shape.to_vec(), data }
    }
    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn matmul_small() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_matches_naive() {
        prop::check("matmul == naive", 10, |rng| {
            let (m, k, n) =
                (1 + rng.below(17), 1 + rng.below(33), 1 + rng.below(17));
            let a = Tensor::from_vec(
                &[m, k],
                (0..m * k).map(|_| rng.normal()).collect(),
            );
            let b = Tensor::from_vec(
                &[k, n],
                (0..k * n).map(|_| rng.normal()).collect(),
            );
            let c = a.matmul(&b);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..k)
                        .map(|kk| a.data[i * k + kk] * b.data[kk * n + j])
                        .sum();
                    prop::assert_close(
                        c.data[i * n + j] as f64,
                        want as f64,
                        1e-4,
                        "entry",
                    );
                }
            }
        });
    }

    #[test]
    fn transpose_involution() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.t().t(), a);
        assert_eq!(a.t().shape, vec![3, 2]);
        assert_eq!(a.t().data, vec![1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn scale_cols_rows() {
        let mut a = Tensor::ones(&[2, 3]);
        a.scale_cols(&[1.0, 2.0, 3.0]);
        assert_eq!(a.data, vec![1., 2., 3., 1., 2., 3.]);
        a.scale_rows(&[10.0, 0.5]);
        assert_eq!(a.data, vec![10., 20., 30., 0.5, 1., 1.5]);
    }

    #[test]
    fn reductions() {
        let a = Tensor::from_vec(&[2, 2], vec![-3.0, 1.0, 2.0, -4.0]);
        assert_eq!(a.col_absmax(), vec![3.0, 4.0]);
        assert_eq!(a.row_absmax(), vec![3.0, 4.0]);
        assert_eq!(a.col_absmean(), vec![2.5, 2.5]);
        assert_eq!(a.frob_sq(), 9.0 + 1.0 + 4.0 + 16.0);
    }

    #[test]
    fn sq_diff_matches_sub_frob() {
        prop::check("sq_diff == sub+frob_sq", 10, |rng| {
            let (m, n) = (1 + rng.below(9), 1 + rng.below(17));
            let a = Tensor::from_vec(
                &[m, n],
                (0..m * n).map(|_| rng.normal()).collect(),
            );
            let b = Tensor::from_vec(
                &[m, n],
                (0..m * n).map(|_| rng.normal()).collect(),
            );
            // bit-for-bit: same f32 diffs, same f64 accumulation order
            assert_eq!(a.sq_diff(&b), a.sub(&b).frob_sq());
            assert_eq!(a.sq_diff(&a), 0.0);
        });
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let a = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let g = a.gram();
        assert_eq!(g.shape, vec![2, 2]);
        assert_eq!(g.data[1], g.data[2]); // symmetric
        assert!(g.data[0] > 0.0 && g.data[3] > 0.0);
    }
}
