//! `sqplus` — the SmoothQuant+ serving CLI (leader entrypoint).
//!
//! ```text
//! sqplus quantize  --model base --method smoothquant+ --out model.sqw
//! sqplus generate  --model tiny --method rtn --prompt "def add(" -n 16
//! sqplus serve     --model small --method smoothquant+ --port 7181 \
//!                  --replicas 2 --routing cache-aware
//! sqplus eval      --model small --methods fp16,rtn,awq,smoothquant+
//! sqplus inspect   --model tiny        # activation/weight statistics
//! ```
//!
//! Everything runs on the PJRT CPU backend from AOT artifacts (`make
//! artifacts`); Python is never invoked here.

use anyhow::{bail, Context, Result};

use sqplus::config::{
    CacheWatermarks, EngineConfig, GpuProfile, KvCacheMode,
    ModelConfig, Precision, QuantConfig, QuantMethod, RouterConfig,
    RoutingPolicy,
};
use sqplus::coordinator::engine::Engine;
use sqplus::coordinator::sequence::SamplingParams;
use sqplus::data::{corpus, tasks};
use sqplus::model::init::{init_weights, InitSpec};
use sqplus::model::store::WeightStore;
use sqplus::quant::{calib, pipeline};
use sqplus::runtime::executor::ModelRuntime;
use sqplus::runtime::manifest;
use sqplus::runtime::simtp::Deployment;
use sqplus::server::{ServeOptions, Server};
use sqplus::tokenizer::Tokenizer;
use sqplus::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut args = Args::from_env();
    let cmd = args.positional.first().cloned().unwrap_or_default();
    match cmd.as_str() {
        "quantize" => cmd_quantize(&mut args),
        "generate" => cmd_generate(&mut args),
        "serve" => cmd_serve(&mut args),
        "eval" => cmd_eval(&mut args),
        "inspect" => cmd_inspect(&mut args),
        _ => {
            println!(
                "sqplus — SmoothQuant+ 4-bit weight quantization + serving\n\
                 \n\
                 usage: sqplus <quantize|generate|serve|eval|inspect> \
                 [options]\n\
                 \n\
                 common options:\n\
                 \x20 --model <tiny|small|base>     model size [tiny]\n\
                 \x20 --method <fp16|rtn|awq|smoothquant+>  [smoothquant+]\n\
                 \x20 --seed <n>                    weight seed [0]\n\
                 \x20 --outliers <n>                injected outlier \
                 channels [8]\n\
                 run a subcommand with --help for its options"
            );
            Ok(())
        }
    }
}

fn parse_method(s: &str) -> Result<QuantMethod> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "fp16" => QuantMethod::Fp16,
        "rtn" => QuantMethod::Rtn,
        "awq" => QuantMethod::Awq,
        "smoothquant+" | "sq+" | "sqplus" => QuantMethod::SmoothQuantPlus,
        other => bail!("unknown method {other}"),
    })
}

/// Shared setup: model weights + calibration + quantization outcome.
fn build_model(args: &mut Args)
    -> Result<(ModelConfig, WeightStore, pipeline::QuantOutcome, Tokenizer)> {
    let size = args.opt("model", "tiny", "model size");
    let method = parse_method(&args.opt("method", "smoothquant+",
                                        "quantization method"))?;
    let seed = args.opt_u64("seed", 0, "weight seed");
    let outliers = args.opt_usize("outliers", 8, "outlier channels");
    let oscale = args.opt_f64("outlier-scale", 12.0, "outlier gain scale") as f32;
    let cfg = ModelConfig::by_name(&size)
        .with_context(|| format!("unknown model {size}"))?;
    eprintln!("[setup] init {size} ({} params), outliers={outliers}",
              cfg.param_count());
    let w = init_weights(&cfg, &InitSpec::with_outliers(seed, outliers, oscale));
    let tok = Tokenizer::train(
        &corpus::tokenizer_training_text(seed, 4000), cfg.vocab);
    let calib_tasks = tasks::task_set(corpus::Domain::CodePython, seed);
    let prompts =
        tasks::tokenized_prompts(&calib_tasks[..32], &tok, cfg.vocab, 24);
    eprintln!("[setup] calibrating on {} prompts", prompts.len());
    let cal = calib::collect(&cfg, &w, &prompts, 256, seed);
    eprintln!("[setup] quantizing with {}", method.as_str());
    let out = pipeline::quantize_model(&cfg, &w, &cal, method,
                                       &QuantConfig::default());
    if let Some(a) = out.alpha {
        eprintln!("[setup] searched alpha = {a:.2} (loss {:.5})",
                  out.loss.total);
    }
    Ok((cfg, w, out, tok))
}

fn make_engine(args: &mut Args, out: &pipeline::QuantOutcome,
               cfg: &ModelConfig) -> Result<Engine> {
    let size = args.opt("model", "tiny", "model size");
    let kv_quant_s = args.opt("kv-quant", "f32",
                              "KV stash precision: f32|q8|q4");
    let kv_cache_mode = KvCacheMode::parse(&kv_quant_s)
        .with_context(|| format!("unknown kv-quant mode {kv_quant_s}"))?;
    let kv_pool_s = args.opt(
        "kv-pool", "0",
        "tiered demotion pool bound (blocks; 0 = tiering off, auto = \
         size from the GPU profile's memory headroom)");
    let man = manifest::require_artifacts()?;
    let (precision, deploy) = match &out.deploy {
        Some(d) => (Precision::W4a16, d.clone()),
        None => (Precision::Fp16,
                 pipeline::fp16_deploy(cfg, &out.effective)),
    };
    let rt = ModelRuntime::load(&man, &size, precision, &deploy)?;
    eprintln!("[setup] runtime loaded ({} buckets)",
              rt.decode_batches().len() + rt.prefill_buckets().len());
    let dep = Deployment::single(rt, GpuProfile::sim_small(512));
    let ecfg = EngineConfig { kv_cache_mode, ..Default::default() };
    let kv_pool_blocks = match kv_pool_s.as_str() {
        "auto" => {
            let blocks =
                Engine::auto_kv_pool_blocks(&dep, ecfg.block_size);
            eprintln!("[setup] kv-pool auto = {blocks} blocks");
            blocks
        }
        s => s.parse::<usize>().map_err(|_| {
            anyhow::anyhow!(
                "--kv-pool must be a block count or \"auto\" (got {s})"
            )
        })?,
    };
    Ok(Engine::new(dep, EngineConfig { kv_pool_blocks, ..ecfg }))
}

/// N replica engines + the router configuration (each replica loads
/// its own runtime: device weights and executables are per-replica
/// state).
fn make_replicas(args: &mut Args, out: &pipeline::QuantOutcome,
                 cfg: &ModelConfig)
    -> Result<(Vec<Engine>, RouterConfig)> {
    let replicas = args.opt_usize("replicas", 1, "replica engines");
    let routing_s = args.opt("routing", "cache-aware",
                             "cache-aware|least-loaded|round-robin");
    let routing = RoutingPolicy::parse(&routing_s)
        .with_context(|| format!("unknown routing policy {routing_s}"))?;
    let high = args.opt_usize("cache-evict-high", 0,
                              "sliding-window high watermark (blocks, \
                               0 = unbounded)");
    let low = args.opt_usize("cache-evict-low", high / 2,
                             "sliding-window low watermark (blocks)");
    let defaults = RouterConfig::default();
    let max_replica_queue = args.opt_usize(
        "max-queue", defaults.max_replica_queue,
        "per-replica queue cap before shedding (0 = unbounded)");
    let max_waiting = args.opt_usize(
        "max-waiting", defaults.max_waiting,
        "global waiting budget before shedding (0 = unbounded)");
    let max_step_retries = args.opt_usize(
        "step-retries", defaults.max_step_retries,
        "transient step failures tolerated before a replica is dead");
    let retry_backoff_steps = args.opt_usize(
        "retry-backoff", defaults.retry_backoff_steps,
        "quarantine backoff base (router steps, doubled per failure)");
    let cache_spread_limit = args.opt_usize(
        "cache-spread", defaults.cache_spread_limit,
        "consecutive cache-aware placements on one replica before the \
         pick spreads (0 = unbounded)");
    let kv_migrate_s = args.opt(
        "kv-migrate", "off",
        "ship stashed KV blocks from warm to cold replicas instead of \
         recomputing warm prefixes (on|off)");
    let kv_migrate = match kv_migrate_s.as_str() {
        "on" => true,
        "off" => false,
        other => bail!("--kv-migrate must be on|off (got {other})"),
    };
    let pooled_hit_discount = args.opt_usize(
        "pooled-hit-discount", defaults.pooled_hit_discount,
        "percent a pool-tier (demoted) hit token scores relative to a \
         device-resident one in cache-aware placement");
    let migrate_hit_discount = args.opt_usize(
        "migrate-hit-discount", defaults.migrate_hit_discount,
        "percent of the best remote prefix credited to every replica \
         when --kv-migrate is on (the migration floor)");
    anyhow::ensure!(replicas >= 1, "--replicas must be at least 1");
    let mut cores = Vec::with_capacity(replicas);
    for i in 0..replicas {
        eprintln!("[setup] loading replica {i}/{replicas}");
        cores.push(make_engine(args, out, cfg)?);
    }
    Ok((cores, RouterConfig {
        replicas,
        routing,
        watermarks: CacheWatermarks::new(high, low),
        max_replica_queue,
        max_waiting,
        max_step_retries,
        retry_backoff_steps,
        cache_spread_limit,
        kv_migrate,
        pooled_hit_discount,
        migrate_hit_discount,
        ..Default::default()
    }))
}

fn cmd_quantize(args: &mut Args) -> Result<()> {
    let out_path = args.opt("out", "model.sqw", "output path");
    let (_, _, out, _) = build_model(args)?;
    let store = match &out.deploy {
        Some(d) => d,
        None => &out.effective,
    };
    store.save(std::path::Path::new(&out_path))?;
    println!(
        "wrote {out_path}: {} tensors, {:.1} MB, method {}, loss {:.5}",
        store.len(),
        store.data_bytes() as f64 / 1e6,
        out.method.as_str(),
        out.loss.total
    );
    Ok(())
}

fn cmd_generate(args: &mut Args) -> Result<()> {
    let prompt_text = args.opt("prompt", "def add(a, b):", "prompt text");
    let n = args.opt_usize("n", 16, "tokens to generate");
    let (cfg, _, out, tok) = build_model(args)?;
    let mut eng = make_engine(args, &out, &cfg)?;
    let ids = tok.encode_for_model(&prompt_text, cfg.vocab);
    let id = eng.submit(
        ids,
        SamplingParams { max_new_tokens: n, ..Default::default() },
    );
    eng.run_to_completion(10_000)?;
    let fin = eng.take_finished();
    let seq = fin.iter().find(|s| s.id == id).context("lost sequence")?;
    println!("prompt: {prompt_text:?}");
    println!("tokens: {:?}", seq.output);
    println!("text:   {:?}", tok.decode(&seq.output));
    eng.metrics.report().print("generate");
    Ok(())
}

fn cmd_serve(args: &mut Args) -> Result<()> {
    let port = args.opt_usize("port", 7181, "TCP port") as u16;
    let loop_s = args.opt("serve-loop", "async",
                          "async (per-replica worker threads) | sync \
                           (single-thread reference loop)");
    let sync_loop = match loop_s.as_str() {
        "async" => false,
        "sync" => true,
        other => bail!("unknown serve loop {other}"),
    };
    let stream_buffer = args.opt_usize(
        "stream-buffer", ServeOptions::default().stream_buffer,
        "buffered lines per streaming response before a slow reader's \
         stream parks");
    let (cfg, _, out, _) = build_model(args)?;
    let (engines, rcfg) = make_replicas(args, &out, &cfg)?;
    let n = engines.len();
    let policy = rcfg.routing.as_str();
    let mode = if sync_loop { "sync" } else { "threaded" };
    let server = Server::spawn(engines, rcfg, port,
                               ServeOptions { stream_buffer, sync_loop })?;
    println!("sqplus serving on {} — {n} replica(s), {policy} routing, \
              {mode} loop \
              (JSON lines: {{\"prompt\":[ids],\"max_new_tokens\":n}}, \
              add \"stream\":true for token lines; \
              admin: {{\"cmd\":\"stats\"}}, {{\"cmd\":\"metrics\"}})",
             server.addr());
    println!("press ctrl-c to stop");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_eval(args: &mut Args) -> Result<()> {
    let methods = args.opt("methods", "fp16,rtn,awq,smoothquant+",
                           "comma-separated methods");
    let n_tasks = args.opt_usize("tasks", 32, "eval prompts");
    let max_new = args.opt_usize("n", 8, "tokens per prompt");
    let size = args.opt("model", "tiny", "model size");
    let seed = args.opt_u64("seed", 0, "weight seed");
    let outliers = args.opt_usize("outliers", 8, "outlier channels");
    let oscale = args.opt_f64("outlier-scale", 12.0, "outlier gain scale") as f32;
    let cfg = ModelConfig::by_name(&size).context("unknown model")?;
    let w = init_weights(&cfg, &InitSpec::with_outliers(seed, outliers, oscale));
    let tok = Tokenizer::train(
        &corpus::tokenizer_training_text(seed, 4000), cfg.vocab);
    let all = tasks::task_set(corpus::Domain::CodePython, seed);
    let cal_prompts =
        tasks::tokenized_prompts(&all[..32], &tok, cfg.vocab, 24);
    let cal = calib::collect(&cfg, &w, &cal_prompts, 256, seed);
    let ev = tasks::tokenized_prompts(&all[32..32 + n_tasks], &tok,
                                      cfg.vocab, 24);
    println!("{:<14} {:>12} {:>12} {:>10} {:>10}",
             "method", "exact-match", "agreement", "nll", "loss");
    for ms in methods.split(',') {
        let method = parse_method(ms)?;
        let out = pipeline::quantize_model(&cfg, &w, &cal, method,
                                           &QuantConfig::default());
        let r = sqplus::eval::evaluate(&cfg, &w, &out.effective, &ev,
                                       max_new);
        println!("{:<14} {:>11.1}% {:>11.1}% {:>10.4} {:>10.5}",
                 method.as_str(), r.exact_match * 100.0,
                 r.token_agreement * 100.0, r.nll, out.loss.total);
    }
    Ok(())
}

fn cmd_inspect(args: &mut Args) -> Result<()> {
    use sqplus::reffwd::Site;
    let size = args.opt("model", "tiny", "model size");
    let seed = args.opt_u64("seed", 0, "weight seed");
    let outliers = args.opt_usize("outliers", 8, "outlier channels");
    let oscale = args.opt_f64("outlier-scale", 12.0, "outlier gain scale") as f32;
    let cfg = ModelConfig::by_name(&size).context("unknown model")?;
    let w = init_weights(&cfg, &InitSpec::with_outliers(seed, outliers, oscale));
    let tok = Tokenizer::train(
        &corpus::tokenizer_training_text(seed, 4000), cfg.vocab);
    let all = tasks::task_set(corpus::Domain::CodePython, seed);
    let prompts = tasks::tokenized_prompts(&all[..16], &tok, cfg.vocab, 24);
    let cal = calib::collect(&cfg, &w, &prompts, 64, seed);
    println!("{:<8} {:<9} {:>12} {:>12} {:>10}",
             "layer", "site", "act absmax", "act median", "ratio");
    for layer in 0..cfg.layers {
        for site in Site::all() {
            let s = cal.stats(layer, site);
            let mut m = s.absmax.clone();
            m.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let max = m[m.len() - 1];
            let med = m[m.len() / 2];
            println!("{:<8} {:<9} {:>12.3} {:>12.4} {:>9.0}x",
                     layer, site.as_str(), max, med, max / med.max(1e-9));
        }
    }
    Ok(())
}
