//! Deployment wrapper: a [`ModelRuntime`] plus a simulated device
//! topology.
//!
//! The paper compares *FP16 sharded over two A100s* (tensor parallel, two
//! all-reduces per layer) against *W4A16 on one A100*. Our testbed is one
//! CPU, so the 2-GPU baseline is simulated: compute runs unchanged on the
//! single PJRT device while the interconnect cost of every decode/prefill
//! step is modeled from a [`GpuProfile`] and — in `Sleep` mode — actually
//! slept, so measured wall-clock includes it. `Account` mode only tallies
//! the time (fast tests). Per-GPU *compute* speedup from sharding is NOT
//! simulated (conservative for the baseline); the analytic
//! [`super::perfmodel`] covers the paper-scale regime. See DESIGN.md §5.

use std::time::Duration;

use anyhow::Result;

use crate::config::GpuProfile;

use super::executor::{
    ChunkResult, DecodeResult, ModelRuntime, PrefillResult,
};

/// How the simulated interconnect cost is applied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommMode {
    /// Sleep the modeled communication time (wall-clock-faithful).
    Sleep,
    /// Only account it in `comm_s` (fast tests).
    Account,
}

/// A deployment: 1 worker, or N simulated tensor-parallel workers.
pub struct Deployment {
    /// The single real PJRT runtime compute executes on.
    pub runtime: ModelRuntime,
    /// Simulated tensor-parallel worker count (1 = no comm cost).
    pub workers: usize,
    /// Device profile the interconnect cost is modeled from.
    pub gpu: GpuProfile,
    /// Sleep vs account-only for the modeled comm time.
    pub mode: CommMode,
    /// Total modeled communication time.
    pub comm_s: std::cell::Cell<f64>,
}

impl Deployment {
    /// One worker, no interconnect cost.
    pub fn single(runtime: ModelRuntime, gpu: GpuProfile) -> Deployment {
        Deployment {
            runtime, workers: 1, gpu,
            mode: CommMode::Account,
            comm_s: std::cell::Cell::new(0.0),
        }
    }

    /// N simulated tensor-parallel workers (comm cost per step).
    pub fn tensor_parallel(runtime: ModelRuntime, gpu: GpuProfile,
                           workers: usize, mode: CommMode) -> Deployment {
        assert!(workers >= 2);
        Deployment {
            runtime, workers, gpu, mode,
            comm_s: std::cell::Cell::new(0.0),
        }
    }

    /// Ring all-reduce time for `bytes` over `self.workers`.
    pub fn allreduce_s(&self, bytes: usize) -> f64 {
        if self.workers <= 1 {
            return 0.0;
        }
        let n = self.workers as f64;
        2.0 * (n - 1.0) / n * bytes as f64 / (self.gpu.link_gbps * 1e9)
            + 2.0 * self.gpu.link_latency_us * 1e-6
    }

    /// Modeled comm for one step over `tokens` activation rows: two
    /// all-reduces per layer of `tokens * dim * 2` bytes (fp16 accounting).
    pub fn step_comm_s(&self, tokens: usize) -> f64 {
        if self.workers <= 1 {
            return 0.0;
        }
        let bytes = tokens * self.runtime.cfg.dim * 2;
        2.0 * self.runtime.cfg.layers as f64 * self.allreduce_s(bytes)
    }

    fn pay_comm(&self, secs: f64) {
        self.comm_s.set(self.comm_s.get() + secs);
        if self.mode == CommMode::Sleep && secs > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(secs));
        }
    }

    /// Batched prefill plus the step's modeled comm cost.
    pub fn prefill(&self, prompts: &[&[u32]]) -> Result<PrefillResult> {
        let r = self.runtime.prefill(prompts)?;
        let tokens: usize = prompts.iter().map(|p| p.len()).sum();
        self.pay_comm(self.step_comm_s(tokens));
        Ok(r)
    }

    /// One decode step plus the step's modeled comm cost.
    pub fn decode(&self, tokens: &[u32], lens: &[usize], kv: &[f32])
        -> Result<DecodeResult> {
        let r = self.runtime.decode(tokens, lens, kv)?;
        self.pay_comm(self.step_comm_s(tokens.len()));
        Ok(r)
    }

    /// One chunked-prefill call plus the modeled comm cost for its
    /// total token count (the same per-token activation all-reduce a
    /// prefill of that many rows would pay).
    pub fn chunk(&self, chunks: &[&[u32]], starts: &[usize], kv: &[f32])
        -> Result<ChunkResult> {
        let r = self.runtime.chunk(chunks, starts, kv)?;
        let tokens: usize = chunks.iter().map(|c| c.len()).sum();
        self.pay_comm(self.step_comm_s(tokens));
        Ok(r)
    }

    /// Weight + per-sequence KV memory check against the simulated GPU
    /// pool (fp16 byte accounting; used by admission control tests).
    pub fn fits_memory(&self, weight_bytes: usize, kv_bytes: usize) -> bool {
        weight_bytes + kv_bytes
            <= self.gpu.mem_bytes * self.workers * 92 / 100
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_scaling() {
        // pure math; no runtime needed — construct via the formulas
        let gpu = GpuProfile::a100_40g();
        let n = 2.0f64;
        let bytes = 1 << 20;
        let t = 2.0 * (n - 1.0) / n * bytes as f64 / (gpu.link_gbps * 1e9)
            + 2.0 * gpu.link_latency_us * 1e-6;
        assert!(t > 0.0 && t < 1e-3);
    }
}
