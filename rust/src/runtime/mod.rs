//! PJRT runtime: load AOT-compiled HLO artifacts and run them on the
//! request path without Python.
//!
//! * [`manifest`] — parse `artifacts/manifest.json` (the Python↔Rust
//!   contract: parameter order, shapes, dtypes per artifact).
//! * [`kv`] — host-side per-sequence KV caches and batch assembly. The
//!   PJRT shim returns execute results as one tuple literal (no
//!   untuple/donation), so the authoritative KV lives on the host and the
//!   executables return only the *new* K/V rows (see
//!   `python/compile/model.py`); batch composition changes are plain
//!   memcpys, which is what makes continuous batching cheap here.
//! * [`executor`] — the model runtime: weight upload (the paper's
//!   "quantize while migrating to the device" loader), lazy executable
//!   compilation per (phase, batch, seq) bucket, prefill/decode execution.
//! * [`kvq`] — group-wise 4/8-bit quantization of stashed KV rows (the
//!   paper's weight grid reused on the cache), backing the engine's
//!   host stash and the tiered demotion pool.
//! * [`simtp`] — deployment wrapper: single worker or simulated
//!   tensor-parallel worker group with an interconnect cost model.
//! * [`perfmodel`] — analytic A100 roofline model that generates the
//!   paper-scale Fig. 7 curves (DESIGN.md §5 substitution).

pub mod executor;
pub mod kv;
pub mod kvq;
pub mod manifest;
pub mod perfmodel;
#[cfg(not(feature = "xla"))]
pub mod pjrt_stub;
pub mod simtp;
