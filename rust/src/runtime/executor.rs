//! The model runtime: weights uploaded to the device once (the paper's
//! "quantize during CPU→GPU migration" loader lives in
//! `quant::pipeline`), executables compiled lazily per bucket, and
//! prefill/decode steps executed through PJRT with no Python anywhere.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

// Without the `xla` feature the PJRT bindings are replaced by a stub
// that errors at runtime (see `super::pjrt_stub`); PJRT-dependent tests
// and benches already self-skip when artifacts are missing.
#[cfg(not(feature = "xla"))]
use super::pjrt_stub as xla;

use crate::config::{ModelConfig, Precision};
use crate::model::store::{Entry, WeightStore};
use crate::model::{weight_names, weight_names_w4a16};

use super::manifest::{ArtifactMeta, Manifest};

/// Runtime counters (compiles, executions, host<->device traffic).
#[derive(Debug, Clone, Default)]
pub struct RuntimeStats {
    /// Executables compiled (lazy, one per bucket).
    pub compiles: usize,
    /// Seconds spent compiling.
    pub compile_s: f64,
    /// Prefill executions.
    pub prefills: usize,
    /// Decode executions.
    pub decodes: usize,
    /// Chunked-prefill executions (one per continuation chunk group).
    pub chunks: usize,
    /// Seconds spent executing.
    pub exec_s: f64,
    /// Bytes uploaded host→device.
    pub h2d_bytes: u64,
    /// Bytes downloaded device→host.
    pub d2h_bytes: u64,
}

impl RuntimeStats {
    /// Total device executions — the launch-overhead currency the
    /// chunked-prefill executable exists to save: a T-token
    /// continuation chunk costs 1 here instead of T decode calls.
    pub fn device_calls(&self) -> usize {
        self.prefills + self.decodes + self.chunks
    }
}

/// One loaded model: PJRT client + device-resident weights + executable
/// cache. Not `Sync`: the engine drives it from a single thread.
pub struct ModelRuntime {
    client: xla::PjRtClient,
    /// Model architecture (from the manifest — cannot drift from HLO).
    pub cfg: ModelConfig,
    /// Weight precision the runtime was loaded with.
    pub precision: Precision,
    arts: Vec<ArtifactMeta>,
    hlo_dir: std::path::PathBuf,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    weights: Vec<xla::PjRtBuffer>,
    /// Execution/compile/traffic counters.
    pub stats: RefCell<RuntimeStats>,
}

/// Output of one batched prefill execution (padded to the bucket).
pub struct PrefillResult {
    /// Bucket batch dimension (>= the live prompt count).
    pub batch: usize,
    /// Bucket sequence dimension (>= the longest prompt).
    pub seq: usize,
    /// `[B, S, V]` row-major.
    pub logits: Vec<f32>,
    /// `[L, 2, B, S, D]` row-major.
    pub kv_new: Vec<f32>,
}

/// Output of one decode execution (padded to the bucket).
pub struct DecodeResult {
    /// Bucket batch dimension (>= the live sequence count).
    pub batch: usize,
    /// `[B, V]` row-major.
    pub logits: Vec<f32>,
    /// `[L, 2, B, 1, D]` row-major.
    pub kv_new: Vec<f32>,
}

/// Output of one chunked-prefill execution (padded to the bucket).
pub struct ChunkResult {
    /// Bucket batch dimension (>= the live chunk count).
    pub batch: usize,
    /// Bucket chunk-length dimension (>= the widest chunk).
    pub seq: usize,
    /// `[B, C, V]` row-major — one logits row per chunk position.
    pub logits: Vec<f32>,
    /// `[L, 2, B, C, D]` row-major — the chunk's new KV rows.
    pub kv_new: Vec<f32>,
}

impl ModelRuntime {
    /// Load a model: verify the deploy store layout, upload every tensor
    /// to the device in canonical order.
    pub fn load(manifest: &Manifest, size: &str, precision: Precision,
                deploy: &WeightStore) -> Result<ModelRuntime> {
        let entry = manifest.model(size)?;
        let cfg = entry.config.clone();
        let want = match precision {
            Precision::Fp16 => weight_names(&cfg),
            Precision::W4a16 => weight_names_w4a16(&cfg),
        };
        if deploy.names() != want {
            bail!(
                "deploy store layout mismatch for {size}/{}: {} names vs {}",
                precision.as_str(), deploy.names().len(), want.len()
            );
        }
        let client = xla::PjRtClient::cpu()?;
        let mut weights = Vec::with_capacity(deploy.len());
        let mut h2d = 0u64;
        for (name, e) in deploy.iter() {
            let buf = match e {
                Entry::F32(t) => {
                    h2d += 4 * t.numel() as u64;
                    client
                        .buffer_from_host_buffer::<f32>(&t.data, &t.shape,
                                                        None)
                        .with_context(|| format!("upload {name}"))?
                }
                Entry::U8(t) => {
                    h2d += t.numel() as u64;
                    client
                        .buffer_from_host_buffer::<u8>(&t.data, &t.shape,
                                                       None)
                        .with_context(|| format!("upload {name}"))?
                }
            };
            weights.push(buf);
        }
        let arts = manifest
            .artifacts(size, precision)?
            .into_iter()
            .cloned()
            .collect();
        Ok(ModelRuntime {
            client,
            cfg,
            precision,
            arts,
            hlo_dir: manifest.dir.clone(),
            exes: RefCell::new(HashMap::new()),
            weights,
            stats: RefCell::new(RuntimeStats {
                h2d_bytes: h2d,
                ..Default::default()
            }),
        })
    }

    /// Available decode batch buckets (ascending).
    pub fn decode_batches(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .arts
            .iter()
            .filter(|a| a.phase == "decode")
            .map(|a| a.batch)
            .collect();
        v.sort_unstable();
        v
    }

    /// Available prefill buckets (batch, seq), sorted by capacity.
    pub fn prefill_buckets(&self) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .arts
            .iter()
            .filter(|a| a.phase == "prefill")
            .map(|a| (a.batch, a.seq))
            .collect();
        v.sort_by_key(|&(b, s)| (b * s, s));
        v
    }

    /// Available chunk buckets (batch, chunk_len, prefix_len), sorted
    /// by capacity. Empty for pre-chunk artifact sets — the engine then
    /// falls back to the token-by-token decode path.
    pub fn chunk_buckets(&self) -> Vec<(usize, usize, usize)> {
        let mut v: Vec<(usize, usize, usize)> = self
            .arts
            .iter()
            .filter(|a| a.phase == "chunk")
            .map(|a| (a.batch, a.seq, a.prefix))
            .collect();
        v.sort_by_key(|&(b, s, p)| (b * s * p, s, p));
        v
    }

    /// The one smallest-fitting-bucket rule every phase routes through:
    /// among `phase` artifacts accepted by `fits`, the minimum of
    /// `capacity` (ties broken by the key's trailing components).
    fn smallest_fit<F, K, O>(&self, phase: &str, fits: F, capacity: K)
        -> Option<&ArtifactMeta>
    where
        F: Fn(&ArtifactMeta) -> bool,
        K: Fn(&ArtifactMeta) -> O,
        O: Ord,
    {
        self.arts
            .iter()
            .filter(|a| a.phase == phase && fits(a))
            .min_by_key(|a| capacity(a))
    }

    fn pick_prefill(&self, batch: usize, seq: usize) -> Result<&ArtifactMeta> {
        self.smallest_fit(
            "prefill",
            |a| a.batch >= batch && a.seq >= seq,
            |a| (a.batch * a.seq, a.seq),
        )
        .with_context(|| {
            format!("no prefill bucket for batch {batch} seq {seq}")
        })
    }

    fn pick_decode(&self, batch: usize) -> Result<&ArtifactMeta> {
        self.smallest_fit("decode", |a| a.batch >= batch, |a| a.batch)
            .with_context(|| format!("no decode bucket for batch {batch}"))
    }

    fn pick_chunk(&self, batch: usize, seq: usize, prefix: usize)
        -> Result<&ArtifactMeta> {
        self.smallest_fit(
            "chunk",
            |a| a.batch >= batch && a.seq >= seq && a.prefix >= prefix,
            |a| (a.batch * a.seq * a.prefix, a.seq, a.prefix),
        )
        .with_context(|| {
            format!("no chunk bucket for batch {batch} seq {seq} \
                     prefix {prefix}")
        })
    }

    /// Smallest decode batch bucket fitting `need` live sequences
    /// (`need` itself when no bucket fits, so the execute call reports
    /// the real error). Shared by the engine's decode round and the
    /// per-token chunk fallback.
    pub fn smallest_decode_batch(&self, need: usize) -> usize {
        self.pick_decode(need).map(|a| a.batch).unwrap_or(need)
    }

    /// Bucket dims `(batch, chunk_len, prefix_len)` the runtime would
    /// execute this chunk shape with, or `None` when no compiled chunk
    /// bucket fits (the engine then uses the per-token fallback). The
    /// caller assembles the KV-prefix batch with exactly these dims;
    /// [`ModelRuntime::chunk`] re-derives the same pick.
    pub fn pick_chunk_bucket(&self, batch: usize, seq: usize,
                             prefix: usize)
        -> Option<(usize, usize, usize)> {
        self.pick_chunk(batch, seq, prefix)
            .ok()
            .map(|a| (a.batch, a.seq, a.prefix))
    }

    /// Largest batch any chunk bucket with `seq >= chunk_len` and
    /// `prefix >= prefix_len` offers (0 if none) — how many chunks of a
    /// matching bucket pair can batch positionwise into one call.
    pub fn max_chunk_batch(&self, seq: usize, prefix: usize) -> usize {
        self.arts
            .iter()
            .filter(|a| {
                a.phase == "chunk" && a.seq >= seq && a.prefix >= prefix
            })
            .map(|a| a.batch)
            .max()
            .unwrap_or(0)
    }

    fn get_exe(&self, art: &ArtifactMeta)
        -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(&art.name) {
            return Ok(e.clone());
        }
        // sqlint: allow(determinism) wall-clock device-call timing for bench stats; results unaffected
        let t0 = Instant::now();
        let path = self.hlo_dir.join(&art.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("load {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        let mut st = self.stats.borrow_mut();
        st.compiles += 1;
        st.compile_s += t0.elapsed().as_secs_f64();
        self.exes.borrow_mut().insert(art.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Force-compile every bucket up front (serving warm-start).
    pub fn warmup(&self) -> Result<()> {
        let arts: Vec<ArtifactMeta> = self.arts.clone();
        for a in &arts {
            self.get_exe(a)?;
        }
        Ok(())
    }

    /// Prefill up to `bucket.batch` prompts (padded). Returns full logits
    /// and the new K/V rows.
    pub fn prefill(&self, prompts: &[&[u32]]) -> Result<PrefillResult> {
        let batch = prompts.len();
        let max_seq = prompts.iter().map(|p| p.len()).max().unwrap_or(1);
        let art = self.pick_prefill(batch, max_seq)?;
        let (ab, aseq) = (art.batch, art.seq);
        let exe = self.get_exe(art)?;

        let mut tokens = vec![0i32; ab * aseq];
        let mut lens = vec![0i32; ab];
        for (b, p) in prompts.iter().enumerate() {
            for (i, &t) in p.iter().enumerate() {
                tokens[b * aseq + i] = t as i32;
            }
            lens[b] = p.len() as i32;
        }
        // sqlint: allow(determinism) wall-clock device-call timing for bench stats; results unaffected
        let t0 = Instant::now();
        let tok_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&tokens, &[ab, aseq], None)?;
        let len_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&lens, &[ab], None)?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf, &len_buf];
        args.extend(self.weights.iter());
        let result = exe.execute_b(&args)?[0][0].to_literal_sync()?;
        let (lg, kv) = result.to_tuple2()?;
        let logits = lg.to_vec::<f32>()?;
        let kv_new = kv.to_vec::<f32>()?;
        let mut st = self.stats.borrow_mut();
        st.prefills += 1;
        st.exec_s += t0.elapsed().as_secs_f64();
        st.h2d_bytes += (tokens.len() * 4 + lens.len() * 4) as u64;
        st.d2h_bytes += (logits.len() * 4 + kv_new.len() * 4) as u64;
        Ok(PrefillResult { batch: ab, seq: aseq, logits, kv_new })
    }

    /// One decode step over an assembled KV batch (`[L,2,B,MAX,D]` from
    /// [`super::kv::assemble_batch`] with `B = bucket`). `tokens`/`lens`
    /// carry the live sequences; padding slots use token 0 / len 0.
    pub fn decode(&self, tokens: &[u32], lens: &[usize], kv_batch: &[f32])
        -> Result<DecodeResult> {
        let live = tokens.len();
        let art = self.pick_decode(live)?;
        let ab = art.batch;
        let exe = self.get_exe(art)?;
        let expected =
            self.cfg.layers * 2 * ab * self.cfg.max_len * self.cfg.dim;
        if kv_batch.len() != expected {
            bail!("kv batch len {} != expected {expected} (bucket {ab})",
                  kv_batch.len());
        }
        let mut toks = vec![0i32; ab];
        let mut ls = vec![0i32; ab];
        for i in 0..live {
            toks[i] = tokens[i] as i32;
            ls[i] = lens[i] as i32;
        }
        // sqlint: allow(determinism) wall-clock device-call timing for bench stats; results unaffected
        let t0 = Instant::now();
        let tok_buf =
            self.client.buffer_from_host_buffer::<i32>(&toks, &[ab], None)?;
        let len_buf =
            self.client.buffer_from_host_buffer::<i32>(&ls, &[ab], None)?;
        let kv_shape =
            [self.cfg.layers, 2, ab, self.cfg.max_len, self.cfg.dim];
        let kv_buf = self
            .client
            .buffer_from_host_buffer::<f32>(kv_batch, &kv_shape, None)?;
        let mut args: Vec<&xla::PjRtBuffer> =
            vec![&tok_buf, &len_buf, &kv_buf];
        args.extend(self.weights.iter());
        let result = exe.execute_b(&args)?[0][0].to_literal_sync()?;
        let (lg, kvn) = result.to_tuple2()?;
        let logits = lg.to_vec::<f32>()?;
        let kv_new = kvn.to_vec::<f32>()?;
        let mut st = self.stats.borrow_mut();
        st.decodes += 1;
        st.exec_s += t0.elapsed().as_secs_f64();
        st.h2d_bytes += (kv_batch.len() * 4 + toks.len() * 8) as u64;
        st.d2h_bytes += (logits.len() * 4 + kv_new.len() * 4) as u64;
        Ok(DecodeResult { batch: ab, logits, kv_new })
    }

    /// One chunked-prefill call: `chunks[b]` holds sequence `b`'s new
    /// tokens, appended at absolute positions `starts[b] ..`, attending
    /// to the `starts[b]` prefix rows in `kv_batch` (layout
    /// `[L, 2, B, P, D]` from [`super::kv::assemble_prefix_batch`],
    /// with `(B, P)` matching the bucket [`pick_chunk_bucket`] reported
    /// for this shape). Sequences may sit at *different* start
    /// positions — that is the positionwise batching of continuation
    /// chunks. Returns logits for every chunk position and the chunk's
    /// new KV rows in one device call.
    ///
    /// [`pick_chunk_bucket`]: ModelRuntime::pick_chunk_bucket
    pub fn chunk(&self, chunks: &[&[u32]], starts: &[usize],
                 kv_batch: &[f32]) -> Result<ChunkResult> {
        let live = chunks.len();
        assert_eq!(live, starts.len());
        let width = chunks.iter().map(|c| c.len()).max().unwrap_or(1);
        let pre = starts.iter().copied().max().unwrap_or(0);
        let art = self.pick_chunk(live, width, pre)?;
        let (ab, ac, ap) = (art.batch, art.seq, art.prefix);
        let exe = self.get_exe(art)?;
        let expected = self.cfg.layers * 2 * ab * ap * self.cfg.dim;
        if kv_batch.len() != expected {
            bail!("kv prefix batch len {} != expected {expected} \
                   (bucket b{ab} p{ap})", kv_batch.len());
        }
        let mut toks = vec![0i32; ab * ac];
        let mut sts = vec![0i32; ab];
        for (b, c) in chunks.iter().enumerate() {
            for (i, &t) in c.iter().enumerate() {
                toks[b * ac + i] = t as i32;
            }
            sts[b] = starts[b] as i32;
        }
        // sqlint: allow(determinism) wall-clock device-call timing for bench stats; results unaffected
        let t0 = Instant::now();
        let tok_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&toks, &[ab, ac], None)?;
        let start_buf =
            self.client.buffer_from_host_buffer::<i32>(&sts, &[ab], None)?;
        let kv_shape = [self.cfg.layers, 2, ab, ap, self.cfg.dim];
        let kv_buf = self
            .client
            .buffer_from_host_buffer::<f32>(kv_batch, &kv_shape, None)?;
        let mut args: Vec<&xla::PjRtBuffer> =
            vec![&tok_buf, &start_buf, &kv_buf];
        args.extend(self.weights.iter());
        let result = exe.execute_b(&args)?[0][0].to_literal_sync()?;
        let (lg, kvn) = result.to_tuple2()?;
        let logits = lg.to_vec::<f32>()?;
        let kv_new = kvn.to_vec::<f32>()?;
        let mut st = self.stats.borrow_mut();
        st.chunks += 1;
        st.exec_s += t0.elapsed().as_secs_f64();
        st.h2d_bytes +=
            (kv_batch.len() * 4 + toks.len() * 4 + sts.len() * 4) as u64;
        st.d2h_bytes += (logits.len() * 4 + kv_new.len() * 4) as u64;
        Ok(ChunkResult { batch: ab, seq: ac, logits, kv_new })
    }

    /// Vocabulary size of the loaded model.
    pub fn vocab(&self) -> usize {
        self.cfg.vocab
    }
}
