//! The model runtime: weights uploaded to the device once (the paper's
//! "quantize during CPU→GPU migration" loader lives in
//! `quant::pipeline`), executables compiled lazily per bucket, and
//! prefill/decode steps executed through PJRT with no Python anywhere.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

// Without the `xla` feature the PJRT bindings are replaced by a stub
// that errors at runtime (see `super::pjrt_stub`); PJRT-dependent tests
// and benches already self-skip when artifacts are missing.
#[cfg(not(feature = "xla"))]
use super::pjrt_stub as xla;

use crate::config::{ModelConfig, Precision};
use crate::model::store::{Entry, WeightStore};
use crate::model::{weight_names, weight_names_w4a16};

use super::manifest::{ArtifactMeta, Manifest};

/// Runtime counters (compiles, executions, host<->device traffic).
#[derive(Debug, Clone, Default)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub compile_s: f64,
    pub prefills: usize,
    pub decodes: usize,
    pub exec_s: f64,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
}

/// One loaded model: PJRT client + device-resident weights + executable
/// cache. Not `Sync`: the engine drives it from a single thread.
pub struct ModelRuntime {
    client: xla::PjRtClient,
    pub cfg: ModelConfig,
    pub precision: Precision,
    arts: Vec<ArtifactMeta>,
    hlo_dir: std::path::PathBuf,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    weights: Vec<xla::PjRtBuffer>,
    pub stats: RefCell<RuntimeStats>,
}

pub struct PrefillResult {
    pub batch: usize,
    pub seq: usize,
    /// `[B, S, V]` row-major.
    pub logits: Vec<f32>,
    /// `[L, 2, B, S, D]` row-major.
    pub kv_new: Vec<f32>,
}

pub struct DecodeResult {
    pub batch: usize,
    /// `[B, V]` row-major.
    pub logits: Vec<f32>,
    /// `[L, 2, B, 1, D]` row-major.
    pub kv_new: Vec<f32>,
}

impl ModelRuntime {
    /// Load a model: verify the deploy store layout, upload every tensor
    /// to the device in canonical order.
    pub fn load(manifest: &Manifest, size: &str, precision: Precision,
                deploy: &WeightStore) -> Result<ModelRuntime> {
        let entry = manifest.model(size)?;
        let cfg = entry.config.clone();
        let want = match precision {
            Precision::Fp16 => weight_names(&cfg),
            Precision::W4a16 => weight_names_w4a16(&cfg),
        };
        if deploy.names() != want {
            bail!(
                "deploy store layout mismatch for {size}/{}: {} names vs {}",
                precision.as_str(), deploy.names().len(), want.len()
            );
        }
        let client = xla::PjRtClient::cpu()?;
        let mut weights = Vec::with_capacity(deploy.len());
        let mut h2d = 0u64;
        for (name, e) in deploy.iter() {
            let buf = match e {
                Entry::F32(t) => {
                    h2d += 4 * t.numel() as u64;
                    client
                        .buffer_from_host_buffer::<f32>(&t.data, &t.shape,
                                                        None)
                        .with_context(|| format!("upload {name}"))?
                }
                Entry::U8(t) => {
                    h2d += t.numel() as u64;
                    client
                        .buffer_from_host_buffer::<u8>(&t.data, &t.shape,
                                                       None)
                        .with_context(|| format!("upload {name}"))?
                }
            };
            weights.push(buf);
        }
        let arts = manifest
            .artifacts(size, precision)?
            .into_iter()
            .cloned()
            .collect();
        Ok(ModelRuntime {
            client,
            cfg,
            precision,
            arts,
            hlo_dir: manifest.dir.clone(),
            exes: RefCell::new(HashMap::new()),
            weights,
            stats: RefCell::new(RuntimeStats {
                h2d_bytes: h2d,
                ..Default::default()
            }),
        })
    }

    /// Available decode batch buckets (ascending).
    pub fn decode_batches(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .arts
            .iter()
            .filter(|a| a.phase == "decode")
            .map(|a| a.batch)
            .collect();
        v.sort_unstable();
        v
    }

    /// Available prefill buckets (batch, seq), sorted by capacity.
    pub fn prefill_buckets(&self) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .arts
            .iter()
            .filter(|a| a.phase == "prefill")
            .map(|a| (a.batch, a.seq))
            .collect();
        v.sort_by_key(|&(b, s)| (b * s, s));
        v
    }

    fn pick_prefill(&self, batch: usize, seq: usize) -> Result<&ArtifactMeta> {
        self.arts
            .iter()
            .filter(|a| {
                a.phase == "prefill" && a.batch >= batch && a.seq >= seq
            })
            .min_by_key(|a| (a.batch * a.seq, a.seq))
            .with_context(|| {
                format!("no prefill bucket for batch {batch} seq {seq}")
            })
    }

    fn pick_decode(&self, batch: usize) -> Result<&ArtifactMeta> {
        self.arts
            .iter()
            .filter(|a| a.phase == "decode" && a.batch >= batch)
            .min_by_key(|a| a.batch)
            .with_context(|| format!("no decode bucket for batch {batch}"))
    }

    fn get_exe(&self, art: &ArtifactMeta)
        -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(&art.name) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let path = self.hlo_dir.join(&art.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("load {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        let mut st = self.stats.borrow_mut();
        st.compiles += 1;
        st.compile_s += t0.elapsed().as_secs_f64();
        self.exes.borrow_mut().insert(art.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Force-compile every bucket up front (serving warm-start).
    pub fn warmup(&self) -> Result<()> {
        let arts: Vec<ArtifactMeta> = self.arts.clone();
        for a in &arts {
            self.get_exe(a)?;
        }
        Ok(())
    }

    /// Prefill up to `bucket.batch` prompts (padded). Returns full logits
    /// and the new K/V rows.
    pub fn prefill(&self, prompts: &[&[u32]]) -> Result<PrefillResult> {
        let batch = prompts.len();
        let max_seq = prompts.iter().map(|p| p.len()).max().unwrap_or(1);
        let art = self.pick_prefill(batch, max_seq)?;
        let (ab, aseq) = (art.batch, art.seq);
        let exe = self.get_exe(art)?;

        let mut tokens = vec![0i32; ab * aseq];
        let mut lens = vec![0i32; ab];
        for (b, p) in prompts.iter().enumerate() {
            for (i, &t) in p.iter().enumerate() {
                tokens[b * aseq + i] = t as i32;
            }
            lens[b] = p.len() as i32;
        }
        let t0 = Instant::now();
        let tok_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&tokens, &[ab, aseq], None)?;
        let len_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&lens, &[ab], None)?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf, &len_buf];
        args.extend(self.weights.iter());
        let result = exe.execute_b(&args)?[0][0].to_literal_sync()?;
        let (lg, kv) = result.to_tuple2()?;
        let logits = lg.to_vec::<f32>()?;
        let kv_new = kv.to_vec::<f32>()?;
        let mut st = self.stats.borrow_mut();
        st.prefills += 1;
        st.exec_s += t0.elapsed().as_secs_f64();
        st.h2d_bytes += (tokens.len() * 4 + lens.len() * 4) as u64;
        st.d2h_bytes += (logits.len() * 4 + kv_new.len() * 4) as u64;
        Ok(PrefillResult { batch: ab, seq: aseq, logits, kv_new })
    }

    /// One decode step over an assembled KV batch (`[L,2,B,MAX,D]` from
    /// [`super::kv::assemble_batch`] with `B = bucket`). `tokens`/`lens`
    /// carry the live sequences; padding slots use token 0 / len 0.
    pub fn decode(&self, tokens: &[u32], lens: &[usize], kv_batch: &[f32])
        -> Result<DecodeResult> {
        let live = tokens.len();
        let art = self.pick_decode(live)?;
        let ab = art.batch;
        let exe = self.get_exe(art)?;
        let expected =
            self.cfg.layers * 2 * ab * self.cfg.max_len * self.cfg.dim;
        if kv_batch.len() != expected {
            bail!("kv batch len {} != expected {expected} (bucket {ab})",
                  kv_batch.len());
        }
        let mut toks = vec![0i32; ab];
        let mut ls = vec![0i32; ab];
        for i in 0..live {
            toks[i] = tokens[i] as i32;
            ls[i] = lens[i] as i32;
        }
        let t0 = Instant::now();
        let tok_buf =
            self.client.buffer_from_host_buffer::<i32>(&toks, &[ab], None)?;
        let len_buf =
            self.client.buffer_from_host_buffer::<i32>(&ls, &[ab], None)?;
        let kv_shape =
            [self.cfg.layers, 2, ab, self.cfg.max_len, self.cfg.dim];
        let kv_buf = self
            .client
            .buffer_from_host_buffer::<f32>(kv_batch, &kv_shape, None)?;
        let mut args: Vec<&xla::PjRtBuffer> =
            vec![&tok_buf, &len_buf, &kv_buf];
        args.extend(self.weights.iter());
        let result = exe.execute_b(&args)?[0][0].to_literal_sync()?;
        let (lg, kvn) = result.to_tuple2()?;
        let logits = lg.to_vec::<f32>()?;
        let kv_new = kvn.to_vec::<f32>()?;
        let mut st = self.stats.borrow_mut();
        st.decodes += 1;
        st.exec_s += t0.elapsed().as_secs_f64();
        st.h2d_bytes += (kv_batch.len() * 4 + toks.len() * 8) as u64;
        st.d2h_bytes += (logits.len() * 4 + kv_new.len() * 4) as u64;
        Ok(DecodeResult { batch: ab, logits, kv_new })
    }

    pub fn vocab(&self) -> usize {
        self.cfg.vocab
    }
}
