//! Host-side KV caches.
//!
//! Each sequence owns a fixed-capacity cache laid out `[L, 2, MAX, D]`
//! (layer-major, K lane then V lane). Batch assembly packs B sequence
//! caches into the `[L, 2, B, MAX, D]` input the decode executables
//! expect; appends scatter the `kv_new` output rows back. All of it is
//! `memcpy`-shaped, which is what makes per-step batch recomposition (the
//! continuous-batching hot path) cheap.

use crate::config::ModelConfig;

/// Per-sequence KV cache with capacity `max_len` tokens.
#[derive(Debug, Clone)]
pub struct SeqKv {
    /// Decoder layers.
    pub layers: usize,
    /// Row capacity (the model's static KV length).
    pub max_len: usize,
    /// Hidden dimension per row.
    pub dim: usize,
    /// Tokens currently stored.
    pub len: usize,
    /// `[L, 2, MAX, D]` row-major.
    data: Vec<f32>,
}

impl SeqKv {
    /// An empty cache sized for `cfg`.
    pub fn new(cfg: &ModelConfig) -> SeqKv {
        SeqKv {
            layers: cfg.layers,
            max_len: cfg.max_len,
            dim: cfg.dim,
            len: 0,
            data: vec![0.0; cfg.layers * 2 * cfg.max_len * cfg.dim],
        }
    }

    #[inline]
    fn lane_off(&self, layer: usize, lane: usize) -> usize {
        ((layer * 2) + lane) * self.max_len * self.dim
    }

    /// One `D`-wide row (`lane` 0 = K, 1 = V) at position `pos`.
    pub fn row(&self, layer: usize, lane: usize, pos: usize) -> &[f32] {
        let o = self.lane_off(layer, lane) + pos * self.dim;
        &self.data[o..o + self.dim]
    }

    /// Mutable access to one row (see [`SeqKv::row`]).
    pub fn row_mut(&mut self, layer: usize, lane: usize, pos: usize)
        -> &mut [f32] {
        let o = self.lane_off(layer, lane) + pos * self.dim;
        &mut self.data[o..o + self.dim]
    }

    /// Contiguous `[MAX, D]` lane slice.
    pub fn lane(&self, layer: usize, lane: usize) -> &[f32] {
        let o = self.lane_off(layer, lane);
        &self.data[o..o + self.max_len * self.dim]
    }

    /// Drop all cached rows (preemption / recompute path).
    pub fn clear(&mut self) {
        self.len = 0;
        self.data.fill(0.0);
    }

    /// Host bytes this cache occupies.
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// Pack B sequence caches into one `[L, 2, B, MAX, D]` buffer.
pub fn assemble_batch(seqs: &[&SeqKv], cfg: &ModelConfig, batch: usize)
    -> Vec<f32> {
    assert!(seqs.len() <= batch);
    let (l, max, d) = (cfg.layers, cfg.max_len, cfg.dim);
    let lane_sz = max * d;
    let mut out = vec![0.0f32; l * 2 * batch * lane_sz];
    for layer in 0..l {
        for lane in 0..2 {
            for (b, s) in seqs.iter().enumerate() {
                debug_assert_eq!(s.max_len, max);
                let dst = (((layer * 2) + lane) * batch + b) * lane_sz;
                out[dst..dst + lane_sz]
                    .copy_from_slice(s.lane(layer, lane));
            }
        }
    }
    out
}

/// Pack the first `prefix` rows of B sequence caches into one
/// `[L, 2, B, P, D]` buffer — the chunk executable's KV-prefix input.
/// Each sequence must hold at most `prefix` rows (the bucket was picked
/// for the largest `start` in the batch); only the `len` live rows are
/// copied — the zero-initialized buffer already covers the padding past
/// them (and past the live sequence count), which the executable masks
/// by `starts` anyway.
pub fn assemble_prefix_batch(seqs: &[&SeqKv], cfg: &ModelConfig,
                             batch: usize, prefix: usize) -> Vec<f32> {
    assert!(seqs.len() <= batch);
    let (l, d) = (cfg.layers, cfg.dim);
    let lane_sz = prefix * d;
    let mut out = vec![0.0f32; l * 2 * batch * lane_sz];
    for layer in 0..l {
        for lane in 0..2 {
            for (b, s) in seqs.iter().enumerate() {
                debug_assert!(s.len <= prefix && prefix <= s.max_len);
                let live = s.len * d;
                let dst = (((layer * 2) + lane) * batch + b) * lane_sz;
                out[dst..dst + live]
                    .copy_from_slice(&s.lane(layer, lane)[..live]);
            }
        }
    }
    out
}

/// Scatter chunk output `kv_new: [L, 2, B, C, D]` rows `0..counts[b]`
/// into each sequence starting at its current length, then advance each
/// length by its count (rows past a sequence's real chunk width are
/// bucket padding and dropped).
pub fn append_chunk_rows(seqs: &mut [&mut SeqKv], cfg: &ModelConfig,
                         batch: usize, seq: usize, kv_new: &[f32],
                         counts: &[usize]) {
    let (l, d) = (cfg.layers, cfg.dim);
    assert_eq!(kv_new.len(), l * 2 * batch * seq * d);
    assert_eq!(seqs.len(), counts.len());
    for layer in 0..l {
        for lane in 0..2 {
            for (b, s) in seqs.iter_mut().enumerate() {
                let n = counts[b];
                debug_assert!(n <= seq);
                let src = ((((layer * 2) + lane) * batch + b) * seq) * d;
                for r in 0..n {
                    let pos = s.len + r;
                    assert!(pos < s.max_len, "KV overflow at pos {pos}");
                    s.row_mut(layer, lane, pos).copy_from_slice(
                        &kv_new[src + r * d..src + (r + 1) * d],
                    );
                }
            }
        }
    }
    for (s, &n) in seqs.iter_mut().zip(counts) {
        s.len += n;
    }
}

/// Scatter decode output `kv_new: [L, 2, B, 1, D]` into each sequence at
/// its current length, then advance lengths.
pub fn append_decode_rows(seqs: &mut [&mut SeqKv], cfg: &ModelConfig,
                          batch: usize, kv_new: &[f32]) {
    let (l, d) = (cfg.layers, cfg.dim);
    assert_eq!(kv_new.len(), l * 2 * batch * d);
    for layer in 0..l {
        for lane in 0..2 {
            for (b, s) in seqs.iter_mut().enumerate() {
                let src = (((layer * 2) + lane) * batch + b) * d;
                let pos = s.len;
                assert!(pos < s.max_len, "KV overflow at pos {pos}");
                s.row_mut(layer, lane, pos)
                    .copy_from_slice(&kv_new[src..src + d]);
            }
        }
    }
    for s in seqs.iter_mut() {
        s.len += 1;
    }
}

/// Scatter prefill output `kv_new: [L, 2, B, S, D]` rows `0..prompt_len`
/// into each sequence (which must be empty), then set lengths.
pub fn fill_prefill_rows(seqs: &mut [&mut SeqKv], cfg: &ModelConfig,
                         batch: usize, seq: usize, kv_new: &[f32],
                         prompt_lens: &[usize]) {
    let (l, d) = (cfg.layers, cfg.dim);
    assert_eq!(kv_new.len(), l * 2 * batch * seq * d);
    assert_eq!(seqs.len(), prompt_lens.len());
    for layer in 0..l {
        for lane in 0..2 {
            for (b, s) in seqs.iter_mut().enumerate() {
                debug_assert_eq!(s.len, 0);
                let n = prompt_lens[b].min(seq);
                let src = ((((layer * 2) + lane) * batch + b) * seq) * d;
                for pos in 0..n {
                    s.row_mut(layer, lane, pos).copy_from_slice(
                        &kv_new[src + pos * d..src + (pos + 1) * d],
                    );
                }
            }
        }
    }
    for (s, &n) in seqs.iter_mut().zip(prompt_lens) {
        s.len = n.min(seq);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig::tiny()
    }

    #[test]
    fn roundtrip_single_rows() {
        let c = cfg();
        let mut s = SeqKv::new(&c);
        s.row_mut(1, 0, 5)[0] = 42.0;
        s.row_mut(1, 1, 5)[127] = -1.0;
        assert_eq!(s.row(1, 0, 5)[0], 42.0);
        assert_eq!(s.row(1, 1, 5)[127], -1.0);
        assert_eq!(s.row(0, 0, 5)[0], 0.0);
        s.clear();
        assert_eq!(s.row(1, 0, 5)[0], 0.0);
    }

    #[test]
    fn assemble_layout() {
        let c = cfg();
        let mut a = SeqKv::new(&c);
        let mut b = SeqKv::new(&c);
        a.row_mut(0, 0, 0)[0] = 1.0;
        b.row_mut(0, 0, 0)[0] = 2.0;
        a.row_mut(1, 1, 3)[7] = 9.0;
        let batch = 4; // padded batch
        let out = assemble_batch(&[&a, &b], &c, batch);
        let lane = c.max_len * c.dim;
        // element (l=0, lane=0, b=0, pos=0, d=0)
        assert_eq!(out[0], 1.0);
        // (l=0, lane=0, b=1, pos=0, d=0)
        assert_eq!(out[lane], 2.0);
        // (l=1, lane=1, b=0, pos=3, d=7)
        let idx = (((1 * 2) + 1) * batch + 0) * lane + 3 * c.dim + 7;
        assert_eq!(out[idx], 9.0);
        // padding slots zero
        assert_eq!(out[2 * lane], 0.0);
    }

    #[test]
    fn append_and_fill() {
        let c = cfg();
        let batch = 2;
        let mut s0 = SeqKv::new(&c);
        let mut s1 = SeqKv::new(&c);
        // prefill 3 tokens for s0, 2 for s1 out of a seq-4 bucket
        let seq = 4;
        let mut kv_new = vec![0.0f32; c.layers * 2 * batch * seq * c.dim];
        // mark (l=0, lane=0, b=0, pos=2, d=0) = 5
        kv_new[2 * c.dim] = 5.0;
        {
            let mut refs = [&mut s0, &mut s1];
            fill_prefill_rows(&mut refs, &c, batch, seq, &kv_new, &[3, 2]);
        }
        assert_eq!(s0.len, 3);
        assert_eq!(s1.len, 2);
        assert_eq!(s0.row(0, 0, 2)[0], 5.0);
        // decode append
        let mut dec = vec![0.0f32; c.layers * 2 * batch * c.dim];
        dec[c.dim] = 7.0; // (l=0, lane=0, b=1, d=0)
        {
            let mut refs = [&mut s0, &mut s1];
            append_decode_rows(&mut refs, &c, batch, &dec);
        }
        assert_eq!(s0.len, 4);
        assert_eq!(s1.len, 3);
        assert_eq!(s1.row(0, 0, 2)[0], 7.0);
    }

    #[test]
    fn prefix_batch_and_chunk_append() {
        let c = cfg();
        let batch = 2; // padded bucket batch
        let mut a = SeqKv::new(&c);
        let mut b = SeqKv::new(&c);
        // a holds 3 prefix rows, b holds 1 (different starts — the
        // positionwise-batched case)
        a.len = 3;
        b.len = 1;
        a.row_mut(1, 0, 2)[5] = 4.0;
        b.row_mut(0, 1, 0)[0] = -2.0;
        let prefix = 4;
        let out = assemble_prefix_batch(&[&a, &b], &c, batch, prefix);
        assert_eq!(out.len(), c.layers * 2 * batch * prefix * c.dim);
        let lane = prefix * c.dim;
        // (l=1, lane=0, b=0, pos=2, d=5)
        let idx = (((1 * 2) + 0) * batch + 0) * lane + 2 * c.dim + 5;
        assert_eq!(out[idx], 4.0);
        // (l=0, lane=1, b=1, pos=0, d=0)
        let idx = (((0 * 2) + 1) * batch + 1) * lane;
        assert_eq!(out[idx], -2.0);

        // chunk append: widths 2 and 3 out of a seq-4 bucket; padded
        // rows past each width must be dropped
        let seqw = 4;
        let mut kv_new =
            vec![0.0f32; c.layers * 2 * batch * seqw * c.dim];
        // (l=0, lane=0, b=0, r=1, d=0) = 8 -> lands at a pos 3+1=4
        kv_new[1 * c.dim] = 8.0;
        // (l=0, lane=0, b=1, r=2, d=1) = 9 -> lands at b pos 1+2=3
        kv_new[(((0 * 2) + 0) * batch + 1) * seqw * c.dim
            + 2 * c.dim + 1] = 9.0;
        {
            let mut refs = [&mut a, &mut b];
            append_chunk_rows(&mut refs, &c, batch, seqw, &kv_new,
                              &[2, 3]);
        }
        assert_eq!(a.len, 5);
        assert_eq!(b.len, 4);
        assert_eq!(a.row(0, 0, 4)[0], 8.0);
        assert_eq!(b.row(0, 0, 3)[1], 9.0);
    }

    #[test]
    #[should_panic]
    fn overflow_panics() {
        let c = cfg();
        let mut s = SeqKv::new(&c);
        s.len = c.max_len;
        let dec = vec![0.0f32; c.layers * 2 * c.dim];
        let mut refs = [&mut s];
        append_decode_rows(&mut refs, &c, 1, &dec);
    }
}
