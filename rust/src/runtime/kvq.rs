//! Group-wise 4/8-bit quantization of stashed KV rows — the paper's
//! weight trick applied to the serving engine's other memory hog.
//!
//! The engine keeps cached prefix blocks host-side as `[L, 2,
//! block_size, D]` row stashes (see `coordinator::engine`). Stored in
//! f32 those stashes cost as much as the device rows they mirror; the
//! tiered demotion pool would inherit the same footprint. This module
//! quantizes each stash with the same group-wise asymmetric grid the
//! weight quantizer uses — per-group `(delta, zero)` over each
//! `dim`-row, [`crate::quant::rtn::int4_grid`] as the single source of
//! truth for the INT4 grid — shrinking a stash 4× (Q8) to 8× (Q4)
//! versus f32.
//!
//! Layouts match `quant/pack.rs`: Q4 packs two *consecutive* values per
//! byte, low nibble first (even `dim` routes through
//! [`crate::quant::pack::pack_nibbles`] itself; an odd `dim` leaves the
//! final nibble of each row's last byte zero). Dequantization reads the
//! packed bytes in place and applies the grid as it goes — the
//! `quant/kernel.rs` fused-dequant idiom, no intermediate nibble
//! buffer.
//!
//! Accuracy contract: quantize→dequantize error is bounded per group by
//! `1.5 * delta` (round-to-nearest plus the rounded zero point plus
//! boundary clamp), property-tested in `tests/quant_properties.rs`.
//! Quantized restores are therefore *not* bit-identical to recompute —
//! the engine tests gate Q4/Q8 on task-level agreement, while
//! [`KvCacheMode::F32`] keeps the exact rows and stays bit-identical.

use crate::config::KvCacheMode;
use crate::quant::pack;
use crate::quant::rtn::{int4_grid, NIBBLE_MAX};

/// Quantization group length along each `dim`-row. Smaller groups track
/// outliers tighter at more scale/zero overhead; 64 keeps the overhead
/// at one f32 pair per 64 values while halving the group the weight
/// quantizer defaults to (KV rows see no smoothing, so finer grouping
/// carries the accuracy instead).
pub const KV_QUANT_GROUP: usize = 64;

/// Largest INT8 code (the Q8 grid spans 0..=255).
const BYTE_MAX: f32 = 255.0;

/// The INT8 grid for one group range: `(delta, zero)` — the Q8
/// analogue of [`int4_grid`], same zero-range guard.
#[inline]
fn int8_grid(lo: f32, hi: f32) -> (f32, f32) {
    let mut delta = (hi - lo) / BYTE_MAX;
    if delta == 0.0 {
        delta = hi.abs().max(1e-12) / BYTE_MAX;
    }
    (delta, (-lo / delta).round())
}

/// One KV block's rows in group-wise quantized form: `rows` rows of
/// `dim` values, each row split into `ceil(dim / group)` groups with a
/// private `(scale, zero)` pair. Q4 data is nibble-packed per row
/// (`(dim + 1) / 2` bytes/row, low nibble first); Q8 is one byte per
/// value.
#[derive(Debug, Clone)]
pub struct QuantKvBlock {
    /// Quantized width ([`KvCacheMode::Q4`] or [`KvCacheMode::Q8`]).
    pub mode: KvCacheMode,
    /// Number of `dim`-rows quantized.
    pub rows: usize,
    /// Values per row.
    pub dim: usize,
    /// Group length the scales/zeros were fit over.
    pub group: usize,
    /// Per-group step, `rows * ceil(dim / group)` entries, row-major.
    pub scales: Vec<f32>,
    /// Per-group zero point (already rounded), same layout as `scales`.
    pub zeros: Vec<f32>,
    /// Quantized codes: packed nibbles (Q4) or bytes (Q8), row-major.
    pub data: Vec<u8>,
}

impl QuantKvBlock {
    /// Groups per row.
    fn groups_per_row(&self) -> usize {
        self.dim.div_ceil(self.group)
    }

    /// Stored bytes per row of `data`.
    fn row_bytes(&self) -> usize {
        match self.mode {
            KvCacheMode::Q4 => self.dim.div_ceil(2),
            _ => self.dim,
        }
    }

    /// Exact heap bytes this block holds (codes + scale/zero tables) —
    /// the number the pool-occupancy accounting and the byte-size
    /// property test pin down.
    pub fn bytes(&self) -> usize {
        self.data.len() + 4 * (self.scales.len() + self.zeros.len())
    }

    /// Reconstruct the f32 rows (`rows * dim` values): read the packed
    /// codes in place and apply each group's grid as it goes — the
    /// fused-dequant idiom, no intermediate nibble buffer.
    pub fn dequantize_rows(&self) -> Vec<f32> {
        let gpr = self.groups_per_row();
        let rb = self.row_bytes();
        let mut out = vec![0.0f32; self.rows * self.dim];
        for r in 0..self.rows {
            let row = &self.data[r * rb..(r + 1) * rb];
            for j in 0..self.dim {
                let q = match self.mode {
                    KvCacheMode::Q4 => {
                        let b = row[j / 2];
                        if j % 2 == 0 { b & 0xF } else { b >> 4 }
                    }
                    _ => row[j],
                };
                let g = r * gpr + j / self.group;
                out[r * self.dim + j] =
                    (q as f32 - self.zeros[g]) * self.scales[g];
            }
        }
        out
    }
}

/// Quantize `rows.len() / dim` rows of `dim` f32 values group-wise at
/// the given width. Each group (length `group`, short tail allowed)
/// gets an asymmetric grid over its own min/max — [`int4_grid`] for Q4
/// so the KV grid and the weight grid cannot drift, the byte-range
/// analogue for Q8. Panics on [`KvCacheMode::F32`] (nothing to
/// quantize; store the rows as [`KvStash::F32`] instead).
pub fn quantize_rows(rows: &[f32], dim: usize, group: usize,
                     mode: KvCacheMode) -> QuantKvBlock {
    assert!(mode != KvCacheMode::F32, "F32 rows are stored verbatim");
    assert!(dim > 0 && group > 0);
    assert_eq!(rows.len() % dim, 0, "rows must be whole dim-rows");
    let nrows = rows.len() / dim;
    let gpr = dim.div_ceil(group);
    let qmax = match mode {
        KvCacheMode::Q4 => NIBBLE_MAX,
        _ => BYTE_MAX,
    };
    let mut scales = Vec::with_capacity(nrows * gpr);
    let mut zeros = Vec::with_capacity(nrows * gpr);
    let mut q = vec![0u8; rows.len()];
    for r in 0..nrows {
        let row = &rows[r * dim..(r + 1) * dim];
        for g in 0..gpr {
            let span = &row[g * group..dim.min((g + 1) * group)];
            let lo = span.iter().copied().fold(f32::INFINITY, f32::min);
            let hi = span.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let (delta, zero) = match mode {
                KvCacheMode::Q4 => int4_grid(lo, hi),
                _ => int8_grid(lo, hi),
            };
            for (j, &v) in span.iter().enumerate() {
                q[r * dim + g * group + j] =
                    ((v / delta).round() + zero).clamp(0.0, qmax) as u8;
            }
            scales.push(delta);
            zeros.push(zero);
        }
    }
    let data = match mode {
        KvCacheMode::Q4 if dim % 2 == 0 => {
            // even rows: the whole buffer pairs cleanly, so the packed
            // layout IS the reference pack (two consecutive values per
            // byte, low nibble first)
            pack::pack_nibbles(&q, q.len(), 1).data
        }
        KvCacheMode::Q4 => {
            // odd dim: pack per row so codes never straddle rows; the
            // final byte's high nibble stays zero
            let rb = dim.div_ceil(2);
            let mut out = vec![0u8; nrows * rb];
            for r in 0..nrows {
                for j in 0..dim {
                    let v = q[r * dim + j];
                    let b = &mut out[r * rb + j / 2];
                    *b |= if j % 2 == 0 { v } else { v << 4 };
                }
            }
            out
        }
        _ => q,
    };
    QuantKvBlock {
        mode,
        rows: nrows,
        dim,
        group,
        scales,
        zeros,
        data,
    }
}

/// One cached block's stashed KV rows, in whichever form
/// [`crate::config::EngineConfig::kv_cache_mode`] selected. `F32` keeps
/// the exact rows the engine stashed (bit-identical restores — the
/// golden-stream contract); `Quant` holds the group-wise quantized
/// form, 4–8× smaller.
#[derive(Debug, Clone)]
pub enum KvStash {
    /// Exact f32 rows, layout `[L, 2, block_size, D]`.
    F32(Vec<f32>),
    /// Group-wise quantized rows (Q4 or Q8).
    Quant(QuantKvBlock),
}

impl KvStash {
    /// Encode freshly stashed rows (`[L, 2, block_size, D]`, row width
    /// `dim`) at the configured mode.
    pub fn encode(rows: Vec<f32>, dim: usize, mode: KvCacheMode)
        -> KvStash {
        match mode {
            KvCacheMode::F32 => KvStash::F32(rows),
            m => KvStash::Quant(quantize_rows(&rows, dim,
                                              KV_QUANT_GROUP, m)),
        }
    }

    /// Heap bytes this stash holds (the pool accounting number).
    pub fn bytes(&self) -> usize {
        match self {
            KvStash::F32(rows) => 4 * rows.len(),
            KvStash::Quant(q) => q.bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand_rows(rng: &mut Rng, nrows: usize, dim: usize) -> Vec<f32> {
        (0..nrows * dim).map(|_| rng.normal() as f32 * 0.3).collect()
    }

    #[test]
    fn q4_roundtrip_is_group_bounded() {
        prop::check("kvq q4 roundtrip", 30, |rng| {
            let dim = 1 + rng.below(40);
            let group = 1 + rng.below(dim + 4);
            let nrows = 1 + rng.below(6);
            let rows = rand_rows(rng, nrows, dim);
            let q = quantize_rows(&rows, dim, group, KvCacheMode::Q4);
            let back = q.dequantize_rows();
            for r in 0..nrows {
                for j in 0..dim {
                    let g = r * dim.div_ceil(group) + j / group;
                    let tol = 1.5 * q.scales[g] + 1e-5;
                    let (a, b) =
                        (rows[r * dim + j], back[r * dim + j]);
                    assert!((a - b).abs() <= tol,
                            "row {r} col {j}: {a} vs {b} (tol {tol})");
                }
            }
        });
    }

    #[test]
    fn q8_is_tighter_than_q4() {
        prop::check("kvq q8 tighter", 20, |rng| {
            let dim = 2 * (1 + rng.below(16));
            let rows = rand_rows(rng, 4, dim);
            let e4 = prop::max_abs_diff(
                &rows,
                &quantize_rows(&rows, dim, 8, KvCacheMode::Q4)
                    .dequantize_rows(),
            );
            let e8 = prop::max_abs_diff(
                &rows,
                &quantize_rows(&rows, dim, 8, KvCacheMode::Q8)
                    .dequantize_rows(),
            );
            assert!(e8 <= e4 + 1e-6, "q8 {e8} worse than q4 {e4}");
        });
    }

    #[test]
    fn constant_group_is_exact() {
        // the zero-range guard: a constant group reconstructs exactly
        let rows = vec![0.25f32; 3 * 10];
        for mode in [KvCacheMode::Q4, KvCacheMode::Q8] {
            let back =
                quantize_rows(&rows, 10, 4, mode).dequantize_rows();
            prop::assert_allclose(&rows, &back, 1e-6, 1e-6, "constant");
        }
    }

    #[test]
    fn byte_accounting_is_exact() {
        // 5 rows of 9 values, group 4 -> 3 groups/row
        let rows: Vec<f32> = (0..45).map(|i| i as f32 * 0.1).collect();
        let q4 = quantize_rows(&rows, 9, 4, KvCacheMode::Q4);
        assert_eq!(q4.data.len(), 5 * 5); // ceil(9/2) bytes/row
        assert_eq!(q4.scales.len(), 5 * 3);
        assert_eq!(q4.bytes(), 25 + 4 * (15 + 15));
        let q8 = quantize_rows(&rows, 9, 4, KvCacheMode::Q8);
        assert_eq!(q8.data.len(), 45);
        assert_eq!(q8.bytes(), 45 + 4 * (15 + 15));
        // the stash wrapper agrees, and F32 is 4 bytes/value
        assert_eq!(KvStash::Quant(q8).bytes(), 45 + 120);
        assert_eq!(KvStash::F32(rows).bytes(), 4 * 45);
    }

    #[test]
    fn even_dim_packing_matches_reference_pack() {
        // the even-dim fast path routes through pack::pack_nibbles; the
        // odd-dim path must agree with it on the shared prefix bytes
        let mut rng = Rng::new(7);
        let rows = rand_rows(&mut rng, 3, 8);
        let q = quantize_rows(&rows, 8, 4, KvCacheMode::Q4);
        // unpack with the reference routine and re-apply the grid
        let packed = crate::tensor::U8Tensor::from_vec(
            &[q.data.len(), 1], q.data.clone());
        let codes = pack::unpack_nibbles(&packed);
        let gpr = 2;
        for r in 0..3 {
            for j in 0..8 {
                let g = r * gpr + j / 4;
                let v = (codes[r * 8 + j] as f32 - q.zeros[g])
                    * q.scales[g];
                let d = q.dequantize_rows()[r * 8 + j];
                assert!((v - d).abs() < 1e-6);
            }
        }
    }
}
