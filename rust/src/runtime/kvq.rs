//! Group-wise 4/8-bit quantization of stashed KV rows — the paper's
//! weight trick applied to the serving engine's other memory hog.
//!
//! The engine keeps cached prefix blocks host-side as `[L, 2,
//! block_size, D]` row stashes (see `coordinator::engine`). Stored in
//! f32 those stashes cost as much as the device rows they mirror; the
//! tiered demotion pool would inherit the same footprint. This module
//! quantizes each stash with the same group-wise asymmetric grid the
//! weight quantizer uses — per-group `(delta, zero)` over each
//! `dim`-row, [`crate::quant::rtn::int4_grid`] as the single source of
//! truth for the INT4 grid — shrinking a stash 4× (Q8) to 8× (Q4)
//! versus f32.
//!
//! Layouts match `quant/pack.rs`: Q4 packs two *consecutive* values per
//! byte, low nibble first (even `dim` routes through
//! [`crate::quant::pack::pack_nibbles`] itself; an odd `dim` leaves the
//! final nibble of each row's last byte zero). Dequantization reads the
//! packed bytes in place and applies the grid as it goes — the
//! `quant/kernel.rs` fused-dequant idiom, no intermediate nibble
//! buffer.
//!
//! Accuracy contract: quantize→dequantize error is bounded per group by
//! `1.5 * delta` (round-to-nearest plus the rounded zero point plus
//! boundary clamp), property-tested in `tests/quant_properties.rs`.
//! Quantized restores are therefore *not* bit-identical to recompute —
//! the engine tests gate Q4/Q8 on task-level agreement, while
//! [`KvCacheMode::F32`] keeps the exact rows and stays bit-identical.

use anyhow::{bail, ensure, Result};

use crate::config::KvCacheMode;
use crate::quant::pack;
use crate::quant::rtn::{int4_grid, NIBBLE_MAX};

/// Quantization group length along each `dim`-row. Smaller groups track
/// outliers tighter at more scale/zero overhead; 64 keeps the overhead
/// at one f32 pair per 64 values while halving the group the weight
/// quantizer defaults to (KV rows see no smoothing, so finer grouping
/// carries the accuracy instead).
pub const KV_QUANT_GROUP: usize = 64;

/// Largest INT8 code (the Q8 grid spans 0..=255).
const BYTE_MAX: f32 = 255.0;

/// The INT8 grid for one group range: `(delta, zero)` — the Q8
/// analogue of [`int4_grid`], same zero-range guard.
#[inline]
fn int8_grid(lo: f32, hi: f32) -> (f32, f32) {
    let mut delta = (hi - lo) / BYTE_MAX;
    if delta == 0.0 {
        delta = hi.abs().max(1e-12) / BYTE_MAX;
    }
    (delta, (-lo / delta).round())
}

/// One KV block's rows in group-wise quantized form: `rows` rows of
/// `dim` values, each row split into `ceil(dim / group)` groups with a
/// private `(scale, zero)` pair. Q4 data is nibble-packed per row
/// (`(dim + 1) / 2` bytes/row, low nibble first); Q8 is one byte per
/// value.
#[derive(Debug, Clone)]
pub struct QuantKvBlock {
    /// Quantized width ([`KvCacheMode::Q4`] or [`KvCacheMode::Q8`]).
    pub mode: KvCacheMode,
    /// Number of `dim`-rows quantized.
    pub rows: usize,
    /// Values per row.
    pub dim: usize,
    /// Group length the scales/zeros were fit over.
    pub group: usize,
    /// Per-group step, `rows * ceil(dim / group)` entries, row-major.
    pub scales: Vec<f32>,
    /// Per-group zero point (already rounded), same layout as `scales`.
    pub zeros: Vec<f32>,
    /// Quantized codes: packed nibbles (Q4) or bytes (Q8), row-major.
    pub data: Vec<u8>,
}

impl QuantKvBlock {
    /// Groups per row.
    fn groups_per_row(&self) -> usize {
        self.dim.div_ceil(self.group)
    }

    /// Stored bytes per row of `data`.
    fn row_bytes(&self) -> usize {
        match self.mode {
            KvCacheMode::Q4 => self.dim.div_ceil(2),
            _ => self.dim,
        }
    }

    /// Exact heap bytes this block holds (codes + scale/zero tables) —
    /// the number the pool-occupancy accounting and the byte-size
    /// property test pin down.
    pub fn bytes(&self) -> usize {
        self.data.len() + 4 * (self.scales.len() + self.zeros.len())
    }

    /// Reconstruct the f32 rows (`rows * dim` values): read the packed
    /// codes in place and apply each group's grid as it goes — the
    /// fused-dequant idiom, no intermediate nibble buffer.
    pub fn dequantize_rows(&self) -> Vec<f32> {
        let gpr = self.groups_per_row();
        let rb = self.row_bytes();
        let mut out = vec![0.0f32; self.rows * self.dim];
        for r in 0..self.rows {
            let row = &self.data[r * rb..(r + 1) * rb];
            for j in 0..self.dim {
                let q = match self.mode {
                    KvCacheMode::Q4 => {
                        let b = row[j / 2];
                        if j % 2 == 0 { b & 0xF } else { b >> 4 }
                    }
                    _ => row[j],
                };
                let g = r * gpr + j / self.group;
                out[r * self.dim + j] =
                    (q as f32 - self.zeros[g]) * self.scales[g];
            }
        }
        out
    }
}

/// Quantize `rows.len() / dim` rows of `dim` f32 values group-wise at
/// the given width. Each group (length `group`, short tail allowed)
/// gets an asymmetric grid over its own min/max — [`int4_grid`] for Q4
/// so the KV grid and the weight grid cannot drift, the byte-range
/// analogue for Q8. Panics on [`KvCacheMode::F32`] (nothing to
/// quantize; store the rows as [`KvStash::F32`] instead).
pub fn quantize_rows(rows: &[f32], dim: usize, group: usize,
                     mode: KvCacheMode) -> QuantKvBlock {
    assert!(mode != KvCacheMode::F32, "F32 rows are stored verbatim");
    assert!(dim > 0 && group > 0);
    assert_eq!(rows.len() % dim, 0, "rows must be whole dim-rows");
    let nrows = rows.len() / dim;
    let gpr = dim.div_ceil(group);
    let qmax = match mode {
        KvCacheMode::Q4 => NIBBLE_MAX,
        _ => BYTE_MAX,
    };
    let mut scales = Vec::with_capacity(nrows * gpr);
    let mut zeros = Vec::with_capacity(nrows * gpr);
    let mut q = vec![0u8; rows.len()];
    for r in 0..nrows {
        let row = &rows[r * dim..(r + 1) * dim];
        for g in 0..gpr {
            let span = &row[g * group..dim.min((g + 1) * group)];
            let lo = span.iter().copied().fold(f32::INFINITY, f32::min);
            let hi = span.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let (delta, zero) = match mode {
                KvCacheMode::Q4 => int4_grid(lo, hi),
                _ => int8_grid(lo, hi),
            };
            for (j, &v) in span.iter().enumerate() {
                q[r * dim + g * group + j] =
                    ((v / delta).round() + zero).clamp(0.0, qmax) as u8;
            }
            scales.push(delta);
            zeros.push(zero);
        }
    }
    let data = match mode {
        KvCacheMode::Q4 if dim % 2 == 0 => {
            // even rows: the whole buffer pairs cleanly, so the packed
            // layout IS the reference pack (two consecutive values per
            // byte, low nibble first)
            pack::pack_nibbles(&q, q.len(), 1).data
        }
        KvCacheMode::Q4 => {
            // odd dim: pack per row so codes never straddle rows; the
            // final byte's high nibble stays zero
            let rb = dim.div_ceil(2);
            let mut out = vec![0u8; nrows * rb];
            for r in 0..nrows {
                for j in 0..dim {
                    let v = q[r * dim + j];
                    let b = &mut out[r * rb + j / 2];
                    *b |= if j % 2 == 0 { v } else { v << 4 };
                }
            }
            out
        }
        _ => q,
    };
    QuantKvBlock {
        mode,
        rows: nrows,
        dim,
        group,
        scales,
        zeros,
        data,
    }
}

/// One cached block's stashed KV rows, in whichever form
/// [`crate::config::EngineConfig::kv_cache_mode`] selected. `F32` keeps
/// the exact rows the engine stashed (bit-identical restores — the
/// golden-stream contract); `Quant` holds the group-wise quantized
/// form, 4–8× smaller.
#[derive(Debug, Clone)]
pub enum KvStash {
    /// Exact f32 rows, layout `[L, 2, block_size, D]`.
    F32(Vec<f32>),
    /// Group-wise quantized rows (Q4 or Q8).
    Quant(QuantKvBlock),
}

impl KvStash {
    /// Encode freshly stashed rows (`[L, 2, block_size, D]`, row width
    /// `dim`) at the configured mode.
    pub fn encode(rows: Vec<f32>, dim: usize, mode: KvCacheMode)
        -> KvStash {
        match mode {
            KvCacheMode::F32 => KvStash::F32(rows),
            m => KvStash::Quant(quantize_rows(&rows, dim,
                                              KV_QUANT_GROUP, m)),
        }
    }

    /// Heap bytes this stash holds (the pool accounting number).
    pub fn bytes(&self) -> usize {
        match self {
            KvStash::F32(rows) => 4 * rows.len(),
            KvStash::Quant(q) => q.bytes(),
        }
    }

    /// Exact serialized size of [`KvStash::to_wire`]'s output: the
    /// payload is always [`KvStash::bytes`] — migration ships the
    /// already-quantized codes verbatim, never a dequantized copy —
    /// plus a fixed per-form header (mode tag + length prefixes).
    pub fn wire_bytes(&self) -> usize {
        match self {
            KvStash::F32(_) => WIRE_F32_HEADER + self.bytes(),
            KvStash::Quant(_) => WIRE_QUANT_HEADER + self.bytes(),
        }
    }

    /// Serialize for cross-replica shipment: one mode-tag byte, then
    /// length-prefixed little-endian sections. The quantized forms ship
    /// their packed codes and grid tables as stored, so a migrated
    /// block costs exactly its pool footprint on the wire (see
    /// [`KvStash::wire_bytes`]).
    pub fn to_wire(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes());
        match self {
            KvStash::F32(rows) => {
                out.push(WIRE_TAG_F32);
                out.extend((rows.len() as u32).to_le_bytes());
                for v in rows {
                    out.extend(v.to_le_bytes());
                }
            }
            KvStash::Quant(q) => {
                out.push(match q.mode {
                    KvCacheMode::Q8 => WIRE_TAG_Q8,
                    _ => WIRE_TAG_Q4,
                });
                out.extend((q.rows as u32).to_le_bytes());
                out.extend((q.dim as u32).to_le_bytes());
                out.extend((q.group as u32).to_le_bytes());
                out.extend((q.scales.len() as u32).to_le_bytes());
                for v in q.scales.iter().chain(&q.zeros) {
                    out.extend(v.to_le_bytes());
                }
                out.extend((q.data.len() as u32).to_le_bytes());
                out.extend_from_slice(&q.data);
            }
        }
        out
    }

    /// Decode a [`KvStash::to_wire`] payload. Strict: an unknown tag, a
    /// truncated section, trailing bytes, or grid-table/code lengths
    /// that disagree with the declared shape are all errors — a
    /// malformed migration grant must fall back to recompute, never
    /// import garbage rows.
    pub fn from_wire(bytes: &[u8]) -> Result<KvStash> {
        let mut cur = WireCursor { bytes, at: 0 };
        let tag = cur.u8()?;
        let stash = match tag {
            WIRE_TAG_F32 => {
                let n = cur.u32()?;
                // validate the prefix against the payload before
                // trusting it for an allocation
                ensure!(cur.at + 4 * n <= bytes.len(),
                        "kv wire: f32 count {n} exceeds payload");
                let mut rows = Vec::with_capacity(n);
                for _ in 0..n {
                    rows.push(cur.f32()?);
                }
                KvStash::F32(rows)
            }
            WIRE_TAG_Q8 | WIRE_TAG_Q4 => {
                let mode = if tag == WIRE_TAG_Q8 {
                    KvCacheMode::Q8
                } else {
                    KvCacheMode::Q4
                };
                let rows = cur.u32()?;
                let dim = cur.u32()?;
                let group = cur.u32()?;
                ensure!(dim > 0 && group > 0,
                        "kv wire: zero dim or group");
                let ngroups = cur.u32()?;
                ensure!(ngroups == rows * dim.div_ceil(group),
                        "kv wire: grid table length {ngroups} does not \
                         match {rows} rows of {dim}/{group}");
                ensure!(cur.at + 8 * ngroups <= bytes.len(),
                        "kv wire: grid tables exceed payload");
                let mut scales = Vec::with_capacity(ngroups);
                for _ in 0..ngroups {
                    scales.push(cur.f32()?);
                }
                let mut zeros = Vec::with_capacity(ngroups);
                for _ in 0..ngroups {
                    zeros.push(cur.f32()?);
                }
                let ndata = cur.u32()?;
                let row_bytes = match mode {
                    KvCacheMode::Q4 => dim.div_ceil(2),
                    _ => dim,
                };
                ensure!(ndata == rows * row_bytes,
                        "kv wire: {ndata} code bytes for {rows} rows \
                         of {row_bytes}");
                let data = cur.take(ndata)?.to_vec();
                KvStash::Quant(QuantKvBlock {
                    mode,
                    rows,
                    dim,
                    group,
                    scales,
                    zeros,
                    data,
                })
            }
            other => bail!("kv wire: unknown mode tag {other}"),
        };
        ensure!(cur.at == bytes.len(),
                "kv wire: {} trailing bytes", bytes.len() - cur.at);
        Ok(stash)
    }
}

/// Wire mode tag: exact f32 rows follow.
const WIRE_TAG_F32: u8 = 0;
/// Wire mode tag: group-wise INT8 block follows.
const WIRE_TAG_Q8: u8 = 1;
/// Wire mode tag: group-wise nibble-packed INT4 block follows.
const WIRE_TAG_Q4: u8 = 2;
/// F32 wire header: tag + row-count prefix.
const WIRE_F32_HEADER: usize = 1 + 4;
/// Quant wire header: tag + rows/dim/group/ngroups/ndata prefixes.
const WIRE_QUANT_HEADER: usize = 1 + 5 * 4;

/// Bounds-checked little-endian reader over a wire payload.
struct WireCursor<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl WireCursor<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        ensure!(self.at + n <= self.bytes.len(),
                "kv wire: truncated at byte {} (wanted {n} more)",
                self.at);
        let s = &self.bytes[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<usize> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]) as usize)
    }

    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand_rows(rng: &mut Rng, nrows: usize, dim: usize) -> Vec<f32> {
        (0..nrows * dim).map(|_| rng.normal() as f32 * 0.3).collect()
    }

    #[test]
    fn q4_roundtrip_is_group_bounded() {
        prop::check("kvq q4 roundtrip", 30, |rng| {
            let dim = 1 + rng.below(40);
            let group = 1 + rng.below(dim + 4);
            let nrows = 1 + rng.below(6);
            let rows = rand_rows(rng, nrows, dim);
            let q = quantize_rows(&rows, dim, group, KvCacheMode::Q4);
            let back = q.dequantize_rows();
            for r in 0..nrows {
                for j in 0..dim {
                    let g = r * dim.div_ceil(group) + j / group;
                    let tol = 1.5 * q.scales[g] + 1e-5;
                    let (a, b) =
                        (rows[r * dim + j], back[r * dim + j]);
                    assert!((a - b).abs() <= tol,
                            "row {r} col {j}: {a} vs {b} (tol {tol})");
                }
            }
        });
    }

    #[test]
    fn q8_is_tighter_than_q4() {
        prop::check("kvq q8 tighter", 20, |rng| {
            let dim = 2 * (1 + rng.below(16));
            let rows = rand_rows(rng, 4, dim);
            let e4 = prop::max_abs_diff(
                &rows,
                &quantize_rows(&rows, dim, 8, KvCacheMode::Q4)
                    .dequantize_rows(),
            );
            let e8 = prop::max_abs_diff(
                &rows,
                &quantize_rows(&rows, dim, 8, KvCacheMode::Q8)
                    .dequantize_rows(),
            );
            assert!(e8 <= e4 + 1e-6, "q8 {e8} worse than q4 {e4}");
        });
    }

    #[test]
    fn constant_group_is_exact() {
        // the zero-range guard: a constant group reconstructs exactly
        let rows = vec![0.25f32; 3 * 10];
        for mode in [KvCacheMode::Q4, KvCacheMode::Q8] {
            let back =
                quantize_rows(&rows, 10, 4, mode).dequantize_rows();
            prop::assert_allclose(&rows, &back, 1e-6, 1e-6, "constant");
        }
    }

    #[test]
    fn byte_accounting_is_exact() {
        // 5 rows of 9 values, group 4 -> 3 groups/row
        let rows: Vec<f32> = (0..45).map(|i| i as f32 * 0.1).collect();
        let q4 = quantize_rows(&rows, 9, 4, KvCacheMode::Q4);
        assert_eq!(q4.data.len(), 5 * 5); // ceil(9/2) bytes/row
        assert_eq!(q4.scales.len(), 5 * 3);
        assert_eq!(q4.bytes(), 25 + 4 * (15 + 15));
        let q8 = quantize_rows(&rows, 9, 4, KvCacheMode::Q8);
        assert_eq!(q8.data.len(), 45);
        assert_eq!(q8.bytes(), 45 + 4 * (15 + 15));
        // the stash wrapper agrees, and F32 is 4 bytes/value
        assert_eq!(KvStash::Quant(q8).bytes(), 45 + 120);
        assert_eq!(KvStash::F32(rows).bytes(), 4 * 45);
    }

    #[test]
    fn wire_roundtrip_is_lossless_and_size_exact() {
        // every mode: decode(encode(stash)) reproduces the stash
        // field-for-field, and the payload length is bytes() plus the
        // fixed header — migration ships the stored form verbatim
        let mut rng = Rng::new(11);
        let rows = rand_rows(&mut rng, 4, 9);
        for mode in [KvCacheMode::F32, KvCacheMode::Q8, KvCacheMode::Q4] {
            let s = KvStash::encode(rows.clone(), 9, mode);
            let w = s.to_wire();
            assert_eq!(w.len(), s.wire_bytes(), "{mode:?} size");
            let hdr = match mode {
                KvCacheMode::F32 => 5,
                _ => 21,
            };
            assert_eq!(w.len(), s.bytes() + hdr, "{mode:?} parity");
            let back = KvStash::from_wire(&w).unwrap();
            match (&s, &back) {
                (KvStash::F32(a), KvStash::F32(b)) => assert_eq!(a, b),
                (KvStash::Quant(a), KvStash::Quant(b)) => {
                    assert_eq!(a.mode, b.mode);
                    assert_eq!(a.rows, b.rows);
                    assert_eq!(a.dim, b.dim);
                    assert_eq!(a.group, b.group);
                    assert_eq!(a.scales, b.scales);
                    assert_eq!(a.zeros, b.zeros);
                    assert_eq!(a.data, b.data);
                }
                _ => panic!("{mode:?} changed form over the wire"),
            }
        }
    }

    #[test]
    fn wire_decode_rejects_malformed_payloads() {
        let s = KvStash::encode(vec![0.5; 2 * 8], 8, KvCacheMode::Q4);
        let good = s.to_wire();
        assert!(KvStash::from_wire(&[]).is_err(), "empty");
        assert!(KvStash::from_wire(&[9]).is_err(), "unknown tag");
        assert!(KvStash::from_wire(&good[..good.len() - 1]).is_err(),
                "truncated");
        let mut trailing = good.clone();
        trailing.push(0);
        assert!(KvStash::from_wire(&trailing).is_err(), "trailing");
        // a lying code-length prefix must not import: 2 rows of dim 8
        // pack to 8 Q4 code bytes, and the u32 prefix sits just before
        // them at the end of the payload
        let mut short = good.clone();
        let ndata_at = good.len() - 8 - 4;
        short[ndata_at..ndata_at + 4]
            .copy_from_slice(&1u32.to_le_bytes());
        assert!(KvStash::from_wire(&short).is_err(), "bad code length");
    }

    #[test]
    fn even_dim_packing_matches_reference_pack() {
        // the even-dim fast path routes through pack::pack_nibbles; the
        // odd-dim path must agree with it on the shared prefix bytes
        let mut rng = Rng::new(7);
        let rows = rand_rows(&mut rng, 3, 8);
        let q = quantize_rows(&rows, 8, 4, KvCacheMode::Q4);
        // unpack with the reference routine and re-apply the grid
        let packed = crate::tensor::U8Tensor::from_vec(
            &[q.data.len(), 1], q.data.clone());
        let codes = pack::unpack_nibbles(&packed);
        let gpr = 2;
        for r in 0..3 {
            for j in 0..8 {
                let g = r * gpr + j / 4;
                let v = (codes[r * 8 + j] as f32 - q.zeros[g])
                    * q.scales[g];
                let d = q.dequantize_rows()[r * 8 + j];
                assert!((v - d).abs() < 1e-6);
            }
        }
    }
}
