//! Analytic A100 roofline model for the paper-scale Fig. 7 curves.
//!
//! The paper's efficiency claims are driven by two terms our CPU testbed
//! cannot exhibit at scale: (i) decode is HBM-bandwidth-bound, so weight
//! bytes dominate the per-step time; (ii) KV memory headroom bounds the
//! achievable batch. This model computes both for the paper's three
//! deployments (FP16 on 2 GPUs with tensor-parallel all-reduce, AWQ/W4A16
//! on 1 GPU) using the Code Llama-34B shapes, reproducing who-wins/by-
//! roughly-what-factor. Constants below; measured CPU counterparts come
//! from the engine benches.

use crate::config::GpuProfile;

/// Paper-scale model description (Code Llama-34B-like).
#[derive(Debug, Clone)]
pub struct PaperModel {
    /// Parameter count.
    pub params: f64,
    /// Decoder layers.
    pub layers: usize,
    /// Hidden dimension.
    pub dim: usize,
    /// KV bytes per token (fp16, both lanes, all layers; GQA folded in).
    pub kv_bytes_per_token: f64,
}

impl PaperModel {
    /// The paper's largest evaluated model (Code Llama-34B shapes).
    pub fn code_llama_34b() -> Self {
        // 34B params, 48 layers, d_model 8192, GQA 8 kv-heads / 64 heads.
        let layers = 48usize;
        let dim = 8192usize;
        let kv_dim = dim / 8; // grouped-query KV heads
        PaperModel {
            params: 34e9,
            layers,
            dim,
            kv_bytes_per_token: (2 * layers * 2 * kv_dim) as f64,
        }
    }
}

/// Deployment under the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Deploy {
    /// FP16 weights sharded over two GPUs (tensor parallel).
    Fp16TwoGpu,
    /// SmoothQuant+ W4A16 on one GPU.
    W4a16OneGpu,
    /// AWQ kernel on one GPU: same memory as W4A16, slower kernel
    /// (dequant inefficiency factor measured by the paper's Fig. 7, where
    /// AWQ under-performs even 2xFP16 per token).
    AwqOneGpu,
}

/// Roofline estimate for one deployment at one context length.
#[derive(Debug, Clone)]
pub struct StepEstimate {
    /// Seconds per decode step at the given batch.
    pub step_s: f64,
    /// Max batch size under the KV memory budget at this context length.
    pub max_batch: usize,
    /// Decode throughput tokens/s at max batch.
    pub tokens_per_s: f64,
}

/// Per-GPU weight bytes for a deployment.
pub fn weight_bytes(m: &PaperModel, d: Deploy) -> f64 {
    match d {
        Deploy::Fp16TwoGpu => m.params * 2.0 / 2.0, // fp16 split over 2
        // int4 + ~3% group overhead (g=128: scale+zero f16 per group)
        Deploy::W4a16OneGpu | Deploy::AwqOneGpu => m.params * 0.5 * 1.06,
    }
}

/// Kernel inefficiency multiplier on the weight-streaming term.
fn kernel_factor(d: Deploy) -> f64 {
    match d {
        Deploy::Fp16TwoGpu => 1.0,
        // LMDeploy-derived kernel: near-roofline dequant fused matmul
        Deploy::W4a16OneGpu => 1.15,
        // AWQ's GEMM (paper Fig. 7: slower than FP16 per token; AutoAWQ
        // dequant-in-loop kernels run ~3x+ off the fp16 roofline at
        // serving batch sizes)
        Deploy::AwqOneGpu => 3.4,
    }
}

/// All-reduce time for one decode step of tensor parallelism (2 reduces
/// per layer of B*dim*2 bytes, ring over n workers).
pub fn allreduce_s(gpu: &GpuProfile, m: &PaperModel, batch: usize,
                   workers: usize) -> f64 {
    if workers <= 1 {
        return 0.0;
    }
    let bytes = (batch * m.dim * 2) as f64;
    let reduces = 2 * m.layers;
    let per = 2.0 * (workers as f64 - 1.0) / workers as f64 * bytes
        / (gpu.link_gbps * 1e9)
        + 2.0 * gpu.link_latency_us * 1e-6;
    reduces as f64 * per
}

/// Decode-step estimate at context length `ctx` for deployment `d`.
pub fn estimate(gpu: &GpuProfile, m: &PaperModel, d: Deploy, ctx: usize)
    -> StepEstimate {
    let workers = if d == Deploy::Fp16TwoGpu { 2 } else { 1 };
    let wb = weight_bytes(m, d);
    let hbm = gpu.hbm_gbps * 1e9;
    let mem = gpu.mem_bytes as f64 * 0.92; // runtime reserve

    // KV headroom bounds the batch: (mem - weights) across all workers.
    let free = ((mem - wb) * workers as f64).max(0.0);
    let kv_per_seq = m.kv_bytes_per_token * ctx as f64;
    let max_batch = (free / kv_per_seq).floor().max(0.0) as usize;
    if max_batch == 0 {
        return StepEstimate { step_s: f64::INFINITY, max_batch: 0,
                              tokens_per_s: 0.0 };
    }
    let batch = max_batch;

    // Per-step time: stream weights once (batched), stream live KV, plus
    // tensor-parallel all-reduce; decode GEMMs are bandwidth-bound at
    // these batch sizes. The AWQ kernel's dequant sits inside the GEMM
    // inner loop and scales with the whole step (the paper's Fig. 7 shows
    // AWQ losing to FP16x2 at every batch); the LMDeploy-style fused
    // W4A16 kernel only pays a small factor on the weight stream.
    let kv_stream = (batch as f64 * m.kv_bytes_per_token * ctx as f64 / 2.0)
        / (hbm * workers as f64);
    let w_stream = wb / hbm;
    let comm = allreduce_s(gpu, m, batch, workers);
    let step_s = match d {
        Deploy::AwqOneGpu => (w_stream + kv_stream) * kernel_factor(d),
        _ => w_stream * kernel_factor(d) + kv_stream,
    } + comm;
    StepEstimate {
        step_s,
        max_batch,
        tokens_per_s: batch as f64 / step_s,
    }
}

/// Seconds to ship a warm KV prefix of `tokens` tokens donor→receiver
/// at `wire_bytes_per_token` (mode-dependent: a q4 stash ships ~4x
/// fewer bytes than the fp16 KV footprint, ~8x fewer than an f32
/// stash). The blocks travel in one export grant, so the handshake's
/// link latency is paid twice (request + grant), not per block; both
/// device hops (the donor's d2h at export, the receiver's h2d at
/// restore) charge the HBM term.
pub fn migrate_prefix_s(gpu: &GpuProfile, tokens: usize,
                        wire_bytes_per_token: f64) -> f64 {
    let b = tokens as f64 * wire_bytes_per_token;
    let hops = 2.0 * b / (gpu.hbm_gbps * 1e9);
    let wire = b / (gpu.link_gbps * 1e9)
        + 2.0 * gpu.link_latency_us * 1e-6;
    hops + wire
}

/// Bandwidth floor for recomputing the same prefix on the cold
/// replica instead: chunked prefill streams the deployment's weights
/// through HBM at least once regardless of prefix length — the term a
/// migration avoids entirely.
pub fn recompute_prefix_s(gpu: &GpuProfile, m: &PaperModel, d: Deploy)
    -> f64 {
    weight_bytes(m, d) * kernel_factor(d) / (gpu.hbm_gbps * 1e9)
}

/// Per-token latency at a fixed (small) batch, the paper's Fig. 7(b)
/// online-traffic regime.
pub fn latency_per_token_s(gpu: &GpuProfile, m: &PaperModel, d: Deploy,
                           ctx: usize, batch: usize) -> f64 {
    let workers = if d == Deploy::Fp16TwoGpu { 2 } else { 1 };
    let hbm = gpu.hbm_gbps * 1e9;
    let w_stream = weight_bytes(m, d) / hbm;
    let kv_stream = (batch as f64 * m.kv_bytes_per_token * ctx as f64 / 2.0)
        / (hbm * workers as f64);
    let core = match d {
        Deploy::AwqOneGpu => (w_stream + kv_stream) * kernel_factor(d),
        _ => w_stream * kernel_factor(d) + kv_stream,
    };
    core + allreduce_s(gpu, m, batch, workers)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (GpuProfile, PaperModel) {
        (GpuProfile::a100_40g(), PaperModel::code_llama_34b())
    }

    #[test]
    fn w4a16_throughput_beats_fp16_2gpu_by_paper_factor() {
        let (gpu, m) = setup();
        for ctx in [512usize, 1024, 2048, 4096] {
            let fp = estimate(&gpu, &m, Deploy::Fp16TwoGpu, ctx);
            let q4 = estimate(&gpu, &m, Deploy::W4a16OneGpu, ctx);
            let ratio = q4.tokens_per_s / fp.tokens_per_s;
            assert!(
                (1.5..=6.0).contains(&ratio),
                "ctx {ctx}: ratio {ratio} outside paper band"
            );
        }
    }

    #[test]
    fn awq_one_gpu_loses_to_fp16_two_gpu_throughput() {
        // paper Fig 7a: AWQ x1 sits below FP16 x2 at every context
        let (gpu, m) = setup();
        for ctx in [512usize, 1024, 2048, 4096] {
            let fp = estimate(&gpu, &m, Deploy::Fp16TwoGpu, ctx);
            let awq = estimate(&gpu, &m, Deploy::AwqOneGpu, ctx);
            assert!(awq.tokens_per_s < fp.tokens_per_s,
                    "ctx {ctx}: awq {} !< fp16x2 {}",
                    awq.tokens_per_s, fp.tokens_per_s);
        }
    }

    #[test]
    fn awq_worse_than_fp16_2gpu_latency() {
        // the paper's observation: AWQ on 1 GPU loses to FP16 on 2 GPUs
        let (gpu, m) = setup();
        let awq = latency_per_token_s(&gpu, &m, Deploy::AwqOneGpu, 1024, 8);
        let fp = latency_per_token_s(&gpu, &m, Deploy::Fp16TwoGpu, 1024, 8);
        assert!(awq > fp, "awq {awq} !> fp16x2 {fp}");
    }

    #[test]
    fn sqplus_latency_about_two_thirds_of_fp16() {
        // paper: per-token latency ~68% of FP16-2GPU
        let (gpu, m) = setup();
        let q4 = latency_per_token_s(&gpu, &m, Deploy::W4a16OneGpu, 1024, 8);
        let fp = latency_per_token_s(&gpu, &m, Deploy::Fp16TwoGpu, 1024, 8);
        let ratio = q4 / fp;
        assert!(
            (0.45..=0.95).contains(&ratio),
            "latency ratio {ratio} outside band"
        );
    }

    #[test]
    fn kv_headroom_shrinks_with_context() {
        let (gpu, m) = setup();
        let a = estimate(&gpu, &m, Deploy::W4a16OneGpu, 512).max_batch;
        let b = estimate(&gpu, &m, Deploy::W4a16OneGpu, 4096).max_batch;
        assert!(a > b && b > 0);
    }

    #[test]
    fn migrating_quantized_kv_beats_the_recompute_floor() {
        let (gpu, m) = setup();
        let recompute =
            recompute_prefix_s(&gpu, &m, Deploy::W4a16OneGpu);
        let fp16 = m.kv_bytes_per_token;
        // wire bytes/token by stash mode (group scales folded in ~6%)
        let f32_s = migrate_prefix_s(&gpu, 1024, fp16 * 2.0);
        let q8_s = migrate_prefix_s(&gpu, 1024, fp16 * 1.06);
        let q4_s = migrate_prefix_s(&gpu, 1024, fp16 * 0.5 * 1.06);
        assert!(q4_s < q8_s && q8_s < f32_s);
        assert!(f32_s < recompute,
                "f32 migration {f32_s} !< recompute {recompute}");
        // the quantized stash keeps a wide margin even on PCIe
        assert!(q4_s * 4.0 < recompute);
    }

    #[test]
    fn migration_latency_floor_is_the_link_round_trip() {
        // an empty grant still pays the request+grant handshake
        let (gpu, _) = setup();
        let empty = migrate_prefix_s(&gpu, 0, 1e9);
        let rt = 2.0 * gpu.link_latency_us * 1e-6;
        assert!((empty - rt).abs() < 1e-12, "{empty} != {rt}");
    }

    #[test]
    fn fp16_one_gpu_cannot_hold_34b() {
        let (gpu, m) = setup();
        // 68 GB of fp16 weights cannot fit one 40 GB card
        assert!(m.params * 2.0 > gpu.mem_bytes as f64);
    }
}
