//! Build-time stand-in for the `xla` crate (PJRT bindings).
//!
//! The container/CI image has no XLA extension, so the real `xla` crate
//! is an optional dependency behind the `xla` cargo feature. Without it,
//! [`super::executor`] compiles against this stub, which mirrors the
//! exact API surface the executor touches and fails at *runtime* (every
//! constructor returns [`Unavailable`]) rather than at compile time.
//! Everything that needs PJRT already self-skips when `make artifacts`
//! hasn't run, so the pure-Rust engine/quant/scheduler stack — and all
//! of its tests — build and run with default features.

use std::fmt;
use std::path::Path;

/// Error returned by every stub entry point.
#[derive(Debug, Clone, Copy)]
pub struct Unavailable;

impl fmt::Display for Unavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "PJRT unavailable: built without the `xla` cargo feature \
             (rebuild with `--features xla` and the XLA extension \
             installed)"
        )
    }
}

impl std::error::Error for Unavailable {}

/// Stub PJRT client; every constructor fails with [`Unavailable`].
#[derive(Debug)]
pub struct PjRtClient;

/// Stub device buffer (never constructed).
#[derive(Debug)]
pub struct PjRtBuffer;

/// Stub compiled executable (never constructed).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

/// Stub host literal (never constructed).
#[derive(Debug)]
pub struct Literal;

/// Stub HLO module proto (never constructed).
#[derive(Debug)]
pub struct HloModuleProto;

/// Stub XLA computation (constructible, but uncompilable).
#[derive(Debug)]
pub struct XlaComputation;

impl PjRtClient {
    /// Mirror of `PjRtClient::cpu`; always [`Unavailable`].
    pub fn cpu() -> Result<PjRtClient, Unavailable> {
        Err(Unavailable)
    }

    /// Mirror of the host->device upload; always [`Unavailable`].
    pub fn buffer_from_host_buffer<T>(
        &self, _data: &[T], _shape: &[usize], _device: Option<()>,
    ) -> Result<PjRtBuffer, Unavailable> {
        Err(Unavailable)
    }

    /// Mirror of executable compilation; always [`Unavailable`].
    pub fn compile(&self, _comp: &XlaComputation)
        -> Result<PjRtLoadedExecutable, Unavailable> {
        Err(Unavailable)
    }
}

impl PjRtBuffer {
    /// Mirror of the device->host readback; always [`Unavailable`].
    pub fn to_literal_sync(&self) -> Result<Literal, Unavailable> {
        Err(Unavailable)
    }
}

impl PjRtLoadedExecutable {
    /// Mirror of buffer-arg execution; always [`Unavailable`].
    pub fn execute_b(&self, _args: &[&PjRtBuffer])
        -> Result<Vec<Vec<PjRtBuffer>>, Unavailable> {
        Err(Unavailable)
    }
}

impl Literal {
    /// Mirror of two-element tuple destructuring; always [`Unavailable`].
    pub fn to_tuple2(self) -> Result<(Literal, Literal), Unavailable> {
        Err(Unavailable)
    }

    /// Mirror of typed literal extraction; always [`Unavailable`].
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Unavailable> {
        Err(Unavailable)
    }
}

impl HloModuleProto {
    /// Mirror of HLO-text parsing; always [`Unavailable`].
    pub fn from_text_file(_path: impl AsRef<Path>)
        -> Result<HloModuleProto, Unavailable> {
        Err(Unavailable)
    }
}

impl XlaComputation {
    /// Mirror of proto wrapping (infallible in the real crate too).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
